//! `flexround` CLI — the Layer-3 entry point.
//!
//! See `cli::USAGE` and the README quickstart.  Typical flows:
//!
//! ```text
//! flexround selftest --backend native                  # no artifacts needed
//! flexround quantize --model tinymobilenet --method flexround --bits 4 --eval
//! flexround quantize --model mlp_units --backend native --parallel-units
//! flexround pipeline --synthetic --iters 100 --recon-input quant --pack-out blk.fxt
//! flexround pack     --model mlp_units --method flexround --bits 4 --out m.fxt
//! flexround infer    --packed m.fxt --rows 32          # no FP weights needed
//! flexround serve    --synthetic --requests 512 --compare
//! flexround generate --packed blk.fxt --max-new 32 --temp 0.8 --top-k 40
//! flexround generate --synthetic --compare            # cached vs recompute
//! flexround sweep    --config configs/t2_weight_only.toml
//! flexround figure   --model tinymobilenet --unit b1 --method flexround --bits 4
//! flexround inspect  --model llm_mini
//! ```
//!
//! `--backend auto` (the default) uses PJRT when the build carries it and
//! the artifact directory is usable, otherwise the native engine; the
//! selected engine (and why) is reported on stderr so logs stay
//! attributable.

use anyhow::{anyhow, bail};
use flexround::cli::{Args, USAGE};
use flexround::config::Config;
use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::recon;
use flexround::report::Reporter;
use flexround::runtime::{Backend, Native};
use flexround::{eval, quant, Result};
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.command.is_empty() || args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let art_dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    let rep_dir = PathBuf::from(args.flag("report").unwrap_or("reports"));
    let quiet = args.has("quiet");

    match args.command.as_str() {
        "inspect" => cmd_inspect(&args, &art_dir),
        "selftest" => cmd_selftest(&args, &art_dir),
        "quantize" | "eval" => cmd_quantize(&args, &art_dir, &rep_dir, quiet),
        "pipeline" => cmd_pipeline(&args, &art_dir, &rep_dir, quiet),
        "pack" => cmd_pack(&args, &art_dir, quiet),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "figure" => cmd_figure(&args, &art_dir, &rep_dir, quiet),
        "sweep" => cmd_sweep(&args, &art_dir, &rep_dir, quiet),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(art: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(flexround::runtime::Pjrt::new(art)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_art: &Path) -> Result<Box<dyn Backend>> {
    bail!(
        "this binary was built without the `pjrt` feature; \
         use --backend native or rebuild with --features pjrt"
    )
}

/// `--backend native|pjrt|auto` → engine.  `auto` prefers PJRT when it is
/// compiled in, the artifact dir is usable (a manifest exists), and a
/// client can be created — else the native engine.  The choice (and the
/// reason) goes to stderr so quantize/serve logs are attributable even in
/// builds without the `pjrt` feature.
fn make_backend(args: &Args, art: &Path) -> Result<Box<dyn Backend>> {
    match args.flag("backend").unwrap_or("auto") {
        "native" => Ok(Box::new(Native::new())),
        "pjrt" => pjrt_backend(art),
        "auto" => {
            let (backend, why): (Box<dyn Backend>, String) =
                if art.join("manifest.json").exists() {
                    match pjrt_backend(art) {
                        Ok(b) => (b, "artifact manifest found and PJRT client created".into()),
                        Err(e) => (
                            Box::new(Native::new()),
                            format!("manifest found but PJRT unavailable: {e:#}"),
                        ),
                    }
                } else {
                    (
                        Box::new(Native::new()),
                        format!("no manifest.json under {}", art.display()),
                    )
                };
            if !args.has("quiet") {
                eprintln!("backend auto: selected {} ({why})", backend.name());
            }
            Ok(backend)
        }
        other => bail!("unknown --backend {other:?} (expected native, pjrt, or auto)"),
    }
}

/// `--method` / `--rounding` are aliases: both select the rounding scheme
/// (`rtn | flexround | flexround_* | adaround`); `--method` wins when both
/// are given (it is the historical spelling).
fn method_from_args(args: &Args) -> &str {
    args.flag("method").or_else(|| args.flag("rounding")).unwrap_or("flexround")
}

fn plan_from_args(args: &Args, man: &Manifest) -> Result<Plan> {
    let model = args
        .flag("model")
        .ok_or_else(|| anyhow!("--model is required"))?;
    let mi = man.model(model)?;
    let mut plan = Plan::new(model, method_from_args(args));
    plan.mode = args
        .flag("mode")
        .map(str::to_string)
        .unwrap_or_else(|| if mi.methods_wa.iter().any(|m| m == &plan.method) && mi.methods_w.is_empty() {
            "wa".into()
        } else {
            "w".into()
        });
    plan.bits_w = args.usize_flag("bits", 4) as u32;
    plan.abits = args.usize_flag("abits", 8) as u32;
    plan.iters = args.usize_flag("iters", 0);
    plan.lr = args.f64_flag("lr", 0.0);
    plan.drop_p = match args.flag("setting") {
        Some("qdrop") | Some("Q") => 0.5,
        Some("brecq") | Some("B") => 0.0,
        _ => args.f64_flag("drop-p", if plan.mode == "wa" { 0.5 } else { 0.0 }),
    };
    plan.calib_n = args.usize_flag("calib-n", 0);
    plan.seed = args.usize_flag("seed", 7) as u64;
    plan.verbose = !args.has("quiet");
    plan.parallel_units = args.has("parallel-units");
    Ok(plan)
}

fn eval_model(sess: &Session, result: Option<&flexround::coordinator::QuantResult>)
              -> Result<std::collections::BTreeMap<String, f64>> {
    let mut m = std::collections::BTreeMap::new();
    match sess.model.kind.as_str() {
        "cnn" => {
            let mm = match result {
                Some(r) => eval::eval_cnn(sess, r)?,
                None => eval::eval_cnn_fp(sess)?,
            };
            m.extend(mm);
        }
        // native transformer-block LMs: perplexity through the weights-FXT
        // lm head — no PJRT artifact needed
        "block_lm" => {
            m.insert("ppl".into(), eval::eval_ppl_hidden(sess, result, "eval_x", "eval_y")?);
        }
        #[cfg(feature = "pjrt")]
        "encoder" => {
            m.extend(eval::eval_encoder(sess, result)?);
        }
        #[cfg(feature = "pjrt")]
        "decoder" => {
            if sess.model.name == "dec_lora" {
                m.insert("bleu_seen".into(), eval::eval_d2t_bleu(sess, result, "seen")?);
                m.insert("bleu_unseen".into(), eval::eval_d2t_bleu(sess, result, "unseen")?);
            } else {
                m.insert("ppl".into(), eval::eval_ppl(sess, result, "eval_x")?);
                if sess.model.name == "llm_mini" {
                    for task in eval::MC_TASKS {
                        m.insert(format!("mc_{task}"), eval::eval_mc(sess, result, task)?);
                    }
                }
            }
        }
        k => bail!("cannot evaluate model kind {k:?} with this build/backend"),
    }
    Ok(m)
}

fn cmd_quantize(args: &Args, art: &PathBuf, rep: &PathBuf, quiet: bool) -> Result<()> {
    let man = Manifest::load(art)?;
    let backend = make_backend(args, art)?;
    let plan = plan_from_args(args, &man)?;
    let sess = Session::open(backend.as_ref(), &man, &plan.model)?;
    let reporter = Reporter::new(rep, quiet)?;

    if args.command == "eval" && args.flag("method").is_none() {
        // full-precision evaluation only
        let m = eval_model(&sess, None)?;
        println!("fp {} → {m:?}", plan.model);
        reporter.metrics(&format!("eval_fp_{}", plan.model), &m)?;
        return Ok(());
    }

    if !quiet {
        println!(
            "quantizing {} with {} ({}-bit W, mode {}, {} setting, {} backend)…",
            plan.model, plan.method, plan.bits_w, plan.mode, plan.setting_label(),
            backend.name()
        );
    }
    let result = sess.quantize(&plan)?;
    if !quiet {
        for u in &result.units {
            println!(
                "  unit {:<8} loss {:.6} → {:.6}  (W{} A{})",
                u.unit, u.first_loss, u.final_loss, u.bits_w, u.abits
            );
        }
        println!(
            "  recon: {} steps in {:.2}s; engine: {}",
            result.recon_steps,
            result.recon_seconds,
            backend.summary()
        );
    }
    if args.has("eval") || args.command == "eval" {
        let m = eval_model(&sess, Some(&result))?;
        let id = format!(
            "quantize_{}_{}_w{}_{}", plan.model, plan.method, plan.bits_w, plan.mode
        );
        println!("metrics: {m:?}");
        reporter.metrics(&id, &m)?;
    }
    Ok(())
}

/// `flexround pipeline` — block-by-block reconstruction over
/// `transformer_block` units, end to end in Rust: calibration →
/// FP/quantized-input propagation (`--recon-input`) with disk-spillable
/// activation caches (`--cache-dir`, `--cache-mb`) → FlexRound per block →
/// perplexity report → optional packed export + engine forward
/// (`--pack-out`).
fn cmd_pipeline(args: &Args, art: &PathBuf, rep: &PathBuf, quiet: bool) -> Result<()> {
    use flexround::block::{self, PipelineOpts, ReconInput, SyntheticBlockSpec};

    let mut opts =
        PipelineOpts::new(method_from_args(args), args.usize_flag("bits", 4) as u32);
    // the synthetic manifest's iters_default is 0 (its tests want RTN-at-init
    // baselines), so an unflagged `pipeline --synthetic` would silently skip
    // reconstruction — give it a real default instead
    opts.iters = if args.has("iters") {
        args.usize_flag("iters", 0)
    } else if args.has("synthetic") {
        200
    } else {
        0 // 0 → manifest default
    };
    opts.lr = args.f64_flag("lr", 0.0);
    opts.calib_n = args.usize_flag("calib-n", 0);
    opts.seed = args.usize_flag("seed", 7) as u64;
    opts.recon_input = ReconInput::parse(args.flag("recon-input").unwrap_or("quant"))?;
    opts.cache_dir = args.flag("cache-dir").map(PathBuf::from);
    opts.cache_budget_bytes = args.usize_flag("cache-mb", 0) << 20;
    opts.verbose = !quiet;
    if let Some(dir) = &opts.cache_dir {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("creating --cache-dir {}: {e}", dir.display()))?;
    }

    // the pipeline's streamed reconstruction is native math; forwards route
    // through the Native backend's block substrate
    let native = Native::new();
    let reporter = Reporter::new(rep, quiet)?;
    if args.has("synthetic") {
        let spec = SyntheticBlockSpec {
            blocks: args.usize_flag("blocks", 2),
            d: args.usize_flag("width", 32),
            heads: args.usize_flag("heads", 4),
            mlp: args.usize_flag("mlp", 64),
            seq: args.usize_flag("seq", 8),
            calib_seqs: args.usize_flag("calib-seqs", 16),
            eval_seqs: args.usize_flag("eval-seqs", 8),
            chunk_seqs: args.usize_flag("chunk-seqs", 4),
            vocab: args.usize_flag("vocab", 64),
            bits: opts.bits_w,
            seed: opts.seed,
        };
        let fx = block::synthetic_block_model(&spec)?;
        let sess = fx.session(&native);
        run_pipeline_cmd(args, &sess, &opts, &reporter, quiet)
    } else {
        let man = Manifest::load(art)?;
        let model = args
            .flag("model")
            .ok_or_else(|| anyhow!("pipeline needs --model <name> or --synthetic"))?;
        let sess = Session::open(&native, &man, model)?;
        run_pipeline_cmd(args, &sess, &opts, &reporter, quiet)
    }
}

fn run_pipeline_cmd(
    args: &Args,
    sess: &Session,
    opts: &flexround::block::PipelineOpts,
    reporter: &Reporter,
    quiet: bool,
) -> Result<()> {
    if !quiet {
        println!(
            "block pipeline: model {} · {} · W{} · {}-input propagation{}",
            sess.model.name,
            opts.method,
            opts.bits_w,
            opts.recon_input.label(),
            match &opts.cache_dir {
                Some(d) => format!(" · cache {}", d.display()),
                None => String::new(),
            }
        );
    }
    let outcome = flexround::block::run_pipeline(sess, opts)?;
    if !quiet {
        for u in &outcome.result.units {
            println!(
                "  block {:<10} loss {:.6} → {:.6}  (W{})",
                u.unit, u.first_loss, u.final_loss, u.bits_w
            );
        }
        println!(
            "  recon: {} steps in {:.2}s; {} chunks per chain, {} spilled to disk",
            outcome.result.recon_steps,
            outcome.result.recon_seconds,
            outcome.chain_chunks,
            outcome.spilled_chunks
        );
    }

    // one packed engine serves every consumer below (calib MSE, quantized
    // perplexity, --pack-out) — Session::forward_q would rebuild the
    // export/pack per call otherwise.  `--act-bits <b>` makes it a W·A{b}
    // engine: static activation grids calibrated from the recon batches.
    let act_bits = args.usize_flag("act-bits", 0) as u32;
    let engine = match if act_bits > 0 {
        sess.packed_model_with_acts(&outcome.result, act_bits).map(|pm| {
            flexround::infer::Engine::new(pm, flexround::util::pool::default_workers())
        })
    } else {
        sess.packed_engine(&outcome.result)
    } {
        Ok(e) => {
            if act_bits > 0 && !quiet {
                println!(
                    "  serving W{}A{act_bits}: stack layers run the integer-domain fused GEMM",
                    opts.bits_w
                );
            }
            Some(e)
        }
        Err(err) => {
            if !quiet {
                eprintln!("  (packed fast path unavailable, using the f32 chain: {err:#})");
            }
            None
        }
    };
    let forward_q = |xs: &flexround::tensor::Tensor| -> Result<Vec<flexround::tensor::Tensor>> {
        let chunks = sess.first_unit_inputs(xs)?;
        match &engine {
            Some(e) => chunks.iter().map(|c| e.forward(c)).collect(),
            None => {
                let mut cur = chunks;
                for (unit, st) in sess.model.units.iter().zip(&outcome.result.units) {
                    cur = sess.advance_q(unit, st, "w", &cur)?;
                }
                Ok(cur)
            }
        }
    };

    let mut metrics = std::collections::BTreeMap::new();
    {
        let calib = sess.dataset("calib_x")?;
        metrics.insert(
            "calib_mse".to_string(),
            flexround::block::mse_vs_fp(sess, &forward_q(calib)?, calib)?,
        );
    }
    if sess.weights.contains_key("head/lm")
        && sess.data.contains_key("eval_x")
        && sess.data.contains_key("eval_y")
    {
        let fp = eval::eval_ppl_hidden(sess, None, "eval_x", "eval_y")?;
        let q = eval::ppl_from_hidden(sess, &forward_q(sess.dataset("eval_x")?)?, "eval_y")?;
        metrics.insert("ppl_fp".to_string(), fp);
        metrics.insert("ppl_q".to_string(), q);
        metrics.insert("ppl_delta".to_string(), q - fp);
        if !quiet {
            println!("  perplexity: fp {fp:.4} → quantized {q:.4} (Δ {:+.4})", q - fp);
        }
    }
    let id = format!(
        "pipeline_{}_{}_w{}_{}",
        sess.model.name,
        opts.method,
        opts.bits_w,
        outcome.recon_input.label()
    );
    if !quiet {
        println!("metrics: {metrics:?}");
    }
    reporter.metrics(&id, &metrics)?;

    if let Some(out) = args.flag("pack-out") {
        let Some(engine) = &engine else {
            bail!("--pack-out needs a packable result (see the message above)");
        };
        // generation-complete when the model carries a native lm head: the
        // already-packed blocks gain a packed `head` stack (no re-packing)
        // so `flexround generate --packed` can decode from the artifact
        let with_head = sess.weights.contains_key("head/lm");
        let headed_engine = if with_head {
            let mut saved = engine.model().clone();
            saved.units.push(sess.packed_head_unit()?);
            saved.save(Path::new(out))?;
            Some(flexround::infer::Engine::new(saved, engine.workers))
        } else {
            engine.model().save(Path::new(out))?;
            None
        };
        // time the forward through the engine serving the *saved* model, so
        // the printed output shape is what the artifact actually produces
        let saved_engine = headed_engine.as_ref().unwrap_or(engine);
        let chunks = sess.first_unit_inputs(sess.dataset("calib_x")?)?;
        let t0 = std::time::Instant::now();
        let y = saved_engine.forward(&chunks[0])?;
        println!(
            "packed → {out}{}; engine forward {:?} → {:?} in {:.3}ms (no FP weights)",
            if with_head { " (with packed lm head — generation-ready)" } else { "" },
            chunks[0].shape(),
            y.shape(),
            1e3 * t0.elapsed().as_secs_f64()
        );
    }
    maybe_write_trace(args)
}

/// `flexround generate` — KV-cached autoregressive decode over a packed
/// block model: prefill the prompt once, then one incremental step per
/// token.  `--synthetic` builds a random packed LM in memory; `--packed`
/// loads a generation-complete artifact (blocks + tied lm head, e.g. from
/// `pipeline --pack-out`).  Fixed `--seed` ⇒ identical token stream.
fn cmd_generate(args: &Args) -> Result<()> {
    use flexround::infer::generate::{self, GenOpts};
    use flexround::infer::{Engine, PackedModel};
    let workers = args.usize_flag("workers", flexround::util::pool::default_workers());
    let model = if let Some(p) = args.flag("packed") {
        PackedModel::load(Path::new(p))?
    } else if args.has("synthetic") {
        generate::synthetic_lm(
            args.usize_flag("blocks", 2),
            args.usize_flag("width", 64),
            args.usize_flag("heads", 4),
            args.usize_flag("mlp", 128),
            args.usize_flag("seq", 16),
            args.usize_flag("vocab", 256),
            args.usize_flag("bits", 4) as u32,
            args.usize_flag("seed", 7) as u64,
        )?
    } else {
        bail!("generate needs --packed <model.fxt> or --synthetic");
    };
    let opts = GenOpts {
        max_new: args.usize_flag("max-new", 32).max(1),
        temp: args.f64_flag("temp", 0.0) as f32,
        top_k: args.usize_flag("top-k", 0),
        seed: args.usize_flag("seed", 7) as u64,
    };
    let engine = Engine::new(model, workers);
    let sessions = args.usize_flag("sessions", 1).max(1);
    if sessions > 1 {
        generate_sessions(args, engine, &opts, sessions)?;
        return maybe_write_trace(args);
    }
    let (prompt_toks, prompt) =
        generate::random_prompt(engine.model(), args.usize_flag("prompt-len", 4), opts.seed)?;
    let gen = generate::generate(&engine, &prompt, &opts)?;
    let per_tok = 1e3 * gen.decode_secs_per_token();
    let join = |ts: &[usize]| {
        ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
    };
    println!("prompt ({} tokens): {}", prompt_toks.len(), join(&prompt_toks));
    println!("generated {} tokens: {}", gen.tokens.len(), join(&gen.tokens));
    println!(
        "prefill {:.3}ms · decode {per_tok:.3}ms/token (KV-cached; temp {}, top-k {}, seed {})",
        1e3 * gen.prefill_secs,
        opts.temp,
        opts.top_k,
        opts.seed
    );
    if args.has("compare") {
        let base = generate::generate_recompute(&engine, &prompt, &opts)?;
        let base_tok = 1e3 * base.decode_secs_per_token();
        println!(
            "recompute baseline {base_tok:.3}ms/token → cached speedup {:.2}×{}",
            base_tok / per_tok.max(1e-9),
            if base.tokens == gen.tokens {
                " (identical stream)"
            } else {
                " (STREAM MISMATCH — file a bug)"
            }
        );
    }
    maybe_write_trace(args)
}

/// Scheduler sizing from the CLI flags (`serve` and `generate --sessions`).
fn sched_cfg_from(args: &Args) -> flexround::sched::SchedConfig {
    let d = flexround::sched::SchedConfig::default();
    flexround::sched::SchedConfig {
        pool_pages: args.usize_flag("pool-pages", d.pool_pages),
        page_tokens: args.usize_flag("page-tokens", d.page_tokens),
        max_active: args.usize_flag("max-active", d.max_active),
        prefill_chunk: args.usize_flag("prefill-chunk", d.prefill_chunk),
        spill_dir: None,
    }
}

/// `flexround generate --sessions n`: decode `n` concurrent sessions
/// through the continuous-batching scheduler — each with its own prompt,
/// sampling seed, and KV pages — and report aggregate throughput.  With
/// `--compare`, every stream is checked bit-identical to its solo
/// KV-cached decode.
fn generate_sessions(
    args: &Args,
    engine: flexround::infer::Engine,
    opts: &flexround::infer::GenOpts,
    sessions: usize,
) -> Result<()> {
    use flexround::infer::generate;
    use flexround::sched::Scheduler;
    let prompt_len = args.usize_flag("prompt-len", 4);
    let mut sched = Scheduler::new(engine, sched_cfg_from(args))?;
    let mut prompts = Vec::with_capacity(sessions);
    for i in 0..sessions {
        let (_, prompt) =
            generate::random_prompt(sched.engine().model(), prompt_len, opts.seed + i as u64)?;
        prompts.push(prompt);
    }
    let mut session_opts = Vec::with_capacity(sessions);
    let t0 = std::time::Instant::now();
    for (i, prompt) in prompts.iter().enumerate() {
        let o = flexround::infer::GenOpts { seed: opts.seed + i as u64, ..*opts };
        sched.submit(prompt.as_f32()?.to_vec(), o)?;
        session_opts.push(o);
    }
    let mut finished = sched.run_all()?;
    let secs = t0.elapsed().as_secs_f64();
    finished.sort_by_key(|f| f.handle);
    let total: usize = finished.iter().map(|f| f.tokens.len()).sum();
    println!(
        "scheduler: {sessions} sessions × {} tokens in {secs:.3}s → {:.0} tok/s aggregate \
         ({} steps, peak pages {}, evictions {})",
        opts.max_new,
        total as f64 / secs.max(1e-9),
        sched.steps(),
        sched.occupancy_peaks().1,
        sched.evictions()
    );
    if args.has("compare") {
        let mut mismatches = 0usize;
        for (i, fin) in finished.iter().enumerate() {
            let solo = generate::generate(sched.engine(), &prompts[i], &session_opts[i])?;
            if solo.tokens != fin.tokens {
                mismatches += 1;
            }
        }
        if mismatches > 0 {
            bail!("{mismatches}/{sessions} scheduled streams diverged from solo decode");
        }
        println!("compare: all {sessions} streams bit-identical to solo KV-cached decode");
    }
    Ok(())
}

fn cmd_pack(args: &Args, art: &PathBuf, quiet: bool) -> Result<()> {
    let man = Manifest::load(art)?;
    let backend = make_backend(args, art)?;
    let plan = plan_from_args(args, &man)?;
    let sess = Session::open(backend.as_ref(), &man, &plan.model)?;
    if !quiet {
        println!(
            "quantizing {} with {} ({}-bit W, {} backend) for packed export…",
            plan.model,
            plan.method,
            plan.bits_w,
            backend.name()
        );
    }
    let result = sess.quantize(&plan)?;
    // `--act-bits <b>` upgrades the weight-only pack to W{bits}A{b}: static
    // activation grids calibrated from the reconstruction batches, served by
    // the integer-domain fused kernels (DESIGN.md §Rounding-Schemes)
    let act_bits = args.usize_flag("act-bits", 0) as u32;
    let pm = if act_bits > 0 {
        sess.packed_model_with_acts(&result, act_bits)?
    } else {
        sess.packed_model(&result)?
    };
    let out = args.flag("out").map(PathBuf::from).unwrap_or_else(|| {
        let a = if act_bits > 0 { format!("a{act_bits}") } else { String::new() };
        PathBuf::from(format!("packed_{}_{}_w{}{a}.fxt", plan.model, plan.method, plan.bits_w))
    });
    pm.save(&out)?;
    let (pb, fb) = (pm.packed_bytes(), pm.fp32_bytes());
    println!(
        "packed {} units → {} ({pb} bytes vs {fb} as dense f32, {:.2}× smaller; \
         artifact carries no FP weights)",
        pm.units.len(),
        out.display(),
        fb as f64 / pb.max(1) as f64
    );
    if act_bits > 0 {
        println!(
            "  W{}A{act_bits}: stack layers carry static activation grids → \
             integer-domain fused GEMM at serve time",
            plan.bits_w
        );
    }
    Ok(())
}

/// `--packed <file.fxt>` loads a pack artifact; `--synthetic` builds a
/// random square model in memory (demo / loadgen without any files).
fn load_engine(args: &Args) -> Result<flexround::infer::Engine> {
    use flexround::infer::{synthetic_model, Engine, PackedModel};
    let workers = args.usize_flag("workers", flexround::util::pool::default_workers());
    let model = if let Some(p) = args.flag("packed") {
        PackedModel::load(Path::new(p))?
    } else if args.has("synthetic") {
        synthetic_model(
            args.usize_flag("units", 2),
            args.usize_flag("width", 512),
            args.usize_flag("bits", 4) as u32,
            args.usize_flag("seed", 7) as u64,
        )?
    } else {
        bail!("infer/serve need --packed <model.fxt> or --synthetic");
    };
    Ok(Engine::new(model, workers))
}

/// `--trace-out <path>`: export the span ring as Chrome `trace_event` JSON
/// (open via chrome://tracing or ui.perfetto.dev).
fn maybe_write_trace(args: &Args) -> Result<()> {
    if let Some(path) = args.flag("trace-out") {
        let n = flexround::obs::write_chrome_trace(Path::new(path))?;
        eprintln!("trace: {n} spans → {path} (Chrome trace_event format)");
    }
    Ok(())
}

/// The `/healthz` model block for `serve --metrics-addr`.
fn model_info_json(engine: &flexround::infer::Engine) -> flexround::ser::json::Json {
    use flexround::ser::json::Json;
    let m = engine.model();
    Json::object(vec![
        ("units", Json::from_f64(m.units.len() as f64)),
        ("in_width", Json::from_f64(engine.in_width().unwrap_or(0) as f64)),
        ("packed_bytes", Json::from_f64(m.packed_bytes() as f64)),
    ])
}

/// Shared tail of every `serve` path: stop the metrics endpoint, dump the
/// final registry snapshot (`--stats-json`), export spans (`--trace-out`).
fn finish_serve(args: &Args, metrics: Option<flexround::obs::MetricsServer>) -> Result<()> {
    if let Some(ms) = metrics {
        ms.shutdown()?;
    }
    if let Some(path) = args.flag("stats-json") {
        let doc = flexround::obs::snapshot_json();
        std::fs::write(path, flexround::ser::json::to_string(&doc, 2) + "\n")
            .map_err(|e| anyhow!("writing --stats-json {path}: {e}"))?;
        eprintln!("stats: metrics snapshot → {path}");
    }
    maybe_write_trace(args)
}

fn cmd_infer(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let rows = args.usize_flag("rows", 8).max(1);
    let width = engine.in_width()?;
    let mut rng =
        flexround::util::rng::Pcg32::seeded(args.usize_flag("seed", 7) as u64);
    let x = flexround::tensor::Tensor::from_f32(
        (0..rows * width).map(|_| rng.next_normal()).collect(),
        &[rows, width],
    )?;
    let t0 = std::time::Instant::now();
    let y = engine.forward(&x)?;
    let fused_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let y_ref = engine.forward_unfused(&x)?;
    let ref_s = t1.elapsed().as_secs_f64();
    println!(
        "infer: {rows}×{width} → {:?} in {:.3}ms fused ({:.3}ms dequant+matmul, \
         max|Δ| {:.2e}); {:.0} rows/s",
        y.shape(),
        1e3 * fused_s,
        1e3 * ref_s,
        y.max_abs_diff(&y_ref)?,
        rows as f64 / fused_s.max(1e-9)
    );
    if let Some(out) = args.flag("out") {
        let mut m = std::collections::BTreeMap::new();
        m.insert("y".to_string(), y);
        flexround::ser::fxt::write(Path::new(out), &m)?;
        println!("wrote outputs to {out}");
    }
    Ok(())
}

/// The serve summary's latency/occupancy lines (shared with `--sessions`
/// runs so mixed and rows-only output stay comparable).
fn print_serve_stats(stats: &flexround::infer::ServeStats) {
    println!(
        "latency: row wait p50 {:.3}ms / p99 {:.3}ms · service p50 {:.3}ms / p99 {:.3}ms",
        stats.row_wait_p50_ms,
        stats.row_wait_p99_ms,
        stats.row_service_p50_ms,
        stats.row_service_p99_ms
    );
    if stats.gen_sessions > 0 {
        println!(
            "sessions: {} answered, {} tokens · wait p50 {:.3}ms / p99 {:.3}ms · \
             service p50 {:.3}ms / p99 {:.3}ms",
            stats.gen_sessions,
            stats.gen_tokens,
            stats.gen_wait_p50_ms,
            stats.gen_wait_p99_ms,
            stats.gen_service_p50_ms,
            stats.gen_service_p99_ms
        );
        println!(
            "scheduler: {} steps · peak {} active sessions · peak {} pool pages · \
             {} evictions",
            stats.sched_steps,
            stats.peak_sessions,
            stats.peak_pages,
            stats.evictions
        );
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    use flexround::infer::{drive, drive_mixed, BatchPolicy};
    let requests = args.usize_flag("requests", 256).max(1);
    let clients = args.usize_flag("clients", 4).max(1);
    let sessions = args.usize_flag("sessions", 0);
    let seed = args.usize_flag("seed", 7) as u64;
    let policy = BatchPolicy {
        max_batch: args.usize_flag("max-batch", 32).max(1),
        deadline: std::time::Duration::from_secs_f64(
            args.f64_flag("deadline-ms", 2.0).max(0.0) / 1e3,
        ),
    };
    // mixed mode needs a generation-complete model (blocks + tied lm head);
    // `--synthetic` therefore builds the same block LM `generate` does
    // instead of load_engine's headless stack
    let engine = if sessions > 0 && args.flag("packed").is_none() && args.has("synthetic") {
        let workers =
            args.usize_flag("workers", flexround::util::pool::default_workers());
        let model = flexround::infer::generate::synthetic_lm(
            args.usize_flag("blocks", 2),
            args.usize_flag("width", 64),
            args.usize_flag("heads", 4),
            args.usize_flag("mlp", 128),
            args.usize_flag("seq", 16),
            args.usize_flag("vocab", 256),
            args.usize_flag("bits", 4) as u32,
            seed,
        )?;
        flexround::infer::Engine::new(model, workers)
    } else {
        load_engine(args)?
    };
    // `--metrics-addr <host:port>` (port 0 = ephemeral): serve /metrics and
    // /healthz from a sidecar thread for the lifetime of the workload
    let metrics = match args.flag("metrics-addr") {
        Some(addr) => {
            let ms = flexround::obs::MetricsServer::start(addr, model_info_json(&engine))?;
            println!("metrics endpoint: http://{}/metrics (and /healthz)", ms.addr());
            Some(ms)
        }
        None => None,
    };
    if sessions > 0 {
        // mixed workload: rows racing generation sessions for the batcher,
        // reproducible from the seed
        let (secs, stats) =
            drive_mixed(engine, policy, sched_cfg_from(args), requests, sessions, clients, seed)?;
        let rps = stats.requests as f64 / secs.max(1e-9);
        let tps = stats.gen_tokens as f64 / secs.max(1e-9);
        println!(
            "serve: {} rows + {} sessions / {clients} clients in {secs:.3}s → \
             {rps:.0} rows/s + {tps:.0} tok/s ({} batches, mean {:.1} rows per batch)",
            stats.requests,
            stats.gen_sessions,
            stats.batches,
            stats.mean_batch(),
        );
        print_serve_stats(&stats);
        return finish_serve(args, metrics);
    }
    let width = engine.in_width()?;
    let mut rng = flexround::util::rng::Pcg32::seeded(seed);
    let rows: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..width).map(|_| rng.next_normal()).collect())
        .collect();
    let (secs, stats) = drive(engine, policy, rows.clone(), clients)?;
    let rps = stats.requests as f64 / secs.max(1e-9);
    println!(
        "serve: {} requests / {clients} clients in {secs:.3}s → {rps:.0} rows/s \
         ({} batches, mean {:.1} / max {} rows per batch, {:.1}% of wall in GEMM)",
        stats.requests,
        stats.batches,
        stats.mean_batch(),
        stats.max_batch,
        100.0 * stats.gemm_secs / secs.max(1e-9)
    );
    print_serve_stats(&stats);
    if args.has("compare") {
        let engine = load_engine(args)?;
        let unbatched =
            BatchPolicy { max_batch: 1, deadline: std::time::Duration::ZERO };
        let (s_u, st_u) = drive(engine, unbatched, rows, clients)?;
        let rps_u = st_u.requests as f64 / s_u.max(1e-9);
        println!(
            "serve: unbatched baseline {rps_u:.0} rows/s ({} batches) → \
             micro-batching speedup {:.2}×",
            st_u.batches,
            rps / rps_u.max(1e-9)
        );
    }
    finish_serve(args, metrics)
}

fn cmd_figure(args: &Args, art: &PathBuf, rep: &PathBuf, quiet: bool) -> Result<()> {
    let man = Manifest::load(art)?;
    let backend = make_backend(args, art)?;
    let plan = plan_from_args(args, &man)?;
    let sess = Session::open(backend.as_ref(), &man, &plan.model)?;
    let reporter = Reporter::new(rep, quiet)?;
    let unit_name = args.flag("unit").ok_or_else(|| anyhow!("--unit is required"))?;

    let result = sess.quantize(&plan)?;
    let (unit, st) = sess
        .model
        .units
        .iter()
        .zip(&result.units)
        .find(|(u, _)| u.name == unit_name)
        .ok_or_else(|| anyhow!("no unit {unit_name}"))?;

    for gs in quant::grid_shifts(&sess, unit, st)? {
        let id = format!("fig_shift_{}_{}_{}_{}_w{}", plan.model, unit_name, gs.layer,
                         plan.method, plan.bits_w);
        let rows: Vec<String> = gs.points.iter().map(|(w, d)| format!("{w},{d}")).collect();
        reporter.series(&id, "weight,grid_shift", &rows)?;
        println!(
            "{}/{}: shifted {:.2}% aggressive {:.2}% max |Δ| {}",
            unit_name, gs.layer, 100.0 * gs.shifted_frac, 100.0 * gs.aggressive_frac,
            gs.max_shift
        );
    }
    let h = quant::delta_hist(&sess, unit, st, 41)?;
    let id = format!("fig_hist_{}_{}_{}_w{}", plan.model, unit_name, plan.method, plan.bits_w);
    let rows: Vec<String> = (0..h.small_counts.len())
        .map(|i| format!("{},{},{}", h.edges[i], h.small_counts[i], h.large_counts[i]))
        .collect();
    reporter.series(&id, "delta_edge,count_small_w,count_large_w", &rows)?;
    println!(
        "ΔW histogram: {} small-|W| points, {} large-|W| points; model large-weight frac {:.3}%",
        h.n_small, h.n_large, 100.0 * quant::large_weight_fraction(&sess)
    );
    Ok(())
}

fn cmd_sweep(args: &Args, art: &PathBuf, rep: &PathBuf, quiet: bool) -> Result<()> {
    let cfg_path = args
        .flag("config")
        .ok_or_else(|| anyhow!("--config is required for sweep"))?;
    let mut cfg = Config::new();
    cfg.load_file(&PathBuf::from(cfg_path))?;
    for ov in args.flag_all("set") {
        cfg.set_override(ov)?;
    }
    let man = Manifest::load(art)?;
    let backend = make_backend(args, art)?;
    let reporter = Reporter::new(rep, quiet)?;
    flexround::sweep::run_sweep(&cfg, &man, backend.as_ref(), &reporter)
}

fn cmd_inspect(args: &Args, art: &PathBuf) -> Result<()> {
    let man = Manifest::load(art)?;
    match args.flag("model") {
        None => {
            println!("{} models in {}:", man.models.len(), art.display());
            for (name, m) in &man.models {
                println!(
                    "  {:<22} {:<8} task={:<6} units={} bits_w={:?} fp={:?}",
                    name, m.kind, m.task, m.units.len(), m.bits_w, m.fp_metric
                );
            }
        }
        Some(name) => {
            let m = man.model(name)?;
            println!("model {name} ({}, task {})", m.kind, m.task);
            println!("  fp metric: {:?}", m.fp_metric);
            println!("  scheme: symmetric={} per_channel={} bits_w={:?} abits={:?}",
                     m.symmetric, m.per_channel, m.bits_w, m.abits);
            println!("  methods: w={:?} wa={:?}", m.methods_w, m.methods_wa);
            for u in &m.units {
                println!(
                    "  unit {:<8} {:<16} in{:?} out{:?} layers={} acts={} bits_override={:?}",
                    u.name, u.kind, u.in_shape, u.out_shape, u.layers.len(), u.act_sites,
                    u.bits_override
                );
            }
            println!("  datasets: {:?}", m.datasets.keys().collect::<Vec<_>>());
        }
    }
    Ok(())
}

fn cmd_selftest(args: &Args, art: &PathBuf) -> Result<()> {
    let backend = make_backend(args, art)?;
    if backend.name() == "native" {
        // Artifact-free: reconstruct a synthetic 3-bit unit end to end.
        println!("backend: native (no artifacts needed)");
        let (before, after) = recon::native_selftest(!args.has("quiet"))?;
        println!(
            "  synthetic 16×32 unit @ 3-bit: output MSE {before:.6} → {after:.6} \
             ({:.1}% of the RTN init)",
            100.0 * after / before.max(1e-12)
        );
        println!("selftest OK; {}", backend.summary());
        return Ok(());
    }
    // PJRT: load + execute a smoke subset of artifacts and verify numerics.
    let man = Manifest::load(art)?;
    println!("backend: {}", backend.name());
    let mut checked = 0;
    for (name, _) in man.models.iter().take(2) {
        let sess = Session::open(backend.as_ref(), &man, name)?;
        let calib = sess.dataset("calib_x")?;
        let b = sess.model.calib_batch;
        let x0 = calib.slice_rows(0, b)?;
        let chunks = sess.first_unit_inputs(&x0)?;
        let u0 = &sess.model.units[0];
        let y = sess.advance_fp(u0, &chunks)?;
        println!(
            "  {name}: fp unit {:?} {:?} → {:?} ok",
            u0.name,
            chunks[0].shape(),
            y[0].shape()
        );
        // one recon step with the first learnable method available
        let method = sess
            .model
            .methods_w
            .iter()
            .chain(sess.model.methods_wa.iter())
            .find(|m| *m != "rtn")
            .cloned();
        if let Some(method) = method {
            let mode = if sess.model.methods_w.iter().any(|m| m == &method) { "w" } else { "wa" };
            let mut plan = Plan::new(name, &method);
            plan.mode = mode.into();
            plan.bits_w = *sess.model.bits_w.iter().max().unwrap_or(&8);
            plan.iters = 2;
            plan.calib_n = b;
            plan.verbose = false;
            let r = sess.quantize(&plan)?;
            println!(
                "  {name}: 2-step {} recon ok (loss {:.5} → {:.5})",
                method, r.units[0].first_loss, r.units[0].final_loss
            );
        }
        checked += 1;
    }
    println!("selftest OK ({checked} models); {}", backend.summary());
    Ok(())
}
