//! # flexround — post-training quantization by learnable element-wise division
//!
//! A Rust + JAX + Pallas reproduction of *FlexRound: Learnable Rounding based
//! on Element-wise Division for Post-Training Quantization* (Lee et al.,
//! ICML 2023).
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build-time Python)** — Pallas fake-quant kernels inside JAX
//!   reconstruction graphs, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the PTQ coordinator: loads the artifacts via
//!   the PJRT C API (`xla` crate), owns calibration data, schedules per-unit
//!   reconstruction, evaluates quantized models, and regenerates every table
//!   and figure of the paper.
//!
//! Python never runs at PTQ time; after `make artifacts` the binary is
//! self-contained.
//!
//! The build image vendors only the `xla` crate's dependency closure, so the
//! substrates usually pulled from crates.io are implemented here from
//! scratch: [`tensor`] (n-d arrays), [`ser`] (JSON + the FXT tensor
//! container), [`config`] (layered TOML-subset), [`cli`], [`util`] (PCG RNG,
//! stats, thread pool, property-test harness), [`report`] (markdown/CSV
//! emitters).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod manifest;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod ser;
pub mod sweep;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-backed, the only vendored error helper).
pub type Result<T> = anyhow::Result<T>;
