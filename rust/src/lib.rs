//! # flexround — post-training quantization by learnable element-wise division
//!
//! A Rust + JAX + Pallas reproduction of *FlexRound: Learnable Rounding based
//! on Element-wise Division for Post-Training Quantization* (Lee et al.,
//! ICML 2023).
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build-time Python)** — Pallas fake-quant kernels inside JAX
//!   reconstruction graphs, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the PTQ coordinator: owns calibration data,
//!   schedules per-unit reconstruction, evaluates quantized models, and
//!   regenerates every table and figure of the paper.  Execution goes
//!   through the [`runtime::Backend`] trait with two engines:
//!   * [`runtime::Native`] — the pure-Rust reconstruction engine
//!     ([`recon`]): fake-quant by element-wise division, closed-form STE
//!     backward (Proposition 3.1's reciprocal rule), Adam.  No artifacts
//!     required — the crate learns `(s1, S2, s3, s4)` entirely on its own.
//!   * `runtime::Pjrt` (feature `pjrt`, default) — loads the AOT artifacts
//!     via the PJRT C API (`xla` crate) and executes the fused
//!     kernels-in-graphs built by the Python path.
//!
//! Python never runs at PTQ time; with the native backend nothing but this
//! binary is needed, and after `make artifacts` the PJRT path is
//! self-contained too.
//!
//! The build image vendors only in-tree crates (no crates.io access), so the
//! substrates usually pulled from the registry are implemented here from
//! scratch: [`tensor`] (n-d arrays) over [`linalg`] (the register-tiled
//! blocked-GEMM core + the one parallel-dispatch policy every matmul in the
//! crate shares), [`ser`] (JSON + the FXT
//! tensor container), [`config`] (layered TOML-subset), [`cli`], [`util`]
//! (PCG RNG, stats, thread pool, property-test harness), [`report`]
//! (markdown/CSV emitters), plus a minimal vendored `anyhow` and a
//! compile-only `xla` stub (`rust/vendor/`).

pub mod block;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod infer;
pub mod linalg;
pub mod manifest;
pub mod obs;
pub mod quant;
pub mod recon;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod ser;
pub mod sweep;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-backed, the only vendored error helper).
pub type Result<T> = anyhow::Result<T>;
