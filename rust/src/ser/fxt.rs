//! FXT named-tensor container — the binary interchange format between the
//! Python build path and this coordinator.  Format spec lives in
//! `python/compile/fxt.py`; both sides round-trip the same reference files.

use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FXT1";

/// Read every tensor in an FXT file.
pub fn read(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    read_bytes(&bytes).map_err(|e| anyhow!("{}: {e}", path.display()))
}

pub fn read_bytes(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut r = Cursor { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        bail!("bad magic");
    }
    let count = r.u32()?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = r.u32()? as usize;
        let name = String::from_utf8(r.take(nlen)?.to_vec())
            .map_err(|_| anyhow!("tensor name is not utf-8"))?;
        let dt = r.u8()?;
        let nd = r.u8()? as usize;
        let mut dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            dims.push(r.u32()? as usize);
        }
        let n: usize = if nd == 0 { 1 } else { dims.iter().product() };
        let raw = r.take(n * 4)?;
        let t = match dt {
            0 => {
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                Tensor::from_f32(v, &dims)?
            }
            1 => {
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                Tensor::from_i32(v, &dims)?
            }
            _ => bail!("unknown dtype tag {dt}"),
        };
        out.insert(name, t);
    }
    if r.i != bytes.len() {
        bail!("trailing bytes after last tensor");
    }
    Ok(out)
}

/// Serialize tensors to FXT bytes (the file format, in memory — packed-model
/// round-trip tests and streaming writers use this directly).
pub fn write_bytes(tensors: &BTreeMap<String, Tensor>) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        out.extend_from_slice(nb);
        let (tag, raw): (u8, Vec<u8>) = match t.dtype() {
            crate::tensor::DType::F32 => (
                0,
                t.as_f32()?.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            crate::tensor::DType::I32 => (
                1,
                t.as_i32()?.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
        };
        out.push(tag);
        out.push(t.shape().len() as u8);
        for &d in t.shape() {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&raw);
    }
    Ok(out)
}

/// Write tensors to an FXT file (reports, tests, packed-model artifacts).
pub fn write(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let bytes = write_bytes(tensors)?;
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow!("creating {}: {e}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(())
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file (want {n} bytes at offset {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

/// Read a `Read`er fully then parse (for streams / tests).
pub fn read_from(mut r: impl Read) -> Result<BTreeMap<String, Tensor>> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    read_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("a/w".into(), Tensor::from_f32(vec![1.5, -2.0, 0.25, 9.0], &[2, 2]).unwrap());
        m.insert("b/idx".into(), Tensor::from_i32(vec![3, -7, 11], &[3]).unwrap());
        m.insert("scalar".into(), Tensor::scalar(42.0));
        m
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fxt_test_rt.fxt");
        let m = sample();
        write(&dir, &m).unwrap();
        let back = read(&dir).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_bytes(b"NOPE").is_err());
        assert!(read_bytes(b"FXT1\x01\x00\x00\x00").is_err()); // count=1 but truncated
        // trailing bytes
        let dir = std::env::temp_dir().join("fxt_test_trail.fxt");
        write(&dir, &sample()).unwrap();
        let mut bytes = std::fs::read(&dir).unwrap();
        bytes.push(0);
        assert!(read_bytes(&bytes).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn empty_container() {
        let bytes = b"FXT1\x00\x00\x00\x00";
        assert!(read_bytes(bytes).unwrap().is_empty());
    }

    #[test]
    fn in_memory_roundtrip() {
        let m = sample();
        let bytes = write_bytes(&m).unwrap();
        assert_eq!(read_bytes(&bytes).unwrap(), m);
    }
}
