//! A from-scratch RFC-8259 JSON parser and writer.
//!
//! serde is not in the vendored crate set, and the coordinator needs JSON in
//! two places: reading `artifacts/manifest.json` (written by `aot.py`) and
//! emitting machine-readable experiment reports.  The parser is a straight
//! recursive-descent over bytes with proper string escapes, number parsing,
//! and depth limiting; the writer is deterministic (sorted object keys)
//! so report files diff cleanly.

use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.  Objects use a BTreeMap so iteration is ordered.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors (ergonomic unwrapping for manifest walking) ----

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("get({key:?}) on non-object"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.arr()?.iter().map(|v| Ok(v.str()?.to_string())).collect()
    }

    // ---- construction helpers for report emission ----

    pub fn object(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn from_str_val(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

const MAX_DEPTH: usize = 128;

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value(0)?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("nesting depth > {MAX_DEPTH}");
        }
        self.ws();
        match self.peek()? {
            b'{' => self.object(depth),
            b'[' => self.array(depth),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape \\{:?}", e as char),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow!("invalid utf-8 in string"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| anyhow!("bad number {s:?} at byte {start}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize with sorted keys; `indent = 0` → compact.
pub fn to_string(v: &Json, indent: usize) -> String {
    let mut s = String::new();
    write_val(&mut s, v, indent, 0);
    s
}

fn write_val(out: &mut String, v: &Json, indent: usize, level: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(out, s),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                nl(out, indent, level + 1);
                write_val(out, x, indent, level + 1);
            }
            if !a.is_empty() {
                nl(out, indent, level);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                nl(out, indent, level + 1);
                write_str(out, k);
                out.push(':');
                if indent > 0 {
                    out.push(' ');
                }
                write_val(out, x, indent, level + 1);
            }
            if !m.is_empty() {
                nl(out, indent, level);
            }
            out.push('}');
        }
    }
}

fn nl(out: &mut String, indent: usize, level: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * level {
            out.push(' ');
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(), "x");
        assert!(v.opt("c").is_none());
        assert!(v.opt("d").is_none());
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        let deep = "[".repeat(300) + &"]".repeat(300);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"m1":{"bits":[2,3,4],"acc":0.75,"sym":true}},"v":1}"#;
        let v = parse(src).unwrap();
        let s = to_string(&v, 0);
        assert_eq!(parse(&s).unwrap(), v);
        let pretty = to_string(&v, 2);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn int_formatting() {
        assert_eq!(to_string(&Json::Num(3.0), 0), "3");
        assert_eq!(to_string(&Json::Num(3.25), 0), "3.25");
    }
}
