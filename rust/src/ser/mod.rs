//! Serialization substrates: a from-scratch JSON parser/writer ([`json`])
//! and the FXT named-tensor container ([`fxt`]) shared with the Python
//! build path (`python/compile/fxt.py`).

pub mod fxt;
pub mod json;
