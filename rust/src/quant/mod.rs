//! Rust-side quantization analysis: grid-shift statistics for the paper's
//! Figures 3–6, weight-update histograms, and invariant checks over the
//! CLE/AHB-preprocessed exports (Table 10).
//!
//! The *learning* happens in whichever engine the session drives — the AOT
//! executables (PJRT backend) or the in-crate [`crate::recon`] loop (native
//! backend).  This module consumes the exported integer codes (the `qw.*`
//! artifacts or their native equivalent) plus the raw weights/init scales
//! from the FXT files and reproduces the figures' data series.

use crate::coordinator::{Session, UnitState};
use crate::manifest::UnitInfo;
use crate::tensor::{qrange, rtn_codes_rows, Tensor};
use crate::Result;
use anyhow::anyhow;

/// Grid-shift analysis of one layer: how far the learned integer codes
/// moved from the rounding-to-nearest grid (Figures 3 right, 4, 5, 6).
#[derive(Clone, Debug)]
pub struct GridShift {
    pub layer: String,
    /// per-weight: (w, Δcode)  where Δcode = learned − RTN
    pub points: Vec<(f32, f32)>,
    /// fraction of weights whose |Δcode| ≥ 2 ("aggressively rounded";
    /// the paper reports 12.8% for MobileNetV2's first block conv)
    pub aggressive_frac: f64,
    /// fraction with |Δcode| ≥ 1 (any deviation from RTN)
    pub shifted_frac: f64,
    pub max_shift: f32,
}

/// Weight-update histogram split by |W| (Figure 3 left/center).
#[derive(Clone, Debug)]
pub struct DeltaHist {
    pub edges: Vec<f32>,
    pub small_counts: Vec<usize>, // |W| < 1
    pub large_counts: Vec<usize>, // |W| ≥ 1
    pub n_small: usize,
    pub n_large: usize,
}

/// Compute grid shifts for every layer of a unit after reconstruction.
pub fn grid_shifts(sess: &Session, unit: &UnitInfo, st: &UnitState) -> Result<Vec<GridShift>> {
    let exported = sess.export_qw(unit, st)?;
    let (qmin, qmax) = qrange(st.bits_w, sess.model.symmetric);
    let mut out = Vec::new();
    for (li, layer) in unit.layers.iter().enumerate() {
        let w = sess
            .weights
            .get(&format!("w/{}/{}", unit.name, layer.name))
            .ok_or_else(|| anyhow!("missing weights for {}/{}", unit.name, layer.name))?;
        let (rows, cols) = (layer.rows, layer.cols);
        // RTN codes from the same init scale the method started from
        let (s1, zp) = init_scale(sess, unit, st, &layer.name)?;
        let rtn = rtn_codes_rows(w.as_f32()?, rows, cols, &s1, &zp, qmin, qmax);
        let learned = exported[li].1.to_f32_vec();
        let wv = w.as_f32()?;
        let mut points = Vec::with_capacity(wv.len());
        let mut agg = 0usize;
        let mut shifted = 0usize;
        let mut max_shift = 0.0f32;
        for i in 0..wv.len() {
            let d = learned[i] - rtn[i];
            points.push((wv[i], d));
            if d.abs() >= 2.0 {
                agg += 1;
            }
            if d.abs() >= 1.0 {
                shifted += 1;
            }
            max_shift = max_shift.max(d.abs());
        }
        out.push(GridShift {
            layer: layer.name.clone(),
            aggressive_frac: agg as f64 / wv.len() as f64,
            shifted_frac: shifted as f64 / wv.len() as f64,
            max_shift,
            points,
        });
    }
    Ok(out)
}

/// The init (s1, zp) per row for a layer, broadcasting per-tensor scales.
fn init_scale(sess: &Session, unit: &UnitInfo, st: &UnitState, layer: &str)
              -> Result<(Vec<f32>, Vec<f32>)> {
    let rows = unit
        .layers
        .iter()
        .find(|l| l.name == layer)
        .map(|l| l.rows)
        .ok_or_else(|| anyhow!("no layer {layer}"))?;
    let s1 = sess
        .inits
        .get(&format!("init/{}/{}/b{}/{}.s1", unit.name, st.method, st.bits_w, layer))
        .ok_or_else(|| anyhow!("missing init s1 for {layer}"))?;
    let zp = sess
        .inits
        .get(&format!("init/{}/{}/b{}/{}.zp", unit.name, st.method, st.bits_w, layer))
        .ok_or_else(|| anyhow!("missing init zp for {layer}"))?;
    let bc = |t: &Tensor| -> Result<Vec<f32>> {
        let v = t.as_f32()?;
        Ok(if v.len() == 1 { vec![v[0]; rows] } else { v.to_vec() })
    };
    Ok((bc(s1)?, bc(zp)?))
}

/// Histogram of ΔW = Ŵ − W_rtn split by weight magnitude (Figure 3).
pub fn delta_hist(sess: &Session, unit: &UnitInfo, st: &UnitState, bins: usize)
                  -> Result<DeltaHist> {
    let exported = sess.export_qw(unit, st)?;
    let (qmin, qmax) = qrange(st.bits_w, sess.model.symmetric);
    let mut deltas_small = Vec::new();
    let mut deltas_large = Vec::new();
    for (li, layer) in unit.layers.iter().enumerate() {
        let w = sess
            .weights
            .get(&format!("w/{}/{}", unit.name, layer.name))
            .ok_or_else(|| anyhow!("missing weights"))?;
        let (s1, zp) = init_scale(sess, unit, st, &layer.name)?;
        let wv = w.as_f32()?;
        let what = exported[li].0.as_f32()?;
        for i in 0..wv.len() {
            let row = i / layer.cols;
            let n = ((wv[i] / s1[row]).round() + zp[row]).clamp(qmin, qmax);
            let w_rtn = s1[row] * (n - zp[row]);
            let d = what[i] - w_rtn;
            if wv[i].abs() < 1.0 {
                deltas_small.push(d);
            } else {
                deltas_large.push(d);
            }
        }
    }
    let all: Vec<f32> = deltas_small.iter().chain(&deltas_large).copied().collect();
    let lo = all.iter().copied().fold(0.0f32, f32::min);
    let hi = all.iter().copied().fold(0.0f32, f32::max).max(lo + 1e-6);
    let mut edges = Vec::with_capacity(bins + 1);
    for i in 0..=bins {
        edges.push(lo + (hi - lo) * i as f32 / bins as f32);
    }
    let hist = |d: &[f32]| {
        let mut c = vec![0usize; bins];
        for &x in d {
            let mut b = ((x - lo) / (hi - lo) * bins as f32) as usize;
            if b >= bins {
                b = bins - 1;
            }
            c[b] += 1;
        }
        c
    };
    Ok(DeltaHist {
        edges,
        small_counts: hist(&deltas_small),
        large_counts: hist(&deltas_large),
        n_small: deltas_small.len(),
        n_large: deltas_large.len(),
    })
}

/// Fraction of pre-trained weights with |W| ≥ 1 in a model — the
/// MobileNet-vs-ResNet regime check backing Figure 3's narrative.
pub fn large_weight_fraction(sess: &Session) -> f64 {
    let mut n = 0usize;
    let mut large = 0usize;
    for (k, t) in &sess.weights {
        if !k.starts_with("w/") {
            continue;
        }
        if let Ok(v) = t.as_f32() {
            n += v.len();
            large += v.iter().filter(|x| x.abs() >= 1.0).count();
        }
    }
    if n == 0 {
        0.0
    } else {
        large as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use crate::tensor::{qrange, rtn_codes_rows};
    use crate::util::prop::{gen_weights, Prop};

    #[test]
    fn rtn_codes_in_grid() {
        Prop::new("rtn codes within qrange").cases(100).check(|rng| {
            let rows = 1 + rng.below(6) as usize;
            let cols = 1 + rng.below(20) as usize;
            let w = gen_weights(rng, rows * cols);
            let bits = 2 + rng.below(7);
            let (qmin, qmax) = qrange(bits, true);
            let s1: Vec<f32> = (0..rows).map(|_| 0.01 + rng.next_f32()).collect();
            let zp = vec![0.0; rows];
            for c in rtn_codes_rows(&w, rows, cols, &s1, &zp, qmin, qmax) {
                if c < qmin || c > qmax || (c - c.round()).abs() > 1e-5 {
                    return Err(format!("code {c} outside [{qmin},{qmax}] grid"));
                }
            }
            Ok(())
        });
    }
}
