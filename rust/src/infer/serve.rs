//! Micro-batched serving front end over the [`Engine`].
//!
//! Single-row requests are the worst case for a packed GEMM: every request
//! pays the full packed-word stream for one dot-product row.  The server
//! amortizes it by coalescing: the batcher thread blocks on an empty queue,
//! and once a request arrives it keeps collecting until either
//! [`BatchPolicy::max_batch`] rows are queued or [`BatchPolicy::deadline`]
//! has elapsed since the batch opened — then runs **one** batched fused GEMM
//! and fans the result rows back to their callers.  Latency is bounded by
//! the deadline; throughput approaches the batched-GEMM rate as load rises.
//!
//! Generation sessions ([`Client::generate`]) do **not** run synchronously
//! on the batcher thread (pre-continuous-batching they did, and one long
//! session head-of-line blocked every row request behind it).  Instead the
//! batcher owns a [`Scheduler`]: sessions are enqueued into it on arrival,
//! and the main loop alternates one row batch with **one scheduler step**
//! — every running session advances one token (or one prefill chunk) per
//! step, so row latency stays bounded by the batch deadline plus a single
//! step even while arbitrarily long generations are in flight, and
//! concurrent sessions share each step's fused GEMMs.  The token streams
//! are bit-identical to the solo [`generate::generate`] path (the
//! scheduler's contract, pinned in `rust/tests/sched.rs`).  Models without
//! an lm head fall back to the synchronous path — generation fails fast on
//! them anyway.
//!
//! The pieces:
//!
//! * [`Server::start`] / [`Server::start_with`] — spawn the batcher thread
//!   owning the [`Engine`] (and its [`Scheduler`], sized by [`SchedConfig`]);
//! * [`Client`] — cheap cloneable handle; [`Client::call`] blocks for the
//!   result, [`Client::submit`] returns the response channel for pipelined
//!   callers, [`Client::generate`] blocks for a whole token stream;
//! * [`drive`] — a synchronous load generator (CLI `serve` subcommand and
//!   `benches/infer.rs`): N client threads × M rows, returns wall time and
//!   the server-side [`ServeStats`];
//! * [`drive_mixed`] — the contention load generator: a seeded, reproducible
//!   interleave of single-row requests and generation sessions of varying
//!   prompt/decode lengths, exercising rows racing sessions for the batcher.
//!
//! ## Shutdown contract
//!
//! Every submit and [`Server::shutdown`]'s stop marker go through one
//! mutex-guarded sender, so the `Msg::Shutdown` marker is a true barrier in
//! the queue: **a request whose submit returned `Ok` is guaranteed a real
//! response** — including a batch still being collected when the marker
//! lands, and every generation session already inside the scheduler (the
//! batcher keeps stepping until the scheduler drains before it exits) — and
//! any submit after the marker fails fast with "server is shut down".
//! (Without the gate, a request could race into the queue *behind* the
//! marker and be silently dropped; the regression test below pins this.)
//! Shutdown never blocks on straggler [`Client`] clones.

use super::engine::Engine;
use super::generate::{self, GenOpts};
use crate::obs::{self, Hist, HistSnapshot};
use crate::obs_counter;
use crate::sched::{SchedConfig, Scheduler};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::anyhow;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// When to close a micro-batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// close as soon as this many rows are queued
    pub max_batch: usize,
    /// …or this long after the first row of the batch arrived
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, deadline: Duration::from_millis(2) }
    }
}

/// Server-side counters, returned by [`Server::shutdown`].
///
/// Latency percentiles are nearest-rank estimates off the shared
/// [`obs`] log-bucketed histograms (within one bucket width, ~1.33×, of
/// the exact sorted answer) over every answered request: *wait* is
/// submit → work start (row: its batch's GEMM launch; session: admission
/// into the scheduler), *service* is work start → answer (row: its
/// batch's GEMM; session: scheduler residency, concurrent sessions
/// overlapping).  The same histograms back the live `/metrics` endpoint
/// (`flexround_serve_*_ms`), so scrape-time and shutdown percentiles come
/// from one source of truth.  Occupancy counters come from the scheduler
/// at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// rows answered
    pub requests: u64,
    /// batched GEMM launches
    pub batches: u64,
    /// largest batch coalesced
    pub max_batch: usize,
    /// seconds spent inside the engine forward
    pub gemm_secs: f64,
    /// generation sessions answered
    pub gen_sessions: u64,
    /// tokens emitted across all generation sessions
    pub gen_tokens: u64,
    /// summed per-session residency seconds (sessions overlap, so this can
    /// exceed wall time)
    pub gen_secs: f64,
    /// row queue-wait percentiles, milliseconds
    pub row_wait_p50_ms: f64,
    pub row_wait_p99_ms: f64,
    /// row service-time percentiles, milliseconds
    pub row_service_p50_ms: f64,
    pub row_service_p99_ms: f64,
    /// session queue-wait percentiles, milliseconds
    pub gen_wait_p50_ms: f64,
    pub gen_wait_p99_ms: f64,
    /// session service-time percentiles, milliseconds
    pub gen_service_p50_ms: f64,
    pub gen_service_p99_ms: f64,
    /// scheduler steps executed (each one batched model forward)
    pub sched_steps: u64,
    /// most sessions simultaneously running in the scheduler
    pub peak_sessions: usize,
    /// most KV pool pages simultaneously in use
    pub peak_pages: usize,
    /// sessions evicted (spilled) under pool pressure
    pub evictions: u64,
}

impl ServeStats {
    /// Mean rows per batched launch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Request {
    row: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    /// client-side submit instant (queue-wait measurement)
    t: Instant,
}

struct GenRequest {
    prompt: Vec<f32>,
    opts: GenOpts,
    resp: Sender<Result<Vec<usize>>>,
    t: Instant,
}

/// Queue messages.  `Shutdown` exists because dropping the server's own
/// `Sender` does not disconnect the channel while [`Client`] clones are
/// alive — [`Server::shutdown`] must not block on stragglers.
enum Msg {
    Req(Request),
    Gen(GenRequest),
    Shutdown,
}

/// The submit/shutdown gate: every accepted message is sent while holding
/// this mutex, and shutdown takes the sender out *under the same lock* —
/// which makes the queued `Msg::Shutdown` marker a barrier no accepted
/// request can land behind.
struct Gate {
    tx: Mutex<Option<Sender<Msg>>>,
}

impl Gate {
    fn send(&self, msg: Msg) -> Result<()> {
        let guard = self.tx.lock().map_err(|_| anyhow!("server gate poisoned"))?;
        let Some(tx) = guard.as_ref() else {
            return Err(anyhow!("server is shut down"));
        };
        tx.send(msg).map_err(|_| anyhow!("server is shut down"))
    }
}

/// Handle for submitting rows (and generation sessions) to a running
/// [`Server`].
#[derive(Clone)]
pub struct Client {
    gate: Arc<Gate>,
    width: usize,
    tok_width: usize,
}

impl Client {
    /// Enqueue one activation row; the returned channel yields its output
    /// row once the batch it lands in has run.  An `Ok` here is a promise:
    /// the row *will* be answered, even if the server shuts down right
    /// after.
    pub fn submit(&self, row: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        if row.len() != self.width {
            return Err(anyhow!(
                "request row has {} values, the served model takes {}",
                row.len(),
                self.width
            ));
        }
        let (tx, rx) = channel();
        self.gate.send(Msg::Req(Request { row, resp: tx, t: Instant::now() }))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, row: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(row)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request (shutting down?)"))?
    }

    /// Submit a whole generation session: `prompt` is `t ≥ 1` flattened
    /// token rows (`t · tok_width` values).  Blocks until the sampled token
    /// ids come back.  The session runs inside the batcher's scheduler,
    /// interleaved step-by-step with row batches and other sessions —
    /// concurrent callers share each step's fused GEMMs, and the token
    /// stream is bit-identical to running [`generate::generate`] alone.
    /// The server caps `max_new` at [`MAX_GEN_TOKENS`] and rejects longer
    /// prompts so one session cannot exhaust the pool or stall
    /// [`Server::shutdown`] indefinitely.
    pub fn generate(&self, prompt: Vec<f32>, opts: GenOpts) -> Result<Vec<usize>> {
        if prompt.is_empty() || prompt.len() % self.tok_width != 0 {
            return Err(anyhow!(
                "generation prompt has {} values, need a nonzero multiple of the \
                 token width {}",
                prompt.len(),
                self.tok_width
            ));
        }
        if prompt.len() / self.tok_width > MAX_GEN_TOKENS {
            return Err(anyhow!(
                "generation prompt has {} rows, the server accepts at most {MAX_GEN_TOKENS}",
                prompt.len() / self.tok_width
            ));
        }
        let (tx, rx) = channel();
        self.gate.send(Msg::Gen(GenRequest { prompt, opts, resp: tx, t: Instant::now() }))?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped the generation session (shutting down?)"))?
    }
}

/// A running micro-batch server (one batcher thread owning the engine).
pub struct Server {
    gate: Arc<Gate>,
    width: usize,
    tok_width: usize,
    handle: std::thread::JoinHandle<ServeStats>,
}

impl Server {
    /// Spawn the batcher thread with default scheduler sizing.  Fails on an
    /// empty model (no input width).
    pub fn start(engine: Engine, policy: BatchPolicy) -> Result<Server> {
        Server::start_with(engine, policy, SchedConfig::default())
    }

    /// Spawn the batcher thread with explicit scheduler sizing (pool pages,
    /// page size, active-session bound, prefill chunk, spill dir).
    pub fn start_with(engine: Engine, policy: BatchPolicy, cfg: SchedConfig) -> Result<Server> {
        let width = engine.in_width()?;
        let tok_width = engine.model().in_width().unwrap_or(width).max(1);
        let max_batch = policy.max_batch.max(1);
        let (tx, rx) = channel::<Msg>();
        let handle =
            std::thread::spawn(move || run_batcher(engine, rx, max_batch, policy.deadline, cfg));
        Ok(Server { gate: Arc::new(Gate { tx: Mutex::new(Some(tx)) }), width, tok_width, handle })
    }

    pub fn client(&self) -> Client {
        Client { gate: Arc::clone(&self.gate), width: self.width, tok_width: self.tok_width }
    }

    /// Stop the batcher and join it.  The gate closes and the stop marker is
    /// queued under one lock, so shutdown is a clean barrier: every request
    /// accepted before it gets a real response (a batch still being
    /// collected when the marker lands is executed and answered, and the
    /// scheduler is stepped until every in-flight session completes), and
    /// every submit after it fails with "server is shut down".  Never blocks
    /// on straggler [`Client`] clones.
    pub fn shutdown(self) -> Result<ServeStats> {
        let Server { gate, width: _, tok_width: _, handle } = self;
        {
            let mut guard = gate.tx.lock().map_err(|_| anyhow!("server gate poisoned"))?;
            if let Some(tx) = guard.take() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        handle.join().map_err(|_| anyhow!("serve batcher thread panicked"))
    }
}

/// The batcher's compute core: a scheduler when the model can generate
/// (lm head present), the bare engine otherwise.
enum Core {
    Sched(Box<Scheduler>),
    Plain(Engine),
}

impl Core {
    fn engine(&self) -> &Engine {
        match self {
            Core::Sched(s) => s.engine(),
            Core::Plain(e) => e,
        }
    }

    fn busy(&self) -> bool {
        matches!(self, Core::Sched(s) if s.has_work())
    }
}

/// An in-flight generation session: scheduler handle → response channel,
/// with its admission instant for the service-time sample.
struct PendingGen {
    handle: u64,
    resp: Sender<Result<Vec<usize>>>,
    admitted: Instant,
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// The batcher's latency histograms: handles into the process-wide
/// [`obs`] registry (`flexround_serve_*_ms`), so a scraper on the
/// `/metrics` endpoint sees wait/service distributions live, plus a
/// baseline snapshot of each taken at batcher start.  [`ServeStats`]
/// percentiles are computed over the snapshot *delta*, so sequential
/// runs in one process (`serve --compare`, parallel tests) report their
/// own window rather than everything since process start.
struct LatHists {
    row_wait: Arc<Hist>,
    row_service: Arc<Hist>,
    gen_wait: Arc<Hist>,
    gen_service: Arc<Hist>,
    base: [HistSnapshot; 4],
}

impl LatHists {
    fn new() -> LatHists {
        let row_wait = obs::histogram("flexround_serve_row_wait_ms");
        let row_service = obs::histogram("flexround_serve_row_service_ms");
        let gen_wait = obs::histogram("flexround_serve_gen_wait_ms");
        let gen_service = obs::histogram("flexround_serve_gen_service_ms");
        let base = [
            row_wait.snapshot(),
            row_service.snapshot(),
            gen_wait.snapshot(),
            gen_service.snapshot(),
        ];
        LatHists { row_wait, row_service, gen_wait, gen_service, base }
    }

    fn fold_into(self, stats: &mut ServeStats) {
        let q = |h: &Hist, base: &HistSnapshot, p: f64| h.snapshot().delta(base).quantile(p);
        stats.row_wait_p50_ms = q(&self.row_wait, &self.base[0], 50.0);
        stats.row_wait_p99_ms = q(&self.row_wait, &self.base[0], 99.0);
        stats.row_service_p50_ms = q(&self.row_service, &self.base[1], 50.0);
        stats.row_service_p99_ms = q(&self.row_service, &self.base[1], 99.0);
        stats.gen_wait_p50_ms = q(&self.gen_wait, &self.base[2], 50.0);
        stats.gen_wait_p99_ms = q(&self.gen_wait, &self.base[2], 99.0);
        stats.gen_service_p50_ms = q(&self.gen_service, &self.base[3], 50.0);
        stats.gen_service_p99_ms = q(&self.gen_service, &self.base[3], 99.0);
    }
}

/// Route one queue message: rows open/extend the current batch, sessions
/// go straight into the scheduler (or run synchronously on the no-head
/// fallback path), the shutdown marker closes intake.
#[allow(clippy::too_many_arguments)]
fn ingest(
    msg: Msg,
    batch: &mut Vec<Request>,
    opened: &mut Option<Instant>,
    core: &mut Core,
    pending: &mut Vec<PendingGen>,
    stats: &mut ServeStats,
    lat: &LatHists,
    open: &mut bool,
) {
    match msg {
        Msg::Req(r) => {
            if batch.is_empty() {
                *opened = Some(Instant::now());
            }
            batch.push(r);
        }
        Msg::Gen(g) => match core {
            Core::Sched(s) => {
                let GenRequest { prompt, mut opts, resp, t } = g;
                opts.max_new = opts.max_new.min(MAX_GEN_TOKENS);
                let rows = prompt.len() / s.engine().model().in_width().unwrap_or(1).max(1);
                if rows > MAX_GEN_TOKENS {
                    // belt-and-braces twin of the Client-side check, so the
                    // invariant holds even if a future producer skips
                    // Client::generate
                    let _ = resp.send(Err(anyhow!(
                        "generation prompt has {rows} rows, the server accepts at most \
                         {MAX_GEN_TOKENS}"
                    )));
                    return;
                }
                match s.submit(prompt, opts) {
                    Ok(handle) => {
                        lat.gen_wait.record(ms(t.elapsed()));
                        pending.push(PendingGen { handle, resp, admitted: Instant::now() });
                    }
                    Err(e) => {
                        let _ = resp.send(Err(anyhow!("generation session rejected: {e:#}")));
                    }
                }
            }
            Core::Plain(e) => {
                lat.gen_wait.record(ms(g.t.elapsed()));
                run_gen(e, g, stats, &lat.gen_service);
            }
        },
        Msg::Shutdown => *open = false,
    }
}

fn run_batcher(
    engine: Engine,
    rx: Receiver<Msg>,
    max_batch: usize,
    deadline: Duration,
    cfg: SchedConfig,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let lat = LatHists::new();
    let queue_depth = obs::gauge("flexround_serve_queue_depth");
    let batch_rows = obs::histogram("flexround_serve_batch_rows");
    let mut core = match Scheduler::supported(engine.model()) {
        Ok(()) => Core::Sched(Box::new(
            Scheduler::new(engine, cfg).expect("scheduler construction was pre-validated"),
        )),
        Err(_) => Core::Plain(engine),
    };
    let mut pending: Vec<PendingGen> = Vec::new();
    let mut open = true;
    // after the shutdown marker the loop keeps running until the scheduler
    // drains — every accepted session gets its real answer
    while open || core.busy() || !pending.is_empty() {
        let mut batch: Vec<Request> = Vec::new();
        let mut opened: Option<Instant> = None;
        // idle (no scheduler work): block until something arrives
        if open && !core.busy() {
            match rx.recv() {
                Ok(m) => {
                    ingest(m, &mut batch, &mut opened, &mut core, &mut pending, &mut stats, &lat, &mut open)
                }
                Err(_) => open = false,
            }
        }
        // coalesce: wait out the deadline while idle, but only drain what is
        // already queued while the scheduler has sessions to step — a full
        // deadline sleep per token would serialize decode behind the clock
        while open && batch.len() < max_batch {
            if core.busy() {
                match rx.try_recv() {
                    Ok(m) => ingest(m, &mut batch, &mut opened, &mut core, &mut pending, &mut stats, &lat, &mut open),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => open = false,
                }
            } else {
                let Some(t0) = opened else { break };
                let Some(left) = deadline.checked_sub(t0.elapsed()) else { break };
                match rx.recv_timeout(left) {
                    Ok(m) => ingest(m, &mut batch, &mut opened, &mut core, &mut pending, &mut stats, &lat, &mut open),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => open = false,
                }
            }
        }
        // the collected row batch: one fused GEMM, fan the rows back out
        if !batch.is_empty() {
            let _span = obs::span("serve/batch");
            let n = batch.len();
            queue_depth.set(n as i64);
            let width = batch[0].row.len();
            let mut flat = Vec::with_capacity(n * width);
            for r in &batch {
                flat.extend_from_slice(&r.row);
            }
            let t0 = Instant::now();
            for r in &batch {
                lat.row_wait.record(ms(r.t.elapsed()));
            }
            let result =
                Tensor::from_f32(flat, &[n, width]).and_then(|x| core.engine().forward(&x));
            let dt = t0.elapsed();
            stats.gemm_secs += dt.as_secs_f64();
            stats.batches += 1;
            stats.requests += n as u64;
            stats.max_batch = stats.max_batch.max(n);
            obs_counter!("flexround_serve_batches_total").inc();
            obs_counter!("flexround_serve_requests_total").add(n as u64);
            batch_rows.record(n as f64);
            for _ in 0..n {
                lat.row_service.record(ms(dt));
            }
            queue_depth.set(0);
            match result {
                Ok(y) => {
                    let out_w = y.shape()[1];
                    let yv = y.as_f32().expect("engine output is f32");
                    for (i, r) in batch.into_iter().enumerate() {
                        let _ = r.resp.send(Ok(yv[i * out_w..(i + 1) * out_w].to_vec()));
                    }
                }
                Err(e) => {
                    for r in batch {
                        let _ = r.resp.send(Err(anyhow!("batched forward failed: {e:#}")));
                    }
                }
            }
        }
        // one scheduler step: every running session advances one chunk/token
        if let Core::Sched(s) = &mut core {
            if s.has_work() {
                match s.step() {
                    Ok(_) => {
                        for fin in s.take_finished() {
                            let Some(pos) = pending.iter().position(|p| p.handle == fin.handle)
                            else {
                                continue;
                            };
                            let p = pending.swap_remove(pos);
                            let dt = p.admitted.elapsed();
                            lat.gen_service.record(ms(dt));
                            stats.gen_secs += dt.as_secs_f64();
                            stats.gen_sessions += 1;
                            stats.gen_tokens += fin.tokens.len() as u64;
                            obs_counter!("flexround_serve_gen_sessions_total").inc();
                            obs_counter!("flexround_serve_gen_tokens_total")
                                .add(fin.tokens.len() as u64);
                            let _ = p.resp.send(Ok(fin.tokens));
                        }
                    }
                    Err(e) => {
                        // a failed step poisons every in-flight session: give
                        // each its real error instead of a hang
                        s.abort_all();
                        for p in pending.drain(..) {
                            let _ = p
                                .resp
                                .send(Err(anyhow!("scheduled generation failed: {e:#}")));
                        }
                    }
                }
            }
        }
    }
    if let Core::Sched(s) = &core {
        stats.sched_steps = s.steps();
        let (peak_sessions, peak_pages) = s.occupancy_peaks();
        stats.peak_sessions = peak_sessions;
        stats.peak_pages = peak_pages;
        stats.evictions = s.evictions();
    }
    lat.fold_into(&mut stats);
    stats
}

/// Server-side ceiling on tokens per generation session — applied to both
/// `max_new` (clamped) and the prompt length (rejected): both are
/// client-supplied, and an uncapped request could exhaust the KV pool's
/// admission bound (or, on the no-head fallback path, pin the batcher) and
/// keep [`Server::shutdown`] joining forever.
pub const MAX_GEN_TOKENS: usize = 4096;

/// Fallback for models the scheduler does not support (no lm head): run the
/// session synchronously on the batcher thread and answer it.  Generation
/// on such models fails fast inside [`generate::generate`], so this path
/// never holds the thread for long.
fn run_gen(engine: &Engine, g: GenRequest, stats: &mut ServeStats, service: &Hist) {
    let GenRequest { prompt, mut opts, resp, t: _ } = g;
    opts.max_new = opts.max_new.min(MAX_GEN_TOKENS);
    let d = engine.model().in_width().unwrap_or(1).max(1);
    let rows = prompt.len() / d;
    if rows > MAX_GEN_TOKENS {
        let _ = resp.send(Err(anyhow!(
            "generation prompt has {rows} rows, the server accepts at most {MAX_GEN_TOKENS}"
        )));
        return;
    }
    let t0 = Instant::now();
    let result = Tensor::from_f32(prompt, &[rows, d])
        .and_then(|x| generate::generate(engine, &x, &opts));
    let dt = t0.elapsed();
    stats.gen_secs += dt.as_secs_f64();
    service.record(ms(dt));
    stats.gen_sessions += 1;
    obs_counter!("flexround_serve_gen_sessions_total").inc();
    match result {
        Ok(gen) => {
            stats.gen_tokens += gen.tokens.len() as u64;
            obs_counter!("flexround_serve_gen_tokens_total").add(gen.tokens.len() as u64);
            let _ = resp.send(Ok(gen.tokens));
        }
        Err(e) => {
            let _ = resp.send(Err(anyhow!("generation session failed: {e:#}")));
        }
    }
}

/// Synchronous load generator: split `rows` across `clients` threads, each
/// blocking on [`Client::call`] per row.  Returns `(wall_seconds, stats)`;
/// errors if any request failed.
pub fn drive(
    engine: Engine,
    policy: BatchPolicy,
    rows: Vec<Vec<f32>>,
    clients: usize,
) -> Result<(f64, ServeStats)> {
    let n = rows.len();
    if n == 0 {
        return Err(anyhow!("drive: no request rows"));
    }
    let server = Server::start(engine, policy)?;
    let clients = clients.clamp(1, n);
    let chunk = n.div_ceil(clients);
    let t0 = Instant::now();
    let failures: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for slice in rows.chunks(chunk) {
            let client = server.client();
            handles.push(s.spawn(move || {
                slice.iter().filter(|r| client.call((*r).clone()).is_err()).count()
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    if failures > 0 {
        return Err(anyhow!("drive: {failures}/{n} requests failed"));
    }
    Ok((secs, stats))
}

/// One operation of the [`drive_mixed`] workload.
enum MixedOp {
    Row(Vec<f32>),
    Gen { prompt: Vec<f32>, opts: GenOpts },
}

/// Seeded mixed load generator: `n_rows` single-row requests interleaved
/// with `n_gens` generation sessions of varying prompt/decode lengths and
/// sampling settings, shuffled deterministically via [`Pcg32`] and split
/// across `clients` threads — the scheduler under realistic contention,
/// reproducibly.  Requires a generation-complete model when `n_gens > 0`.
/// Returns `(wall_seconds, stats)`; errors if any request failed.
///
/// [`Pcg32`]: crate::util::rng::Pcg32
pub fn drive_mixed(
    engine: Engine,
    policy: BatchPolicy,
    cfg: SchedConfig,
    n_rows: usize,
    n_gens: usize,
    clients: usize,
    seed: u64,
) -> Result<(f64, ServeStats)> {
    use crate::util::rng::Pcg32;
    if n_rows + n_gens == 0 {
        return Err(anyhow!("drive_mixed: no work (n_rows + n_gens == 0)"));
    }
    if n_gens > 0 {
        Scheduler::supported(engine.model())
            .map_err(|e| anyhow!("drive_mixed: model cannot serve generation sessions: {e:#}"))?;
    }
    let width = engine.in_width()?;
    let mut rng = Pcg32::seeded(seed);
    let mut ops: Vec<MixedOp> = Vec::with_capacity(n_rows + n_gens);
    {
        let mut row_rng = rng.fork(1);
        for _ in 0..n_rows {
            ops.push(MixedOp::Row((0..width).map(|_| row_rng.next_normal()).collect()));
        }
    }
    for gi in 0..n_gens {
        let prompt_len = 1 + rng.below(8) as usize;
        let (_, prompt) = generate::random_prompt(engine.model(), prompt_len, seed ^ gi as u64)?;
        let opts = GenOpts {
            max_new: 1 + rng.below(24) as usize,
            temp: [0.0, 0.7, 1.0][rng.below(3) as usize],
            top_k: [0usize, 4, 8][rng.below(3) as usize],
            seed: seed.wrapping_add(0x5851_F42D).wrapping_mul(1 + gi as u64),
        };
        ops.push(MixedOp::Gen { prompt: prompt.as_f32()?.to_vec(), opts });
    }
    // Fisher–Yates: the interleave (and thus the contention pattern) is a
    // pure function of `seed`
    for i in (1..ops.len()).rev() {
        let j = rng.below(i as u32 + 1) as usize;
        ops.swap(i, j);
    }
    let n = ops.len();
    let server = Server::start_with(engine, policy, cfg)?;
    let clients = clients.clamp(1, n);
    let chunk = n.div_ceil(clients);
    let t0 = Instant::now();
    let chunks: Vec<Vec<MixedOp>> = {
        let mut it = ops.into_iter();
        (0..n.div_ceil(chunk)).map(|_| it.by_ref().take(chunk).collect()).collect()
    };
    let failures: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for slice in chunks {
            let client = server.client();
            handles.push(s.spawn(move || {
                slice
                    .into_iter()
                    .filter(|op| match op {
                        MixedOp::Row(r) => client.call(r.clone()).is_err(),
                        MixedOp::Gen { prompt, opts } => {
                            client.generate(prompt.clone(), *opts).is_err()
                        }
                    })
                    .count()
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    if failures > 0 {
        return Err(anyhow!("drive_mixed: {failures}/{n} requests failed"));
    }
    Ok((secs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::engine::synthetic_model;
    use crate::util::rng::Pcg32;

    fn engine() -> Engine {
        Engine::new(synthetic_model(2, 16, 4, 3).unwrap(), 1)
    }

    fn rows(n: usize, width: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..width).map(|_| rng.next_normal()).collect()).collect()
    }

    #[test]
    fn responses_match_direct_forward() {
        let reference = engine();
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        for row in rows(6, 16, 1) {
            let got = client.call(row.clone()).unwrap();
            let want = reference.forward_row(&row).unwrap();
            assert_eq!(got, want, "served row must equal the direct forward");
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches <= 6 && stats.batches >= 1);
    }

    #[test]
    fn queued_requests_coalesce_into_one_batch() {
        // All 8 rows are submitted (non-blocking) before any response is
        // read; the generous deadline means the batcher sees them all within
        // one window and runs a single GEMM.
        let server = Server::start(
            engine(),
            BatchPolicy { max_batch: 8, deadline: Duration::from_secs(5) },
        )
        .unwrap();
        let client = server.client();
        let pending: Vec<_> =
            rows(8, 16, 2).into_iter().map(|r| client.submit(r).unwrap()).collect();
        for rx in pending {
            assert_eq!(rx.recv().unwrap().unwrap().len(), 16);
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.batches, 1, "pre-queued rows must coalesce");
        assert_eq!(stats.max_batch, 8);
    }

    #[test]
    fn unbatched_policy_runs_one_gemm_per_request() {
        let policy = BatchPolicy { max_batch: 1, deadline: Duration::from_millis(1) };
        let (_, stats) = drive(engine(), policy, rows(10, 16, 4), 2).unwrap();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.max_batch, 1);
    }

    #[test]
    fn wrong_width_is_rejected_before_queueing() {
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        assert!(client.call(vec![0.0; 3]).is_err());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn drive_reports_throughput() {
        let policy = BatchPolicy { max_batch: 16, deadline: Duration::from_millis(1) };
        let (secs, stats) = drive(engine(), policy, rows(64, 16, 5), 4).unwrap();
        assert!(secs > 0.0);
        assert_eq!(stats.requests, 64);
        assert!(stats.mean_batch() >= 1.0);
        // percentiles come back populated and ordered
        assert!(stats.row_wait_p99_ms >= stats.row_wait_p50_ms);
        assert!(stats.row_service_p99_ms >= stats.row_service_p50_ms);
        assert!(stats.row_service_p50_ms > 0.0);
    }

    #[test]
    fn shutdown_is_a_barrier_every_accepted_request_is_answered() {
        // Regression (PR 4 shutdown race): a submit that returns Ok must
        // receive a *real* response even when it races Server::shutdown.
        // Pre-fix, a request could land in the queue behind the Shutdown
        // marker and be silently dropped — its caller saw a disconnect
        // instead of a result.  Many rounds with varied timing so the race
        // window is actually explored.
        for round in 0..25u64 {
            let server = Server::start(
                engine(),
                BatchPolicy { max_batch: 3, deadline: Duration::from_micros(200) },
            )
            .unwrap();
            let client = server.client();
            let row = rows(1, 16, round).remove(0);
            let submitter = std::thread::spawn(move || {
                let mut accepted = Vec::new();
                loop {
                    match client.submit(row.clone()) {
                        Ok(rx) => accepted.push(rx),
                        Err(_) => break,
                    }
                }
                accepted
            });
            // let some submits land before (and while) the shutdown races in
            std::thread::sleep(Duration::from_micros(60 + 137 * (round % 7)));
            let stats = server.shutdown().unwrap();
            let accepted = submitter.join().unwrap();
            for (i, rx) in accepted.iter().enumerate() {
                let resp = rx.recv().unwrap_or_else(|_| {
                    panic!(
                        "round {round}: accepted request {i}/{} was dropped on shutdown",
                        accepted.len()
                    )
                });
                assert!(resp.is_ok(), "round {round}: accepted request {i} got {resp:?}");
            }
            assert_eq!(
                stats.requests as usize,
                accepted.len(),
                "round {round}: server answered a different number of rows than it accepted"
            );
        }
    }

    #[test]
    fn generation_sessions_run_alongside_row_batching() {
        use crate::infer::generate::{self, GenOpts};
        let model = generate::synthetic_lm(2, 8, 2, 16, 4, 12, 4, 5).unwrap();
        let reference = Engine::new(model.clone(), 1);
        let opts = GenOpts { max_new: 6, temp: 0.7, top_k: 4, seed: 11 };
        let (_, prompt) = generate::random_prompt(reference.model(), 3, 9).unwrap();
        let want = generate::generate(&reference, &prompt, &opts).unwrap().tokens;

        let server = Server::start(Engine::new(model, 1), BatchPolicy::default()).unwrap();
        let client = server.client();
        // a generation session and a plain row request share the queue
        let got = client.generate(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        assert_eq!(got, want, "served generation must equal the direct decode loop");
        let row_out = client.call(vec![0.0; 4 * 8]).unwrap();
        assert_eq!(row_out.len(), 4 * 12, "row serving still works on an LM model");
        // bad prompts are rejected before queueing; bad sessions answer with
        // an error instead of hanging
        assert!(client.generate(vec![0.0; 3], opts).is_err());
        // over-long prompts are refused (pool-exhaustion/shutdown-stall guard)
        assert!(client.generate(vec![0.0; (MAX_GEN_TOKENS + 1) * 8], opts).is_err());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.gen_sessions, 1);
        assert_eq!(stats.gen_tokens as usize, want.len());
        assert_eq!(stats.requests, 1);
        assert!(stats.gen_secs >= 0.0);
        assert!(stats.sched_steps >= want.len() as u64, "one step per emitted token at least");
        assert_eq!(stats.peak_sessions, 1);
        assert!(stats.peak_pages >= 1);
    }

    #[test]
    fn long_generation_does_not_head_of_line_block_rows() {
        // Regression (PR 7): a generation session used to run to completion
        // on the batcher thread, so a queued row request waited out the
        // whole session instead of the batch deadline.  Now sessions advance
        // one scheduler step at a time: a row submitted mid-generation must
        // come back while the session is still in flight.
        use crate::infer::generate::{self, GenOpts};
        use std::sync::atomic::{AtomicBool, Ordering};
        let model = generate::synthetic_lm(2, 16, 4, 32, 4, 24, 4, 5).unwrap();
        let (_, prompt) = generate::random_prompt(&model, 3, 7).unwrap();
        let server = Server::start(
            Engine::new(model, 1),
            BatchPolicy { max_batch: 4, deadline: Duration::from_micros(200) },
        )
        .unwrap();
        // thousands of decode steps: plenty of runway for the row below
        let opts = GenOpts { max_new: MAX_GEN_TOKENS, temp: 0.9, top_k: 8, seed: 3 };
        let done = Arc::new(AtomicBool::new(false));
        let gen_client = server.client();
        let gen_done = Arc::clone(&done);
        let gen_prompt = prompt.as_f32().unwrap().to_vec();
        let gen_thread = std::thread::spawn(move || {
            let out = gen_client.generate(gen_prompt, opts);
            gen_done.store(true, Ordering::SeqCst);
            out
        });
        // give the session a moment to land in the scheduler
        std::thread::sleep(Duration::from_micros(500));
        let client = server.client();
        let row_out = client.call(vec![0.25; 4 * 16]).unwrap();
        assert_eq!(row_out.len(), 4 * 24);
        assert!(
            !done.load(Ordering::SeqCst),
            "row request waited out the whole generation session (head-of-line blocking)"
        );
        let tokens = gen_thread.join().unwrap().unwrap();
        assert_eq!(tokens.len(), MAX_GEN_TOKENS);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.gen_sessions, 1);
        assert!(stats.sched_steps as usize >= MAX_GEN_TOKENS);
    }

    #[test]
    fn drive_mixed_reports_contention_stats() {
        use crate::infer::generate;
        let model = generate::synthetic_lm(2, 8, 2, 16, 4, 12, 4, 5).unwrap();
        let policy = BatchPolicy { max_batch: 8, deadline: Duration::from_micros(500) };
        let (secs, stats) = drive_mixed(
            Engine::new(model, 1),
            policy,
            SchedConfig::default(),
            24,
            6,
            4,
            42,
        )
        .unwrap();
        assert!(secs > 0.0);
        assert_eq!(stats.requests, 24);
        assert_eq!(stats.gen_sessions, 6);
        assert!(stats.gen_tokens >= 6, "every session emits at least one token");
        assert!(stats.sched_steps >= 1);
        assert!(stats.peak_sessions >= 1);
        assert!(stats.gen_service_p99_ms >= stats.gen_service_p50_ms);
        // rows must not error against a generating scheduler
        // (drive_mixed already failed the whole run if any did)
    }

    #[test]
    fn drive_mixed_is_seed_reproducible_in_shape() {
        use crate::infer::generate;
        let mk = || Engine::new(generate::synthetic_lm(2, 8, 2, 16, 4, 12, 4, 5).unwrap(), 1);
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_micros(200) };
        let (_, a) = drive_mixed(mk(), policy, SchedConfig::default(), 10, 4, 2, 7).unwrap();
        let (_, b) = drive_mixed(mk(), policy, SchedConfig::default(), 10, 4, 2, 7).unwrap();
        // same seed ⇒ same workload ⇒ same token volume (timing may differ)
        assert_eq!(a.gen_tokens, b.gen_tokens, "seeded workload must be reproducible");
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.gen_sessions, b.gen_sessions);
        // rows-only workloads reject gens cleanly on headless models
        let headless = Engine::new(synthetic_model(2, 16, 4, 3).unwrap(), 1);
        assert!(drive_mixed(headless, policy, SchedConfig::default(), 0, 2, 1, 1).is_err());
    }
}
