//! Micro-batched serving front end over the [`Engine`].
//!
//! Single-row requests are the worst case for a packed GEMM: every request
//! pays the full packed-word stream for one dot-product row.  The server
//! amortizes it by coalescing: the batcher thread blocks on an empty queue,
//! and once a request arrives it keeps collecting until either
//! [`BatchPolicy::max_batch`] rows are queued or [`BatchPolicy::deadline`]
//! has elapsed since the batch opened — then runs **one** batched fused GEMM
//! and fans the result rows back to their callers.  Latency is bounded by
//! the deadline; throughput approaches the batched-GEMM rate as load rises.
//!
//! The pieces:
//!
//! * [`Server::start`] — spawns the batcher thread owning the [`Engine`];
//! * [`Client`] — cheap cloneable handle; [`Client::call`] blocks for the
//!   result, [`Client::submit`] returns the response channel for pipelined
//!   callers;
//! * [`drive`] — a synchronous load generator (CLI `serve` subcommand and
//!   `benches/infer.rs`): N client threads × M rows, returns wall time and
//!   the server-side [`ServeStats`].

use super::engine::Engine;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::anyhow;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// When to close a micro-batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// close as soon as this many rows are queued
    pub max_batch: usize,
    /// …or this long after the first row of the batch arrived
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, deadline: Duration::from_millis(2) }
    }
}

/// Server-side counters, returned by [`Server::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// rows answered
    pub requests: u64,
    /// batched GEMM launches
    pub batches: u64,
    /// largest batch coalesced
    pub max_batch: usize,
    /// seconds spent inside the engine forward
    pub gemm_secs: f64,
}

impl ServeStats {
    /// Mean rows per batched launch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Request {
    row: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
}

/// Queue messages.  `Shutdown` exists because dropping the server's own
/// `Sender` does not disconnect the channel while [`Client`] clones are
/// alive — [`Server::shutdown`] must not block on stragglers.
enum Msg {
    Req(Request),
    Shutdown,
}

/// Handle for submitting rows to a running [`Server`].
#[derive(Clone)]
pub struct Client {
    tx: Sender<Msg>,
    width: usize,
}

impl Client {
    /// Enqueue one activation row; the returned channel yields its output
    /// row once the batch it lands in has run.
    pub fn submit(&self, row: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        if row.len() != self.width {
            return Err(anyhow!(
                "request row has {} values, the served model takes {}",
                row.len(),
                self.width
            ));
        }
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Req(Request { row, resp: tx }))
            .map_err(|_| anyhow!("server is shut down"))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, row: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(row)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request (shutting down?)"))?
    }
}

/// A running micro-batch server (one batcher thread owning the engine).
pub struct Server {
    tx: Sender<Msg>,
    width: usize,
    handle: std::thread::JoinHandle<ServeStats>,
}

impl Server {
    /// Spawn the batcher thread.  Fails on an empty model (no input width).
    pub fn start(engine: Engine, policy: BatchPolicy) -> Result<Server> {
        let width = engine.in_width()?;
        let max_batch = policy.max_batch.max(1);
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || run_batcher(engine, rx, max_batch, policy.deadline));
        Ok(Server { tx, width, handle })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone(), width: self.width }
    }

    /// Stop the batcher and join it.  Requests already queued ahead of the
    /// stop marker are answered first; rows arriving after it (racing
    /// clients) get a "server dropped the request" error on their response
    /// channel, and later submits fail with "server is shut down".  Never
    /// blocks on straggler [`Client`] clones.
    pub fn shutdown(self) -> Result<ServeStats> {
        let Server { tx, width: _, handle } = self;
        let _ = tx.send(Msg::Shutdown);
        drop(tx);
        handle.join().map_err(|_| anyhow!("serve batcher thread panicked"))
    }
}

fn run_batcher(
    engine: Engine,
    rx: Receiver<Msg>,
    max_batch: usize,
    deadline: Duration,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let mut open = true;
    while open {
        // block until a batch opens
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let opened = Instant::now();
        let mut batch = vec![first];
        while batch.len() < max_batch {
            let Some(left) = deadline.checked_sub(opened.elapsed()) else { break };
            match rx.recv_timeout(left) {
                Ok(Msg::Req(r)) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let n = batch.len();
        let width = batch[0].row.len();
        let mut flat = Vec::with_capacity(n * width);
        for r in &batch {
            flat.extend_from_slice(&r.row);
        }
        let t0 = Instant::now();
        let result = Tensor::from_f32(flat, &[n, width]).and_then(|x| engine.forward(&x));
        stats.gemm_secs += t0.elapsed().as_secs_f64();
        stats.batches += 1;
        stats.requests += n as u64;
        stats.max_batch = stats.max_batch.max(n);
        match result {
            Ok(y) => {
                let out_w = y.shape()[1];
                let yv = y.as_f32().expect("engine output is f32");
                for (i, r) in batch.into_iter().enumerate() {
                    let _ = r.resp.send(Ok(yv[i * out_w..(i + 1) * out_w].to_vec()));
                }
            }
            Err(e) => {
                for r in batch {
                    let _ = r.resp.send(Err(anyhow!("batched forward failed: {e:#}")));
                }
            }
        }
    }
    stats
}

/// Synchronous load generator: split `rows` across `clients` threads, each
/// blocking on [`Client::call`] per row.  Returns `(wall_seconds, stats)`;
/// errors if any request failed.
pub fn drive(
    engine: Engine,
    policy: BatchPolicy,
    rows: Vec<Vec<f32>>,
    clients: usize,
) -> Result<(f64, ServeStats)> {
    let n = rows.len();
    if n == 0 {
        return Err(anyhow!("drive: no request rows"));
    }
    let server = Server::start(engine, policy)?;
    let clients = clients.clamp(1, n);
    let chunk = n.div_ceil(clients);
    let t0 = Instant::now();
    let failures: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for slice in rows.chunks(chunk) {
            let client = server.client();
            handles.push(s.spawn(move || {
                slice.iter().filter(|r| client.call((*r).clone()).is_err()).count()
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    if failures > 0 {
        return Err(anyhow!("drive: {failures}/{n} requests failed"));
    }
    Ok((secs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::engine::synthetic_model;
    use crate::util::rng::Pcg32;

    fn engine() -> Engine {
        Engine::new(synthetic_model(2, 16, 4, 3).unwrap(), 1)
    }

    fn rows(n: usize, width: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..width).map(|_| rng.next_normal()).collect()).collect()
    }

    #[test]
    fn responses_match_direct_forward() {
        let reference = engine();
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        for row in rows(6, 16, 1) {
            let got = client.call(row.clone()).unwrap();
            let want = reference.forward_row(&row).unwrap();
            assert_eq!(got, want, "served row must equal the direct forward");
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches <= 6 && stats.batches >= 1);
    }

    #[test]
    fn queued_requests_coalesce_into_one_batch() {
        // All 8 rows are submitted (non-blocking) before any response is
        // read; the generous deadline means the batcher sees them all within
        // one window and runs a single GEMM.
        let server = Server::start(
            engine(),
            BatchPolicy { max_batch: 8, deadline: Duration::from_secs(5) },
        )
        .unwrap();
        let client = server.client();
        let pending: Vec<_> =
            rows(8, 16, 2).into_iter().map(|r| client.submit(r).unwrap()).collect();
        for rx in pending {
            assert_eq!(rx.recv().unwrap().unwrap().len(), 16);
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.batches, 1, "pre-queued rows must coalesce");
        assert_eq!(stats.max_batch, 8);
    }

    #[test]
    fn unbatched_policy_runs_one_gemm_per_request() {
        let policy = BatchPolicy { max_batch: 1, deadline: Duration::from_millis(1) };
        let (_, stats) = drive(engine(), policy, rows(10, 16, 4), 2).unwrap();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.max_batch, 1);
    }

    #[test]
    fn wrong_width_is_rejected_before_queueing() {
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        assert!(client.call(vec![0.0; 3]).is_err());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn drive_reports_throughput() {
        let policy = BatchPolicy { max_batch: 16, deadline: Duration::from_millis(1) };
        let (secs, stats) = drive(engine(), policy, rows(64, 16, 5), 4).unwrap();
        assert!(secs > 0.0);
        assert_eq!(stats.requests, 64);
        assert!(stats.mean_batch() >= 1.0);
    }
}
