//! Micro-batched serving front end over the [`Engine`].
//!
//! Single-row requests are the worst case for a packed GEMM: every request
//! pays the full packed-word stream for one dot-product row.  The server
//! amortizes it by coalescing: the batcher thread blocks on an empty queue,
//! and once a request arrives it keeps collecting until either
//! [`BatchPolicy::max_batch`] rows are queued or [`BatchPolicy::deadline`]
//! has elapsed since the batch opened — then runs **one** batched fused GEMM
//! and fans the result rows back to their callers.  Latency is bounded by
//! the deadline; throughput approaches the batched-GEMM rate as load rises.
//!
//! Alongside row micro-batching the queue carries whole **generation
//! sessions** ([`Client::generate`]): a prompt plus sampling options, run on
//! the batcher thread through the KV-cached decode loop
//! (`infer::generate`), answered with the sampled token ids.
//!
//! The pieces:
//!
//! * [`Server::start`] — spawns the batcher thread owning the [`Engine`];
//! * [`Client`] — cheap cloneable handle; [`Client::call`] blocks for the
//!   result, [`Client::submit`] returns the response channel for pipelined
//!   callers, [`Client::generate`] blocks for a whole token stream;
//! * [`drive`] — a synchronous load generator (CLI `serve` subcommand and
//!   `benches/infer.rs`): N client threads × M rows, returns wall time and
//!   the server-side [`ServeStats`].
//!
//! ## Shutdown contract
//!
//! Every submit and [`Server::shutdown`]'s stop marker go through one
//! mutex-guarded sender, so the `Msg::Shutdown` marker is a true barrier in
//! the queue: **a request whose submit returned `Ok` is guaranteed a real
//! response** — including a batch still being collected when the marker
//! lands — and any submit after the marker fails fast with "server is shut
//! down".  (Without the gate, a request could race into the queue *behind*
//! the marker and be silently dropped; the regression test below pins
//! this.)  Shutdown never blocks on straggler [`Client`] clones.

use super::engine::Engine;
use super::generate::{self, GenOpts};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::anyhow;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// When to close a micro-batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// close as soon as this many rows are queued
    pub max_batch: usize,
    /// …or this long after the first row of the batch arrived
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, deadline: Duration::from_millis(2) }
    }
}

/// Server-side counters, returned by [`Server::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// rows answered
    pub requests: u64,
    /// batched GEMM launches
    pub batches: u64,
    /// largest batch coalesced
    pub max_batch: usize,
    /// seconds spent inside the engine forward
    pub gemm_secs: f64,
    /// generation sessions answered
    pub gen_sessions: u64,
    /// tokens emitted across all generation sessions
    pub gen_tokens: u64,
    /// seconds spent inside generation (prefill + decode)
    pub gen_secs: f64,
}

impl ServeStats {
    /// Mean rows per batched launch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Request {
    row: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
}

struct GenRequest {
    prompt: Vec<f32>,
    opts: GenOpts,
    resp: Sender<Result<Vec<usize>>>,
}

/// Queue messages.  `Shutdown` exists because dropping the server's own
/// `Sender` does not disconnect the channel while [`Client`] clones are
/// alive — [`Server::shutdown`] must not block on stragglers.
enum Msg {
    Req(Request),
    Gen(GenRequest),
    Shutdown,
}

/// The submit/shutdown gate: every accepted message is sent while holding
/// this mutex, and shutdown takes the sender out *under the same lock* —
/// which makes the queued `Msg::Shutdown` marker a barrier no accepted
/// request can land behind.
struct Gate {
    tx: Mutex<Option<Sender<Msg>>>,
}

impl Gate {
    fn send(&self, msg: Msg) -> Result<()> {
        let guard = self.tx.lock().map_err(|_| anyhow!("server gate poisoned"))?;
        let Some(tx) = guard.as_ref() else {
            return Err(anyhow!("server is shut down"));
        };
        tx.send(msg).map_err(|_| anyhow!("server is shut down"))
    }
}

/// Handle for submitting rows (and generation sessions) to a running
/// [`Server`].
#[derive(Clone)]
pub struct Client {
    gate: Arc<Gate>,
    width: usize,
    tok_width: usize,
}

impl Client {
    /// Enqueue one activation row; the returned channel yields its output
    /// row once the batch it lands in has run.  An `Ok` here is a promise:
    /// the row *will* be answered, even if the server shuts down right
    /// after.
    pub fn submit(&self, row: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        if row.len() != self.width {
            return Err(anyhow!(
                "request row has {} values, the served model takes {}",
                row.len(),
                self.width
            ));
        }
        let (tx, rx) = channel();
        self.gate.send(Msg::Req(Request { row, resp: tx }))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, row: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(row)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request (shutting down?)"))?
    }

    /// Submit a whole generation session: `prompt` is `t ≥ 1` flattened
    /// token rows (`t · tok_width` values).  Blocks until the sampled token
    /// ids come back; the session runs KV-cached on the batcher thread
    /// *between* row batches (row traffic waits out the session, so the
    /// deadline bound does not cover it), and the server caps `max_new` at
    /// [`MAX_GEN_TOKENS`] so one session cannot pin the batcher — or stall
    /// [`Server::shutdown`] — indefinitely.
    pub fn generate(&self, prompt: Vec<f32>, opts: GenOpts) -> Result<Vec<usize>> {
        if prompt.is_empty() || prompt.len() % self.tok_width != 0 {
            return Err(anyhow!(
                "generation prompt has {} values, need a nonzero multiple of the \
                 token width {}",
                prompt.len(),
                self.tok_width
            ));
        }
        if prompt.len() / self.tok_width > MAX_GEN_TOKENS {
            return Err(anyhow!(
                "generation prompt has {} rows, the server accepts at most {MAX_GEN_TOKENS}",
                prompt.len() / self.tok_width
            ));
        }
        let (tx, rx) = channel();
        self.gate.send(Msg::Gen(GenRequest { prompt, opts, resp: tx }))?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped the generation session (shutting down?)"))?
    }
}

/// A running micro-batch server (one batcher thread owning the engine).
pub struct Server {
    gate: Arc<Gate>,
    width: usize,
    tok_width: usize,
    handle: std::thread::JoinHandle<ServeStats>,
}

impl Server {
    /// Spawn the batcher thread.  Fails on an empty model (no input width).
    pub fn start(engine: Engine, policy: BatchPolicy) -> Result<Server> {
        let width = engine.in_width()?;
        let tok_width = engine.model().in_width().unwrap_or(width).max(1);
        let max_batch = policy.max_batch.max(1);
        let (tx, rx) = channel::<Msg>();
        let handle =
            std::thread::spawn(move || run_batcher(engine, rx, max_batch, policy.deadline));
        Ok(Server { gate: Arc::new(Gate { tx: Mutex::new(Some(tx)) }), width, tok_width, handle })
    }

    pub fn client(&self) -> Client {
        Client { gate: Arc::clone(&self.gate), width: self.width, tok_width: self.tok_width }
    }

    /// Stop the batcher and join it.  The gate closes and the stop marker is
    /// queued under one lock, so shutdown is a clean barrier: every request
    /// accepted before it gets a real response (a batch still being
    /// collected when the marker lands is executed and answered), and every
    /// submit after it fails with "server is shut down".  Never blocks on
    /// straggler [`Client`] clones.
    pub fn shutdown(self) -> Result<ServeStats> {
        let Server { gate, width: _, tok_width: _, handle } = self;
        {
            let mut guard = gate.tx.lock().map_err(|_| anyhow!("server gate poisoned"))?;
            if let Some(tx) = guard.take() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        handle.join().map_err(|_| anyhow!("serve batcher thread panicked"))
    }
}

fn run_batcher(
    engine: Engine,
    rx: Receiver<Msg>,
    max_batch: usize,
    deadline: Duration,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let mut open = true;
    while open {
        // block until a batch opens (generation sessions run immediately —
        // they own the engine for many sequential steps anyway)
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Gen(g)) => {
                run_gen(&engine, g, &mut stats);
                continue;
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let opened = Instant::now();
        let mut batch = vec![first];
        // generation sessions arriving while the batch coalesces run after
        // its GEMM, so row latency stays bounded by the deadline
        let mut gens: Vec<GenRequest> = Vec::new();
        while batch.len() < max_batch {
            let Some(left) = deadline.checked_sub(opened.elapsed()) else { break };
            match rx.recv_timeout(left) {
                Ok(Msg::Req(r)) => batch.push(r),
                Ok(Msg::Gen(g)) => gens.push(g),
                Err(RecvTimeoutError::Timeout) => break,
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    // the in-flight batch (and any collected generation
                    // sessions) must still be executed and answered — the
                    // shutdown barrier guarantees nothing accepted sits
                    // behind the marker
                    open = false;
                    break;
                }
            }
        }
        let n = batch.len();
        let width = batch[0].row.len();
        let mut flat = Vec::with_capacity(n * width);
        for r in &batch {
            flat.extend_from_slice(&r.row);
        }
        let t0 = Instant::now();
        let result = Tensor::from_f32(flat, &[n, width]).and_then(|x| engine.forward(&x));
        stats.gemm_secs += t0.elapsed().as_secs_f64();
        stats.batches += 1;
        stats.requests += n as u64;
        stats.max_batch = stats.max_batch.max(n);
        match result {
            Ok(y) => {
                let out_w = y.shape()[1];
                let yv = y.as_f32().expect("engine output is f32");
                for (i, r) in batch.into_iter().enumerate() {
                    let _ = r.resp.send(Ok(yv[i * out_w..(i + 1) * out_w].to_vec()));
                }
            }
            Err(e) => {
                for r in batch {
                    let _ = r.resp.send(Err(anyhow!("batched forward failed: {e:#}")));
                }
            }
        }
        for g in gens {
            run_gen(&engine, g, &mut stats);
        }
    }
    stats
}

/// Server-side ceiling on tokens per generation session — applied to both
/// `max_new` (clamped) and the prompt length (rejected): both are
/// client-supplied, and the batcher runs sessions synchronously, so an
/// uncapped request would head-of-line block every row request and keep
/// [`Server::shutdown`] joining forever.
pub const MAX_GEN_TOKENS: usize = 4096;

/// Run one generation session on the batcher thread and answer it.
fn run_gen(engine: &Engine, g: GenRequest, stats: &mut ServeStats) {
    let GenRequest { prompt, mut opts, resp } = g;
    opts.max_new = opts.max_new.min(MAX_GEN_TOKENS);
    let d = engine.model().in_width().unwrap_or(1).max(1);
    let rows = prompt.len() / d;
    if rows > MAX_GEN_TOKENS {
        // belt-and-braces twin of the Client-side check, so the invariant
        // holds even if a future producer skips Client::generate
        let _ = resp.send(Err(anyhow!(
            "generation prompt has {rows} rows, the server accepts at most {MAX_GEN_TOKENS}"
        )));
        return;
    }
    let t0 = Instant::now();
    let result = Tensor::from_f32(prompt, &[rows, d])
        .and_then(|x| generate::generate(engine, &x, &opts));
    stats.gen_secs += t0.elapsed().as_secs_f64();
    stats.gen_sessions += 1;
    match result {
        Ok(gen) => {
            stats.gen_tokens += gen.tokens.len() as u64;
            let _ = resp.send(Ok(gen.tokens));
        }
        Err(e) => {
            let _ = resp.send(Err(anyhow!("generation session failed: {e:#}")));
        }
    }
}

/// Synchronous load generator: split `rows` across `clients` threads, each
/// blocking on [`Client::call`] per row.  Returns `(wall_seconds, stats)`;
/// errors if any request failed.
pub fn drive(
    engine: Engine,
    policy: BatchPolicy,
    rows: Vec<Vec<f32>>,
    clients: usize,
) -> Result<(f64, ServeStats)> {
    let n = rows.len();
    if n == 0 {
        return Err(anyhow!("drive: no request rows"));
    }
    let server = Server::start(engine, policy)?;
    let clients = clients.clamp(1, n);
    let chunk = n.div_ceil(clients);
    let t0 = Instant::now();
    let failures: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for slice in rows.chunks(chunk) {
            let client = server.client();
            handles.push(s.spawn(move || {
                slice.iter().filter(|r| client.call((*r).clone()).is_err()).count()
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).sum()
    });
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    if failures > 0 {
        return Err(anyhow!("drive: {failures}/{n} requests failed"));
    }
    Ok((secs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::engine::synthetic_model;
    use crate::util::rng::Pcg32;

    fn engine() -> Engine {
        Engine::new(synthetic_model(2, 16, 4, 3).unwrap(), 1)
    }

    fn rows(n: usize, width: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..width).map(|_| rng.next_normal()).collect()).collect()
    }

    #[test]
    fn responses_match_direct_forward() {
        let reference = engine();
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        for row in rows(6, 16, 1) {
            let got = client.call(row.clone()).unwrap();
            let want = reference.forward_row(&row).unwrap();
            assert_eq!(got, want, "served row must equal the direct forward");
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches <= 6 && stats.batches >= 1);
    }

    #[test]
    fn queued_requests_coalesce_into_one_batch() {
        // All 8 rows are submitted (non-blocking) before any response is
        // read; the generous deadline means the batcher sees them all within
        // one window and runs a single GEMM.
        let server = Server::start(
            engine(),
            BatchPolicy { max_batch: 8, deadline: Duration::from_secs(5) },
        )
        .unwrap();
        let client = server.client();
        let pending: Vec<_> =
            rows(8, 16, 2).into_iter().map(|r| client.submit(r).unwrap()).collect();
        for rx in pending {
            assert_eq!(rx.recv().unwrap().unwrap().len(), 16);
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.batches, 1, "pre-queued rows must coalesce");
        assert_eq!(stats.max_batch, 8);
    }

    #[test]
    fn unbatched_policy_runs_one_gemm_per_request() {
        let policy = BatchPolicy { max_batch: 1, deadline: Duration::from_millis(1) };
        let (_, stats) = drive(engine(), policy, rows(10, 16, 4), 2).unwrap();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.max_batch, 1);
    }

    #[test]
    fn wrong_width_is_rejected_before_queueing() {
        let server = Server::start(engine(), BatchPolicy::default()).unwrap();
        let client = server.client();
        assert!(client.call(vec![0.0; 3]).is_err());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn drive_reports_throughput() {
        let policy = BatchPolicy { max_batch: 16, deadline: Duration::from_millis(1) };
        let (secs, stats) = drive(engine(), policy, rows(64, 16, 5), 4).unwrap();
        assert!(secs > 0.0);
        assert_eq!(stats.requests, 64);
        assert!(stats.mean_batch() >= 1.0);
    }

    #[test]
    fn shutdown_is_a_barrier_every_accepted_request_is_answered() {
        // Regression (PR 4 shutdown race): a submit that returns Ok must
        // receive a *real* response even when it races Server::shutdown.
        // Pre-fix, a request could land in the queue behind the Shutdown
        // marker and be silently dropped — its caller saw a disconnect
        // instead of a result.  Many rounds with varied timing so the race
        // window is actually explored.
        for round in 0..25u64 {
            let server = Server::start(
                engine(),
                BatchPolicy { max_batch: 3, deadline: Duration::from_micros(200) },
            )
            .unwrap();
            let client = server.client();
            let row = rows(1, 16, round).remove(0);
            let submitter = std::thread::spawn(move || {
                let mut accepted = Vec::new();
                loop {
                    match client.submit(row.clone()) {
                        Ok(rx) => accepted.push(rx),
                        Err(_) => break,
                    }
                }
                accepted
            });
            // let some submits land before (and while) the shutdown races in
            std::thread::sleep(Duration::from_micros(60 + 137 * (round % 7)));
            let stats = server.shutdown().unwrap();
            let accepted = submitter.join().unwrap();
            for (i, rx) in accepted.iter().enumerate() {
                let resp = rx.recv().unwrap_or_else(|_| {
                    panic!(
                        "round {round}: accepted request {i}/{} was dropped on shutdown",
                        accepted.len()
                    )
                });
                assert!(resp.is_ok(), "round {round}: accepted request {i} got {resp:?}");
            }
            assert_eq!(
                stats.requests as usize,
                accepted.len(),
                "round {round}: server answered a different number of rows than it accepted"
            );
        }
    }

    #[test]
    fn generation_sessions_run_alongside_row_batching() {
        use crate::infer::generate::{self, GenOpts};
        let model = generate::synthetic_lm(2, 8, 2, 16, 4, 12, 4, 5).unwrap();
        let reference = Engine::new(model.clone(), 1);
        let opts = GenOpts { max_new: 6, temp: 0.7, top_k: 4, seed: 11 };
        let (_, prompt) = generate::random_prompt(reference.model(), 3, 9).unwrap();
        let want = generate::generate(&reference, &prompt, &opts).unwrap().tokens;

        let server = Server::start(Engine::new(model, 1), BatchPolicy::default()).unwrap();
        let client = server.client();
        // a generation session and a plain row request share the queue
        let got = client.generate(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        assert_eq!(got, want, "served generation must equal the direct decode loop");
        let row_out = client.call(vec![0.0; 4 * 8]).unwrap();
        assert_eq!(row_out.len(), 4 * 12, "row serving still works on an LM model");
        // bad prompts are rejected before queueing; bad sessions answer with
        // an error instead of hanging
        assert!(client.generate(vec![0.0; 3], opts).is_err());
        // over-long prompts are refused (head-of-line/shutdown-stall guard)
        assert!(client.generate(vec![0.0; (MAX_GEN_TOKENS + 1) * 8], opts).is_err());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.gen_sessions, 1);
        assert_eq!(stats.gen_tokens as usize, want.len());
        assert_eq!(stats.requests, 1);
        assert!(stats.gen_secs >= 0.0);
    }
}
