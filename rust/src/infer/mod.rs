//! Quantized inference engine — the first real consumer of reconstruction
//! output (DESIGN.md §Inference-and-Serving).
//!
//! `recon` learns `(s1, S2, s3, s4)`; what deployment actually needs is far
//! smaller: the integer grid codes and the per-row dequantization grid
//! `(s1, zp)`.  This module takes a finished `Session::quantize` result the
//! rest of the way to serving:
//!
//! * [`packed`] — storage: codes bit-packed into `u32` words at 2/3/4/8 bits
//!   with per-row scales, plus the `.fxt` packed-model artifact
//!   ([`PackedModel`]) that reloads with **no FP weights on disk**;
//! * [`kernels`] — compute: fused dequant-GEMM ([`kernels::gemm_fused`])
//!   that decodes words on the fly and applies the per-channel scale in
//!   register, with a scalar reference kernel and the
//!   dequantize-then-matmul baseline it is benchmarked against;
//! * [`engine`] — the [`Engine`] forward API over a packed model
//!   (`Session::forward_q`'s fast path), including `transformer_block`
//!   units: all six projections run the fused GEMM while layernorm /
//!   causal attention / GELU / residuals stay f32 (`crate::block`);
//! * [`kv`] — per-block K/V caches behind [`Engine::prefill`] /
//!   [`Engine::decode_step`]: incremental decode attends one new token
//!   against everything cached instead of recomputing full-context
//!   attention per emitted token;
//! * [`generate`] — autoregressive token generation over those primitives:
//!   tied lm-head embeddings, greedy + temperature/top-k sampling, and the
//!   full-context recompute baseline (`flexround generate`);
//! * [`serve`] — a micro-batched request queue ([`Server`]) that coalesces
//!   single-row requests up to a batch deadline, runs one fused GEMM per
//!   batch, and fans results back out — with generation sessions enqueued
//!   into the continuous-batching scheduler ([`crate::sched`]) and stepped
//!   alongside row batches (`flexround serve`).

pub mod engine;
pub mod generate;
pub mod kernels;
pub mod kv;
pub mod packed;
pub mod serve;

pub use engine::{synthetic_model, Engine};
pub use generate::{GenOpts, Generated};
pub use kv::{BlockKv, GenState, KvCache};
pub use packed::{PackedLayer, PackedMatrix, PackedModel, PackedUnit};
pub use serve::{drive, drive_mixed, BatchPolicy, Client, Server, ServeStats, MAX_GEN_TOKENS};
