//! Bit-packed low-bit weight storage + the `.fxt` packed-model artifact.
//!
//! After reconstruction, a layer's quantized weights are fully described by
//! the integer grid codes `n_c ∈ [qmin, qmax]` (Eq. 2 after clipping) plus
//! the per-row dequantization grid `(s1, zp)`: `Ŵ = s1 · (n_c − zp)`.  The
//! FP weights are *not* needed at inference time — that is the paper's
//! deployment claim, and this module is where the repo finally cashes it in.
//!
//! Storage layout ([`PackedMatrix`]):
//!
//! * codes are stored as unsigned offsets `u = n_c − qmin` (`u < 2^bits`),
//!   packed LSB-first into `u32` words, `⌊32 / bits⌋` codes per word
//!   (bits = 3 wastes 2 bits per word; 2/4/8 pack densely);
//! * every row starts on a fresh word boundary (row-aligned), so row-sliced
//!   kernels and non-word-aligned row lengths need no cross-row bit
//!   arithmetic;
//! * `scale`/`zp` are per-row f32 (per-tensor grids are broadcast at pack
//!   time).
//!
//! A whole model ([`PackedModel`]) serializes into the existing FXT
//! named-tensor container (`ser::fxt`) under the `q/…` key namespace — see
//! `DESIGN.md` §Inference-and-Serving for the exact key grammar.  The
//! artifact holds only packed words + grids + biases: loading it back
//! requires no weights FXT, no manifest, and no backend.

use crate::ser::fxt;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::Path;

pub use crate::recon::rounding::ActQuant;

/// Bit-widths the packer supports (the paper's low-bit operating points).
pub const SUPPORTED_BITS: [u32; 4] = [2, 3, 4, 8];

/// Artifact format version (bumped on any key-grammar change).  Version 2
/// added the `qu/…` unit-meta group for `transformer_block` units; version 3
/// added the optional per-layer `…/actq` activation grid (W4A8 artifacts).
/// Version-1 and -2 artifacts still load.
pub const FORMAT_VERSION: i32 = 3;

/// Codes stored per `u32` word at a bit-width.
pub fn codes_per_word(bits: u32) -> usize {
    (32 / bits) as usize
}

/// One bit-packed weight matrix with its per-row dequantization grid.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMatrix {
    rows: usize,
    cols: usize,
    bits: u32,
    /// grid lower bound: stored offset `u` decodes to `qmin + u`
    qmin: i32,
    words_per_row: usize,
    words: Vec<u32>,
    scale: Vec<f32>,
    zp: Vec<f32>,
}

impl PackedMatrix {
    /// Pack integer grid codes (row-major, `rows × cols`) at `bits` with the
    /// per-row grid `(scale, zp)`.  Every code must lie in
    /// `[qmin, qmin + 2^bits − 1]`.
    pub fn pack(
        codes: &[i32],
        rows: usize,
        cols: usize,
        bits: u32,
        qmin: i32,
        scale: Vec<f32>,
        zp: Vec<f32>,
    ) -> Result<PackedMatrix> {
        if !SUPPORTED_BITS.contains(&bits) {
            bail!("packed store supports bits in {SUPPORTED_BITS:?}, got {bits}");
        }
        if rows == 0 || cols == 0 {
            bail!("cannot pack an empty {rows}×{cols} matrix");
        }
        if codes.len() != rows * cols {
            bail!("pack: {} codes for a {rows}×{cols} matrix", codes.len());
        }
        if scale.len() != rows || zp.len() != rows {
            bail!(
                "pack: scale/zp must be per-row ({rows} values), got {}/{}",
                scale.len(),
                zp.len()
            );
        }
        let qmax = qmin + ((1i64 << bits) - 1) as i32;
        let cpw = codes_per_word(bits);
        let wpr = cols.div_ceil(cpw);
        let mut words = vec![0u32; rows * wpr];
        for r in 0..rows {
            for c in 0..cols {
                let code = codes[r * cols + c];
                if code < qmin || code > qmax {
                    bail!("pack: code {code} at ({r},{c}) outside [{qmin}, {qmax}] for {bits}-bit");
                }
                let u = (code - qmin) as u32;
                words[r * wpr + c / cpw] |= u << ((c % cpw) as u32 * bits);
            }
        }
        Ok(PackedMatrix { rows, cols, bits, qmin, words_per_row: wpr, words, scale, zp })
    }

    /// Pack from tensors: `codes` i32 (or integral f32) of shape `(r, c)`,
    /// `scale`/`zp` of 1 or `r` values (per-tensor grids broadcast).
    pub fn from_tensors(
        codes: &Tensor,
        scale: &Tensor,
        zp: &Tensor,
        bits: u32,
        qmin: i32,
    ) -> Result<PackedMatrix> {
        if codes.ndim() != 2 {
            bail!("from_tensors: codes must be 2-D, got {:?}", codes.shape());
        }
        let (rows, cols) = (codes.shape()[0], codes.shape()[1]);
        let cv: Vec<i32> = codes.to_f32_vec().iter().map(|&x| x.round() as i32).collect();
        let bc = |t: &Tensor, what: &str| -> Result<Vec<f32>> {
            let v = t.to_f32_vec();
            match v.len() {
                1 => Ok(vec![v[0]; rows]),
                n if n == rows => Ok(v),
                n => bail!("from_tensors: {what} has {n} values, expected 1 or {rows}"),
            }
        };
        let scale = bc(scale, "scale")?;
        let zp = bc(zp, "zp")?;
        PackedMatrix::pack(&cv, rows, cols, bits, qmin, scale, zp)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn qmin(&self) -> i32 {
        self.qmin
    }

    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn scale(&self) -> &[f32] {
        &self.scale
    }

    pub fn zp(&self) -> &[f32] {
        &self.zp
    }

    /// The packed words backing row `r` — the in-register SIMD decode
    /// (`linalg::simd::unpack_codes_*`) reads a row's words directly
    /// instead of going through the scalar word walk below.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u32] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Decode a single code (test/reference-kernel path).
    #[inline]
    pub fn code_at(&self, r: usize, c: usize) -> i32 {
        let cpw = codes_per_word(self.bits);
        let w = self.words[r * self.words_per_row + c / cpw];
        let mask = (1u32 << self.bits) - 1;
        self.qmin + ((w >> ((c % cpw) as u32 * self.bits)) & mask) as i32
    }

    /// Decode row `r`'s codes as f32 into `out` (length `cols`) — the fused
    /// kernel's scratch-fill: one row stays L1-resident while the GEMM
    /// streams activations against it.
    #[inline]
    pub fn unpack_row(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        let cpw = codes_per_word(self.bits);
        let mask = (1u32 << self.bits) - 1;
        let words = &self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
        let mut t = 0usize;
        for &w in words {
            let mut v = w;
            let lim = cpw.min(self.cols - t);
            for _ in 0..lim {
                out[t] = (self.qmin + (v & mask) as i32) as f32;
                v >>= self.bits;
                t += 1;
            }
        }
    }

    /// Decode row `r`'s codes as raw i32 into `out` (length `cols`) — the
    /// integer-domain fused kernel's scratch-fill, same word walk as
    /// [`PackedMatrix::unpack_row`] minus the f32 cast.
    #[inline]
    pub fn unpack_row_i32(&self, r: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.cols);
        let cpw = codes_per_word(self.bits);
        let mask = (1u32 << self.bits) - 1;
        let words = &self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
        let mut t = 0usize;
        for &w in words {
            let mut v = w;
            let lim = cpw.min(self.cols - t);
            for _ in 0..lim {
                out[t] = self.qmin + (v & mask) as i32;
                v >>= self.bits;
                t += 1;
            }
        }
    }

    /// All codes, row-major (round-trip tests).
    pub fn unpack(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(self.code_at(r, c));
            }
        }
        out
    }

    /// Materialize the full f32 weight matrix `Ŵ = scale · (code − zp)`
    /// (the dequantize-then-matmul baseline; the fused kernels never call
    /// this).
    pub fn dequantize(&self) -> Result<Tensor> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut buf = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            self.unpack_row(r, &mut buf);
            let (s, z) = (self.scale[r], self.zp[r]);
            for (o, &n) in out[r * self.cols..(r + 1) * self.cols].iter_mut().zip(&buf) {
                *o = s * (n - z);
            }
        }
        Tensor::from_f32(out, &[self.rows, self.cols])
    }

    /// Bytes of the packed representation (words + per-row grids).
    pub fn packed_bytes(&self) -> usize {
        self.words.len() * 4 + (self.scale.len() + self.zp.len()) * 4
    }

    /// Bytes the same weights occupy as dense f32.
    pub fn fp32_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

// ---------------------------------------------------------------------------
// Whole-model artifact
// ---------------------------------------------------------------------------

/// One packed layer: matrix + optional bias + whether ReLU follows it
/// (`mlp_relu` units apply ReLU between layers).  `act` carries the
/// calibrated static activation grid when the artifact was packed with
/// `--act-bits` (W4A8): the engine then quantizes this layer's input onto
/// it and runs the GEMM in the integer domain
/// ([`crate::infer::kernels::gemm_fused_act_int`]).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedLayer {
    pub name: String,
    pub mat: PackedMatrix,
    pub bias: Option<Vec<f32>>,
    pub relu_after: bool,
    pub act: Option<ActQuant>,
}

/// One packed unit: an ordered contraction stack (`kind == "stack"`), or a
/// transformer block (`kind == "transformer_block"`, six layers in
/// `block::CANON_LAYERS` order plus layernorm parameters and attention
/// geometry).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedUnit {
    pub name: String,
    pub kind: String,
    /// attention heads (block units; 1 otherwise)
    pub heads: usize,
    /// rows per sequence for causal attention (block units; 1 otherwise)
    pub seq: usize,
    /// pre-attention layernorm `(gain, bias)` (block units)
    pub ln1: Option<(Vec<f32>, Vec<f32>)>,
    /// pre-MLP layernorm `(gain, bias)` (block units)
    pub ln2: Option<(Vec<f32>, Vec<f32>)>,
    pub layers: Vec<PackedLayer>,
}

impl PackedUnit {
    /// A plain sequential contraction stack (the pre-block unit shape).
    pub fn stack(name: &str, layers: Vec<PackedLayer>) -> PackedUnit {
        PackedUnit {
            name: name.to_string(),
            kind: "stack".to_string(),
            heads: 1,
            seq: 1,
            ln1: None,
            ln2: None,
            layers,
        }
    }
}

/// A fully packed model — everything the inference engine needs, nothing it
/// does not (no FP weights, no manifest, no init packs).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PackedModel {
    pub units: Vec<PackedUnit>,
}

impl PackedModel {
    /// Input width of the first layer, if the model is non-empty.
    pub fn in_width(&self) -> Option<usize> {
        self.units.first().and_then(|u| u.layers.first()).map(|l| l.mat.cols())
    }

    /// Output width of the last layer, if the model is non-empty.
    pub fn out_width(&self) -> Option<usize> {
        self.units.last().and_then(|u| u.layers.last()).map(|l| l.mat.rows())
    }

    /// Rows per sequence the model's attention expects (1 when no
    /// transformer-block unit is present).
    pub fn seq(&self) -> usize {
        self.units.iter().map(|u| u.seq.max(1)).max().unwrap_or(1)
    }

    /// Whether any unit is a transformer block.
    pub fn has_blocks(&self) -> bool {
        self.units.iter().any(|u| u.kind == "transformer_block")
    }

    pub fn packed_bytes(&self) -> usize {
        self.units
            .iter()
            .flat_map(|u| &u.layers)
            .map(|l| l.mat.packed_bytes() + l.bias.as_ref().map_or(0, |b| b.len() * 4))
            .sum()
    }

    pub fn fp32_bytes(&self) -> usize {
        self.units
            .iter()
            .flat_map(|u| &u.layers)
            .map(|l| l.mat.fp32_bytes() + l.bias.as_ref().map_or(0, |b| b.len() * 4))
            .sum()
    }

    /// Lower to FXT tensors.  Key grammar (one group per layer, plus one
    /// unit-meta group per `transformer_block` unit):
    ///
    /// ```text
    ///   packed/version                        i32 [1]
    ///   q/{uuuu}/{unit}/{ll}/{layer}/words    i32 [rows, words_per_row]  (u32 bit-cast)
    ///   q/{uuuu}/{unit}/{ll}/{layer}/meta     i32 [6] = rows cols bits qmin relu has_bias
    ///   q/{uuuu}/{unit}/{ll}/{layer}/scale    f32 [rows]
    ///   q/{uuuu}/{unit}/{ll}/{layer}/zp       f32 [rows]
    ///   q/{uuuu}/{unit}/{ll}/{layer}/bias     f32 [rows]  (only when has_bias)
    ///   q/{uuuu}/{unit}/{ll}/{layer}/actq     f32 [3] = abits step zp  (W4A8 only)
    ///   qu/{uuuu}/{unit}/meta                 i32 [3] = kind(1=block) heads seq
    ///   qu/{uuuu}/{unit}/ln1_g|ln1_b|ln2_g|ln2_b  f32 [d]   (block units)
    /// ```
    ///
    /// Zero-padded indices make BTreeMap iteration recover unit/layer order.
    pub fn to_tensors(&self) -> Result<BTreeMap<String, Tensor>> {
        let mut out = BTreeMap::new();
        out.insert(
            "packed/version".to_string(),
            Tensor::from_i32(vec![FORMAT_VERSION], &[1])?,
        );
        for (ui, unit) in self.units.iter().enumerate() {
            // index order is recovered from lexicographic key order, so the
            // zero-padded widths are hard limits — overflow would silently
            // reorder on reload
            if ui > 9999 {
                bail!("packed artifact: at most 10000 units (got {})", self.units.len());
            }
            if unit.kind == "transformer_block" {
                let upfx = format!("qu/{ui:04}/{}", unit.name);
                out.insert(
                    format!("{upfx}/meta"),
                    Tensor::from_i32(
                        vec![1, unit.heads as i32, unit.seq as i32],
                        &[3],
                    )?,
                );
                let (g1, b1) = unit.ln1.as_ref().ok_or_else(|| {
                    anyhow!("block unit {:?} has no ln1 parameters", unit.name)
                })?;
                let (g2, b2) = unit.ln2.as_ref().ok_or_else(|| {
                    anyhow!("block unit {:?} has no ln2 parameters", unit.name)
                })?;
                for (k, v) in
                    [("ln1_g", g1), ("ln1_b", b1), ("ln2_g", g2), ("ln2_b", b2)]
                {
                    out.insert(
                        format!("{upfx}/{k}"),
                        Tensor::from_f32(v.clone(), &[v.len()])?,
                    );
                }
            } else if unit.kind != "stack" {
                bail!("packed artifact: unknown unit kind {:?}", unit.kind);
            }
            for (li, layer) in unit.layers.iter().enumerate() {
                if li > 99 {
                    bail!(
                        "packed artifact: at most 100 layers per unit (unit {:?} has {})",
                        unit.name,
                        unit.layers.len()
                    );
                }
                if unit.name.contains('/') || layer.name.contains('/') {
                    bail!(
                        "packed artifact: unit/layer names may not contain '/' \
                         (got {:?}/{:?})",
                        unit.name,
                        layer.name
                    );
                }
                let m = &layer.mat;
                let pfx = format!("q/{ui:04}/{}/{li:02}/{}", unit.name, layer.name);
                out.insert(
                    format!("{pfx}/words"),
                    Tensor::from_i32(
                        m.words().iter().map(|&w| w as i32).collect(),
                        &[m.rows(), m.words_per_row()],
                    )?,
                );
                out.insert(
                    format!("{pfx}/meta"),
                    Tensor::from_i32(
                        vec![
                            m.rows() as i32,
                            m.cols() as i32,
                            m.bits() as i32,
                            m.qmin(),
                            layer.relu_after as i32,
                            layer.bias.is_some() as i32,
                        ],
                        &[6],
                    )?,
                );
                out.insert(format!("{pfx}/scale"), Tensor::from_f32(m.scale().to_vec(), &[m.rows()])?);
                out.insert(format!("{pfx}/zp"), Tensor::from_f32(m.zp().to_vec(), &[m.rows()])?);
                if let Some(b) = &layer.bias {
                    out.insert(format!("{pfx}/bias"), Tensor::from_f32(b.clone(), &[b.len()])?);
                }
                if let Some(a) = &layer.act {
                    out.insert(
                        format!("{pfx}/actq"),
                        Tensor::from_f32(vec![a.abits as f32, a.step, a.zp], &[3])?,
                    );
                }
            }
        }
        Ok(out)
    }

    /// Rebuild from FXT tensors (inverse of [`PackedModel::to_tensors`]).
    pub fn from_tensors(tensors: &BTreeMap<String, Tensor>) -> Result<PackedModel> {
        let version = tensors
            .get("packed/version")
            .ok_or_else(|| anyhow!("not a packed-model artifact (no packed/version entry)"))?
            .as_i32()?[0];
        // v1 (stack units only) and v2 (no actq grids) still load
        if !(1..=FORMAT_VERSION).contains(&version) {
            bail!("packed artifact version {version}, this build reads 1..={FORMAT_VERSION}");
        }
        // Group field tensors by their layer prefix; BTreeMap order (zero-
        // padded indices) is unit/layer order.  `qu/{uuuu}/{unit}/{field}`
        // carries unit-level meta for transformer blocks.
        let mut groups: BTreeMap<String, BTreeMap<String, &Tensor>> = BTreeMap::new();
        let mut unit_meta: BTreeMap<String, BTreeMap<String, &Tensor>> = BTreeMap::new();
        for (key, t) in tensors {
            if let Some(rest) = key.strip_prefix("qu/") {
                let parts: Vec<&str> = rest.split('/').collect();
                let (uidx, field) = match &parts[..] {
                    [uidx, _uname, field] => (*uidx, *field),
                    _ => bail!("malformed packed unit-meta key {key:?}"),
                };
                unit_meta.entry(uidx.to_string()).or_default().insert(field.to_string(), t);
                continue;
            }
            let Some(rest) = key.strip_prefix("q/") else { continue };
            let (prefix, field) = rest
                .rsplit_once('/')
                .ok_or_else(|| anyhow!("malformed packed key {key:?}"))?;
            groups.entry(prefix.to_string()).or_default().insert(field.to_string(), t);
        }
        let mut units: Vec<PackedUnit> = Vec::new();
        let mut last_uidx: Option<String> = None;
        for (prefix, fields) in &groups {
            let parts: Vec<&str> = prefix.split('/').collect();
            let (uidx, uname, lname) = match &parts[..] {
                [uidx, uname, _lidx, lname] => (*uidx, *uname, *lname),
                _ => bail!("malformed packed layer prefix q/{prefix}"),
            };
            let take = |f: &str| {
                fields.get(f).copied().ok_or_else(|| anyhow!("q/{prefix} is missing /{f}"))
            };
            let meta = take("meta")?.as_i32()?;
            if meta.len() != 6 {
                bail!("q/{prefix}/meta has {} values, expected 6", meta.len());
            }
            let (rows, cols) = (meta[0] as usize, meta[1] as usize);
            let (bits, qmin) = (meta[2] as u32, meta[3]);
            let words_t = take("words")?;
            let cpw = if SUPPORTED_BITS.contains(&bits) {
                codes_per_word(bits)
            } else {
                bail!("q/{prefix}: unsupported bit-width {bits}");
            };
            let wpr = cols.div_ceil(cpw);
            if words_t.shape() != &[rows, wpr][..] {
                bail!(
                    "q/{prefix}/words has shape {:?}, expected [{rows}, {wpr}]",
                    words_t.shape()
                );
            }
            let words: Vec<u32> = words_t.as_i32()?.iter().map(|&w| w as u32).collect();
            let scale = take("scale")?.as_f32()?.to_vec();
            let zp = take("zp")?.as_f32()?.to_vec();
            if scale.len() != rows || zp.len() != rows {
                bail!("q/{prefix}: scale/zp length {}/{} vs {rows} rows", scale.len(), zp.len());
            }
            // Reconstruct through `pack`'s validation by decoding: cheaper to
            // trust the words directly — the mask on decode keeps any stray
            // high bits from escaping the grid.
            let mat = PackedMatrix { rows, cols, bits, qmin, words_per_row: wpr, words, scale, zp };
            let bias = match fields.get("bias") {
                Some(t) => {
                    let b = t.as_f32()?.to_vec();
                    if b.len() != rows {
                        bail!("q/{prefix}/bias has {} values vs {rows} rows", b.len());
                    }
                    Some(b)
                }
                None => {
                    if meta[5] != 0 {
                        bail!("q/{prefix}: meta says has_bias but /bias is missing");
                    }
                    None
                }
            };
            let act = match fields.get("actq") {
                Some(t) => {
                    let v = t.as_f32()?;
                    if v.len() != 3 {
                        bail!("q/{prefix}/actq has {} values, expected 3", v.len());
                    }
                    let abits = v[0].round() as u32;
                    if !(1..=16).contains(&abits) {
                        bail!("q/{prefix}/actq: activation bit-width {abits} out of range");
                    }
                    Some(ActQuant { abits, step: v[1], zp: v[2] })
                }
                None => None,
            };
            let layer = PackedLayer {
                name: lname.to_string(),
                mat,
                bias,
                relu_after: meta[4] != 0,
                act,
            };
            // group by the unit *index* (not the name): units sharing a name
            // must stay distinct so save→load is structurally exact
            if last_uidx.as_deref() == Some(uidx) {
                units.last_mut().expect("uidx seen ⇒ unit exists").layers.push(layer);
            } else {
                let mut pu = PackedUnit::stack(uname, vec![layer]);
                if let Some(fields) = unit_meta.get(uidx) {
                    let meta = fields
                        .get("meta")
                        .ok_or_else(|| anyhow!("qu/{uidx}/{uname} is missing /meta"))?
                        .as_i32()?;
                    if meta.len() != 3 || meta[0] != 1 {
                        bail!("qu/{uidx}/{uname}/meta malformed: {meta:?}");
                    }
                    pu.kind = "transformer_block".to_string();
                    pu.heads = (meta[1].max(1)) as usize;
                    pu.seq = (meta[2].max(1)) as usize;
                    let ln = |g: &str, b: &str| -> Result<(Vec<f32>, Vec<f32>)> {
                        let take = |f: &str| -> Result<Vec<f32>> {
                            Ok(fields
                                .get(f)
                                .ok_or_else(|| anyhow!("qu/{uidx}/{uname} is missing /{f}"))?
                                .as_f32()?
                                .to_vec())
                        };
                        Ok((take(g)?, take(b)?))
                    };
                    pu.ln1 = Some(ln("ln1_g", "ln1_b")?);
                    pu.ln2 = Some(ln("ln2_g", "ln2_b")?);
                }
                units.push(pu);
                last_uidx = Some(uidx.to_string());
            }
        }
        if units.is_empty() {
            bail!("packed artifact holds no layers");
        }
        Ok(PackedModel { units })
    }

    /// Save as an FXT packed artifact (conventional extension: `.fxt`).
    pub fn save(&self, path: &Path) -> Result<()> {
        fxt::write(path, &self.to_tensors()?)
    }

    /// Load a packed artifact — no FP weights, manifest, or backend needed.
    pub fn load(path: &Path) -> Result<PackedModel> {
        PackedModel::from_tensors(&fxt::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qrange;
    use crate::util::prop::Prop;

    fn grid(bits: u32, symmetric: bool) -> (i32, i32) {
        let (lo, hi) = qrange(bits, symmetric);
        (lo as i32, hi as i32)
    }

    #[test]
    fn pack_unpack_identity_all_bits() {
        // Satellite: pack→unpack identity for bits ∈ {2,3,4,8}, signed codes
        // at range edges, non-word-aligned row lengths.
        for &bits in &SUPPORTED_BITS {
            Prop::new("pack→unpack identity").cases(48).check(|rng| {
                let rows = 1 + rng.below(6) as usize;
                // up to 37 columns: never a multiple of 16/10/8/4 for long
                // stretches, so partial last words are exercised constantly
                let cols = 1 + rng.below(37) as usize;
                let (qmin, qmax) = grid(bits, rng.next_f32() < 0.5);
                let span = (qmax - qmin + 1) as u32;
                let mut codes: Vec<i32> =
                    (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
                // force both grid edges into every case
                codes[0] = qmin;
                let n = codes.len();
                codes[n - 1] = qmax;
                let scale: Vec<f32> = (0..rows).map(|_| 0.01 + rng.next_f32()).collect();
                let zp: Vec<f32> = (0..rows).map(|_| rng.below(5) as f32 - 2.0).collect();
                let m = PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp)
                    .map_err(|e| e.to_string())?;
                if m.unpack() != codes {
                    return Err(format!("round-trip mismatch at {bits}-bit {rows}×{cols}"));
                }
                // spot-check the single-code decoder against the bulk one
                let r = rng.below(rows as u32) as usize;
                let c = rng.below(cols as u32) as usize;
                if m.code_at(r, c) != codes[r * cols + c] {
                    return Err(format!("code_at({r},{c}) disagrees"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn pack_roundtrip_asymmetric_grids() {
        // Satellite (PR 4): *asymmetric* grids — the unsigned [0, 2^b − 1]
        // code range with genuinely nonzero per-row zero points.  PR 2's
        // props randomized the range but kept |zp| ≤ 2 and mostly symmetric
        // grids; real asymmetric calibration (minmax_scale with
        // symmetric = false) lands zp anywhere inside the grid.  Checks
        // pack→unpack identity, the dequantization formula, and fused-GEMM
        // parity at every supported bit-width on non-word-aligned rows.
        use crate::infer::kernels;
        for &bits in &SUPPORTED_BITS {
            Prop::new("asymmetric pack/unpack/dequant/gemm").cases(32).check(|rng| {
                let rows = 1 + rng.below(6) as usize;
                // up to 37 columns so partial last words are constant
                let cols = 1 + rng.below(37) as usize;
                let (qmin, qmax) = grid(bits, false);
                let span = (qmax - qmin + 1) as u32;
                let mut codes: Vec<i32> =
                    (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
                codes[0] = qmin;
                let n = codes.len();
                codes[n - 1] = qmax;
                let scale: Vec<f32> = (0..rows).map(|_| 0.01 + 0.2 * rng.next_f32()).collect();
                // per-row zero points strictly inside the grid, never zero
                let zp: Vec<f32> = (0..rows)
                    .map(|_| 1.0 + rng.below(span.saturating_sub(1).max(1)) as f32)
                    .collect();
                let m =
                    PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale.clone(), zp.clone())
                        .map_err(|e| e.to_string())?;
                if m.unpack() != codes {
                    return Err(format!(
                        "asymmetric round-trip mismatch at {bits}-bit {rows}×{cols}"
                    ));
                }
                // Ŵ = s·(n − zp) elementwise, zp honored per row
                let w = m.dequantize().map_err(|e| e.to_string())?;
                let wv = w.as_f32().map_err(|e| e.to_string())?;
                for r in 0..rows {
                    for c in 0..cols {
                        let want = scale[r] * (codes[r * cols + c] as f32 - zp[r]);
                        if (wv[r * cols + c] - want).abs() > 1e-6 * (1.0 + want.abs()) {
                            return Err(format!(
                                "dequant mismatch at ({r},{c}) for {bits}-bit asymmetric grid"
                            ));
                        }
                    }
                }
                // the artifact round trip preserves the asymmetric grid
                let unit = PackedUnit::stack(
                    "u",
                    vec![PackedLayer {
                        name: "fc".into(),
                        mat: m.clone(),
                        bias: None,
                        relu_after: false,
                        act: None,
                    }],
                );
                let model = PackedModel { units: vec![unit] };
                let back = PackedModel::from_tensors(&model.to_tensors().map_err(|e| e.to_string())?)
                    .map_err(|e| e.to_string())?;
                if back != model {
                    return Err(format!("artifact round trip lost the {bits}-bit grid"));
                }
                // the fused kernel must honor the nonzero zero point
                let nb = 1 + rng.below(3) as usize;
                let x = Tensor::from_f32(
                    (0..nb * cols).map(|_| rng.next_normal()).collect(),
                    &[nb, cols],
                )
                .map_err(|e| e.to_string())?;
                let fused = kernels::gemm_fused(&x, &m, 2).map_err(|e| e.to_string())?;
                let reference = kernels::gemm_ref(&x, &m).map_err(|e| e.to_string())?;
                let d = fused.max_abs_diff(&reference).map_err(|e| e.to_string())?;
                let tol = 1e-4 * (1.0 + reference.abs_max());
                if d > tol {
                    return Err(format!(
                        "asymmetric fused gemm drift {d} > {tol} at {bits}-bit {rows}×{cols}"
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn word_layout_is_row_aligned() {
        // bits=3 packs 10 codes per word: 10 cols → 1 word/row, 11 → 2.
        let codes = vec![1i32; 22];
        let m = PackedMatrix::pack(&codes, 2, 11, 3, 0, vec![1.0; 2], vec![0.0; 2]).unwrap();
        assert_eq!(m.words_per_row(), 2);
        assert_eq!(m.words().len(), 4);
        let m = PackedMatrix::pack(&codes[..20], 2, 10, 3, 0, vec![1.0; 2], vec![0.0; 2]).unwrap();
        assert_eq!(m.words_per_row(), 1);
    }

    #[test]
    fn pack_rejects_bad_inputs() {
        let ok = vec![0i32; 4];
        assert!(PackedMatrix::pack(&ok, 2, 2, 5, 0, vec![1.0; 2], vec![0.0; 2]).is_err());
        assert!(PackedMatrix::pack(&ok, 2, 3, 4, 0, vec![1.0; 2], vec![0.0; 2]).is_err());
        assert!(PackedMatrix::pack(&ok, 2, 2, 4, 0, vec![1.0], vec![0.0; 2]).is_err());
        // code 16 does not fit 4 unsigned bits above qmin=0
        let hot = vec![0, 0, 16, 0];
        assert!(PackedMatrix::pack(&hot, 2, 2, 4, 0, vec![1.0; 2], vec![0.0; 2]).is_err());
        // …but fits 8 bits
        assert!(PackedMatrix::pack(&hot, 2, 2, 8, 0, vec![1.0; 2], vec![0.0; 2]).is_ok());
    }

    #[test]
    fn dequantize_matches_grid_formula() {
        let codes = vec![-8, 7, 0, -1, 3, -5];
        let m = PackedMatrix::pack(&codes, 2, 3, 4, -8, vec![0.5, 0.25], vec![1.0, -2.0]).unwrap();
        let w = m.dequantize().unwrap();
        let v = w.as_f32().unwrap();
        for r in 0..2 {
            for c in 0..3 {
                let expect = m.scale()[r] * (codes[r * 3 + c] as f32 - m.zp()[r]);
                assert_eq!(v[r * 3 + c], expect);
            }
        }
        assert!(m.packed_bytes() < m.fp32_bytes());
    }

    #[test]
    fn artifact_tensors_roundtrip() {
        let mk = |seed: i32, rows: usize, cols: usize, bits: u32, qmin: i32| {
            let span = (1i64 << bits) as i32;
            let codes: Vec<i32> =
                (0..rows * cols).map(|i| qmin + (i as i32 * 7 + seed).rem_euclid(span)).collect();
            PackedMatrix::pack(
                &codes,
                rows,
                cols,
                bits,
                qmin,
                (0..rows).map(|r| 0.1 + r as f32 * 0.01).collect(),
                vec![0.0; rows],
            )
            .unwrap()
        };
        let model = PackedModel {
            units: vec![
                PackedUnit::stack(
                    "u0",
                    vec![
                        PackedLayer {
                            name: "up".into(),
                            mat: mk(1, 6, 5, 4, -8),
                            bias: Some(vec![0.5; 6]),
                            relu_after: true,
                            act: Some(ActQuant { abits: 8, step: 0.0125, zp: 96.0 }),
                        },
                        PackedLayer {
                            name: "down".into(),
                            mat: mk(2, 4, 6, 3, -4),
                            bias: None,
                            relu_after: false,
                            act: None,
                        },
                    ],
                ),
                PackedUnit::stack(
                    "u1",
                    vec![PackedLayer {
                        name: "fc".into(),
                        mat: mk(3, 3, 4, 8, 0),
                        bias: None,
                        relu_after: false,
                        act: None,
                    }],
                ),
            ],
        };
        let tensors = model.to_tensors().unwrap();
        let back = PackedModel::from_tensors(&tensors).unwrap();
        assert_eq!(model, back);
        assert_eq!(model.in_width(), Some(5));
        assert_eq!(model.out_width(), Some(3));
        assert_eq!(model.seq(), 1);
        assert!(!model.has_blocks());
        // in-memory FXT round-trip too (the on-disk format, minus the disk)
        let bytes = fxt::write_bytes(&tensors).unwrap();
        let back2 = PackedModel::from_tensors(&fxt::read_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(model, back2);
    }

    #[test]
    fn block_unit_roundtrip_with_unit_meta() {
        let d = 6usize;
        let mlp = 10usize;
        let mk = |seed: i32, rows: usize, cols: usize| {
            let codes: Vec<i32> =
                (0..rows * cols).map(|i| -8 + (i as i32 * 5 + seed).rem_euclid(16)).collect();
            PackedMatrix::pack(
                &codes,
                rows,
                cols,
                4,
                -8,
                (0..rows).map(|r| 0.05 + r as f32 * 0.01).collect(),
                vec![0.0; rows],
            )
            .unwrap()
        };
        let layer = |name: &str, rows: usize, cols: usize, seed: i32| PackedLayer {
            name: name.into(),
            mat: mk(seed, rows, cols),
            bias: Some(vec![0.01; rows]),
            relu_after: false,
            act: None,
        };
        let block = PackedUnit {
            name: "blk0".into(),
            kind: "transformer_block".into(),
            heads: 2,
            seq: 4,
            ln1: Some((vec![1.0; d], vec![0.0; d])),
            ln2: Some((vec![0.9; d], vec![0.1; d])),
            layers: vec![
                layer("wq", d, d, 1),
                layer("wk", d, d, 2),
                layer("wv", d, d, 3),
                layer("wo", d, d, 4),
                layer("up", mlp, d, 5),
                layer("down", d, mlp, 6),
            ],
        };
        let model = PackedModel {
            units: vec![block, PackedUnit::stack("head", vec![layer("fc", 3, d, 7)])],
        };
        let back = PackedModel::from_tensors(&model.to_tensors().unwrap()).unwrap();
        assert_eq!(model, back);
        assert_eq!(back.units[0].kind, "transformer_block");
        assert_eq!(back.units[0].heads, 2);
        assert_eq!(back.units[0].seq, 4);
        assert_eq!(model.seq(), 4);
        assert!(model.has_blocks());
        // a block missing its layernorms must fail to serialize
        let mut broken = model.clone();
        broken.units[0].ln1 = None;
        assert!(broken.to_tensors().is_err());
    }

    #[test]
    fn duplicate_unit_names_stay_distinct() {
        // consecutive units may share a name (repeated block types); load
        // groups by index, so the structure must survive the round trip
        let unit = |name: &str| {
            PackedUnit::stack(
                name,
                vec![PackedLayer {
                    name: "fc".into(),
                    mat: PackedMatrix::pack(
                        &[0, 1, -1, 2], 2, 2, 4, -8, vec![1.0; 2], vec![0.0; 2],
                    )
                    .unwrap(),
                    bias: None,
                    relu_after: false,
                    act: None,
                }],
            )
        };
        let model = PackedModel { units: vec![unit("blk"), unit("blk")] };
        let back = PackedModel::from_tensors(&model.to_tensors().unwrap()).unwrap();
        assert_eq!(back.units.len(), 2);
        assert_eq!(model, back);
    }

    #[test]
    fn from_tensors_rejects_garbage() {
        let mut m = BTreeMap::new();
        assert!(PackedModel::from_tensors(&m).is_err());
        m.insert("packed/version".to_string(), Tensor::from_i32(vec![99], &[1]).unwrap());
        assert!(PackedModel::from_tensors(&m).is_err());
        m.insert("packed/version".to_string(), Tensor::from_i32(vec![1], &[1]).unwrap());
        assert!(PackedModel::from_tensors(&m).is_err(), "no layers must be rejected");
    }
}
