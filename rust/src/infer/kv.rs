//! Per-block K/V caches for incremental autoregressive decode (DESIGN.md
//! §Generation).
//!
//! Full-context serving recomputes attention over the whole sequence on
//! every call — O(t²) work to emit token `t + 1`.  The decode path instead
//! caches each transformer block's key/value rows as they are produced:
//! [`crate::infer::Engine::prefill`] fills one [`BlockKv`] per block from
//! the prompt, and every [`crate::infer::Engine::decode_step`] appends one
//! row per block and attends the new token against everything cached — the
//! causal mask degenerates to "attend to all", so the per-token cost is
//! O(t) attention reads plus O(1) GEMM work in the generated length.  The
//! attention reads themselves are strided `crate::linalg::dot` calls over
//! these buffers (`block::attn_score_row`) and the GEMMs take the batch-1
//! gemv path — the decode loop runs on the same kernel core as everything
//! else, with zero per-token allocation against the cache.
//!
//! [`KvCache`] tracks the committed token position across blocks and
//! validates that every block advanced in lockstep (a desynchronized cache
//! means a dropped or double-pushed row, which would silently corrupt every
//! later token).  [`GenState`] is the engine-facing bundle: the cache plus
//! reusable attention scratch.

use crate::Result;
use anyhow::{anyhow, bail};

/// K/V rows cached for one transformer block, row-major `(pos, d)`.
#[derive(Clone, Debug)]
pub struct BlockKv {
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl BlockKv {
    fn new(d: usize, capacity_rows: usize) -> BlockKv {
        BlockKv {
            d,
            k: Vec::with_capacity(capacity_rows * d),
            v: Vec::with_capacity(capacity_rows * d),
        }
    }

    /// Hidden width of one cached row.
    pub fn width(&self) -> usize {
        self.d
    }

    /// Rows cached so far.
    pub fn len(&self) -> usize {
        self.k.len() / self.d.max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    /// All cached key rows, row-major `(len, d)`.
    pub fn k(&self) -> &[f32] {
        &self.k
    }

    /// All cached value rows, row-major `(len, d)`.
    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// Append whole `(rows, d)` K/V row groups — prefill pushes the full
    /// prompt at once, decode pushes one row per step.
    pub fn extend(&mut self, krows: &[f32], vrows: &[f32]) -> Result<()> {
        if krows.is_empty() || krows.len() != vrows.len() || krows.len() % self.d != 0 {
            bail!(
                "kv extend: {} k values vs {} v values (row width {})",
                krows.len(),
                vrows.len(),
                self.d
            );
        }
        self.k.extend_from_slice(krows);
        self.v.extend_from_slice(vrows);
        Ok(())
    }
}

/// The whole model's K/V state: one [`BlockKv`] per transformer-block unit
/// plus the committed token position.
pub struct KvCache {
    blocks: Vec<BlockKv>,
    pos: usize,
}

impl KvCache {
    /// One per-block cache per hidden width in `dims`, sized for
    /// `capacity_rows` tokens before the first reallocation (a hint, not a
    /// limit — generation may run past it).
    pub fn new(dims: &[usize], capacity_rows: usize) -> KvCache {
        KvCache {
            blocks: dims.iter().map(|&d| BlockKv::new(d, capacity_rows)).collect(),
            pos: 0,
        }
    }

    /// Number of block caches.
    pub fn blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Tokens committed (prompt + decoded so far).
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn block_mut(&mut self, i: usize) -> Result<&mut BlockKv> {
        let n = self.blocks.len();
        self.blocks
            .get_mut(i)
            .ok_or_else(|| anyhow!("kv cache has {n} block slots, asked for {i}"))
    }

    /// Commit position `t`: every block must hold exactly `t` rows — a
    /// mismatch means some block missed (or double-pushed) a row and the
    /// cache is corrupt.
    pub fn set_pos(&mut self, t: usize) -> Result<()> {
        for (i, b) in self.blocks.iter().enumerate() {
            if b.len() != t {
                bail!("kv cache block {i} holds {} rows, expected {t}", b.len());
            }
        }
        self.pos = t;
        Ok(())
    }

    /// Commit one decode step (every block grew by exactly one row).
    pub fn advance(&mut self) -> Result<()> {
        self.set_pos(self.pos + 1)
    }

    /// Bytes held across every block's K and V buffers.
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| (b.k.len() + b.v.len()) * 4).sum()
    }
}

/// One generation session's mutable state: the KV cache plus reusable
/// attention-probability and embedding-row scratch.  Produced by
/// [`crate::infer::Engine::prefill`], advanced by
/// [`crate::infer::Engine::decode_step`].
pub struct GenState {
    pub(crate) kv: KvCache,
    pub(crate) probs_scratch: Vec<f32>,
    /// Token-embedding row reused across the decode loop
    /// (`generate::embed_token_into`): one allocation per session instead
    /// of one per emitted token.
    pub(crate) embed_scratch: Vec<f32>,
}

impl GenState {
    pub fn new(kv: KvCache) -> GenState {
        GenState { kv, probs_scratch: Vec::new(), embed_scratch: Vec::new() }
    }

    /// Tokens currently committed (prompt + generated so far).
    pub fn pos(&self) -> usize {
        self.kv.pos()
    }

    pub fn kv(&self) -> &KvCache {
        &self.kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_rows_accumulate_and_positions_commit() {
        let mut cache = KvCache::new(&[4, 4], 8);
        assert_eq!(cache.blocks(), 2);
        assert_eq!(cache.pos(), 0);
        // prefill three rows into both blocks, then commit
        let rows = vec![1.0f32; 3 * 4];
        cache.block_mut(0).unwrap().extend(&rows, &rows).unwrap();
        cache.block_mut(1).unwrap().extend(&rows, &rows).unwrap();
        cache.set_pos(3).unwrap();
        assert_eq!(cache.pos(), 3);
        assert_eq!(cache.bytes(), 2 * 2 * 3 * 4 * 4);
        // one decode step: one row per block
        let one = vec![2.0f32; 4];
        cache.block_mut(0).unwrap().extend(&one, &one).unwrap();
        cache.block_mut(1).unwrap().extend(&one, &one).unwrap();
        cache.advance().unwrap();
        assert_eq!(cache.pos(), 4);
        let b = cache.block_mut(0).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(&b.k()[3 * 4..], &one[..]);
    }

    #[test]
    fn desynchronized_blocks_are_rejected() {
        let mut cache = KvCache::new(&[4, 4], 2);
        let one = vec![0.0f32; 4];
        cache.block_mut(0).unwrap().extend(&one, &one).unwrap();
        // block 1 never pushed → the commit must fail, pos must not move
        assert!(cache.advance().is_err());
        assert_eq!(cache.pos(), 0);
        assert!(cache.block_mut(9).is_err());
    }

    #[test]
    fn extend_validates_row_shapes() {
        let mut cache = KvCache::new(&[4], 2);
        let b = cache.block_mut(0).unwrap();
        assert!(b.extend(&[0.0; 4], &[0.0; 8]).is_err(), "k/v length mismatch");
        assert!(b.extend(&[0.0; 3], &[0.0; 3]).is_err(), "not a whole row");
        assert!(b.extend(&[], &[]).is_err(), "empty push");
        assert!(b.is_empty());
        assert_eq!(b.width(), 4);
    }
}
