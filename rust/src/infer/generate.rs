//! Autoregressive generation over packed block models (DESIGN.md
//! §Generation).
//!
//! The paper's NLG claim is that block-by-block reconstructed models *serve
//! generation* at negligible quality loss — which needs an incremental
//! decode path, not just full-context forwards.  This module drives
//! [`Engine::prefill`] / [`Engine::decode_step`] into a token loop:
//!
//! * the lm head is the packed model's trailing stack unit (the `(vocab, d)`
//!   projection `Session::packed_lm_model` exports from the `head/lm`
//!   weights), and token embeddings are **tied** to it — the embedding of
//!   token `t` is the head matrix's dequantized row `t`, so a packed
//!   artifact is generation-complete with no extra tensors;
//! * sampling is greedy at `temp == 0`, otherwise a max-shifted softmax
//!   ([`crate::eval::log_sum_exp`]) over `logits / temp` restricted to the
//!   `top_k` highest logits, drawn through the deterministic
//!   [`Pcg32`] stream — a fixed seed replays the exact token stream;
//! * [`generate_recompute`] is the full-context baseline (re-forward the
//!   whole prefix for every token, O(t) GEMM work per token where the
//!   cached path is O(1)): it must emit the identical stream, and
//!   `benches/generate.rs` measures the cached path against it.

use super::engine::Engine;
use super::packed::{PackedLayer, PackedMatrix, PackedModel, PackedUnit};
use crate::eval::log_sum_exp;
use crate::linalg::{simd, Isa};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};
use std::time::Instant;

/// Sampling controls for one generation session.
#[derive(Clone, Copy, Debug)]
pub struct GenOpts {
    /// tokens to generate after the prompt
    pub max_new: usize,
    /// `0.0` → greedy argmax; otherwise the softmax temperature
    pub temp: f32,
    /// restrict sampling to the k highest logits (`0` → full vocabulary)
    pub top_k: usize,
    /// sampling stream seed (fixed seed ⇒ identical token stream)
    pub seed: u64,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts { max_new: 16, temp: 0.0, top_k: 0, seed: 7 }
    }
}

/// One finished generation: the sampled token ids plus the wall-clock
/// split between prompt prefill (the cached path's prompt pass, or the
/// recompute path's first full-prompt forward) and the decode loop — the
/// loop emits `tokens.len() − 1` incremental positions, the first token
/// being sampled from the prefill logits.
#[derive(Clone, Debug)]
pub struct Generated {
    pub tokens: Vec<usize>,
    pub prefill_secs: f64,
    pub decode_secs: f64,
}

impl Generated {
    /// Mean decode cost per *incremental step* (the first token rides the
    /// prefill, so `tokens.len() − 1` steps paid `decode_secs`).
    pub fn decode_secs_per_token(&self) -> f64 {
        self.decode_secs / self.tokens.len().saturating_sub(1).max(1) as f64
    }
}

/// The tied lm head of a packed model: the last unit must be a contraction
/// stack whose final layer maps the block width `d` to the vocabulary —
/// its rows double as the (dequantized) token embedding table.
pub fn lm_head(model: &PackedModel) -> Result<&PackedMatrix> {
    let unit = model.units.last().ok_or_else(|| anyhow!("empty packed model"))?;
    if unit.kind != "stack" {
        bail!(
            "generation needs a trailing lm-head stack unit; the last unit {:?} is a {:?}",
            unit.name,
            unit.kind
        );
    }
    let mat = &unit
        .layers
        .last()
        .ok_or_else(|| anyhow!("head unit {:?} has no layers", unit.name))?
        .mat;
    let d = model.in_width().unwrap_or(0);
    if mat.cols() != d {
        bail!(
            "lm head {:?} contracts {} columns but the model's token width is {d}; \
             tied embeddings need a (vocab, d) head",
            unit.name,
            mat.cols()
        );
    }
    Ok(mat)
}

/// Vocabulary size served by the tied head.
pub fn vocab(model: &PackedModel) -> Result<usize> {
    Ok(lm_head(model)?.rows())
}

/// Tied token embedding: the head matrix's dequantized row `tok`.
pub fn embed_token(model: &PackedModel, tok: usize) -> Result<Vec<f32>> {
    let mut row = Vec::new();
    embed_token_into(model, tok, &mut row)?;
    Ok(row)
}

/// [`embed_token`] into caller-owned scratch — the decode loop's per-step
/// path, which reuses `GenState`'s embedding buffer instead of allocating
/// one row per token.  The row decodes through the ISA-routed in-register
/// unpack; both arms produce identical bits (integer decode + exact int→f32
/// conversion), so the generate parity pins are arm-independent here.
pub fn embed_token_into(model: &PackedModel, tok: usize, row: &mut Vec<f32>) -> Result<()> {
    let m = lm_head(model)?;
    if tok >= m.rows() {
        bail!("token {tok} outside the {}-token head", m.rows());
    }
    row.clear();
    row.resize(m.cols(), 0.0);
    simd::unpack_codes_f32(Isa::active(), m.row_words(tok), m.cols(), m.bits(), m.qmin(), row);
    let (s, z) = (m.scale()[tok], m.zp()[tok]);
    for x in row.iter_mut() {
        *x = s * (*x - z);
    }
    Ok(())
}

/// Sample one token id from a logit row.  `temp == 0` is greedy argmax
/// (first maximum wins, deterministically); otherwise a max-shifted softmax
/// over `logits / temp`, restricted to the `top_k` highest logits when
/// `top_k ∈ [1, vocab)`, with ties broken by token id so the candidate set
/// is platform-deterministic.
pub fn sample_token(logits: &[f32], temp: f32, top_k: usize, rng: &mut Pcg32) -> usize {
    debug_assert!(!logits.is_empty());
    if temp <= 0.0 {
        let mut best = 0usize;
        for (j, &v) in logits.iter().enumerate() {
            let b = logits[best];
            // same deterministic rule as Tensor::argmax_rows: first maximum
            // wins, a NaN never beats a number (all-NaN rows yield 0)
            if (b.is_nan() && !v.is_nan()) || v > b {
                best = j;
            }
        }
        return best;
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        let by_logit_desc = |a: &usize, b: &usize| {
            logits[*b]
                .partial_cmp(&logits[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        // O(V) partition for the k largest, then order just those k — a
        // full-vocabulary sort per emitted token would put O(V log V) in
        // the decode hot loop.  The post-sort keeps the candidate *order*
        // (which the CDF walk below observes) deterministic regardless of
        // the partition's internal layout.
        idx.select_nth_unstable_by(top_k - 1, by_logit_desc);
        idx.truncate(top_k);
        idx.sort_unstable_by(by_logit_desc);
    }
    let scaled: Vec<f32> = idx.iter().map(|&j| logits[j] / temp).collect();
    // the most probable candidate (by raw logit, immune to scaled-overflow
    // ties): the fallback for both the degenerate regime below and the CDF
    // walk's residual rounding mass
    let mut bc = 0usize;
    for (c, &j) in idx.iter().enumerate() {
        if logits[j] > logits[idx[bc]] {
            bc = c;
        }
    }
    let lse = log_sum_exp(&scaled);
    if !lse.is_finite() {
        // a microscopic temperature (or huge logits) overflowed logits/temp:
        // the distribution is numerically a point mass — behave like greedy
        // instead of emitting NaN-driven garbage
        return idx[bc];
    }
    let mut u = rng.next_f32();
    let mut pick = idx[bc];
    for (c, &j) in idx.iter().enumerate() {
        let p = (scaled[c] - lse).exp();
        if u <= p {
            pick = j;
            break;
        }
        u -= p;
    }
    pick
}

/// KV-cached generation: prefill the prompt (`(t, d)` token rows), then
/// decode `opts.max_new` tokens incrementally — one [`Engine::decode_step`]
/// per token.
pub fn generate(engine: &Engine, prompt: &Tensor, opts: &GenOpts) -> Result<Generated> {
    let v = vocab(engine.model())?;
    let mut rng = Pcg32::seeded(opts.seed);
    let t0 = Instant::now();
    let (mut state, logits) = engine.prefill(prompt)?;
    let prefill_secs = t0.elapsed().as_secs_f64();
    let rows = logits.shape()[0];
    let width = logits.shape()[1];
    if width != v {
        bail!("prefill emitted {width}-wide rows, expected the {v}-token head");
    }
    let mut last: Vec<f32> = logits.as_f32()?[(rows - 1) * width..rows * width].to_vec();
    let mut tokens = Vec::with_capacity(opts.max_new);
    let t1 = Instant::now();
    // the embedding-row scratch lives in GenState: taken out for the loop
    // (decode_step needs &mut state alongside &row) and put back after, so
    // long decodes allocate one row total instead of one per token
    let mut row = std::mem::take(&mut state.embed_scratch);
    for _ in 0..opts.max_new {
        let tok = sample_token(&last, opts.temp, opts.top_k, &mut rng);
        tokens.push(tok);
        if tokens.len() == opts.max_new {
            break;
        }
        embed_token_into(engine.model(), tok, &mut row)?;
        last = engine.decode_step(&mut state, &row)?;
    }
    state.embed_scratch = row;
    Ok(Generated { tokens, prefill_secs, decode_secs: t1.elapsed().as_secs_f64() })
}

/// Full-context recompute baseline: the identical token stream (same seed ⇒
/// same samples off bit-identical logits), but every step re-forwards the
/// whole prefix through [`Engine::forward_ctx`] — O(t) GEMM work per token
/// where the cached path is O(1).  Exists as the parity check and the
/// bench baseline; never the serving path.
pub fn generate_recompute(engine: &Engine, prompt: &Tensor, opts: &GenOpts) -> Result<Generated> {
    let v = vocab(engine.model())?;
    let d = engine
        .model()
        .in_width()
        .ok_or_else(|| anyhow!("empty packed model"))?;
    if prompt.ndim() != 2 || prompt.shape()[0] == 0 || prompt.shape()[1] != d {
        bail!("recompute generation: prompt {:?}, expected (t ≥ 1, {d})", prompt.shape());
    }
    let mut rng = Pcg32::seeded(opts.seed);
    let mut work: Vec<f32> = prompt.as_f32()?.to_vec();
    let mut t = prompt.shape()[0];
    let mut tokens = Vec::with_capacity(opts.max_new);
    let t0 = Instant::now();
    // the first full-prompt forward is this path's prefill-equivalent —
    // reported as prefill_secs so decode_secs stays comparable with the
    // cached path's per-token decode loop
    let mut prefill_secs = 0.0f64;
    for step in 0..opts.max_new {
        let x = Tensor::from_f32(work.clone(), &[t, d])?;
        let logits = engine.forward_ctx(&x, t)?;
        if step == 0 {
            prefill_secs = t0.elapsed().as_secs_f64();
        }
        let width = logits.shape()[1];
        if width != v {
            bail!("forward emitted {width}-wide rows, expected the {v}-token head");
        }
        let lv = logits.as_f32()?;
        let tok = sample_token(&lv[(t - 1) * width..t * width], opts.temp, opts.top_k, &mut rng);
        tokens.push(tok);
        if tokens.len() == opts.max_new {
            break;
        }
        work.extend_from_slice(&embed_token(engine.model(), tok)?);
        t += 1;
    }
    Ok(Generated {
        tokens,
        prefill_secs,
        decode_secs: t0.elapsed().as_secs_f64() - prefill_secs,
    })
}

/// A self-contained random packed *language model*: `blocks` transformer
/// blocks (hidden `d`, `heads`, MLP width `mlp`, packed context `seq`)
/// followed by a tied `(vocab, d)` lm-head stack — everything [`generate`]
/// needs, no files.  Weight scales keep activations O(1) through the depth.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_lm(
    blocks: usize,
    d: usize,
    heads: usize,
    mlp: usize,
    seq: usize,
    vocab: usize,
    bits: u32,
    seed: u64,
) -> Result<PackedModel> {
    if blocks == 0 || heads == 0 || d % heads != 0 || vocab == 0 || seq == 0 || mlp == 0 {
        bail!(
            "synthetic lm: blocks/heads/mlp/vocab/seq must be ≥ 1 and heads must divide d \
             (got blocks={blocks} d={d} heads={heads} mlp={mlp} seq={seq} vocab={vocab})"
        );
    }
    let (qmin, qmax) = crate::tensor::qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let mut rng = Pcg32::seeded(seed);
    let mk = |rng: &mut Pcg32, rows: usize, cols: usize, s0: f32| -> Result<PackedMatrix> {
        let codes: Vec<i32> =
            (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> =
            (0..rows).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
        PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, vec![0.0; rows])
    };
    let layer = |name: &str, mat: PackedMatrix| PackedLayer {
        name: name.into(),
        mat,
        bias: None,
        relu_after: false,
        act: None,
    };
    // residual-friendly scales: uniform grid codes have rms ≈ qmax/√3, so
    // s0·qmax/√3·√cols ≈ 0.3 keeps each branch small next to the residual
    let s_d = 0.5 / (qmax.max(1) as f32 * (d as f32).sqrt());
    let s_mlp = 0.5 / (qmax.max(1) as f32 * (mlp as f32).sqrt());
    let mut units = Vec::with_capacity(blocks + 1);
    for ui in 0..blocks {
        units.push(PackedUnit {
            name: format!("blk{ui}"),
            kind: "transformer_block".into(),
            heads,
            seq,
            ln1: Some((vec![1.0; d], vec![0.0; d])),
            ln2: Some((vec![1.0; d], vec![0.0; d])),
            layers: vec![
                layer("wq", mk(&mut rng, d, d, s_d)?),
                layer("wk", mk(&mut rng, d, d, s_d)?),
                layer("wv", mk(&mut rng, d, d, s_d)?),
                layer("wo", mk(&mut rng, d, d, s_d)?),
                layer("up", mk(&mut rng, mlp, d, s_d)?),
                layer("down", mk(&mut rng, d, mlp, s_mlp)?),
            ],
        });
    }
    // head scale spreads logits over a few units so sampling has contrast
    let s_head = 3.0 / (qmax.max(1) as f32 * (d as f32).sqrt());
    units.push(PackedUnit::stack("head", vec![layer("lm", mk(&mut rng, vocab, d, s_head)?)]));
    Ok(PackedModel { units })
}

/// Deterministic prompt for demos/benches/loadgen: `len` tied-embedding
/// rows of random tokens drawn from the model's vocabulary (seeded apart
/// from the sampling stream so prompt and samples do not correlate).
pub fn random_prompt(model: &PackedModel, len: usize, seed: u64) -> Result<(Vec<usize>, Tensor)> {
    let v = vocab(model)?;
    let d = model.in_width().ok_or_else(|| anyhow!("empty packed model"))?;
    let mut rng = Pcg32::seeded(seed ^ 0x9E37_79B9);
    let n = len.max(1);
    let mut toks = Vec::with_capacity(n);
    let mut rows = Vec::with_capacity(n * d);
    for _ in 0..n {
        let t = rng.below(v as u32) as usize;
        toks.push(t);
        rows.extend_from_slice(&embed_token(model, t)?);
    }
    let x = Tensor::from_f32(rows, &[n, d])?;
    Ok((toks, x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Pcg32::seeded(1);
        let logits = [0.1f32, 2.5, -1.0, 2.5, 0.0];
        // first maximum wins on ties
        assert_eq!(sample_token(&logits, 0.0, 0, &mut rng), 1);
        assert_eq!(sample_token(&logits, 0.0, 3, &mut rng), 1);
        // NaN never wins — the same contract as Tensor::argmax_rows, so
        // argmax-based eval and greedy decode name the same token
        assert_eq!(sample_token(&[f32::NAN, 2.0, 1.0], 0.0, 0, &mut rng), 1);
        assert_eq!(sample_token(&[f32::NAN, f32::NAN], 0.0, 0, &mut rng), 0);
    }

    #[test]
    fn top_k_restricts_the_candidate_set() {
        let logits = [0.0f32, 10.0, -5.0, 9.0, 1.0];
        let mut rng = Pcg32::seeded(2);
        for _ in 0..200 {
            let t = sample_token(&logits, 1.0, 2, &mut rng);
            assert!(t == 1 || t == 3, "top-2 must only emit tokens 1/3, got {t}");
        }
        // full-vocab sampling with a huge temperature eventually leaves the
        // top-2 set
        let mut rng = Pcg32::seeded(3);
        let mut saw_other = false;
        for _ in 0..500 {
            let t = sample_token(&logits, 50.0, 0, &mut rng);
            if t != 1 && t != 3 {
                saw_other = true;
            }
        }
        assert!(saw_other, "unrestricted sampling should reach the tail");
    }

    #[test]
    fn microscopic_temperature_degenerates_to_greedy() {
        // logits/temp overflows f32 here — the sampler must behave like
        // argmax instead of emitting NaN-driven junk (PR 4 review fix)
        let logits = [1.0f32, 3.0, -2.0];
        let mut rng = Pcg32::seeded(8);
        for _ in 0..20 {
            assert_eq!(sample_token(&logits, 1e-40, 0, &mut rng), 1);
            assert_eq!(sample_token(&logits, 1e-40, 2, &mut rng), 1);
        }
    }

    #[test]
    fn sampling_survives_extreme_logits() {
        // ±90-range logits overflow a naive softmax; the max-shifted path
        // must keep sampling well-defined (and still prefer the peak)
        let logits = [90.0f32, -90.0, 0.0];
        let mut rng = Pcg32::seeded(4);
        for _ in 0..50 {
            assert_eq!(sample_token(&logits, 1.0, 0, &mut rng), 0);
        }
    }

    #[test]
    fn tied_embeddings_match_the_dequantized_head_rows() {
        let model = synthetic_lm(1, 8, 2, 16, 4, 10, 4, 9).unwrap();
        assert_eq!(vocab(&model).unwrap(), 10);
        let head = lm_head(&model).unwrap().clone();
        let w = head.dequantize().unwrap();
        let wv = w.as_f32().unwrap();
        for tok in [0usize, 3, 9] {
            let e = embed_token(&model, tok).unwrap();
            assert_eq!(e.as_slice(), &wv[tok * 8..(tok + 1) * 8], "embedding row {tok}");
        }
        assert!(embed_token(&model, 10).is_err());
    }

    #[test]
    fn models_without_a_tied_head_are_rejected() {
        let mut model = synthetic_lm(1, 8, 2, 16, 4, 10, 4, 9).unwrap();
        model.units.pop(); // drop the head: last unit is now a block
        assert!(lm_head(&model).is_err());
        let engine = Engine::new(model, 1);
        let (_, prompt) = {
            let full = synthetic_lm(1, 8, 2, 16, 4, 10, 4, 9).unwrap();
            random_prompt(&full, 3, 5).unwrap()
        };
        assert!(generate(&engine, &prompt, &GenOpts::default()).is_err());
    }

    #[test]
    fn synthetic_lm_shapes_and_determinism() {
        let a = synthetic_lm(2, 16, 4, 32, 8, 24, 4, 11).unwrap();
        let b = synthetic_lm(2, 16, 4, 32, 8, 24, 4, 11).unwrap();
        assert_eq!(a, b, "same seed must build the same model");
        assert_eq!(a.units.len(), 3);
        assert!(a.has_blocks());
        assert_eq!(a.in_width(), Some(16));
        assert_eq!(a.out_width(), Some(24));
        assert!(synthetic_lm(2, 16, 3, 32, 8, 24, 4, 11).is_err(), "heads must divide d");
    }
}
