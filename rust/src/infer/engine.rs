//! The quantized inference engine: a [`PackedModel`] plus the fused kernels,
//! exposed as a plain `forward` API.
//!
//! An [`Engine`] is the programmatic consumer of a packed artifact: load (or
//! build) a [`PackedModel`], then push activation batches through every
//! packed unit with [`kernels::gemm_fused`] — no FP weights, no manifest, no
//! backend.  `Session::forward_q` uses it as a fast path, `infer::serve`
//! wraps it in a micro-batched request queue, and the `infer`/`serve` CLI
//! subcommands drive it directly.
//!
//! Transformer-block units run every projection (`wq wk wv wo up down`)
//! through the same fused dequant-GEMM; layernorm, causal softmax attention
//! (shared with [`crate::block`]), GELU, and the residual adds stay f32.
//! Block models accept two input layouts: token rows `(n·seq, d)` (the
//! `Session::forward_q` chunk shape) and *flattened sequences*
//! `(n, seq·d)` — one request row per sequence — which is what
//! [`Engine::in_width`] advertises so the serving layer coalesces whole
//! sequences.

use super::kernels;
use super::packed::{PackedLayer, PackedMatrix, PackedModel, PackedUnit};
use crate::block::{attn_ctx, LN_EPS};
use crate::tensor::{layernorm_rows, Tensor};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};

/// A loaded packed model ready to serve forwards.
pub struct Engine {
    model: PackedModel,
    pub workers: usize,
}

impl Engine {
    pub fn new(model: PackedModel, workers: usize) -> Engine {
        Engine { model, workers: workers.max(1) }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Width of one *request row*: the first layer's columns, times the
    /// model's rows-per-sequence for transformer-block models (a request is
    /// one flattened sequence).
    pub fn in_width(&self) -> Result<usize> {
        let tok = self
            .model
            .in_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        Ok(tok * self.model.seq())
    }

    /// Width of one output row, matching [`Engine::in_width`]'s layout.
    pub fn out_width(&self) -> Result<usize> {
        let tok = self
            .model
            .out_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        Ok(tok * self.model.seq())
    }

    /// Batched quantized forward through every unit: `x` is `(n, in_width)`,
    /// the result `(n, out_width)`.  One fused GEMM per layer — the larger
    /// `n`, the better the packed-word traffic amortizes (which is what the
    /// serving layer's micro-batching buys).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, true)
    }

    /// Forward through the dequantize-then-matmul baseline kernel (bench and
    /// parity-check path; numerically equivalent to [`Engine::forward`] up
    /// to f32 summation order).
    pub fn forward_unfused(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, false)
    }

    fn forward_with(&self, x: &Tensor, fused: bool) -> Result<Tensor> {
        let seq = self.model.seq();
        let tok_w = self
            .model
            .in_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        // flattened-sequence entry: one row per sequence (the serving shape)
        let flat = x.ndim() == 2 && seq > 1 && x.shape()[1] == seq * tok_w;
        let mut h = if flat {
            x.reshape(&[x.shape()[0] * seq, tok_w])?
        } else {
            x.clone()
        };
        for unit in &self.model.units {
            if unit.kind == "transformer_block" {
                h = self.block_forward(unit, &h, fused)?;
                continue;
            }
            for layer in &unit.layers {
                let mut y = if fused {
                    kernels::gemm_fused(&h, &layer.mat, self.workers)?
                } else {
                    kernels::dequant_matmul(&h, &layer.mat)?
                };
                y.bias_relu_inplace(layer.bias.as_deref(), layer.relu_after)?;
                h = y;
            }
        }
        if flat {
            let rows = x.shape()[0];
            let width = h.len() / rows.max(1);
            h = h.reshape(&[rows, width])?;
        }
        Ok(h)
    }

    /// One transformer block over token rows `(n·seq, d)`: fused dequant
    /// GEMMs for all six projections, f32 layernorm / causal attention /
    /// GELU / residuals — the same math as `block::forward_with`, with the
    /// packed matrices never dequantized into a dense Ŵ.
    fn block_forward(&self, unit: &PackedUnit, h: &Tensor, fused: bool) -> Result<Tensor> {
        let [wq, wk, wv, wo, up, down] = match unit.layers.as_slice() {
            [a, b, c, d, e, f] => [a, b, c, d, e, f],
            _ => bail!(
                "block unit {:?} has {} layers, expected the canonical 6",
                unit.name,
                unit.layers.len()
            ),
        };
        let (g1, b1) = unit
            .ln1
            .as_ref()
            .ok_or_else(|| anyhow!("block unit {:?} lacks ln1 parameters", unit.name))?;
        let (g2, b2) = unit
            .ln2
            .as_ref()
            .ok_or_else(|| anyhow!("block unit {:?} lacks ln2 parameters", unit.name))?;
        if unit.seq == 0 || h.ndim() != 2 || h.shape()[0] % unit.seq != 0 {
            bail!(
                "block unit {:?}: input {:?} rows must be a multiple of seq {}",
                unit.name,
                h.shape(),
                unit.seq
            );
        }
        let gemm = |x: &Tensor, l: &PackedLayer| -> Result<Tensor> {
            let mut y = if fused {
                kernels::gemm_fused(x, &l.mat, self.workers)?
            } else {
                kernels::dequant_matmul(x, &l.mat)?
            };
            y.bias_relu_inplace(l.bias.as_deref(), false)?;
            Ok(y)
        };
        let (h1, _, _) = layernorm_rows(h, g1, b1, LN_EPS)?;
        let q = gemm(&h1, wq)?;
        let k = gemm(&h1, wk)?;
        let v = gemm(&h1, wv)?;
        let ctx = attn_ctx(&q, &k, &v, unit.heads, unit.seq)?;
        let attn = gemm(&ctx, wo)?;
        let x2 = h.zip(&attn, |a, b| a + b)?;
        let (h2, _, _) = layernorm_rows(&x2, g2, b2, LN_EPS)?;
        let m = gemm(&h2, up)?.gelu();
        let y = gemm(&m, down)?;
        x2.zip(&y, |a, b| a + b)
    }

    /// Single-row forward (the serving fallback for a batch of one).
    pub fn forward_row(&self, row: &[f32]) -> Result<Vec<f32>> {
        let x = Tensor::from_f32(row.to_vec(), &[1, row.len()])?;
        Ok(self.forward(&x)?.as_f32()?.to_vec())
    }
}

/// A self-contained random packed model (demo / bench / serve-loadgen input
/// when no real artifact is at hand): `units` chained square `width×width`
/// contraction units at `bits`, symmetric grid, small scales so activations
/// stay O(1) through the chain.
pub fn synthetic_model(units: usize, width: usize, bits: u32, seed: u64) -> Result<PackedModel> {
    let (qmin, qmax) = crate::tensor::qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let mut rng = Pcg32::seeded(seed);
    // keep ‖Ŵ·x‖ ≈ ‖x‖: scale ~ 1/(|grid|·√width)
    let s0 = 2.0 / (qmax.max(1) as f32 * (width as f32).sqrt());
    let mut out = Vec::with_capacity(units);
    for ui in 0..units {
        let codes: Vec<i32> =
            (0..width * width).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..width).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
        let zp = vec![0.0f32; width];
        let mat = PackedMatrix::pack(&codes, width, width, bits, qmin, scale, zp)?;
        out.push(PackedUnit::stack(
            &format!("u{ui}"),
            vec![PackedLayer { name: "fc".into(), mat, bias: None, relu_after: false }],
        ));
    }
    Ok(PackedModel { units: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_forward_shapes_and_parity() {
        let model = synthetic_model(3, 24, 4, 11).unwrap();
        let engine = Engine::new(model, 2);
        assert_eq!(engine.in_width().unwrap(), 24);
        assert_eq!(engine.out_width().unwrap(), 24);
        let mut rng = Pcg32::seeded(5);
        let x = Tensor::from_f32((0..4 * 24).map(|_| rng.next_normal()).collect(), &[4, 24])
            .unwrap();
        let fused = engine.forward(&x).unwrap();
        let unfused = engine.forward_unfused(&x).unwrap();
        assert_eq!(fused.shape(), &[4, 24]);
        let d = fused.max_abs_diff(&unfused).unwrap();
        assert!(d <= 1e-4 * (1.0 + unfused.abs_max()), "fused vs unfused max|Δ| {d}");
        // single-row API agrees with the batch API
        let row = engine.forward_row(x.slice_rows(0, 1).unwrap().as_f32().unwrap()).unwrap();
        for (a, b) in row.iter().zip(fused.as_f32().unwrap()) {
            assert!((a - b).abs() <= 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn bias_and_relu_are_applied() {
        // 1×1 identity-ish layer: code 1, scale 2 → Ŵ = [[2]]; bias −5;
        // ReLU clips the negative result.
        let mat = PackedMatrix::pack(&[1], 1, 1, 4, -8, vec![2.0], vec![0.0]).unwrap();
        let model = PackedModel {
            units: vec![PackedUnit::stack(
                "u",
                vec![PackedLayer {
                    name: "fc".into(),
                    mat,
                    bias: Some(vec![-5.0]),
                    relu_after: true,
                }],
            )],
        };
        let engine = Engine::new(model, 1);
        let y = engine.forward(&Tensor::from_f32(vec![1.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[0.0]); // relu(2·1 − 5)
        let y = engine.forward(&Tensor::from_f32(vec![4.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.0]); // relu(2·4 − 5)
    }

    #[test]
    fn empty_model_is_rejected() {
        let engine = Engine::new(PackedModel::default(), 1);
        assert!(engine.in_width().is_err());
    }

    /// Random packed transformer block (one unit) for engine tests.
    fn block_model(d: usize, mlp: usize, heads: usize, seq: usize) -> PackedModel {
        let mut rng = Pcg32::seeded(41);
        let mut mk = |rows: usize, cols: usize| {
            let codes: Vec<i32> =
                (0..rows * cols).map(|_| -8 + rng.below(16) as i32).collect();
            let s0 = 1.0 / (8.0 * (cols as f32).sqrt());
            let scale: Vec<f32> = (0..rows).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
            PackedMatrix::pack(&codes, rows, cols, 4, -8, scale, vec![0.0; rows]).unwrap()
        };
        let mut mats = vec![mk(d, d), mk(d, d), mk(d, d), mk(d, d), mk(mlp, d), mk(d, mlp)];
        let layer = |name: &str, mat: PackedMatrix| PackedLayer {
            name: name.into(),
            mat,
            bias: None,
            relu_after: false,
        };
        let unit = PackedUnit {
            name: "blk".into(),
            kind: "transformer_block".into(),
            heads,
            seq,
            ln1: Some((vec![1.0; d], vec![0.0; d])),
            ln2: Some((vec![1.0; d], vec![0.0; d])),
            layers: vec![
                layer("wq", mats.remove(0)),
                layer("wk", mats.remove(0)),
                layer("wv", mats.remove(0)),
                layer("wo", mats.remove(0)),
                layer("up", mats.remove(0)),
                layer("down", mats.remove(0)),
            ],
        };
        PackedModel { units: vec![unit] }
    }

    #[test]
    fn block_forward_token_and_flat_entries_agree() {
        let (d, mlp, heads, seq) = (8usize, 16usize, 2usize, 4usize);
        let engine = Engine::new(block_model(d, mlp, heads, seq), 2);
        // request width is one flattened sequence
        assert_eq!(engine.in_width().unwrap(), seq * d);
        let mut rng = Pcg32::seeded(6);
        let nseq = 3usize;
        let tokens = Tensor::from_f32(
            (0..nseq * seq * d).map(|_| rng.next_normal()).collect(),
            &[nseq * seq, d],
        )
        .unwrap();
        let toks_out = engine.forward(&tokens).unwrap();
        assert_eq!(toks_out.shape(), &[nseq * seq, d]);
        // same data as flattened sequences → same numbers, reshaped
        let flat = tokens.reshape(&[nseq, seq * d]).unwrap();
        let flat_out = engine.forward(&flat).unwrap();
        assert_eq!(flat_out.shape(), &[nseq, seq * d]);
        assert_eq!(
            toks_out.as_f32().unwrap(),
            flat_out.as_f32().unwrap(),
            "flattened-sequence entry must match the token-row entry"
        );
        // fused vs dequantize-then-matmul parity through the whole block
        let unfused = engine.forward_unfused(&tokens).unwrap();
        let dmax = toks_out.max_abs_diff(&unfused).unwrap();
        assert!(dmax <= 1e-4 * (1.0 + unfused.abs_max()), "fused block drift {dmax}");
        // rows not a multiple of seq are rejected
        let bad = Tensor::from_f32(vec![0.0; 3 * d], &[3, d]).unwrap();
        assert!(engine.forward(&bad).is_err());
        // serving row API: one flattened sequence in, one out
        let row = engine.forward_row(flat.slice_rows(0, 1).unwrap().as_f32().unwrap()).unwrap();
        assert_eq!(row.len(), seq * d);
        for (a, b) in row.iter().zip(flat_out.as_f32().unwrap()) {
            assert!((a - b).abs() <= 1e-5);
        }
    }
}
