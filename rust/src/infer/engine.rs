//! The quantized inference engine: a [`PackedModel`] plus the fused kernels,
//! exposed as a plain `forward` API.
//!
//! An [`Engine`] is the programmatic consumer of a packed artifact: load (or
//! build) a [`PackedModel`], then push activation batches through every
//! packed unit with [`kernels::gemm_fused`] — no FP weights, no manifest, no
//! backend.  `Session::forward_q` uses it as a fast path, `infer::serve`
//! wraps it in a micro-batched request queue, and the `infer`/`serve` CLI
//! subcommands drive it directly.

use super::kernels;
use super::packed::{PackedLayer, PackedMatrix, PackedModel, PackedUnit};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::anyhow;

/// A loaded packed model ready to serve forwards.
pub struct Engine {
    model: PackedModel,
    pub workers: usize,
}

impl Engine {
    pub fn new(model: PackedModel, workers: usize) -> Engine {
        Engine { model, workers: workers.max(1) }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Input width the engine expects (first packed layer's columns).
    pub fn in_width(&self) -> Result<usize> {
        self.model.in_width().ok_or_else(|| anyhow!("engine holds an empty packed model"))
    }

    /// Output width the engine produces (last packed layer's rows).
    pub fn out_width(&self) -> Result<usize> {
        self.model.out_width().ok_or_else(|| anyhow!("engine holds an empty packed model"))
    }

    /// Batched quantized forward through every unit: `x` is `(n, in_width)`,
    /// the result `(n, out_width)`.  One fused GEMM per layer — the larger
    /// `n`, the better the packed-word traffic amortizes (which is what the
    /// serving layer's micro-batching buys).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, true)
    }

    /// Forward through the dequantize-then-matmul baseline kernel (bench and
    /// parity-check path; numerically equivalent to [`Engine::forward`] up
    /// to f32 summation order).
    pub fn forward_unfused(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, false)
    }

    fn forward_with(&self, x: &Tensor, fused: bool) -> Result<Tensor> {
        let mut h = x.clone();
        for unit in &self.model.units {
            for layer in &unit.layers {
                let mut y = if fused {
                    kernels::gemm_fused(&h, &layer.mat, self.workers)?
                } else {
                    kernels::dequant_matmul(&h, &layer.mat)?
                };
                y.bias_relu_inplace(layer.bias.as_deref(), layer.relu_after)?;
                h = y;
            }
        }
        Ok(h)
    }

    /// Single-row forward (the serving fallback for a batch of one).
    pub fn forward_row(&self, row: &[f32]) -> Result<Vec<f32>> {
        let x = Tensor::from_f32(row.to_vec(), &[1, row.len()])?;
        Ok(self.forward(&x)?.as_f32()?.to_vec())
    }
}

/// A self-contained random packed model (demo / bench / serve-loadgen input
/// when no real artifact is at hand): `units` chained square `width×width`
/// contraction units at `bits`, symmetric grid, small scales so activations
/// stay O(1) through the chain.
pub fn synthetic_model(units: usize, width: usize, bits: u32, seed: u64) -> Result<PackedModel> {
    let (qmin, qmax) = crate::tensor::qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let mut rng = Pcg32::seeded(seed);
    // keep ‖Ŵ·x‖ ≈ ‖x‖: scale ~ 1/(|grid|·√width)
    let s0 = 2.0 / (qmax.max(1) as f32 * (width as f32).sqrt());
    let mut out = Vec::with_capacity(units);
    for ui in 0..units {
        let codes: Vec<i32> =
            (0..width * width).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..width).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
        let zp = vec![0.0f32; width];
        let mat = PackedMatrix::pack(&codes, width, width, bits, qmin, scale, zp)?;
        out.push(PackedUnit {
            name: format!("u{ui}"),
            layers: vec![PackedLayer { name: "fc".into(), mat, bias: None, relu_after: false }],
        });
    }
    Ok(PackedModel { units: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_forward_shapes_and_parity() {
        let model = synthetic_model(3, 24, 4, 11).unwrap();
        let engine = Engine::new(model, 2);
        assert_eq!(engine.in_width().unwrap(), 24);
        assert_eq!(engine.out_width().unwrap(), 24);
        let mut rng = Pcg32::seeded(5);
        let x = Tensor::from_f32((0..4 * 24).map(|_| rng.next_normal()).collect(), &[4, 24])
            .unwrap();
        let fused = engine.forward(&x).unwrap();
        let unfused = engine.forward_unfused(&x).unwrap();
        assert_eq!(fused.shape(), &[4, 24]);
        let d = fused.max_abs_diff(&unfused).unwrap();
        assert!(d <= 1e-4 * (1.0 + unfused.abs_max()), "fused vs unfused max|Δ| {d}");
        // single-row API agrees with the batch API
        let row = engine.forward_row(x.slice_rows(0, 1).unwrap().as_f32().unwrap()).unwrap();
        for (a, b) in row.iter().zip(fused.as_f32().unwrap()) {
            assert!((a - b).abs() <= 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn bias_and_relu_are_applied() {
        // 1×1 identity-ish layer: code 1, scale 2 → Ŵ = [[2]]; bias −5;
        // ReLU clips the negative result.
        let mat = PackedMatrix::pack(&[1], 1, 1, 4, -8, vec![2.0], vec![0.0]).unwrap();
        let model = PackedModel {
            units: vec![PackedUnit {
                name: "u".into(),
                layers: vec![PackedLayer {
                    name: "fc".into(),
                    mat,
                    bias: Some(vec![-5.0]),
                    relu_after: true,
                }],
            }],
        };
        let engine = Engine::new(model, 1);
        let y = engine.forward(&Tensor::from_f32(vec![1.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[0.0]); // relu(2·1 − 5)
        let y = engine.forward(&Tensor::from_f32(vec![4.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.0]); // relu(2·4 − 5)
    }

    #[test]
    fn empty_model_is_rejected() {
        let engine = Engine::new(PackedModel::default(), 1);
        assert!(engine.in_width().is_err());
    }
}
