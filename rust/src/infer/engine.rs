//! The quantized inference engine: a [`PackedModel`] plus the fused kernels,
//! exposed as a plain `forward` API.
//!
//! An [`Engine`] is the programmatic consumer of a packed artifact: load (or
//! build) a [`PackedModel`], then push activation batches through every
//! packed unit with [`kernels::gemm_fused`] — no FP weights, no manifest, no
//! backend.  `Session::forward_q` uses it as a fast path, `infer::serve`
//! wraps it in a micro-batched request queue, and the `infer`/`serve` CLI
//! subcommands drive it directly.
//!
//! Transformer-block units run every projection (`wq wk wv wo up down`)
//! through the same fused dequant-GEMM — which itself runs the crate-wide
//! [`crate::linalg`] tile loop, so serving shares one kernel core and one
//! parallel-dispatch policy with reconstruction and eval; layernorm, causal
//! softmax attention (shared with [`crate::block`]), GELU, and the residual
//! adds stay f32.
//! Beyond the batch `forward`, block models expose the incremental decode
//! pair [`Engine::prefill`] / [`Engine::decode_step`] over a per-block
//! [`KvCache`] — one token per step, attention against the cached K/V rows
//! only — plus [`Engine::forward_ctx`] (full-context forward at an explicit
//! sequence length), the decode path's parity oracle and recompute
//! baseline.  `infer::generate` wires these into token sampling.
//! Block models accept two input layouts: token rows `(n·seq, d)` (the
//! `Session::forward_q` chunk shape) and *flattened sequences*
//! `(n, seq·d)` — one request row per sequence — which is what
//! [`Engine::in_width`] advertises so the serving layer coalesces whole
//! sequences.

use super::kernels;
use super::kv::{BlockKv, GenState, KvCache};
use super::packed::{PackedLayer, PackedMatrix, PackedModel, PackedUnit};
use crate::block::{attn_ctx, attn_score_row, LN_EPS};
use crate::tensor::{layernorm_rows, Tensor};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};

/// A loaded packed model ready to serve forwards.
pub struct Engine {
    model: PackedModel,
    pub workers: usize,
}

impl Engine {
    pub fn new(model: PackedModel, workers: usize) -> Engine {
        Engine { model, workers: workers.max(1) }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Take the packed model back out (calibration builds an engine to walk
    /// units, then mutates the model it walked).
    pub fn into_model(self) -> PackedModel {
        self.model
    }

    /// Forward `h` through one unit on the fused path — the body of one
    /// [`Engine::forward`] step, exposed so calibration walks can observe
    /// the activations *between* units.
    pub(crate) fn unit_forward(&self, unit: &PackedUnit, h: &Tensor) -> Result<Tensor> {
        if unit.kind == "transformer_block" {
            self.block_forward(unit, h, true, unit.seq)
        } else {
            self.stack_forward(unit, h, true)
        }
    }

    /// Width of one *request row*: the first layer's columns, times the
    /// model's rows-per-sequence for transformer-block models (a request is
    /// one flattened sequence).
    pub fn in_width(&self) -> Result<usize> {
        let tok = self
            .model
            .in_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        Ok(tok * self.model.seq())
    }

    /// Width of one output row, matching [`Engine::in_width`]'s layout.
    pub fn out_width(&self) -> Result<usize> {
        let tok = self
            .model
            .out_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        Ok(tok * self.model.seq())
    }

    /// Batched quantized forward through every unit: `x` is `(n, in_width)`,
    /// the result `(n, out_width)`.  One fused GEMM per layer — the larger
    /// `n`, the better the packed-word traffic amortizes (which is what the
    /// serving layer's micro-batching buys).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, true)
    }

    /// Forward through the dequantize-then-matmul baseline kernel (bench and
    /// parity-check path; numerically equivalent to [`Engine::forward`] up
    /// to f32 summation order).
    pub fn forward_unfused(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, false)
    }

    fn forward_with(&self, x: &Tensor, fused: bool) -> Result<Tensor> {
        let seq = self.model.seq();
        let tok_w = self
            .model
            .in_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        // flattened-sequence entry: one row per sequence (the serving shape)
        let flat = x.ndim() == 2 && seq > 1 && x.shape()[1] == seq * tok_w;
        let mut h = if flat {
            x.reshape(&[x.shape()[0] * seq, tok_w])?
        } else {
            x.clone()
        };
        for unit in &self.model.units {
            h = if unit.kind == "transformer_block" {
                self.block_forward(unit, &h, fused, unit.seq)?
            } else {
                self.stack_forward(unit, &h, fused)?
            };
        }
        if flat {
            let rows = x.shape()[0];
            let width = h.len() / rows.max(1);
            h = h.reshape(&[rows, width])?;
        }
        Ok(h)
    }

    /// Full-context forward with an explicit rows-per-sequence `seq`
    /// overriding every block's packed `seq`: the attention geometry carries
    /// no learned positional state, so any context length works.  This is
    /// the generation path's full-recompute baseline and the parity oracle
    /// for [`Engine::prefill`] + [`Engine::decode_step`]
    /// (`rust/tests/generate.rs`).  Token-rows entry only (`x` is
    /// `(n·seq, d)`).
    pub fn forward_ctx(&self, x: &Tensor, seq: usize) -> Result<Tensor> {
        if seq == 0 {
            bail!("forward_ctx: seq must be ≥ 1");
        }
        let mut h = x.clone();
        for unit in &self.model.units {
            h = if unit.kind == "transformer_block" {
                self.block_forward(unit, &h, true, seq)?
            } else {
                self.stack_forward(unit, &h, true)?
            };
        }
        Ok(h)
    }

    /// One layer's GEMM on the right kernel: layers carrying a calibrated
    /// activation grid (W4A8 artifacts) run the integer-domain
    /// [`kernels::gemm_fused_act_int`]; everything else takes the f32 fused
    /// path (or the dequantize-then-matmul baseline when `fused` is off —
    /// which also serves as the act-layers' f32 reference path, activations
    /// fake-quantized first so both kernels see the same grid).
    fn layer_gemm(&self, x: &Tensor, l: &PackedLayer, fused: bool) -> Result<Tensor> {
        match (&l.act, fused) {
            (Some(aq), true) => kernels::gemm_fused_act_int(x, aq, &l.mat, self.workers),
            (Some(aq), false) => kernels::dequant_matmul(&aq.fake_quant(x)?, &l.mat),
            (None, true) => kernels::gemm_fused(x, &l.mat, self.workers),
            (None, false) => kernels::dequant_matmul(x, &l.mat),
        }
    }

    /// An ordered contraction stack over activation rows.
    pub(crate) fn stack_forward(&self, unit: &PackedUnit, h: &Tensor, fused: bool) -> Result<Tensor> {
        let mut out: Option<Tensor> = None;
        for layer in &unit.layers {
            let x = out.as_ref().unwrap_or(h);
            let mut y = self.layer_gemm(x, layer, fused)?;
            y.bias_relu_inplace(layer.bias.as_deref(), layer.relu_after)?;
            out = Some(y);
        }
        out.ok_or_else(|| anyhow!("unit {:?} has no layers", unit.name))
    }

    /// Fused (or baseline) GEMM plus bias for one packed projection.
    pub(crate) fn gemm_bias(&self, x: &Tensor, l: &PackedLayer, fused: bool) -> Result<Tensor> {
        let mut y = self.layer_gemm(x, l, fused)?;
        y.bias_relu_inplace(l.bias.as_deref(), false)?;
        Ok(y)
    }

    /// One transformer block over token rows `(n·seq, d)` at an explicit
    /// `seq`: fused dequant GEMMs for all six projections, f32 layernorm /
    /// causal attention / GELU / residuals — the same math as
    /// `block::forward_with`, with the packed matrices never dequantized
    /// into a dense Ŵ.
    fn block_forward(
        &self,
        unit: &PackedUnit,
        h: &Tensor,
        fused: bool,
        seq: usize,
    ) -> Result<Tensor> {
        let p = block_parts(unit)?;
        if seq == 0 || h.ndim() != 2 || h.shape()[0] % seq != 0 {
            bail!(
                "block unit {:?}: input {:?} rows must be a multiple of seq {seq}",
                unit.name,
                h.shape()
            );
        }
        let (h1, _, _) = layernorm_rows(h, p.g1, p.b1, LN_EPS)?;
        let q = self.gemm_bias(&h1, p.wq, fused)?;
        let k = self.gemm_bias(&h1, p.wk, fused)?;
        let v = self.gemm_bias(&h1, p.wv, fused)?;
        let ctx = attn_ctx(&q, &k, &v, unit.heads, seq)?;
        self.block_tail(&p, h, &ctx, fused)
    }

    /// Post-attention half of a block (`wo` projection, residual, MLP) —
    /// shared by the full-context, prefill, incremental decode, and
    /// continuous-batching ([`crate::sched`]) paths.
    pub(crate) fn block_tail(
        &self,
        p: &BlockParts,
        x: &Tensor,
        ctx: &Tensor,
        fused: bool,
    ) -> Result<Tensor> {
        let attn = self.gemm_bias(ctx, p.wo, fused)?;
        let x2 = x.zip(&attn, |a, b| a + b)?;
        let (h2, _, _) = layernorm_rows(&x2, p.g2, p.b2, LN_EPS)?;
        let m = self.gemm_bias(&h2, p.up, fused)?.gelu();
        let y = self.gemm_bias(&m, p.down, fused)?;
        x2.zip(&y, |a, b| a + b)
    }

    /// One block over the whole prompt (a single sequence of `t` rows) —
    /// the same math as [`Engine::block_forward`] at `seq = t`, additionally
    /// pushing every K/V row into `kv` for later decode steps.
    fn block_prefill(
        &self,
        unit: &PackedUnit,
        h: &Tensor,
        t: usize,
        kv: &mut BlockKv,
    ) -> Result<Tensor> {
        let p = block_parts(unit)?;
        let (h1, _, _) = layernorm_rows(h, p.g1, p.b1, LN_EPS)?;
        let q = self.gemm_bias(&h1, p.wq, true)?;
        let k = self.gemm_bias(&h1, p.wk, true)?;
        let v = self.gemm_bias(&h1, p.wv, true)?;
        kv.extend(k.as_f32()?, v.as_f32()?)?;
        let ctx = attn_ctx(&q, &k, &v, unit.heads, t)?;
        self.block_tail(&p, h, &ctx, true)
    }

    /// One block over one new token row: append its K/V rows to the cache
    /// and attend against everything cached (the causal mask degenerates to
    /// "attend to all cached positions").
    fn block_decode(
        &self,
        unit: &PackedUnit,
        x: &Tensor,
        kv: &mut BlockKv,
        probs: &mut Vec<f32>,
    ) -> Result<Tensor> {
        let p = block_parts(unit)?;
        let (h1, _, _) = layernorm_rows(x, p.g1, p.b1, LN_EPS)?;
        let q = self.gemm_bias(&h1, p.wq, true)?;
        let k = self.gemm_bias(&h1, p.wk, true)?;
        let v = self.gemm_bias(&h1, p.wv, true)?;
        kv.extend(k.as_f32()?, v.as_f32()?)?;
        let d = kv.width();
        let heads = unit.heads.max(1);
        if d % heads != 0 {
            bail!("block unit {:?}: width {d} not divisible by {heads} heads", unit.name);
        }
        let dh = d / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let count = kv.len();
        if probs.len() < count {
            probs.resize(count, 0.0);
        }
        let qv = q.as_f32()?;
        let mut ctx = vec![0.0f32; d];
        for hd in 0..heads {
            let c0 = hd * dh;
            attn_score_row(
                &qv[c0..c0 + dh],
                kv.k(),
                kv.v(),
                d,
                c0,
                count,
                scale,
                probs,
                &mut ctx[c0..c0 + dh],
            );
        }
        let ctx = Tensor::from_f32(ctx, &[1, d])?;
        self.block_tail(&p, x, &ctx, true)
    }

    /// Run the whole prompt (`(t ≥ 1, d)` token rows, one sequence) through
    /// the model once, filling a fresh KV cache with every block's key/value
    /// rows, and return the generation state plus the output at **all** `t`
    /// positions (`(t, out_width)` — logits when the model ends in an
    /// lm-head stack).  Bit-for-bit equivalent to
    /// [`Engine::forward_ctx`]`(x, t)`: that is the prefill/decode parity
    /// contract (`rust/tests/generate.rs`).
    pub fn prefill(&self, x: &Tensor) -> Result<(GenState, Tensor)> {
        if x.ndim() != 2 || x.shape()[0] == 0 {
            bail!("prefill: prompt must be (t ≥ 1, d) token rows, got {:?}", x.shape());
        }
        let t = x.shape()[0];
        let mut dims = Vec::new();
        for u in self.model.units.iter().filter(|u| u.kind == "transformer_block") {
            let d = u
                .layers
                .first()
                .map(|l| l.mat.cols())
                .ok_or_else(|| anyhow!("block unit {:?} has no layers", u.name))?;
            dims.push(d);
        }
        let mut kv = KvCache::new(&dims, t + self.model.seq());
        let mut h = x.clone();
        let mut bi = 0usize;
        for unit in &self.model.units {
            h = if unit.kind == "transformer_block" {
                let out = self.block_prefill(unit, &h, t, kv.block_mut(bi)?)?;
                bi += 1;
                out
            } else {
                self.stack_forward(unit, &h, true)?
            };
        }
        kv.set_pos(t)?;
        Ok((GenState::new(kv), h))
    }

    /// Advance generation by one token: `row` is the token's input
    /// embedding (the model's token width).  Appends the token's K/V rows
    /// to every block's cache, attends against everything cached, and
    /// returns this position's output row — logits when the packed model
    /// ends in an lm-head stack.  Cost is O(1) in the generated length for
    /// the GEMMs and O(t) for the attention reads, versus O(t) GEMMs for a
    /// full-context recompute.  Every projection here is a batch-1 fused
    /// GEMM, which `kernels` routes to the shared `linalg::gemv_nt` core —
    /// bit-identical to the batched tile loop, minus its bookkeeping (tile
    /// overhead is pure loss at one row, and decode is the latency path).
    pub fn decode_step(&self, state: &mut GenState, row: &[f32]) -> Result<Vec<f32>> {
        let tok_w = self
            .model
            .in_width()
            .ok_or_else(|| anyhow!("engine holds an empty packed model"))?;
        if row.len() != tok_w {
            bail!("decode_step: input row has {} values, the model takes {tok_w}", row.len());
        }
        let mut h = Tensor::from_f32(row.to_vec(), &[1, tok_w])?;
        let mut bi = 0usize;
        for unit in &self.model.units {
            h = if unit.kind == "transformer_block" {
                let out =
                    self.block_decode(unit, &h, state.kv.block_mut(bi)?, &mut state.probs_scratch)?;
                bi += 1;
                out
            } else {
                self.stack_forward(unit, &h, true)?
            };
        }
        state.kv.advance()?;
        Ok(h.as_f32()?.to_vec())
    }

    /// Single-row forward (the serving fallback for a batch of one).
    pub fn forward_row(&self, row: &[f32]) -> Result<Vec<f32>> {
        let x = Tensor::from_f32(row.to_vec(), &[1, row.len()])?;
        Ok(self.forward(&x)?.as_f32()?.to_vec())
    }
}

/// Borrowed views of one packed transformer block's six projections and
/// layernorm parameters (validated once per unit call).
pub(crate) struct BlockParts<'a> {
    pub(crate) wq: &'a PackedLayer,
    pub(crate) wk: &'a PackedLayer,
    pub(crate) wv: &'a PackedLayer,
    pub(crate) wo: &'a PackedLayer,
    pub(crate) up: &'a PackedLayer,
    pub(crate) down: &'a PackedLayer,
    pub(crate) g1: &'a [f32],
    pub(crate) b1: &'a [f32],
    pub(crate) g2: &'a [f32],
    pub(crate) b2: &'a [f32],
}

pub(crate) fn block_parts(unit: &PackedUnit) -> Result<BlockParts<'_>> {
    let [wq, wk, wv, wo, up, down] = match unit.layers.as_slice() {
        [a, b, c, d, e, f] => [a, b, c, d, e, f],
        _ => bail!(
            "block unit {:?} has {} layers, expected the canonical 6",
            unit.name,
            unit.layers.len()
        ),
    };
    let (g1, b1) = unit
        .ln1
        .as_ref()
        .map(|(g, b)| (g.as_slice(), b.as_slice()))
        .ok_or_else(|| anyhow!("block unit {:?} lacks ln1 parameters", unit.name))?;
    let (g2, b2) = unit
        .ln2
        .as_ref()
        .map(|(g, b)| (g.as_slice(), b.as_slice()))
        .ok_or_else(|| anyhow!("block unit {:?} lacks ln2 parameters", unit.name))?;
    Ok(BlockParts { wq, wk, wv, wo, up, down, g1, b1, g2, b2 })
}

/// A self-contained random packed model (demo / bench / serve-loadgen input
/// when no real artifact is at hand): `units` chained square `width×width`
/// contraction units at `bits`, symmetric grid, small scales so activations
/// stay O(1) through the chain.
pub fn synthetic_model(units: usize, width: usize, bits: u32, seed: u64) -> Result<PackedModel> {
    let (qmin, qmax) = crate::tensor::qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let mut rng = Pcg32::seeded(seed);
    // keep ‖Ŵ·x‖ ≈ ‖x‖: scale ~ 1/(|grid|·√width)
    let s0 = 2.0 / (qmax.max(1) as f32 * (width as f32).sqrt());
    let mut out = Vec::with_capacity(units);
    for ui in 0..units {
        let codes: Vec<i32> =
            (0..width * width).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..width).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
        let zp = vec![0.0f32; width];
        let mat = PackedMatrix::pack(&codes, width, width, bits, qmin, scale, zp)?;
        out.push(PackedUnit::stack(
            &format!("u{ui}"),
            vec![PackedLayer { name: "fc".into(), mat, bias: None, relu_after: false, act: None }],
        ));
    }
    Ok(PackedModel { units: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_forward_shapes_and_parity() {
        let model = synthetic_model(3, 24, 4, 11).unwrap();
        let engine = Engine::new(model, 2);
        assert_eq!(engine.in_width().unwrap(), 24);
        assert_eq!(engine.out_width().unwrap(), 24);
        let mut rng = Pcg32::seeded(5);
        let x = Tensor::from_f32((0..4 * 24).map(|_| rng.next_normal()).collect(), &[4, 24])
            .unwrap();
        let fused = engine.forward(&x).unwrap();
        let unfused = engine.forward_unfused(&x).unwrap();
        assert_eq!(fused.shape(), &[4, 24]);
        let d = fused.max_abs_diff(&unfused).unwrap();
        assert!(d <= 1e-4 * (1.0 + unfused.abs_max()), "fused vs unfused max|Δ| {d}");
        // single-row API agrees with the batch API
        let row = engine.forward_row(x.slice_rows(0, 1).unwrap().as_f32().unwrap()).unwrap();
        for (a, b) in row.iter().zip(fused.as_f32().unwrap()) {
            assert!((a - b).abs() <= 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn bias_and_relu_are_applied() {
        // 1×1 identity-ish layer: code 1, scale 2 → Ŵ = [[2]]; bias −5;
        // ReLU clips the negative result.
        let mat = PackedMatrix::pack(&[1], 1, 1, 4, -8, vec![2.0], vec![0.0]).unwrap();
        let model = PackedModel {
            units: vec![PackedUnit::stack(
                "u",
                vec![PackedLayer {
                    name: "fc".into(),
                    mat,
                    bias: Some(vec![-5.0]),
                    relu_after: true,
                    act: None,
                }],
            )],
        };
        let engine = Engine::new(model, 1);
        let y = engine.forward(&Tensor::from_f32(vec![1.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[0.0]); // relu(2·1 − 5)
        let y = engine.forward(&Tensor::from_f32(vec![4.0], &[1, 1]).unwrap()).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.0]); // relu(2·4 − 5)
    }

    #[test]
    fn empty_model_is_rejected() {
        let engine = Engine::new(PackedModel::default(), 1);
        assert!(engine.in_width().is_err());
    }

    /// Random packed transformer block (one unit) for engine tests.
    fn block_model(d: usize, mlp: usize, heads: usize, seq: usize) -> PackedModel {
        let mut rng = Pcg32::seeded(41);
        let mut mk = |rows: usize, cols: usize| {
            let codes: Vec<i32> =
                (0..rows * cols).map(|_| -8 + rng.below(16) as i32).collect();
            let s0 = 1.0 / (8.0 * (cols as f32).sqrt());
            let scale: Vec<f32> = (0..rows).map(|_| s0 * (0.75 + 0.5 * rng.next_f32())).collect();
            PackedMatrix::pack(&codes, rows, cols, 4, -8, scale, vec![0.0; rows]).unwrap()
        };
        let mut mats = vec![mk(d, d), mk(d, d), mk(d, d), mk(d, d), mk(mlp, d), mk(d, mlp)];
        let layer = |name: &str, mat: PackedMatrix| PackedLayer {
            name: name.into(),
            mat,
            bias: None,
            relu_after: false,
            act: None,
        };
        let unit = PackedUnit {
            name: "blk".into(),
            kind: "transformer_block".into(),
            heads,
            seq,
            ln1: Some((vec![1.0; d], vec![0.0; d])),
            ln2: Some((vec![1.0; d], vec![0.0; d])),
            layers: vec![
                layer("wq", mats.remove(0)),
                layer("wk", mats.remove(0)),
                layer("wv", mats.remove(0)),
                layer("wo", mats.remove(0)),
                layer("up", mats.remove(0)),
                layer("down", mats.remove(0)),
            ],
        };
        PackedModel { units: vec![unit] }
    }

    #[test]
    fn block_forward_token_and_flat_entries_agree() {
        let (d, mlp, heads, seq) = (8usize, 16usize, 2usize, 4usize);
        let engine = Engine::new(block_model(d, mlp, heads, seq), 2);
        // request width is one flattened sequence
        assert_eq!(engine.in_width().unwrap(), seq * d);
        let mut rng = Pcg32::seeded(6);
        let nseq = 3usize;
        let tokens = Tensor::from_f32(
            (0..nseq * seq * d).map(|_| rng.next_normal()).collect(),
            &[nseq * seq, d],
        )
        .unwrap();
        let toks_out = engine.forward(&tokens).unwrap();
        assert_eq!(toks_out.shape(), &[nseq * seq, d]);
        // same data as flattened sequences → same numbers, reshaped
        let flat = tokens.reshape(&[nseq, seq * d]).unwrap();
        let flat_out = engine.forward(&flat).unwrap();
        assert_eq!(flat_out.shape(), &[nseq, seq * d]);
        assert_eq!(
            toks_out.as_f32().unwrap(),
            flat_out.as_f32().unwrap(),
            "flattened-sequence entry must match the token-row entry"
        );
        // fused vs dequantize-then-matmul parity through the whole block
        let unfused = engine.forward_unfused(&tokens).unwrap();
        let dmax = toks_out.max_abs_diff(&unfused).unwrap();
        assert!(dmax <= 1e-4 * (1.0 + unfused.abs_max()), "fused block drift {dmax}");
        // rows not a multiple of seq are rejected
        let bad = Tensor::from_f32(vec![0.0; 3 * d], &[3, d]).unwrap();
        assert!(engine.forward(&bad).is_err());
        // serving row API: one flattened sequence in, one out
        let row = engine.forward_row(flat.slice_rows(0, 1).unwrap().as_f32().unwrap()).unwrap();
        assert_eq!(row.len(), seq * d);
        for (a, b) in row.iter().zip(flat_out.as_f32().unwrap()) {
            assert!((a - b).abs() <= 1e-5);
        }
    }

    #[test]
    fn forward_ctx_matches_forward_at_the_packed_seq() {
        let (d, mlp, heads, seq) = (8usize, 16usize, 2usize, 4usize);
        let engine = Engine::new(block_model(d, mlp, heads, seq), 2);
        let mut rng = Pcg32::seeded(17);
        let x = Tensor::from_f32(
            (0..2 * seq * d).map(|_| rng.next_normal()).collect(),
            &[2 * seq, d],
        )
        .unwrap();
        let a = engine.forward(&x).unwrap();
        let b = engine.forward_ctx(&x, seq).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        // an explicit seq override changes the attention grouping: rows not
        // a multiple of it are rejected, odd lengths are served
        assert!(engine.forward_ctx(&x, 3).is_err());
        let odd = engine.forward_ctx(&x.slice_rows(0, 5).unwrap(), 5).unwrap();
        assert_eq!(odd.shape(), &[5, d]);
        assert!(engine.forward_ctx(&x, 0).is_err());
    }

    #[test]
    fn prefill_then_decode_is_bit_identical_to_full_context() {
        let (d, mlp, heads, seq) = (8usize, 16usize, 2usize, 4usize);
        let engine = Engine::new(block_model(d, mlp, heads, seq), 2);
        let mut rng = Pcg32::seeded(23);
        let t = 6usize;
        let x = Tensor::from_f32(
            (0..t * d).map(|_| rng.next_normal()).collect(),
            &[t, d],
        )
        .unwrap();
        let full = engine.forward_ctx(&x, t).unwrap();
        let fv = full.as_f32().unwrap();
        // one-shot prefill replays the whole prompt
        let (state, pre) = engine.prefill(&x).unwrap();
        assert_eq!(state.pos(), t);
        assert_eq!(state.kv().blocks(), 1);
        assert_eq!(pre.as_f32().unwrap(), fv, "prefill must equal the full-context forward");
        // prefill one row, then decode the rest incrementally
        let (mut st, first) = engine.prefill(&x.slice_rows(0, 1).unwrap()).unwrap();
        assert_eq!(first.as_f32().unwrap(), &fv[..d]);
        let xv = x.as_f32().unwrap();
        for i in 1..t {
            let out = engine.decode_step(&mut st, &xv[i * d..(i + 1) * d]).unwrap();
            assert_eq!(st.pos(), i + 1);
            assert_eq!(
                out.as_slice(),
                &fv[i * d..(i + 1) * d],
                "decode step {i} must be bit-identical to the full-context row"
            );
        }
        // wrong-width rows are rejected before touching the cache
        assert!(engine.decode_step(&mut st, &[0.0; 3]).is_err());
        assert_eq!(st.pos(), t);
    }
}
