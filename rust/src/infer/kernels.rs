//! Fused dequant-GEMM kernels over [`PackedMatrix`].
//!
//! The serving hot path is `Y = X · Ŵᵀ` with `Ŵ = s · (n − z)` never
//! materialized.  Three implementations, slowest to fastest:
//!
//! * [`gemm_ref`] — scalar reference: decodes and scales every element
//!   independently.  The correctness oracle for the other two.
//! * [`dequant_matmul`] — the naive deployment baseline: materialize the
//!   full f32 `Ŵ` (4 bytes/element), then run the dense [`Tensor::matmul_nt`].
//!   Benchmared against the fused kernel in `benches/infer.rs`.
//! * [`gemm_fused`] — unpack-on-the-fly: one weight row's codes are decoded
//!   into an L1-resident scratch buffer (`cols × 4` bytes, reused across the
//!   whole micro-batch), the integer-code dot product runs against each
//!   activation row, and the per-channel scale is applied once per output in
//!   register via
//!
//!   ```text
//!     y[i][j] = s_j · ( Σ_t n[j][t]·x[i][t]  −  z_j · Σ_t x[i][t] )
//!   ```
//!
//!   so memory traffic is the packed words (bits/8 bytes per weight) instead
//!   of the dense f32 matrix — the whole point of serving low-bit weights.
//!   Row-ranges fan out over [`crate::util::pool`] like the reconstruction
//!   matmuls.

use super::packed::PackedMatrix;
use crate::tensor::Tensor;
use crate::util::pool;
use crate::Result;
use anyhow::bail;

fn check_shapes(x: &Tensor, m: &PackedMatrix) -> Result<(usize, usize)> {
    if x.ndim() != 2 || x.shape()[1] != m.cols() {
        bail!(
            "packed gemm: activations {:?} vs weight matrix {}×{}",
            x.shape(),
            m.rows(),
            m.cols()
        );
    }
    Ok((x.shape()[0], x.shape()[1]))
}

/// Scalar reference kernel: per-element decode + scale (no scratch, no
/// algebraic refactoring).  Slow; exists so the fused kernel has an
/// independent oracle.
pub fn gemm_ref(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let xv = x.as_f32()?;
    let rows = m.rows();
    let mut out = vec![0.0f32; n * rows];
    for i in 0..n {
        let xrow = &xv[i * k..(i + 1) * k];
        for j in 0..rows {
            let (s, z) = (m.scale()[j], m.zp()[j]);
            let mut acc = 0.0f32;
            for (t, &xt) in xrow.iter().enumerate() {
                acc += s * (m.code_at(j, t) as f32 - z) * xt;
            }
            out[i * rows + j] = acc;
        }
    }
    Tensor::from_f32(out, &[n, rows])
}

/// Deployment baseline: materialize f32 `Ŵ`, then dense matmul.
pub fn dequant_matmul(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    check_shapes(x, m)?;
    x.matmul_nt(&m.dequantize()?)
}

/// Fused kernel over weight rows `[jlo, jhi)`: returns the `(n, jhi−jlo)`
/// output block, column-major-free (row-major within the block).
fn fused_block(
    xv: &[f32],
    sumx: &[f32],
    n: usize,
    k: usize,
    m: &PackedMatrix,
    jlo: usize,
    jhi: usize,
) -> Vec<f32> {
    let width = jhi - jlo;
    let mut out = vec![0.0f32; n * width];
    let mut buf = vec![0.0f32; k];
    for j in jlo..jhi {
        m.unpack_row(j, &mut buf);
        let (s, z) = (m.scale()[j], m.zp()[j]);
        for i in 0..n {
            let xrow = &xv[i * k..(i + 1) * k];
            let mut acc = 0.0f32;
            for (&c, &xt) in buf.iter().zip(xrow) {
                acc += c * xt;
            }
            out[i * width + (j - jlo)] = s * (acc - z * sumx[i]);
        }
    }
    out
}

/// Fused dequant-GEMM `Y = X · Ŵᵀ` without materializing `Ŵ`; exact same
/// shapes as [`Tensor::matmul_nt`] against the dequantized matrix.  Splits
/// weight rows across `workers` pool threads when the problem is big enough
/// to amortize the fan-out.
pub fn gemm_fused(x: &Tensor, m: &PackedMatrix, workers: usize) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let rows = m.rows();
    let xv = x.as_f32()?;
    let sumx: Vec<f32> = (0..n).map(|i| xv[i * k..(i + 1) * k].iter().sum()).collect();
    let serial = workers <= 1 || rows < 2 * workers || n * rows * k < (1 << 16);
    let out = if serial {
        fused_block(xv, &sumx, n, k, m, 0, rows)
    } else {
        let chunk = rows.div_ceil(workers);
        let ranges: Vec<(usize, usize)> = (0..workers)
            .map(|w| (w * chunk, ((w + 1) * chunk).min(rows)))
            .filter(|(lo, hi)| lo < hi)
            .collect();
        let blocks = pool::par_map(ranges.len(), &ranges, |_, &(lo, hi)| {
            fused_block(xv, &sumx, n, k, m, lo, hi)
        });
        let mut out = vec![0.0f32; n * rows];
        for (&(lo, hi), block) in ranges.iter().zip(&blocks) {
            let width = hi - lo;
            for i in 0..n {
                out[i * rows + lo..i * rows + hi]
                    .copy_from_slice(&block[i * width..(i + 1) * width]);
            }
        }
        out
    };
    Tensor::from_f32(out, &[n, rows])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qrange;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg32;

    fn random_packed(rng: &mut Pcg32, rows: usize, cols: usize, bits: u32) -> PackedMatrix {
        let (qmin, qmax) = qrange(bits, true);
        let (qmin, qmax) = (qmin as i32, qmax as i32);
        let span = (qmax - qmin + 1) as u32;
        let codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
        let zp: Vec<f32> = (0..rows).map(|_| rng.below(3) as f32 - 1.0).collect();
        PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).unwrap()
    }

    #[test]
    fn fused_matches_reference_and_baseline() {
        Prop::new("fused gemm ≡ reference ≡ dequant+matmul").cases(40).check(|rng| {
            let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
            let rows = 1 + rng.below(20) as usize;
            let cols = 1 + rng.below(40) as usize;
            let n = 1 + rng.below(6) as usize;
            let m = random_packed(rng, rows, cols, bits);
            let x = Tensor::from_f32(
                (0..n * cols).map(|_| rng.next_normal()).collect(),
                &[n, cols],
            )
            .map_err(|e| e.to_string())?;
            let reference = gemm_ref(&x, &m).map_err(|e| e.to_string())?;
            let baseline = dequant_matmul(&x, &m).map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let fused = gemm_fused(&x, &m, workers).map_err(|e| e.to_string())?;
                if fused.shape() != reference.shape() {
                    return Err(format!("shape {:?} vs {:?}", fused.shape(), reference.shape()));
                }
                for (label, other) in [("ref", &reference), ("dequant", &baseline)] {
                    let d = fused.max_abs_diff(other).map_err(|e| e.to_string())?;
                    let tol = 1e-4 * (1.0 + other.abs_max());
                    if d > tol {
                        return Err(format!(
                            "fused(workers={workers}) vs {label}: max|Δ| {d} > {tol} \
                             ({bits}-bit {rows}×{cols}, batch {n})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_split_covers_large_matrices() {
        // big enough to cross the serial threshold: results must agree with
        // the serial fused path exactly (same per-element op order).
        let mut rng = Pcg32::seeded(9);
        let m = random_packed(&mut rng, 96, 64, 4);
        let x = Tensor::from_f32((0..12 * 64).map(|_| rng.next_normal()).collect(), &[12, 64])
            .unwrap();
        let serial = gemm_fused(&x, &m, 1).unwrap();
        let par = gemm_fused(&x, &m, 4).unwrap();
        assert_eq!(serial.as_f32().unwrap(), par.as_f32().unwrap());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rng = Pcg32::seeded(2);
        let m = random_packed(&mut rng, 4, 6, 4);
        let x = Tensor::from_f32(vec![0.0; 10], &[2, 5]).unwrap();
        assert!(gemm_fused(&x, &m, 1).is_err());
        assert!(gemm_ref(&x, &m).is_err());
    }
}
