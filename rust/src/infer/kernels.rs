//! Fused dequant-GEMM kernels over [`PackedMatrix`], built on the unified
//! [`crate::linalg`] kernel core (DESIGN.md §Compute-Kernels).
//!
//! The serving hot path is `Y = X · Ŵᵀ` with `Ŵ = s · (n − z)` never
//! materialized.  Implementations, slowest to fastest:
//!
//! * [`gemm_ref`] — scalar reference: decodes and scales every element
//!   independently.  The correctness oracle for everything else.
//! * [`dequant_matmul`] — the naive deployment baseline: materialize the
//!   full f32 `Ŵ` (4 bytes/element), then run the dense
//!   [`Tensor::matmul_nt`].
//! * [`gemm_fused_rowwise`] — one weight row decoded at a time, a scalar
//!   dot per activation row (PR 2's original fused kernel).  Retained as
//!   the second oracle — it must stay *bit-identical* to the panel kernel
//!   — and as the baseline for `cargo bench --bench kernels`.
//! * [`gemm_fused`] — the production kernel: an [`linalg::NR`]-row panel of
//!   weight codes is decoded into an L1-resident scratch, the shared
//!   register-tiled loop ([`linalg::gemm_nt_into`]) contracts activations
//!   against the decoded panel, and the per-channel scale lands once per
//!   output in the epilogue via the algebraic form
//!
//!   ```text
//!     y[i][j] = s_j · ( Σ_t n[j][t]·x[i][t]  −  z_j · Σ_t x[i][t] )
//!   ```
//!
//!   so memory traffic stays the packed words (bits/8 bytes per weight)
//!   instead of the dense f32 matrix.  Batch-1 inputs (the KV-cached
//!   decode hot path, `Engine::decode_step`) skip the tile loop for the
//!   shared [`linalg::gemv_nt`] core — same bits, no tile bookkeeping.
//!
//! Weight-row ranges fan out under the crate-wide [`Dispatch`] policy —
//! the same flops threshold and pool fan-out as every other matmul (the
//! old one-off `n·rows·k < 2¹⁶` cutoff lives on *as* that policy's
//! [`crate::linalg::PAR_FLOPS_MIN`]).  Because every kernel sums k
//! ascending with one accumulator per element, serial, parallel, rowwise,
//! panel, and gemv paths are all bit-identical.

use super::packed::PackedMatrix;
use crate::linalg::{self, Dispatch};
use crate::tensor::Tensor;
use crate::util::pool;
use crate::Result;
use anyhow::bail;

fn check_shapes(x: &Tensor, m: &PackedMatrix) -> Result<(usize, usize)> {
    if x.ndim() != 2 || x.shape()[1] != m.cols() {
        bail!(
            "packed gemm: activations {:?} vs weight matrix {}×{}",
            x.shape(),
            m.rows(),
            m.cols()
        );
    }
    Ok((x.shape()[0], x.shape()[1]))
}

/// Scalar reference kernel: per-element decode + scale (no scratch, no
/// algebraic refactoring).  Slow; exists so the fused kernels have an
/// independent oracle.
pub fn gemm_ref(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let xv = x.as_f32()?;
    let rows = m.rows();
    let mut out = vec![0.0f32; n * rows];
    for i in 0..n {
        let xrow = &xv[i * k..(i + 1) * k];
        for j in 0..rows {
            let (s, z) = (m.scale()[j], m.zp()[j]);
            let mut acc = 0.0f32;
            for (t, &xt) in xrow.iter().enumerate() {
                acc += s * (m.code_at(j, t) as f32 - z) * xt;
            }
            out[i * rows + j] = acc;
        }
    }
    Tensor::from_f32(out, &[n, rows])
}

/// Deployment baseline: materialize f32 `Ŵ`, then dense matmul (which
/// itself runs the blocked `linalg` kernel these days — the comparison in
/// `benches/kernels.rs` is therefore pure memory-traffic, not loop shape).
pub fn dequant_matmul(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    check_shapes(x, m)?;
    x.matmul_nt(&m.dequantize()?)
}

/// Row-sums of the activation batch — the `Σ_t x[i][t]` half of the fused
/// algebraic form, shared by the rowwise and panel kernels.
fn row_sums(xv: &[f32], n: usize, k: usize) -> Vec<f32> {
    (0..n).map(|i| xv[i * k..(i + 1) * k].iter().sum()).collect()
}

/// PR 2's original fused kernel: one weight row decoded at a time, scalar
/// dots against every activation row.  Serial, whole-matrix.  Kept as the
/// bit-exact oracle and bench baseline for the panel kernel ([`gemm_fused`]
/// must match it exactly — same per-element accumulation order).
pub fn gemm_fused_rowwise(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let rows = m.rows();
    let xv = x.as_f32()?;
    let sumx = row_sums(xv, n, k);
    let mut out = vec![0.0f32; n * rows];
    let mut buf = vec![0.0f32; k];
    for j in 0..rows {
        m.unpack_row(j, &mut buf);
        let (s, z) = (m.scale()[j], m.zp()[j]);
        for i in 0..n {
            let xrow = &xv[i * k..(i + 1) * k];
            let mut acc = 0.0f32;
            for (&c, &xt) in buf.iter().zip(xrow) {
                acc += c * xt;
            }
            out[i * rows + j] = s * (acc - z * sumx[i]);
        }
    }
    Tensor::from_f32(out, &[n, rows])
}

/// Fused kernel over weight rows `[jlo, jhi)`: decode an
/// [`linalg::NR`]-row panel of codes into the f32 scratch, contract with
/// the shared register-tiled loop (or the gemv core at batch 1), apply the
/// `s·(acc − z·Σx)` epilogue.  Returns the `(n, jhi − jlo)` output block
/// (row-major within the block).
fn fused_block(
    xv: &[f32],
    sumx: &[f32],
    n: usize,
    k: usize,
    m: &PackedMatrix,
    jlo: usize,
    jhi: usize,
) -> Vec<f32> {
    let width = jhi - jlo;
    let mut out = vec![0.0f32; n * width];
    let mut panel = vec![0.0f32; linalg::NR * k];
    let mut tmp = vec![0.0f32; n * linalg::NR];
    let mut j = jlo;
    while j < jhi {
        let nr = linalg::NR.min(jhi - j);
        for p in 0..nr {
            m.unpack_row(j + p, &mut panel[p * k..(p + 1) * k]);
        }
        // no re-zeroing: both contraction paths below assign every element
        // of tmp's active region exactly once (overwrite semantics)
        if n == 1 {
            // decode hot path: one activation row, no tile bookkeeping
            linalg::gemv_nt(xv, &panel[..nr * k], k, nr, &mut tmp[..nr]);
        } else {
            linalg::gemm_nt_into(xv, &panel[..nr * k], n, k, nr, &mut tmp[..n * nr]);
        }
        for p in 0..nr {
            let (s, z) = (m.scale()[j + p], m.zp()[j + p]);
            for i in 0..n {
                out[i * width + (j - jlo) + p] = s * (tmp[i * nr + p] - z * sumx[i]);
            }
        }
        j += nr;
    }
    out
}

/// Fused dequant-GEMM `Y = X · Ŵᵀ` without materializing `Ŵ`; exact same
/// shapes as [`Tensor::matmul_nt`] against the dequantized matrix.  Weight
/// rows split across pool workers under the crate-wide [`Dispatch`] policy
/// (serial below the shared flops threshold) — serial and parallel results
/// are bit-identical.
pub fn gemm_fused(x: &Tensor, m: &PackedMatrix, workers: usize) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let rows = m.rows();
    let xv = x.as_f32()?;
    let sumx = row_sums(xv, n, k);
    let out = match Dispatch::new(workers).panels(rows, n * rows * k) {
        None => fused_block(xv, &sumx, n, k, m, 0, rows),
        Some(ranges) => {
            let blocks = pool::par_map(ranges.len(), &ranges, |_, &(lo, hi)| {
                fused_block(xv, &sumx, n, k, m, lo, hi)
            });
            let mut out = vec![0.0f32; n * rows];
            for (&(lo, hi), block) in ranges.iter().zip(&blocks) {
                let width = hi - lo;
                for i in 0..n {
                    out[i * rows + lo..i * rows + hi]
                        .copy_from_slice(&block[i * width..(i + 1) * width]);
                }
            }
            out
        }
    };
    Tensor::from_f32(out, &[n, rows])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qrange;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg32;

    fn random_packed(rng: &mut Pcg32, rows: usize, cols: usize, bits: u32) -> PackedMatrix {
        let (qmin, qmax) = qrange(bits, true);
        let (qmin, qmax) = (qmin as i32, qmax as i32);
        let span = (qmax - qmin + 1) as u32;
        let codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
        let zp: Vec<f32> = (0..rows).map(|_| rng.below(3) as f32 - 1.0).collect();
        PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).unwrap()
    }

    #[test]
    fn fused_matches_reference_and_baseline() {
        Prop::new("fused gemm ≡ reference ≡ dequant+matmul").cases(40).check(|rng| {
            let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
            let rows = 1 + rng.below(20) as usize;
            let cols = 1 + rng.below(40) as usize;
            let n = 1 + rng.below(6) as usize;
            let m = random_packed(rng, rows, cols, bits);
            let x = Tensor::from_f32(
                (0..n * cols).map(|_| rng.next_normal()).collect(),
                &[n, cols],
            )
            .map_err(|e| e.to_string())?;
            let reference = gemm_ref(&x, &m).map_err(|e| e.to_string())?;
            let baseline = dequant_matmul(&x, &m).map_err(|e| e.to_string())?;
            let rowwise = gemm_fused_rowwise(&x, &m).map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let fused = gemm_fused(&x, &m, workers).map_err(|e| e.to_string())?;
                if fused.shape() != reference.shape() {
                    return Err(format!("shape {:?} vs {:?}", fused.shape(), reference.shape()));
                }
                // the panel kernel must reproduce the rowwise oracle
                // bit-for-bit: identical per-element accumulation order
                if fused.as_f32().map_err(|e| e.to_string())?
                    != rowwise.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!(
                        "panel kernel (workers={workers}) drifted from the rowwise oracle \
                         ({bits}-bit {rows}×{cols}, batch {n})"
                    ));
                }
                for (label, other) in [("ref", &reference), ("dequant", &baseline)] {
                    let d = fused.max_abs_diff(other).map_err(|e| e.to_string())?;
                    let tol = 1e-4 * (1.0 + other.abs_max());
                    if d > tol {
                        return Err(format!(
                            "fused(workers={workers}) vs {label}: max|Δ| {d} > {tol} \
                             ({bits}-bit {rows}×{cols}, batch {n})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_split_covers_large_matrices() {
        // big enough to cross the shared dispatch threshold: results must
        // agree with the serial fused path exactly (same per-element op
        // order on both sides of the panel split).
        let mut rng = Pcg32::seeded(9);
        let m = random_packed(&mut rng, 96, 64, 4);
        let x = Tensor::from_f32((0..12 * 64).map(|_| rng.next_normal()).collect(), &[12, 64])
            .unwrap();
        let serial = gemm_fused(&x, &m, 1).unwrap();
        let par = gemm_fused(&x, &m, 4).unwrap();
        assert_eq!(serial.as_f32().unwrap(), par.as_f32().unwrap());
    }

    #[test]
    fn batch1_gemv_path_matches_batched_rows() {
        // the decode hot path: a single activation row must produce exactly
        // the bits the same row yields inside a batch (the prefill/decode
        // parity contract depends on this).
        let mut rng = Pcg32::seeded(21);
        for bits in [2u32, 4, 8] {
            let m = random_packed(&mut rng, 33, 17, bits);
            let batch = Tensor::from_f32(
                (0..5 * 17).map(|_| rng.next_normal()).collect(),
                &[5, 17],
            )
            .unwrap();
            let full = gemm_fused(&batch, &m, 1).unwrap();
            for i in 0..5 {
                let row = batch.slice_rows(i, i + 1).unwrap();
                let one = gemm_fused(&row, &m, 1).unwrap();
                assert_eq!(
                    one.as_f32().unwrap(),
                    &full.as_f32().unwrap()[i * 33..(i + 1) * 33],
                    "{bits}-bit batch-1 row {i} drifted from the batched result"
                );
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rng = Pcg32::seeded(2);
        let m = random_packed(&mut rng, 4, 6, 4);
        let x = Tensor::from_f32(vec![0.0; 10], &[2, 5]).unwrap();
        assert!(gemm_fused(&x, &m, 1).is_err());
        assert!(gemm_ref(&x, &m).is_err());
        assert!(gemm_fused_rowwise(&x, &m).is_err());
    }
}
