//! Fused dequant-GEMM kernels over [`PackedMatrix`], built on the unified
//! [`crate::linalg`] kernel core (DESIGN.md §Compute-Kernels).
//!
//! The serving hot path is `Y = X · Ŵᵀ` with `Ŵ = s · (n − z)` never
//! materialized.  Implementations, slowest to fastest:
//!
//! * [`gemm_ref`] — scalar reference: decodes and scales every element
//!   independently.  The correctness oracle for everything else.
//! * [`dequant_matmul`] — the naive deployment baseline: materialize the
//!   full f32 `Ŵ` (4 bytes/element), then run the dense
//!   [`Tensor::matmul_nt`].
//! * [`gemm_fused_rowwise`] — one weight row decoded at a time, one
//!   [`crate::linalg::simd::dot`] per activation row (PR 2's original fused
//!   kernel, now ISA-routed).  Retained as the second oracle — it must stay
//!   *bit-identical* to the panel kernel within an ISA arm — and as the
//!   baseline for `cargo bench --bench kernels`.
//! * [`gemm_fused`] — the production kernel: an [`linalg::NR`]-row panel of
//!   weight codes is decoded into an L1-resident scratch, the shared
//!   register-tiled loop ([`linalg::gemm_nt_into`]) contracts activations
//!   against the decoded panel, and the per-channel scale lands once per
//!   output in the epilogue via the algebraic form
//!
//!   ```text
//!     y[i][j] = s_j · ( Σ_t n[j][t]·x[i][t]  −  z_j · Σ_t x[i][t] )
//!   ```
//!
//!   so memory traffic stays the packed words (bits/8 bytes per weight)
//!   instead of the dense f32 matrix.  Batch-1 inputs (the KV-cached
//!   decode hot path, `Engine::decode_step`) skip the tile loop for the
//!   shared [`crate::linalg::simd::gemv_nt`] core — same bits, no tile
//!   bookkeeping.
//!
//! # Integer domain
//!
//! When every activation is an exact integer (token one-hots, integer
//! embeddings, quantized activations), [`gemm_fused`] drops the f32 tiles
//! entirely and accumulates `Σ n·x` and `Σ x` on unpacked i32 codes
//! ([`crate::linalg::simd::dot_i32`]), applying `s·(acc − z·Σx)` once per
//! output element.  Integer addition is associative, so this path is
//! **bit-exact** against [`gemm_fused_rowwise`] on every ISA arm: the
//! auto-route only fires inside the f32 exactness window (all intermediate
//! magnitudes `< 2²⁴`, see [`IntActs::capture`]'s limit), where f32
//! arithmetic is itself exact and therefore order-independent — the i32
//! accumulator and the f32 accumulator hold the *same* number, and the
//! epilogue expression trees are identical.  [`gemm_fused_int`] exposes the
//! integer kernel over its full domain (`|x| ≤ i32::MAX / max|code|`),
//! where i32 accumulation may overflow: [`int_safe_k`] pins the safe
//! contraction length and the kernel chunks K beyond it, widening each i32
//! partial into an i64 total (the split-accumulator fallback) — still
//! associative, still chunk-size-invariant.  At batch 1 the integer rowwise
//! loop *is* the integer gemv decode fast path: one `dot_i32` per weight
//! row, no tile bookkeeping to skip.
//!
//! # The i16-madd route
//!
//! When every weight code *and* every activation code fits i16 (W≤8 grids
//! against A8-and-smaller activations — the whole practical serving
//! envelope), the integer kernel drops from 32-bit to 16-bit lanes:
//! weight rows decode straight to i16 via the in-register unpack
//! ([`crate::linalg::simd::unpack_codes_i16`], 16 codes per store) and the
//! contraction runs `_mm256_madd_epi16`
//! ([`crate::linalg::simd::dot_i16_madd`]) — 16 products per instruction
//! with adjacent pairs hardware-summed into i32 lanes, twice [`simd::dot_i32`]'s
//! width.  The pair-sum provably fits i32 (see [`int_safe_k`]'s bound) and
//! the chunk totals follow the same `int_safe_k` guard as the i32 path, so
//! the route is **bit-identical** to `dot_i32` on every arm — which is what
//! makes it safely auto-selectable: [`Dispatch::use_madd`] turns it on
//! wherever AVX2 is active, `FLEXROUND_FORCE_NO_MADD=1` pins it off, and
//! [`IntRoute`] lets the differential harness force either kernel.  At
//! `n == 1` the madd rowwise loop *is* the batch-1 gemv decode fast path:
//! one in-register row decode + one madd dot per weight row.
//!
//! Weight-row ranges fan out under the crate-wide [`Dispatch`] policy —
//! the same flops threshold and pool fan-out as every other matmul (the
//! old one-off `n·rows·k < 2¹⁶` cutoff lives on *as* that policy's
//! [`crate::linalg::PAR_FLOPS_MIN`]).  Because every kernel gives each
//! output element one fixed per-element reduction tree within an ISA arm,
//! serial, parallel, rowwise, panel, and gemv paths are all bit-identical
//! *per arm*; the integer paths (i32 and i16-madd) are bit-identical
//! across arms too.

use super::packed::{ActQuant, PackedMatrix};
use crate::linalg::{self, simd, Dispatch, Isa};
use crate::tensor::Tensor;
use crate::util::pool;
use crate::Result;
use anyhow::bail;

fn check_shapes(x: &Tensor, m: &PackedMatrix) -> Result<(usize, usize)> {
    if x.ndim() != 2 || x.shape()[1] != m.cols() {
        bail!(
            "packed gemm: activations {:?} vs weight matrix {}×{}",
            x.shape(),
            m.rows(),
            m.cols()
        );
    }
    Ok((x.shape()[0], x.shape()[1]))
}

/// Scalar reference kernel: per-element decode + scale (no scratch, no
/// algebraic refactoring).  Slow; exists so the fused kernels have an
/// independent oracle.
pub fn gemm_ref(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let xv = x.as_f32()?;
    let rows = m.rows();
    let mut out = vec![0.0f32; n * rows];
    for i in 0..n {
        let xrow = &xv[i * k..(i + 1) * k];
        for j in 0..rows {
            let (s, z) = (m.scale()[j], m.zp()[j]);
            let mut acc = 0.0f32;
            for (t, &xt) in xrow.iter().enumerate() {
                acc += s * (m.code_at(j, t) as f32 - z) * xt;
            }
            out[i * rows + j] = acc;
        }
    }
    Tensor::from_f32(out, &[n, rows])
}

/// Deployment baseline: materialize f32 `Ŵ`, then dense matmul (which
/// itself runs the blocked `linalg` kernel these days — the comparison in
/// `benches/kernels.rs` is therefore pure memory-traffic, not loop shape).
pub fn dequant_matmul(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    check_shapes(x, m)?;
    x.matmul_nt(&m.dequantize()?)
}

/// Row-sums of the activation batch — the `Σ_t x[i][t]` half of the fused
/// algebraic form, shared by the rowwise and panel kernels.
fn row_sums(xv: &[f32], n: usize, k: usize) -> Vec<f32> {
    (0..n).map(|i| xv[i * k..(i + 1) * k].iter().sum()).collect()
}

/// PR 2's original fused kernel on the *active* ISA arm — see
/// [`gemm_fused_rowwise_isa`].
pub fn gemm_fused_rowwise(x: &Tensor, m: &PackedMatrix) -> Result<Tensor> {
    gemm_fused_rowwise_isa(x, m, Isa::active())
}

/// One weight row decoded at a time, one ISA-routed dot per activation
/// row.  Serial, whole-matrix.  Kept as the bit-exact oracle and bench
/// baseline for the panel kernel: within an ISA arm, [`gemm_fused`] must
/// match it exactly — the panel tiles give every output element the same
/// per-element reduction tree this loop does.
pub fn gemm_fused_rowwise_isa(x: &Tensor, m: &PackedMatrix, isa: Isa) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let rows = m.rows();
    let xv = x.as_f32()?;
    let sumx = row_sums(xv, n, k);
    let mut out = vec![0.0f32; n * rows];
    let mut buf = vec![0.0f32; k];
    for j in 0..rows {
        m.unpack_row(j, &mut buf);
        let (s, z) = (m.scale()[j], m.zp()[j]);
        for i in 0..n {
            let xrow = &xv[i * k..(i + 1) * k];
            let acc = simd::dot(isa, &buf, xrow);
            out[i * rows + j] = s * (acc - z * sumx[i]);
        }
    }
    Tensor::from_f32(out, &[n, rows])
}

/// Fused kernel over weight rows `[jlo, jhi)`: decode an
/// [`linalg::NR`]-row panel of codes into the f32 scratch, contract with
/// the shared register-tiled loop (or the gemv core at batch 1) on `isa`,
/// apply the `s·(acc − z·Σx)` epilogue.  Returns the `(n, jhi − jlo)`
/// output block (row-major within the block).
#[allow(clippy::too_many_arguments)]
fn fused_block(
    xv: &[f32],
    sumx: &[f32],
    n: usize,
    k: usize,
    m: &PackedMatrix,
    jlo: usize,
    jhi: usize,
    isa: Isa,
) -> Vec<f32> {
    let width = jhi - jlo;
    let mut out = vec![0.0f32; n * width];
    // panel + tmp are the decoded-panel cache: allocated once per block and
    // reused across the whole j-loop, refilled in-register per panel
    let mut panel = vec![0.0f32; linalg::NR * k];
    let mut tmp = vec![0.0f32; n * linalg::NR];
    let (bits, qmin) = (m.bits(), m.qmin());
    let mut j = jlo;
    while j < jhi {
        let nr = linalg::NR.min(jhi - j);
        for p in 0..nr {
            simd::unpack_codes_f32(
                isa,
                m.row_words(j + p),
                k,
                bits,
                qmin,
                &mut panel[p * k..(p + 1) * k],
            );
        }
        // no re-zeroing: both contraction paths below assign every element
        // of tmp's active region exactly once (overwrite semantics)
        if n == 1 {
            // decode hot path: one activation row, no tile bookkeeping
            simd::gemv_nt(isa, xv, &panel[..nr * k], k, nr, &mut tmp[..nr]);
        } else {
            linalg::gemm_nt_into(isa, xv, &panel[..nr * k], n, k, nr, &mut tmp[..n * nr]);
        }
        for p in 0..nr {
            let (s, z) = (m.scale()[j + p], m.zp()[j + p]);
            for i in 0..n {
                out[i * width + (j - jlo) + p] = s * (tmp[i * nr + p] - z * sumx[i]);
            }
        }
        j += nr;
    }
    out
}

/// Stitch per-range output blocks (each `(n, hi − lo)` row-major) back into
/// the `(n, rows)` output — shared by the f32 and integer parallel paths.
fn gather_blocks(
    n: usize,
    rows: usize,
    ranges: &[(usize, usize)],
    blocks: &[Vec<f32>],
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * rows];
    for (&(lo, hi), block) in ranges.iter().zip(blocks) {
        let width = hi - lo;
        for i in 0..n {
            out[i * rows + lo..i * rows + hi]
                .copy_from_slice(&block[i * width..(i + 1) * width]);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Integer domain
// ---------------------------------------------------------------------------

/// Largest code magnitude the matrix's grid can produce:
/// `max(|qmin|, |qmin + 2^bits − 1|)`, clamped ≥ 1.
fn code_mag(m: &PackedMatrix) -> i64 {
    let qmin = m.qmin() as i64;
    let qmax = qmin + (1i64 << m.bits()) - 1;
    qmin.abs().max(qmax.abs()).max(1)
}

/// Activation-magnitude bound under which the whole fused contraction stays
/// inside f32's exact-integer window: with `|x| ≤ exact_amax`, every
/// product `|n·x| ≤ nmax·amax` and every partial sum
/// `|Σ n·x| ≤ k·nmax·amax ≤ 2²⁴ − 1` is an integer f32 represents exactly,
/// so f32 accumulation in *any* order equals the i32 result bit-for-bit.
fn exact_amax(k: usize, nmax: i64) -> i64 {
    ((1i64 << 24) - 1) / ((k.max(1) as i64) * nmax)
}

/// Longest contraction the plain i32 accumulator provably survives:
/// `⌊i32::MAX / (code_mag · act_mag)⌋` terms of magnitude
/// `≤ code_mag · act_mag` can never leave `[i32::MIN, i32::MAX]`, whatever
/// their signs.  Beyond it the integer kernel chunks K and widens each i32
/// partial into an i64 total.  Pinned worst cases (asserted in
/// `rust/tests/kernels.rs`):
///
/// * W8 asymmetric grid (codes in `[0, 255]`) against 8-bit-magnitude
///   activations (`|x| ≤ 127`): per-term bound `255·127 = 32385`, so
///   `safe_k = ⌊2147483647 / 32385⌋ = 66_311` — every practical hidden
///   width fits a single i32 accumulator;
/// * the same grid against adversarial `|x| = 2²⁰` activations: per-term
///   bound `255·2²⁰ = 267_386_880`, so `safe_k = 8` — the fallback is
///   load-bearing, not theoretical.
///
/// Result clamps ≥ 1 so a single term (which by the explicit-API input
/// bound `|x| ≤ i32::MAX / code_mag` cannot overflow) always passes.
///
/// The same bound covers the i16-madd route's extra intermediate: the
/// `_mm256_madd_epi16` pair-sum.  Madd multiplies 16 i16 pairs and sums
/// *adjacent pairs* into i32 lanes before any accumulation the guard sees;
/// with both operands i16-bounded a pair-sum is at most
/// `2 · 32767² = 2_147_352_578 < i32::MAX = 2_147_483_647`, so the
/// instruction itself can never overflow — the worst case
/// `int_safe_k(32767, 32767) = 2` (not 1) is exactly this headroom, and
/// every lane partial within a `safe_k` chunk stays `≤ safe_k · code_mag ·
/// act_mag ≤ i32::MAX` like the i32 path's.
pub fn int_safe_k(code_mag: i64, act_mag: i64) -> usize {
    let per = code_mag.max(1) * act_mag.max(1);
    (((i32::MAX as i64) / per).max(1)) as usize
}

/// Activation batch captured into the integer domain: the i32 code view,
/// per-row i64 sums (`Σ_t x[i][t]`), and the observed magnitude bound.
struct IntActs {
    q: Vec<i32>,
    sumq: Vec<i64>,
    amax: i64,
}

impl IntActs {
    /// `Some` iff every activation is an exact integer with `|x| ≤ limit`
    /// (so NaN/±inf/fractional batches — the common serving case — bail on
    /// pass 1 without allocating; the f64 compare avoids f32→int cast
    /// saturation for huge finite values).
    fn capture(xv: &[f32], n: usize, k: usize, limit: i64) -> Option<IntActs> {
        if limit < 1 {
            return None;
        }
        let lim = limit as f64;
        for &v in xv {
            let d = v as f64;
            // NaN: fract() is NaN ≠ 0; ±inf: likewise — both rejected here
            if d.fract() != 0.0 || d.abs() > lim {
                return None;
            }
        }
        let mut q = Vec::with_capacity(xv.len());
        let mut amax = 0i64;
        for &v in xv {
            let c = v as i64; // exact: v is integral with |v| ≤ limit
            amax = amax.max(c.abs());
            q.push(c as i32);
        }
        let sumq: Vec<i64> = (0..n)
            .map(|i| q[i * k..(i + 1) * k].iter().map(|&c| c as i64).sum::<i64>())
            .collect();
        Some(IntActs { q, sumq, amax: amax.max(1) })
    }
}

/// i32 panel dot with the overflow guard: a single [`simd::dot_i32`] when
/// the whole contraction fits [`int_safe_k`], otherwise K chunked at
/// `safe_k` with each i32 partial widened into the i64 total (the
/// split-accumulator fallback).  Integer addition is associative, so every
/// chunking — and every ISA arm — yields identical bits.
fn dot_i32_widening(isa: Isa, a: &[i32], b: &[i32], safe_k: usize) -> i64 {
    if a.len() <= safe_k {
        return simd::dot_i32(isa, a, b) as i64;
    }
    a.chunks(safe_k)
        .zip(b.chunks(safe_k))
        .map(|(ca, cb)| simd::dot_i32(isa, ca, cb) as i64)
        .sum()
}

/// Integer-domain fused kernel over weight rows `[jlo, jhi)`: decode row
/// codes as raw i32, one [`dot_i32_widening`] per activation row, epilogue
/// `s·(acc − z·Σx)` once per output element.  At `n == 1` this loop *is*
/// the batch-1 integer gemv decode fast path — one integer dot per weight
/// row, nothing to skip.
#[allow(clippy::too_many_arguments)]
fn int_block(
    acts: &IntActs,
    n: usize,
    k: usize,
    m: &PackedMatrix,
    jlo: usize,
    jhi: usize,
    isa: Isa,
    safe_k: usize,
) -> Vec<f32> {
    let width = jhi - jlo;
    let mut out = vec![0.0f32; n * width];
    let mut codes = vec![0i32; k];
    let (bits, qmin) = (m.bits(), m.qmin());
    for j in jlo..jhi {
        simd::unpack_codes_i32(isa, m.row_words(j), k, bits, qmin, &mut codes);
        let (s, z) = (m.scale()[j], m.zp()[j]);
        for i in 0..n {
            let xrow = &acts.q[i * k..(i + 1) * k];
            let acc = dot_i32_widening(isa, &codes, xrow, safe_k);
            // identical expression tree to the f32 epilogue: inside the
            // exactness window `acc as f32` / `sumq as f32` are the very
            // bits the f32 kernels accumulate, so the result is bit-exact
            out[i * width + (j - jlo)] = s * (acc as f32 - z * (acts.sumq[i] as f32));
        }
    }
    out
}

/// Which kernel the integer-domain fused GEMM contracts with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntRoute {
    /// The production policy: i16-madd when [`Dispatch::use_madd`] allows
    /// it *and* every weight code and activation fits i16; the i32 kernel
    /// otherwise.  Both outcomes are bit-identical, so the choice is pure
    /// throughput.
    Auto,
    /// Always the i32 `mullo` kernel (the pre-madd behavior) — the middle
    /// arm of verify.sh's three-arm differential.
    Dot32,
    /// Always the i16-madd kernel.  [`gemm_fused_int_route`] errors when
    /// codes or activations exceed i16 range (the operands would truncate);
    /// on the scalar arm this runs the bit-identical scalar emulation, so
    /// tests can pin the route on any machine.
    Madd,
}

/// Whether this matrix/activation pair can feed the i16-madd kernel: every
/// decodable code and every captured activation must fit i16.
fn madd_fits(m: &PackedMatrix, amax: i64) -> bool {
    code_mag(m) <= i16::MAX as i64 && amax <= i16::MAX as i64
}

/// i16 panel dot with the same overflow guard as [`dot_i32_widening`]: one
/// [`simd::dot_i16_madd`] when the contraction fits [`int_safe_k`],
/// otherwise K chunked at `safe_k` with each i32 partial widened into the
/// i64 total.  Identical chunk boundaries and associative i32 addition keep
/// it bit-identical to the i32 path on every arm.
fn dot_i16_widening(isa: Isa, a: &[i16], b: &[i16], safe_k: usize) -> i64 {
    if a.len() <= safe_k {
        return simd::dot_i16_madd(isa, a, b) as i64;
    }
    a.chunks(safe_k)
        .zip(b.chunks(safe_k))
        .map(|(ca, cb)| simd::dot_i16_madd(isa, ca, cb) as i64)
        .sum()
}

/// i16-madd fused kernel over weight rows `[jlo, jhi)`: in-register decode
/// of each weight row straight to i16 codes, one [`dot_i16_widening`] per
/// activation row, the same `s·(acc − z·Σx)` epilogue expression tree as
/// [`int_block`] — so the two integer kernels are bit-identical.  At
/// `n == 1` this loop *is* the batch-1 madd gemv decode fast path.
#[allow(clippy::too_many_arguments)]
fn madd_block(
    q16: &[i16],
    sumq: &[i64],
    n: usize,
    k: usize,
    m: &PackedMatrix,
    jlo: usize,
    jhi: usize,
    isa: Isa,
    safe_k: usize,
) -> Vec<f32> {
    let width = jhi - jlo;
    let mut out = vec![0.0f32; n * width];
    let mut codes = vec![0i16; k];
    let (bits, qmin) = (m.bits(), m.qmin());
    for j in jlo..jhi {
        simd::unpack_codes_i16(isa, m.row_words(j), k, bits, qmin, &mut codes);
        let (s, z) = (m.scale()[j], m.zp()[j]);
        for i in 0..n {
            let xrow = &q16[i * k..(i + 1) * k];
            let acc = dot_i16_widening(isa, &codes, xrow, safe_k);
            out[i * width + (j - jlo)] = s * (acc as f32 - z * (sumq[i] as f32));
        }
    }
    out
}

/// Shared integer-domain driver: weight rows fan out under `d` exactly like
/// the f32 path, each worker running [`int_block`] — or [`madd_block`] when
/// `route` resolves to the i16-madd kernel — over its range.
fn gemm_int(
    acts: &IntActs,
    n: usize,
    k: usize,
    m: &PackedMatrix,
    d: &Dispatch,
    route: IntRoute,
) -> Vec<f32> {
    let rows = m.rows();
    let isa = d.isa();
    let safe_k = int_safe_k(code_mag(m), acts.amax);
    let madd = match route {
        IntRoute::Dot32 => false,
        IntRoute::Madd => true,
        IntRoute::Auto => d.use_madd() && madd_fits(m, acts.amax),
    };
    if !madd {
        return match d.panels(rows, n * rows * k) {
            None => int_block(acts, n, k, m, 0, rows, isa, safe_k),
            Some(ranges) => {
                let blocks = pool::par_map(ranges.len(), &ranges, |_, &(lo, hi)| {
                    int_block(acts, n, k, m, lo, hi, isa, safe_k)
                });
                gather_blocks(n, rows, &ranges, &blocks)
            }
        };
    }
    if crate::obs::enabled() {
        crate::obs_counter!("flexround_fused_gemm_madd_total").inc();
    }
    // One i16 view of the activation batch, shared read-only across
    // workers (madd_fits guarantees the narrowing is lossless).
    let q16: Vec<i16> = acts.q.iter().map(|&c| c as i16).collect();
    match d.panels(rows, n * rows * k) {
        None => madd_block(&q16, &acts.sumq, n, k, m, 0, rows, isa, safe_k),
        Some(ranges) => {
            let blocks = pool::par_map(ranges.len(), &ranges, |_, &(lo, hi)| {
                madd_block(&q16, &acts.sumq, n, k, m, lo, hi, isa, safe_k)
            });
            gather_blocks(n, rows, &ranges, &blocks)
        }
    }
}

/// Whether [`gemm_fused`] would take the integer-domain path for this
/// input: every activation an exact integer inside the f32 exactness
/// window for this matrix's grid and contraction length.
pub fn int_gemm_eligible(x: &Tensor, m: &PackedMatrix) -> bool {
    match check_shapes(x, m) {
        Ok((n, k)) => x
            .as_f32()
            .map(|xv| IntActs::capture(xv, n, k, exact_amax(k, code_mag(m))).is_some())
            .unwrap_or(false),
        Err(_) => false,
    }
}

/// Explicit integer-domain fused GEMM — see [`gemm_fused_int_with`].
pub fn gemm_fused_int(x: &Tensor, m: &PackedMatrix, workers: usize) -> Result<Tensor> {
    gemm_fused_int_with(x, m, &Dispatch::new(workers))
}

/// Explicit integer-domain fused GEMM over the kernel's *full* domain:
/// activations must be exact integers with `|x| ≤ i32::MAX / max|code|`
/// (the per-product i32 bound), which is far wider than [`gemm_fused`]'s
/// auto-route window — beyond [`int_safe_k`] terms the kernel chunks K and
/// widens partials into i64, then rounds once at the f32 epilogue.  Errors
/// on non-integer or out-of-range activations instead of silently falling
/// back.
pub fn gemm_fused_int_with(x: &Tensor, m: &PackedMatrix, d: &Dispatch) -> Result<Tensor> {
    gemm_fused_int_route(x, m, d, IntRoute::Auto)
}

/// [`gemm_fused_int_with`] with an explicit integer-kernel route.  The
/// differential harness (`rust/tests/kernels.rs`, verify.sh's three arms)
/// pins [`IntRoute::Dot32`] against [`IntRoute::Madd`] bit-for-bit;
/// production callers want [`IntRoute::Auto`].  Errors when the madd route
/// is *forced* on inputs whose codes or activations exceed i16 range
/// (narrowing would truncate) — Auto falls back to i32 for those instead.
pub fn gemm_fused_int_route(
    x: &Tensor,
    m: &PackedMatrix,
    d: &Dispatch,
    route: IntRoute,
) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let limit = (i32::MAX as i64) / code_mag(m);
    let acts = match IntActs::capture(x.as_f32()?, n, k, limit) {
        Some(a) => a,
        None => bail!(
            "integer fused gemm: every activation must be an exact integer with \
             |x| ≤ {limit} (i32::MAX / max|code| for this {}-bit grid)",
            m.bits()
        ),
    };
    if route == IntRoute::Madd && !madd_fits(m, acts.amax) {
        bail!(
            "i16-madd route forced but the operands exceed i16 range \
             (max|code| {}, act magnitude {}; both must be ≤ {})",
            code_mag(m),
            acts.amax,
            i16::MAX
        );
    }
    if crate::obs::enabled() {
        crate::obs_counter!("flexround_fused_gemm_int_total").inc();
    }
    Tensor::from_f32(gemm_int(&acts, n, k, m, d, route), &[n, m.rows()])
}

/// W4A8 serving kernel: quantize the f32 activation batch onto the layer's
/// calibrated static grid and contract **entirely in the integer domain** —
/// see [`gemm_fused_act_int_with`].
pub fn gemm_fused_act_int(
    x: &Tensor,
    aq: &ActQuant,
    m: &PackedMatrix,
    workers: usize,
) -> Result<Tensor> {
    gemm_fused_act_int_with(x, aq, m, &Dispatch::new(workers))
}

/// Statically-quantized-activation fused GEMM.  With `x̂ = step·(c − zp_a)`
/// and `Ŵ = s·(n − z)`, the contraction factors as
///
/// ```text
///   y[i][j] = step · s_j · ( Σ_t c'[i][t]·n[j][t]  −  z_j · Σ_t c'[i][t] )
///             with  c' = c − zp_a  ∈ ℤ
/// ```
///
/// so the shifted activation codes `c'` (exact integers: `zp_a` is rounded
/// at calibration) feed straight into [`gemm_fused_int_with`] — integer
/// dots (the i16-madd route auto-fires here: A8 codes always fit i16),
/// `int_safe_k` overflow guard, per-row weight epilogue — and the single
/// per-tensor `step` lands once per output element.  The f32 reference is
/// [`ActQuant::fake_quant`] followed by any f32 kernel; parity is pinned
/// ≤ 1e-4 in `rust/tests/rounding.rs`.
pub fn gemm_fused_act_int_with(
    x: &Tensor,
    aq: &ActQuant,
    m: &PackedMatrix,
    d: &Dispatch,
) -> Result<Tensor> {
    check_shapes(x, m)?;
    let shifted: Vec<f32> =
        aq.codes(x.as_f32()?).iter().map(|&c| c as f32 - aq.zp).collect();
    let xq = Tensor::from_f32(shifted, x.shape())?;
    if crate::obs::enabled() {
        crate::obs_counter!("flexround_fused_gemm_act_int_total").inc();
    }
    let y = gemm_fused_int_with(&xq, m, d)?;
    let scaled: Vec<f32> = y.as_f32()?.iter().map(|v| v * aq.step).collect();
    Tensor::from_f32(scaled, y.shape())
}

/// Fused dequant-GEMM `Y = X · Ŵᵀ` without materializing `Ŵ` — see
/// [`gemm_fused_with`].
pub fn gemm_fused(x: &Tensor, m: &PackedMatrix, workers: usize) -> Result<Tensor> {
    gemm_fused_with(x, m, &Dispatch::new(workers))
}

/// Fused dequant-GEMM `Y = X · Ŵᵀ` without materializing `Ŵ`; exact same
/// shapes as [`Tensor::matmul_nt`] against the dequantized matrix.  Weight
/// rows split across pool workers under `d` (serial below the shared flops
/// threshold) — serial and parallel results are bit-identical per ISA arm.
/// Integral activation batches inside the f32 exactness window auto-route
/// to the integer-domain kernel (bit-exact, see the module docs); all
/// others run the f32 panel path on `d`'s ISA arm.
pub fn gemm_fused_with(x: &Tensor, m: &PackedMatrix, d: &Dispatch) -> Result<Tensor> {
    let (n, k) = check_shapes(x, m)?;
    let rows = m.rows();
    let xv = x.as_f32()?;
    // per-call route counters (integer-domain vs f32 panels) — innermost
    // serving hot path, so the kill switch gates them
    let counted = crate::obs::enabled();
    if counted {
        crate::obs_counter!("flexround_fused_gemm_total").inc();
    }
    if let Some(acts) = IntActs::capture(xv, n, k, exact_amax(k, code_mag(m))) {
        if counted {
            crate::obs_counter!("flexround_fused_gemm_int_total").inc();
        }
        return Tensor::from_f32(gemm_int(&acts, n, k, m, d, IntRoute::Auto), &[n, rows]);
    }
    let sumx = row_sums(xv, n, k);
    let isa = d.isa();
    let out = match d.panels(rows, n * rows * k) {
        None => fused_block(xv, &sumx, n, k, m, 0, rows, isa),
        Some(ranges) => {
            let blocks = pool::par_map(ranges.len(), &ranges, |_, &(lo, hi)| {
                fused_block(xv, &sumx, n, k, m, lo, hi, isa)
            });
            gather_blocks(n, rows, &ranges, &blocks)
        }
    };
    Tensor::from_f32(out, &[n, rows])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qrange;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg32;

    fn random_packed(rng: &mut Pcg32, rows: usize, cols: usize, bits: u32) -> PackedMatrix {
        let (qmin, qmax) = qrange(bits, true);
        let (qmin, qmax) = (qmin as i32, qmax as i32);
        let span = (qmax - qmin + 1) as u32;
        let codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
        let zp: Vec<f32> = (0..rows).map(|_| rng.below(3) as f32 - 1.0).collect();
        PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).unwrap()
    }

    #[test]
    fn fused_matches_reference_and_baseline() {
        Prop::new("fused gemm ≡ reference ≡ dequant+matmul").cases(40).check(|rng| {
            let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
            let rows = 1 + rng.below(20) as usize;
            let cols = 1 + rng.below(40) as usize;
            let n = 1 + rng.below(6) as usize;
            let m = random_packed(rng, rows, cols, bits);
            let x = Tensor::from_f32(
                (0..n * cols).map(|_| rng.next_normal()).collect(),
                &[n, cols],
            )
            .map_err(|e| e.to_string())?;
            let reference = gemm_ref(&x, &m).map_err(|e| e.to_string())?;
            let baseline = dequant_matmul(&x, &m).map_err(|e| e.to_string())?;
            let rowwise = gemm_fused_rowwise(&x, &m).map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let fused = gemm_fused(&x, &m, workers).map_err(|e| e.to_string())?;
                if fused.shape() != reference.shape() {
                    return Err(format!("shape {:?} vs {:?}", fused.shape(), reference.shape()));
                }
                // the panel kernel must reproduce the rowwise oracle
                // bit-for-bit: identical per-element accumulation order
                // (both run the active ISA arm here)
                if fused.as_f32().map_err(|e| e.to_string())?
                    != rowwise.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!(
                        "panel kernel (workers={workers}) drifted from the rowwise oracle \
                         ({bits}-bit {rows}×{cols}, batch {n})"
                    ));
                }
                for (label, other) in [("ref", &reference), ("dequant", &baseline)] {
                    let d = fused.max_abs_diff(other).map_err(|e| e.to_string())?;
                    let tol = 1e-4 * (1.0 + other.abs_max());
                    if d > tol {
                        return Err(format!(
                            "fused(workers={workers}) vs {label}: max|Δ| {d} > {tol} \
                             ({bits}-bit {rows}×{cols}, batch {n})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int_path_routes_and_matches() {
        // integral in-window activations: gemm_fused must take the integer
        // route and still be bit-exact against the f32 rowwise oracle; the
        // explicit integer API must agree with both.
        Prop::new("integer auto-route ≡ rowwise, bitwise").cases(32).check(|rng| {
            let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
            let rows = 1 + rng.below(16) as usize;
            let cols = 1 + rng.below(32) as usize;
            let n = 1 + rng.below(4) as usize;
            let m = random_packed(rng, rows, cols, bits);
            let amax = super::exact_amax(cols, super::code_mag(&m)).clamp(1, 50) as u32;
            let x = Tensor::from_f32(
                (0..n * cols)
                    .map(|_| rng.below(2 * amax + 1) as f32 - amax as f32)
                    .collect(),
                &[n, cols],
            )
            .map_err(|e| e.to_string())?;
            if !int_gemm_eligible(&x, &m) {
                return Err(format!("{bits}-bit integral batch should be int-eligible"));
            }
            let rowwise = gemm_fused_rowwise(&x, &m).map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let auto = gemm_fused(&x, &m, workers).map_err(|e| e.to_string())?;
                let explicit = gemm_fused_int(&x, &m, workers).map_err(|e| e.to_string())?;
                if auto.as_f32().map_err(|e| e.to_string())?
                    != rowwise.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!(
                        "integer auto-route drifted from rowwise ({bits}-bit {rows}×{cols})"
                    ));
                }
                if explicit.as_f32().map_err(|e| e.to_string())?
                    != auto.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!(
                        "gemm_fused_int disagrees with the auto route ({bits}-bit)"
                    ));
                }
            }
            Ok(())
        });
        // non-integral activations: not eligible, explicit API refuses
        let mut rng = Pcg32::seeded(3);
        let m = random_packed(&mut rng, 4, 6, 4);
        let x = Tensor::from_f32(vec![0.5; 12], &[2, 6]).unwrap();
        assert!(!int_gemm_eligible(&x, &m));
        assert!(gemm_fused_int(&x, &m, 1).is_err());
    }

    #[test]
    fn act_int_kernel_matches_fake_quant_reference() {
        // the W4A8 contract: integer-domain serving with statically
        // quantized activations ≡ fake-quant f32 reference within 1e-4
        let mut rng = Pcg32::seeded(31);
        for bits in [2u32, 4, 8] {
            let m = random_packed(&mut rng, 12, 23, bits);
            let x = Tensor::from_f32(
                (0..3 * 23).map(|_| 2.0 * rng.next_normal()).collect(),
                &[3, 23],
            )
            .unwrap();
            let aq = ActQuant::calibrate(-4.5, 4.5, 8);
            for workers in [1usize, 4] {
                let got = gemm_fused_act_int(&x, &aq, &m, workers).unwrap();
                let reference = gemm_ref(&aq.fake_quant(&x).unwrap(), &m).unwrap();
                let d = got.max_abs_diff(&reference).unwrap();
                let tol = 1e-4 * (1.0 + reference.abs_max());
                assert!(
                    d <= tol,
                    "act-int kernel drift {d} > {tol} ({bits}-bit weights, workers {workers})"
                );
            }
        }
    }

    #[test]
    fn parallel_split_covers_large_matrices() {
        // big enough to cross the shared dispatch threshold: results must
        // agree with the serial fused path exactly (same per-element op
        // order on both sides of the panel split).
        let mut rng = Pcg32::seeded(9);
        let m = random_packed(&mut rng, 96, 64, 4);
        let x = Tensor::from_f32((0..12 * 64).map(|_| rng.next_normal()).collect(), &[12, 64])
            .unwrap();
        let serial = gemm_fused(&x, &m, 1).unwrap();
        let par = gemm_fused(&x, &m, 4).unwrap();
        assert_eq!(serial.as_f32().unwrap(), par.as_f32().unwrap());
    }

    #[test]
    fn batch1_gemv_path_matches_batched_rows() {
        // the decode hot path: a single activation row must produce exactly
        // the bits the same row yields inside a batch (the prefill/decode
        // parity contract depends on this).
        let mut rng = Pcg32::seeded(21);
        for bits in [2u32, 4, 8] {
            let m = random_packed(&mut rng, 33, 17, bits);
            let batch = Tensor::from_f32(
                (0..5 * 17).map(|_| rng.next_normal()).collect(),
                &[5, 17],
            )
            .unwrap();
            let full = gemm_fused(&batch, &m, 1).unwrap();
            for i in 0..5 {
                let row = batch.slice_rows(i, i + 1).unwrap();
                let one = gemm_fused(&row, &m, 1).unwrap();
                assert_eq!(
                    one.as_f32().unwrap(),
                    &full.as_f32().unwrap()[i * 33..(i + 1) * 33],
                    "{bits}-bit batch-1 row {i} drifted from the batched result"
                );
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rng = Pcg32::seeded(2);
        let m = random_packed(&mut rng, 4, 6, 4);
        let x = Tensor::from_f32(vec![0.0; 10], &[2, 5]).unwrap();
        assert!(gemm_fused(&x, &m, 1).is_err());
        assert!(gemm_ref(&x, &m).is_err());
        assert!(gemm_fused_rowwise(&x, &m).is_err());
        assert!(gemm_fused_int(&x, &m, 1).is_err());
    }
}
