//! Continuous-batching scheduler: many concurrent generation sessions, one
//! batched GEMM per model step (DESIGN.md §Continuous-Batching).
//!
//! [`crate::infer::generate::generate`] decodes one session at a time —
//! every projection runs as a batch-1 gemv, so the packed weight stream is
//! re-read per token per user and nothing amortizes.  The scheduler
//! interleaves **prefill and decode across sessions** instead: each
//! [`Scheduler::step`] gathers the current token row of every running
//! decode session plus a bounded *prefill chunk* of every admitting
//! session into one `(n_active, d)` batch, runs each block's six
//! projections as one fused GEMM ([`crate::infer::kernels`]), scatters the
//! fresh K/V rows into the session's pages of a [`PagedKvPool`], walks
//! each session's page list with [`crate::block::attn_score_segments`] for
//! the attention reads, and finishes with the batched block tail and
//! lm-head stack.  Sampling then advances every session that produced a
//! fresh logits row.
//!
//! ## Bit-identity with the single-session path
//!
//! Batched multi-session decode is **bit-identical** to running each
//! session alone through `generate` (pinned in `rust/tests/sched.rs` and
//! verify.sh's scheduler differential gate, on both ISA arms):
//!
//! * every GEMM in the crate is bit-exact per *row* regardless of batch
//!   composition — one accumulator per output element, contraction index
//!   ascending (`crate::linalg`), with gemv ≡ batched-row and the
//!   integer-domain fused path ≡ the rowwise oracle pinned since PR 5/6;
//! * layernorm, GELU, residual adds, and bias are per-row/element-wise;
//! * the attention reads are `linalg::dot` calls iterated in position
//!   order — [`crate::block::attn_score_row`] *delegates to* the segmented
//!   walk, so the paged read is the same code as the contiguous one;
//! * sampling state is per-session: each session carries its own
//!   [`Pcg32`] seeded exactly as `generate` seeds it, and draws in the
//!   same order (once after prefill, once after every decode step).
//!
//! ## Admission, scheduling, and eviction
//!
//! `submit` rejects sessions that could never fit the pool
//! (`prompt + max_new` pages vs the whole pool); everything else queues.
//! Admission moves queued sessions into the running set while slots
//! (`max_active`) are free — long prompts are prefilled in
//! `prefill_chunk`-row pieces so they cannot starve running decoders.
//! When a running session cannot reserve pages for its next rows, the
//! least-recently-stepped *other* running session not in the current step
//! is evicted: its K/V spill through the [`crate::block::ActivationCache`]
//! FXT machinery, its pages return to the free list, and it re-queues for
//! admission, restoring bit-identically once pages free up.  Progress is
//! guaranteed: every running session's remaining work is bounded by
//! `max_new`, and a session that fits the pool alone always fits once its
//! peers retire.

pub mod paged;

pub use paged::PagedKvPool;

use crate::block::{attn_score_segments, LN_EPS};
use crate::infer::engine::{block_parts, Engine};
use crate::infer::generate::{self, GenOpts};
use crate::tensor::{layernorm_rows, Tensor};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::VecDeque;
use std::path::PathBuf;

/// Scheduler sizing knobs.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// KV pages in the pool (shared by all sessions)
    pub pool_pages: usize,
    /// token rows per page
    pub page_tokens: usize,
    /// running-session bound (admission control on slots)
    pub max_active: usize,
    /// prompt rows prefilled per step per session (long prompts cannot
    /// starve running decoders)
    pub prefill_chunk: usize,
    /// where evicted sessions' K/V spill as FXT files (in-memory if None)
    pub spill_dir: Option<PathBuf>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            pool_pages: 512,
            page_tokens: 16,
            max_active: 8,
            prefill_chunk: 32,
            spill_dir: None,
        }
    }
}

/// One completed generation session.
#[derive(Clone, Debug)]
pub struct FinishedGen {
    /// the handle `submit` returned
    pub handle: u64,
    /// sampled token ids (identical to `generate` run alone)
    pub tokens: Vec<usize>,
}

struct Session {
    handle: u64,
    pool_id: usize,
    prompt: Vec<f32>,
    prompt_len: usize,
    opts: GenOpts,
    rng: Pcg32,
    /// prompt rows already prefilled
    prefill_done: usize,
    /// the next decode step's input row (embedding of the last sampled
    /// token); `None` while prefilling
    pending_row: Option<Vec<f32>>,
    tokens: Vec<usize>,
    /// LRU stamp: the step this session last ran in
    last_step: u64,
}

struct PlanItem {
    sess: usize,
    pool_id: usize,
    rows: usize,
    start_pos: usize,
}

/// The continuous-batching scheduler: owns the [`Engine`] and the
/// [`PagedKvPool`], advances every session one bounded piece per
/// [`Scheduler::step`].
pub struct Scheduler {
    engine: Engine,
    cfg: SchedConfig,
    pool: PagedKvPool,
    tok_w: usize,
    vocab: usize,
    running: Vec<Session>,
    queued: VecDeque<Session>,
    finished: Vec<FinishedGen>,
    next_handle: u64,
    steps: u64,
    probs_scratch: Vec<f32>,
    max_active_seen: usize,
    max_pages_seen: usize,
}

impl Scheduler {
    /// Whether a model can be scheduled at all: nonempty, a tied lm head,
    /// well-formed block units.  After this passes, [`Scheduler::new`] on
    /// the same model cannot fail (the config knobs are clamped) — which is
    /// what lets the serve batcher pick its core without consuming the
    /// engine speculatively.
    pub fn supported(model: &crate::infer::PackedModel) -> Result<()> {
        model.in_width().ok_or_else(|| anyhow!("scheduler: empty packed model"))?;
        generate::vocab(model)?;
        for u in model.units.iter().filter(|u| u.kind == "transformer_block") {
            if u.layers.is_empty() {
                bail!("block unit {:?} has no layers", u.name);
            }
        }
        Ok(())
    }

    /// Build a scheduler over a generation-complete packed model (blocks +
    /// tied lm head).  Fails fast on a model `generate` could not serve.
    /// Degenerate config values are clamped up to 1 rather than rejected.
    pub fn new(engine: Engine, cfg: SchedConfig) -> Result<Scheduler> {
        let cfg = SchedConfig {
            pool_pages: cfg.pool_pages.max(1),
            page_tokens: cfg.page_tokens.max(1),
            max_active: cfg.max_active.max(1),
            prefill_chunk: cfg.prefill_chunk.max(1),
            ..cfg
        };
        let tok_w = engine
            .model()
            .in_width()
            .ok_or_else(|| anyhow!("scheduler: empty packed model"))?;
        let vocab = generate::vocab(engine.model())?;
        let mut dims = Vec::new();
        for u in engine.model().units.iter().filter(|u| u.kind == "transformer_block") {
            let d = u
                .layers
                .first()
                .map(|l| l.mat.cols())
                .ok_or_else(|| anyhow!("block unit {:?} has no layers", u.name))?;
            dims.push(d);
        }
        let pool =
            PagedKvPool::new(&dims, cfg.pool_pages, cfg.page_tokens, cfg.spill_dir.as_deref())?;
        Ok(Scheduler {
            engine,
            cfg,
            pool,
            tok_w,
            vocab,
            running: Vec::new(),
            queued: VecDeque::new(),
            finished: Vec::new(),
            next_handle: 0,
            steps: 0,
            probs_scratch: Vec::new(),
            max_active_seen: 0,
            max_pages_seen: 0,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Sessions currently admitted (running a prefill chunk or decode row
    /// per step).
    pub fn active_sessions(&self) -> usize {
        self.running.len()
    }

    /// Sessions waiting for admission (including evicted ones).
    pub fn queued_sessions(&self) -> usize {
        self.queued.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.pool.pages_in_use()
    }

    pub fn evictions(&self) -> u64 {
        self.pool.evictions()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// High-water marks since construction: `(active sessions, pool pages)`.
    pub fn occupancy_peaks(&self) -> (usize, usize) {
        (self.max_active_seen, self.max_pages_seen)
    }

    /// Anything left to step?
    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.queued.is_empty()
    }

    /// Enqueue a generation session: `prompt` is `t ≥ 1` flattened token
    /// rows, `opts` exactly as [`generate::generate`] takes them.  Returns
    /// the session handle [`FinishedGen`] will carry.  Rejects sessions
    /// whose `prompt + max_new` tokens could never fit the pool — the
    /// admission-control bound tied to pool capacity.
    pub fn submit(&mut self, prompt: Vec<f32>, opts: GenOpts) -> Result<u64> {
        if prompt.is_empty() || prompt.len() % self.tok_w != 0 {
            bail!(
                "scheduler: prompt has {} values, need a nonzero multiple of the token \
                 width {}",
                prompt.len(),
                self.tok_w
            );
        }
        let t = prompt.len() / self.tok_w;
        let total = t.saturating_add(opts.max_new);
        if !self.pool.fits(total) {
            bail!(
                "scheduler: session needs {} tokens ({} pages) but the pool holds only \
                 {} pages of {} tokens — raise --pool-pages or shorten the request",
                total,
                self.pool.pages_for(total),
                self.pool.num_pages(),
                self.pool.page_tokens()
            );
        }
        let handle = self.next_handle;
        self.next_handle += 1;
        self.queued.push_back(Session {
            handle,
            pool_id: self.pool.open(),
            prompt,
            prompt_len: t,
            opts,
            rng: Pcg32::seeded(opts.seed),
            prefill_done: 0,
            pending_row: None,
            tokens: Vec::new(),
            last_step: 0,
        });
        Ok(handle)
    }

    /// Completed sessions since the last call (order of completion).
    pub fn take_finished(&mut self) -> Vec<FinishedGen> {
        std::mem::take(&mut self.finished)
    }

    /// Abort every queued and running session, releasing their pages.
    /// Returns the handles that will now never finish (the serve layer
    /// answers them with an error).
    pub fn abort_all(&mut self) -> Vec<u64> {
        let mut handles = Vec::new();
        for s in self.running.drain(..).chain(self.queued.drain(..)) {
            let _ = self.pool.close(s.pool_id);
            handles.push(s.handle);
        }
        handles
    }

    /// Step every session to completion and return the finished set —
    /// the batch analogue of calling [`generate::generate`] per session.
    pub fn run_all(&mut self) -> Result<Vec<FinishedGen>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    /// Move queued sessions into the running set while slots are free.  An
    /// evicted session at the head must restore first; if pages are short
    /// it blocks the queue head (fair — it has been waiting longest) until
    /// peers retire.
    fn admit(&mut self) -> Result<()> {
        while self.running.len() < self.cfg.max_active {
            let Some(front) = self.queued.front() else { break };
            if self.pool.is_evicted(front.pool_id) && !self.pool.restore(front.pool_id)? {
                break;
            }
            let s = self.queued.pop_front().unwrap();
            self.running.push(s);
        }
        Ok(())
    }

    /// One scheduler step: admit, plan, run one batched forward over every
    /// planned row, scatter K/V, sample, retire.  Returns the number of
    /// token rows processed (0 = the scheduler is idle).
    pub fn step(&mut self) -> Result<usize> {
        let _span = crate::obs::span("sched/step");
        self.admit()?;
        if self.running.is_empty() {
            return Ok(0);
        }

        // -- plan: what does each running session process this step? --
        let mut plan: Vec<PlanItem> = Vec::with_capacity(self.running.len());
        let mut si = 0usize;
        while si < self.running.len() {
            let s = &self.running[si];
            let rows = if s.prefill_done < s.prompt_len {
                self.cfg.prefill_chunk.min(s.prompt_len - s.prefill_done)
            } else if s.pending_row.is_some() {
                1
            } else {
                si += 1;
                continue;
            };
            let start_pos = self.pool.len(s.pool_id)?;
            let pool_id = s.pool_id;
            // reserve pages; evict LRU unplanned peers until it fits
            while !self.pool.reserve(pool_id, start_pos + rows)? {
                let victim = self
                    .running
                    .iter()
                    .enumerate()
                    .filter(|(vi, v)| {
                        *vi != si
                            && !plan.iter().any(|p| p.sess == *vi)
                            && self.pool.len(v.pool_id).map(|l| l > 0).unwrap_or(false)
                    })
                    .min_by_key(|(_, v)| v.last_step)
                    .map(|(vi, _)| vi);
                let Some(vi) = victim else { break };
                self.pool.evict(self.running[vi].pool_id)?;
                let evicted = self.running.remove(vi);
                self.queued.push_back(evicted);
                // removal shifts indices: fix up si and the planned items
                if vi < si {
                    si -= 1;
                }
                for p in &mut plan {
                    if p.sess > vi {
                        p.sess -= 1;
                    }
                }
            }
            if self.pool.reserve(pool_id, start_pos + rows)? {
                plan.push(PlanItem { sess: si, pool_id, rows, start_pos });
            }
            // else: no evictable peer — the session skips this step
            si += 1;
        }
        if plan.is_empty() {
            return Ok(0);
        }
        self.max_active_seen = self.max_active_seen.max(self.running.len());
        self.max_pages_seen = self.max_pages_seen.max(self.pool.pages_in_use());

        // -- gather the batch: plan order, token rows --
        let n: usize = plan.iter().map(|p| p.rows).sum();
        let mut flat = Vec::with_capacity(n * self.tok_w);
        let mut prefill_rows = 0usize;
        let mut prefill_chunks = 0u64;
        for p in &plan {
            let s = &self.running[p.sess];
            if s.prefill_done < s.prompt_len {
                let a = s.prefill_done * self.tok_w;
                flat.extend_from_slice(&s.prompt[a..a + p.rows * self.tok_w]);
                prefill_rows += p.rows;
                prefill_chunks += 1;
            } else {
                flat.extend_from_slice(s.pending_row.as_ref().expect("planned decode row"));
            }
        }
        let x = Tensor::from_f32(flat, &[n, self.tok_w])?;

        // -- one batched forward over every unit --
        let logits = forward_batch(
            &self.engine,
            &mut self.pool,
            &plan,
            &x,
            &mut self.probs_scratch,
        )?;
        if logits.shape() != [n, self.vocab] {
            bail!(
                "scheduler: step emitted {:?}, expected [{n}, {}]",
                logits.shape(),
                self.vocab
            );
        }
        let lv = logits.as_f32()?;

        // -- commit, sample, retire --
        self.steps += 1;
        let mut row0 = 0usize;
        let mut done: Vec<usize> = Vec::new();
        for p in &plan {
            self.pool.commit(p.pool_id, p.start_pos + p.rows)?;
            let s = &mut self.running[p.sess];
            s.last_step = self.steps;
            let fresh = if s.prefill_done < s.prompt_len {
                s.prefill_done += p.rows;
                s.prefill_done == s.prompt_len // the final chunk's last row
            } else {
                s.pending_row = None;
                true
            };
            if fresh {
                // replicate generate()'s sample loop exactly: sample, push,
                // stop at max_new *before* embedding the next input row
                if s.tokens.len() < s.opts.max_new {
                    let last = &lv[(row0 + p.rows - 1) * self.vocab..(row0 + p.rows) * self.vocab];
                    let tok = generate::sample_token(last, s.opts.temp, s.opts.top_k, &mut s.rng);
                    s.tokens.push(tok);
                    if s.tokens.len() < s.opts.max_new {
                        s.pending_row = Some(generate::embed_token(self.engine.model(), tok)?);
                    }
                }
                if s.tokens.len() >= s.opts.max_new {
                    done.push(p.sess);
                }
            }
            row0 += p.rows;
        }
        // retire finished sessions (highest index first so removals do not
        // shift the remaining ones)
        done.sort_unstable_by(|a, b| b.cmp(a));
        for di in done {
            let s = self.running.remove(di);
            self.pool.close(s.pool_id)?;
            self.finished.push(FinishedGen { handle: s.handle, tokens: s.tokens });
        }
        // publish scheduler liveness for /metrics and /healthz: one counter
        // bump, one histogram sample, and four gauge stores per step — noise
        // next to the batched forward above, so not gated by the kill switch
        crate::obs_counter!("flexround_sched_steps_total").inc();
        crate::obs_counter!("flexround_sched_prefill_rows_total").add(prefill_rows as u64);
        crate::obs_counter!("flexround_sched_prefill_chunks_total").add(prefill_chunks);
        crate::obs_counter!("flexround_sched_decode_rows_total").add((n - prefill_rows) as u64);
        crate::obs_hist!("flexround_sched_step_rows").record(n as f64);
        crate::obs_gauge!("flexround_sched_active_sessions").set(self.running.len() as i64);
        crate::obs_gauge!("flexround_sched_queued_sessions").set(self.queued.len() as i64);
        crate::obs_gauge!("flexround_sched_pages_in_use").set(self.pool.pages_in_use() as i64);
        Ok(n)
    }
}

/// The batched model forward of one scheduler step: token rows of every
/// planned session, K/V scattered into each session's pages, attention
/// walking the page lists, block tail + lm-head stack batched.  Per-row
/// bit-identical to [`Engine::prefill`]/[`Engine::decode_step`] on the
/// same rows (see the module docs for why).
fn forward_batch(
    engine: &Engine,
    pool: &mut PagedKvPool,
    plan: &[PlanItem],
    x: &Tensor,
    probs: &mut Vec<f32>,
) -> Result<Tensor> {
    let n = x.shape()[0];
    let mut h = x.clone();
    let mut bi = 0usize;
    for unit in &engine.model().units {
        if unit.kind != "transformer_block" {
            h = engine.stack_forward(unit, &h, true)?;
            continue;
        }
        let p = block_parts(unit)?;
        let (h1, _, _) = layernorm_rows(&h, p.g1, p.b1, LN_EPS)?;
        let q = engine.gemm_bias(&h1, p.wq, true)?;
        let k = engine.gemm_bias(&h1, p.wk, true)?;
        let v = engine.gemm_bias(&h1, p.wv, true)?;
        let d = k.shape()[1];
        let heads = unit.heads.max(1);
        if d % heads != 0 {
            bail!("block unit {:?}: width {d} not divisible by {heads} heads", unit.name);
        }
        let dh = d / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let (qv, kv, vv) = (q.as_f32()?, k.as_f32()?, v.as_f32()?);
        // scatter each session's fresh K/V rows into its pages, then walk
        // the page list for the attention reads (count = causal frontier)
        let mut ctx = vec![0.0f32; n * d];
        let mut row0 = 0usize;
        for item in plan {
            pool.append_rows(
                item.pool_id,
                bi,
                &kv[row0 * d..(row0 + item.rows) * d],
                &vv[row0 * d..(row0 + item.rows) * d],
            )?;
            let segs = pool.segments(item.pool_id, bi)?;
            for i in 0..item.rows {
                let count = item.start_pos + i + 1;
                if probs.len() < count {
                    probs.resize(count, 0.0);
                }
                for hd in 0..heads {
                    let c0 = hd * dh;
                    attn_score_segments(
                        &qv[(row0 + i) * d + c0..(row0 + i) * d + c0 + dh],
                        &segs,
                        d,
                        c0,
                        count,
                        scale,
                        probs,
                        &mut ctx[(row0 + i) * d + c0..(row0 + i) * d + c0 + dh],
                    );
                }
            }
            row0 += item.rows;
        }
        let ctx = Tensor::from_f32(ctx, &[n, d])?;
        h = engine.block_tail(&p, &h, &ctx, true)?;
        bi += 1;
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::generate::{random_prompt, synthetic_lm};

    fn lm_engine(bits: u32) -> Engine {
        Engine::new(synthetic_lm(2, 16, 4, 32, 8, 24, bits, 13).unwrap(), 1)
    }

    #[test]
    fn single_session_matches_generate() {
        let engine = lm_engine(4);
        let reference = lm_engine(4);
        let opts = GenOpts { max_new: 9, temp: 0.8, top_k: 5, seed: 21 };
        let (_, prompt) = random_prompt(reference.model(), 5, 3).unwrap();
        let want = generate::generate(&reference, &prompt, &opts).unwrap().tokens;
        let mut sched = Scheduler::new(engine, SchedConfig::default()).unwrap();
        let h = sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        let fin = sched.run_all().unwrap();
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].handle, h);
        assert_eq!(fin[0].tokens, want, "scheduled decode must equal generate()");
        assert!(!sched.has_work());
        assert_eq!(sched.pages_in_use(), 0, "retired sessions must free their pages");
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        // prompt longer than prefill_chunk: the chunked path must emit the
        // same stream as generate()'s one-shot prefill
        let engine = lm_engine(4);
        let reference = lm_engine(4);
        let opts = GenOpts { max_new: 6, temp: 0.0, top_k: 0, seed: 7 };
        let (_, prompt) = random_prompt(reference.model(), 11, 5).unwrap();
        let want = generate::generate(&reference, &prompt, &opts).unwrap().tokens;
        let cfg = SchedConfig { prefill_chunk: 3, ..SchedConfig::default() };
        let mut sched = Scheduler::new(engine, cfg).unwrap();
        sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        let fin = sched.run_all().unwrap();
        assert_eq!(fin[0].tokens, want, "chunked prefill diverged from one-shot");
        assert!(sched.steps() >= 4, "11 prompt rows / chunk 3 needs ≥4 steps");
    }

    #[test]
    fn zero_max_new_finishes_with_no_tokens() {
        let engine = lm_engine(4);
        let opts = GenOpts { max_new: 0, temp: 0.0, top_k: 0, seed: 1 };
        let (_, prompt) = random_prompt(engine.model(), 3, 2).unwrap();
        let mut sched = Scheduler::new(engine, SchedConfig::default()).unwrap();
        sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        let fin = sched.run_all().unwrap();
        assert!(fin[0].tokens.is_empty());
    }

    #[test]
    fn oversized_sessions_are_rejected_at_submit() {
        let engine = lm_engine(4);
        let (_, prompt) = random_prompt(engine.model(), 4, 2).unwrap();
        let cfg = SchedConfig { pool_pages: 2, page_tokens: 4, ..SchedConfig::default() };
        let mut sched = Scheduler::new(engine, cfg).unwrap();
        // 4 prompt + 8 new = 12 tokens > 2×4 pool
        let opts = GenOpts { max_new: 8, temp: 0.0, top_k: 0, seed: 1 };
        assert!(sched.submit(prompt.as_f32().unwrap().to_vec(), opts).is_err());
        // 4 + 4 = 8 fits exactly
        let opts = GenOpts { max_new: 4, temp: 0.0, top_k: 0, seed: 1 };
        sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        assert_eq!(sched.run_all().unwrap()[0].tokens.len(), 4);
        // malformed prompts
        assert!(sched.submit(vec![], opts).is_err());
        assert!(sched.submit(vec![0.0; 3], opts).is_err());
    }

    #[test]
    fn abort_all_releases_everything() {
        let engine = lm_engine(4);
        let (_, prompt) = random_prompt(engine.model(), 4, 2).unwrap();
        let mut sched = Scheduler::new(engine, SchedConfig::default()).unwrap();
        let opts = GenOpts { max_new: 8, temp: 0.0, top_k: 0, seed: 1 };
        let a = sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        let b = sched.submit(prompt.as_f32().unwrap().to_vec(), opts).unwrap();
        sched.step().unwrap();
        let mut aborted = sched.abort_all();
        aborted.sort_unstable();
        assert_eq!(aborted, vec![a, b]);
        assert!(!sched.has_work());
        assert_eq!(sched.pages_in_use(), 0);
    }
}
