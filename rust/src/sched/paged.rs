//! Paged KV pool: fixed-size pages of K/V rows behind a free-list
//! allocator (DESIGN.md §Continuous-Batching).
//!
//! The contiguous [`crate::infer::KvCache`] grows one `Vec` per block per
//! session — fine for a single decode loop, hopeless for many concurrent
//! sessions: memory fragments per-session and nothing bounds the total.
//! The pool instead owns one slab per transformer block, carved into
//! `num_pages` pages of `page_tokens` rows each.  A single **page id**
//! reserves its row range in *every* block's slab (K/V lengths are always
//! in lockstep across blocks, so per-block page tables would only buy
//! bookkeeping), which leaves one free list for the whole pool and makes
//! capacity accounting exact: a session holding `p` pages holds
//! `p · page_tokens` token slots in each block.
//!
//! Per-session state is a page table (ordered page ids), the committed
//! token count, and a per-block written-row count — committed in lockstep
//! exactly like `KvCache::set_pos`, so a dropped or double-pushed row is
//! caught at the commit, not three tokens later as garbage attention.
//!
//! Attention never copies rows out of the pool: [`PagedKvPool::segments`]
//! returns the session's pages as an ordered `(k_slice, v_slice, rows)`
//! list that [`crate::block::attn_score_segments`] walks in position
//! order — bit-identical to the contiguous walk by construction.
//!
//! Eviction spills a session's gathered K/V tensors through the existing
//! [`ActivationCache`] FXT-spill machinery (budget 0 + a spill dir ⇒ every
//! chunk goes straight to disk; no dir ⇒ the chunks stay in memory), frees
//! its pages, and [`PagedKvPool::restore`] scatters the rows back into
//! freshly allocated pages **bit-identically** — f32 bits round-trip the
//! FXT container exactly, and the page-table layout is invisible to the
//! segmented attention walk.

use crate::block::ActivationCache;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};
use std::path::{Path, PathBuf};

/// One transformer block's slab: `num_pages · page_tokens` K and V rows of
/// width `d`, row-addressed by `page_id · page_tokens + offset`.
struct Slab {
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

/// One session's residency state.
struct Entry {
    /// ordered page table: logical token row `r` lives in
    /// `pages[r / page_tokens]` at offset `r % page_tokens`
    pages: Vec<usize>,
    /// committed tokens (prompt + decoded), lockstep across blocks
    len: usize,
    /// rows written per block since the session opened — must all equal the
    /// target at [`PagedKvPool::commit`]
    written: Vec<usize>,
    /// evicted K/V, two tensors per block (K then V), `(len, d)` each
    spilled: Option<ActivationCache>,
}

/// A slab of fixed-size KV pages shared by every concurrent generation
/// session, with block-granular alloc/free and spill-backed eviction.
pub struct PagedKvPool {
    dims: Vec<usize>,
    page_tokens: usize,
    num_pages: usize,
    slabs: Vec<Slab>,
    /// free page ids (LIFO — reuse hot pages first)
    free: Vec<usize>,
    sessions: Vec<Option<Entry>>,
    spill_dir: Option<PathBuf>,
    evictions: u64,
}

impl PagedKvPool {
    /// A pool of `num_pages` pages of `page_tokens` token rows each, one
    /// slab per block width in `dims`.  `spill_dir` is where evicted
    /// sessions' K/V chunks go as FXT files (in-memory when `None`).
    pub fn new(
        dims: &[usize],
        num_pages: usize,
        page_tokens: usize,
        spill_dir: Option<&Path>,
    ) -> Result<PagedKvPool> {
        if page_tokens == 0 {
            bail!("paged kv pool: page_tokens must be ≥ 1");
        }
        if num_pages == 0 && !dims.is_empty() {
            bail!("paged kv pool: num_pages must be ≥ 1 when the model has blocks");
        }
        let rows = num_pages * page_tokens;
        let slabs = dims
            .iter()
            .map(|&d| Slab { d, k: vec![0.0; rows * d], v: vec![0.0; rows * d] })
            .collect();
        Ok(PagedKvPool {
            dims: dims.to_vec(),
            page_tokens,
            num_pages,
            slabs,
            free: (0..num_pages).rev().collect(),
            sessions: Vec::new(),
            spill_dir: spill_dir.map(Path::to_path_buf),
            evictions: 0,
        })
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.num_pages - self.free.len()
    }

    /// Sessions evicted so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Pages needed to hold `tokens` rows.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Whether a session of `tokens` total rows can *ever* fit (admission
    /// control: against the whole pool, not the current free list).
    pub fn fits(&self, tokens: usize) -> bool {
        self.dims.is_empty() || self.pages_for(tokens) <= self.num_pages
    }

    /// Open a session slot; returns its id.  Allocates no pages yet.
    pub fn open(&mut self) -> usize {
        let entry = Entry {
            pages: Vec::new(),
            len: 0,
            written: vec![0; self.dims.len()],
            spilled: None,
        };
        for (i, s) in self.sessions.iter_mut().enumerate() {
            if s.is_none() {
                *s = Some(entry);
                return i;
            }
        }
        self.sessions.push(Some(entry));
        self.sessions.len() - 1
    }

    /// Close a session, returning its pages to the free list (spilled
    /// chunks are purged via the `ActivationCache` drop).
    pub fn close(&mut self, id: usize) -> Result<()> {
        let entry = self
            .sessions
            .get_mut(id)
            .and_then(Option::take)
            .ok_or_else(|| anyhow!("paged kv pool: no session {id}"))?;
        self.free.extend(entry.pages);
        Ok(())
    }

    fn entry(&self, id: usize) -> Result<&Entry> {
        self.sessions
            .get(id)
            .and_then(Option::as_ref)
            .ok_or_else(|| anyhow!("paged kv pool: no session {id}"))
    }

    fn entry_mut(&mut self, id: usize) -> Result<&mut Entry> {
        self.sessions
            .get_mut(id)
            .and_then(Option::as_mut)
            .ok_or_else(|| anyhow!("paged kv pool: no session {id}"))
    }

    /// Committed tokens of a session.
    pub fn len(&self, id: usize) -> Result<usize> {
        Ok(self.entry(id)?.len)
    }

    /// Whether the session's K/V currently live in spill storage.
    pub fn is_evicted(&self, id: usize) -> bool {
        self.entry(id).map(|e| e.spilled.is_some()).unwrap_or(false)
    }

    /// Grow the session's page table until it holds `tokens` rows.  Returns
    /// `false` (allocating nothing) when the free list cannot cover it —
    /// the caller decides whether to evict someone or wait.
    pub fn reserve(&mut self, id: usize, tokens: usize) -> Result<bool> {
        let have = self.entry(id)?.pages.len();
        if self.entry(id)?.spilled.is_some() {
            bail!("paged kv pool: reserve on evicted session {id} (restore first)");
        }
        let need = self.pages_for(tokens);
        if self.dims.is_empty() || need <= have {
            return Ok(true);
        }
        if need - have > self.free.len() {
            return Ok(false);
        }
        let grown: Vec<usize> = (0..need - have).map(|_| self.free.pop().unwrap()).collect();
        self.entry_mut(id)?.pages.extend(grown);
        Ok(true)
    }

    /// Scatter `(rows, d)` K/V row groups for `block` into the session's
    /// pages, after the committed frontier.  Capacity must already be
    /// reserved; the rows count toward the next [`PagedKvPool::commit`].
    pub fn append_rows(
        &mut self,
        id: usize,
        block: usize,
        krows: &[f32],
        vrows: &[f32],
    ) -> Result<()> {
        let page_tokens = self.page_tokens;
        let d = *self
            .dims
            .get(block)
            .ok_or_else(|| anyhow!("paged kv pool has {} blocks, asked for {block}", self.dims.len()))?;
        if krows.is_empty() || krows.len() != vrows.len() || krows.len() % d != 0 {
            bail!(
                "paged kv append: {} k values vs {} v values (row width {d})",
                krows.len(),
                vrows.len()
            );
        }
        let entry = self
            .sessions
            .get(id)
            .and_then(Option::as_ref)
            .ok_or_else(|| anyhow!("paged kv pool: no session {id}"))?;
        if entry.spilled.is_some() {
            bail!("paged kv pool: append to evicted session {id}");
        }
        let start = entry.written[block];
        let n = krows.len() / d;
        if (start + n) > entry.pages.len() * page_tokens {
            bail!(
                "paged kv pool: session {id} block {block} writes row {} past its {} reserved \
                 rows (reserve before append)",
                start + n,
                entry.pages.len() * page_tokens
            );
        }
        // borrow dance: copy the page table (small), then write the slab
        let pages = entry.pages.clone();
        let slab = &mut self.slabs[block];
        for i in 0..n {
            let r = start + i;
            let row0 = (pages[r / page_tokens] * page_tokens + r % page_tokens) * d;
            slab.k[row0..row0 + d].copy_from_slice(&krows[i * d..(i + 1) * d]);
            slab.v[row0..row0 + d].copy_from_slice(&vrows[i * d..(i + 1) * d]);
        }
        self.entry_mut(id)?.written[block] += n;
        Ok(())
    }

    /// Commit position `t`: every block must have written exactly `t` rows
    /// (the same lockstep contract as `KvCache::set_pos`).
    pub fn commit(&mut self, id: usize, t: usize) -> Result<()> {
        let entry = self.entry(id)?;
        for (b, &w) in entry.written.iter().enumerate() {
            if w != t {
                bail!("paged kv pool: session {id} block {b} wrote {w} rows, expected {t}");
            }
        }
        self.entry_mut(id)?.len = t;
        Ok(())
    }

    /// The session's written K/V rows for `block`, as an ordered
    /// `(k_slice, v_slice, rows)` page-segment list for
    /// [`crate::block::attn_score_segments`].  Covers every *written* row —
    /// during a step the current chunk's rows are appended before they are
    /// attended, so the walk sees them ahead of the commit.
    pub fn segments(&self, id: usize, block: usize) -> Result<Vec<(&[f32], &[f32], usize)>> {
        let entry = self.entry(id)?;
        if entry.spilled.is_some() {
            bail!("paged kv pool: segments of evicted session {id}");
        }
        let d = *self
            .dims
            .get(block)
            .ok_or_else(|| anyhow!("paged kv pool has {} blocks, asked for {block}", self.dims.len()))?;
        let slab = &self.slabs[block];
        let mut left = entry.written[block];
        let mut out = Vec::with_capacity(entry.pages.len());
        for &p in &entry.pages {
            if left == 0 {
                break;
            }
            let rows = left.min(self.page_tokens);
            let a = p * self.page_tokens * d;
            let b = a + rows * d;
            out.push((&slab.k[a..b], &slab.v[a..b], rows));
            left -= rows;
        }
        Ok(out)
    }

    /// Evict a session: gather its committed K/V rows per block into
    /// contiguous tensors, push them through an [`ActivationCache`] (budget
    /// 0 + the pool's spill dir ⇒ straight to FXT files on disk), and free
    /// its pages.  Refuses while uncommitted rows exist — eviction is only
    /// legal between steps, when every block is in lockstep.
    pub fn evict(&mut self, id: usize) -> Result<()> {
        let _span = crate::obs::span("sched/evict");
        let entry = self.entry(id)?;
        if entry.spilled.is_some() {
            bail!("paged kv pool: session {id} is already evicted");
        }
        if entry.len == 0 {
            bail!("paged kv pool: session {id} has no committed rows to evict");
        }
        for (b, &w) in entry.written.iter().enumerate() {
            if w != entry.len {
                bail!(
                    "paged kv pool: evicting session {id} with uncommitted rows \
                     (block {b}: {w} written vs {} committed)",
                    entry.len
                );
            }
        }
        let len = entry.len;
        let mut cache = match &self.spill_dir {
            Some(dir) => ActivationCache::with_budget(0, Some(dir.as_path())),
            None => ActivationCache::unbounded(),
        };
        for b in 0..self.dims.len() {
            let d = self.dims[b];
            let segs = self.segments(id, b)?;
            let mut k = Vec::with_capacity(len * d);
            let mut v = Vec::with_capacity(len * d);
            for (ks, vs, _) in segs {
                k.extend_from_slice(ks);
                v.extend_from_slice(vs);
            }
            cache.push(Tensor::from_f32(k, &[len, d])?)?;
            cache.push(Tensor::from_f32(v, &[len, d])?)?;
        }
        let entry = self.entry_mut(id)?;
        let pages = std::mem::take(&mut entry.pages);
        entry.spilled = Some(cache);
        self.free.extend(pages);
        self.evictions += 1;
        crate::obs_counter!("flexround_sched_evictions_total").inc();
        Ok(())
    }

    /// Bring an evicted session back: allocate pages for its committed
    /// length and scatter the spilled rows back in.  Returns `false`
    /// (leaving the session evicted) when the free list cannot cover it.
    /// The restored rows are bit-identical to what was evicted — the FXT
    /// round trip preserves f32 bits and the segment walk hides the layout.
    pub fn restore(&mut self, id: usize) -> Result<bool> {
        let _span = crate::obs::span("sched/restore");
        let entry = self.entry(id)?;
        let Some(cache) = &entry.spilled else {
            bail!("paged kv pool: session {id} is not evicted");
        };
        let len = entry.len;
        if cache.len() != 2 * self.dims.len() {
            bail!(
                "paged kv pool: session {id} spill holds {} chunks, expected {}",
                cache.len(),
                2 * self.dims.len()
            );
        }
        let need = self.pages_for(len);
        if need > self.free.len() {
            return Ok(false);
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        let page_tokens = self.page_tokens;
        for b in 0..self.dims.len() {
            let d = self.dims[b];
            // take() below drops the cache, so read through a fresh borrow
            let cache = self.sessions[id].as_ref().unwrap().spilled.as_ref().unwrap();
            let k = cache.get(2 * b)?.into_owned();
            let v = cache.get(2 * b + 1)?.into_owned();
            if k.shape() != [len, d] || v.shape() != [len, d] {
                bail!("paged kv pool: session {id} spill chunk {b} has the wrong shape");
            }
            let (kv, vv) = (k.as_f32()?, v.as_f32()?);
            let slab = &mut self.slabs[b];
            for r in 0..len {
                let row0 = (pages[r / page_tokens] * page_tokens + r % page_tokens) * d;
                slab.k[row0..row0 + d].copy_from_slice(&kv[r * d..(r + 1) * d]);
                slab.v[row0..row0 + d].copy_from_slice(&vv[r * d..(r + 1) * d]);
            }
        }
        let entry = self.entry_mut(id)?;
        entry.pages = pages;
        entry.spilled = None; // drop purges the spill files
        crate::obs_counter!("flexround_sched_restores_total").inc();
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n * d).map(|_| rng.next_normal()).collect()
    }

    /// Gather a session's rows back out through the segment walk.
    fn gather(pool: &PagedKvPool, id: usize, block: usize) -> (Vec<f32>, Vec<f32>) {
        let (mut k, mut v) = (Vec::new(), Vec::new());
        for (ks, vs, _) in pool.segments(id, block).unwrap() {
            k.extend_from_slice(ks);
            v.extend_from_slice(vs);
        }
        (k, v)
    }

    #[test]
    fn alloc_append_commit_roundtrip_across_page_boundaries() {
        let d = 4usize;
        let mut pool = PagedKvPool::new(&[d, d], 8, 3, None).unwrap();
        let id = pool.open();
        // 7 rows straddle three 3-row pages
        let (k, v) = (rows(7, d, 1), rows(7, d, 2));
        assert!(pool.reserve(id, 7).unwrap());
        assert_eq!(pool.pages_in_use(), 3);
        for b in 0..2 {
            // append in uneven chunks: 2 + 4 + 1 rows
            pool.append_rows(id, b, &k[..2 * d], &v[..2 * d]).unwrap();
            pool.append_rows(id, b, &k[2 * d..6 * d], &v[2 * d..6 * d]).unwrap();
            pool.append_rows(id, b, &k[6 * d..], &v[6 * d..]).unwrap();
        }
        pool.commit(id, 7).unwrap();
        assert_eq!(pool.len(id).unwrap(), 7);
        for b in 0..2 {
            let (gk, gv) = gather(&pool, id, b);
            assert_eq!(gk, k, "block {b} K rows must round-trip the page layout");
            assert_eq!(gv, v, "block {b} V rows must round-trip the page layout");
        }
        // segments are cut at page boundaries: 3 + 3 + 1 rows
        let segs = pool.segments(id, 0).unwrap();
        assert_eq!(segs.iter().map(|s| s.2).collect::<Vec<_>>(), vec![3, 3, 1]);
        pool.close(id).unwrap();
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn lockstep_commit_is_enforced() {
        let d = 4usize;
        let mut pool = PagedKvPool::new(&[d, d], 4, 2, None).unwrap();
        let id = pool.open();
        assert!(pool.reserve(id, 2).unwrap());
        let (k, v) = (rows(1, d, 3), rows(1, d, 4));
        pool.append_rows(id, 0, &k, &v).unwrap();
        // block 1 never wrote → commit must fail and len must not move
        assert!(pool.commit(id, 1).is_err());
        assert_eq!(pool.len(id).unwrap(), 0);
        pool.append_rows(id, 1, &k, &v).unwrap();
        pool.commit(id, 1).unwrap();
        // shape mismatches and unreserved writes are rejected
        assert!(pool.append_rows(id, 0, &k[..3], &v[..3]).is_err());
        assert!(pool.append_rows(id, 9, &k, &v).is_err());
        let big = rows(9, d, 5);
        assert!(pool.append_rows(id, 0, &big, &big).is_err(), "write past reservation");
    }

    #[test]
    fn churn_reuses_pages_without_cross_talk() {
        let d = 2usize;
        let mut pool = PagedKvPool::new(&[d], 4, 2, None).unwrap();
        // session A takes all four pages, then frees them
        let a = pool.open();
        assert!(pool.reserve(a, 8).unwrap());
        let (ka, va) = (rows(8, d, 10), rows(8, d, 11));
        pool.append_rows(a, 0, &ka, &va).unwrap();
        pool.commit(a, 8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.close(a).unwrap();
        assert_eq!(pool.free_pages(), 4);
        // two new sessions split the recycled pages; their data stays theirs
        let b = pool.open();
        let c = pool.open();
        let (kb, vb) = (rows(3, d, 20), rows(3, d, 21));
        let (kc, vc) = (rows(4, d, 30), rows(4, d, 31));
        assert!(pool.reserve(b, 3).unwrap());
        assert!(pool.reserve(c, 4).unwrap());
        pool.append_rows(b, 0, &kb, &vb).unwrap();
        pool.append_rows(c, 0, &kc, &vc).unwrap();
        pool.commit(b, 3).unwrap();
        pool.commit(c, 4).unwrap();
        assert_eq!(gather(&pool, b, 0), (kb, vb));
        assert_eq!(gather(&pool, c, 0), (kc, vc));
        // incremental growth onto a fresh page
        let (k1, v1) = (rows(1, d, 40), rows(1, d, 41));
        assert!(pool.reserve(b, 4).unwrap());
        pool.append_rows(b, 0, &k1, &v1).unwrap();
        pool.commit(b, 4).unwrap();
        let (gk, _) = gather(&pool, b, 0);
        assert_eq!(&gk[3 * d..], &k1[..]);
    }

    #[test]
    fn exhaustion_reports_false_and_allocates_nothing() {
        let d = 2usize;
        let mut pool = PagedKvPool::new(&[d], 2, 2, None).unwrap();
        let a = pool.open();
        assert!(pool.reserve(a, 4).unwrap());
        let b = pool.open();
        assert!(!pool.reserve(b, 1).unwrap(), "no pages left");
        assert_eq!(pool.free_pages(), 0);
        assert!(pool.fits(4));
        assert!(!pool.fits(5), "a 5-token session can never fit 2×2 pages");
        pool.close(a).unwrap();
        assert!(pool.reserve(b, 1).unwrap(), "freed pages become allocatable");
    }

    #[test]
    fn evict_spill_restore_is_bit_identical_and_cleans_up() {
        let d = 4usize;
        let dir = std::env::temp_dir()
            .join(format!("flexround_paged_spill_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spill_files = |dir: &Path| {
            std::fs::read_dir(dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref().unwrap().file_name().to_string_lossy().starts_with("actcache_")
                })
                .count()
        };
        let mut pool = PagedKvPool::new(&[d, d], 4, 2, Some(&dir)).unwrap();
        let id = pool.open();
        assert!(pool.reserve(id, 5).unwrap());
        let (k, v) = (rows(5, d, 50), rows(5, d, 51));
        for b in 0..2 {
            pool.append_rows(id, b, &k, &v).unwrap();
        }
        pool.commit(id, 5).unwrap();
        let before: Vec<_> = (0..2).map(|b| gather(&pool, id, b)).collect();

        pool.evict(id).unwrap();
        assert!(pool.is_evicted(id));
        assert_eq!(pool.free_pages(), 4, "eviction must return every page");
        assert_eq!(pool.evictions(), 1);
        assert_eq!(spill_files(&dir), 4, "2 blocks × (K,V) spilled to disk");
        assert!(pool.segments(id, 0).is_err(), "no reads while evicted");
        assert!(pool.evict(id).is_err(), "double evict");

        // another session may use the freed pages meanwhile
        let other = pool.open();
        assert!(pool.reserve(other, 2).unwrap());
        let (ko, vo) = (rows(2, d, 60), rows(2, d, 61));
        for b in 0..2 {
            pool.append_rows(other, b, &ko, &vo).unwrap();
        }
        pool.commit(other, 2).unwrap();

        assert!(pool.restore(id).unwrap());
        assert!(!pool.is_evicted(id));
        assert_eq!(spill_files(&dir), 0, "restore must purge the spill files");
        assert_eq!(pool.len(id).unwrap(), 5);
        for (b, want) in before.iter().enumerate() {
            assert_eq!(&gather(&pool, id, b), want, "block {b} K/V must restore bit-identically");
        }
        // the bystander's rows survived the shuffle
        assert_eq!(gather(&pool, other, 0), (ko.clone(), vo.clone()));

        // restore with zero free pages reports false and changes nothing
        pool.evict(id).unwrap();
        let filler = pool.open();
        assert!(pool.reserve(filler, 6).unwrap());
        assert!(!pool.restore(id).unwrap());
        assert!(pool.is_evicted(id));
        pool.close(filler).unwrap();
        assert!(pool.restore(id).unwrap());
        for (b, want) in before.iter().enumerate() {
            assert_eq!(&gather(&pool, id, b), want, "second restore round trip (block {b})");
        }
        // dropping the pool with an evicted session leaks no spill files
        pool.evict(id).unwrap();
        assert!(spill_files(&dir) > 0);
        drop(pool);
        assert_eq!(spill_files(&dir), 0, "pool drop must clean spill files");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blockless_models_degrade_gracefully() {
        let mut pool = PagedKvPool::new(&[], 0, 4, None).unwrap();
        let id = pool.open();
        assert!(pool.reserve(id, 100).unwrap(), "no blocks ⇒ nothing to reserve");
        pool.commit(id, 0).unwrap();
        assert!(pool.fits(usize::MAX / 8));
        pool.close(id).unwrap();
        assert!(PagedKvPool::new(&[4], 0, 4, None).is_err());
        assert!(PagedKvPool::new(&[4], 4, 0, None).is_err());
    }
}
