//! Hand-rolled CLI parser (clap is not vendored): subcommands, long flags
//! with values, boolean switches, repeated `--set` overrides, and generated
//! help text.

use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  Grammar: `prog <command> [--flag [value]] [pos…]`;
    /// `--flag=value` and repeated flags are supported; a flag followed by
    /// another flag (or end) is treated as boolean `"true"`.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.command = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare -- is not supported");
                }
                if let Some(eq) = name.find('=') {
                    let (k, v) = name.split_at(eq);
                    a.flags.entry(k.to_string()).or_default().push(v[1..].to_string());
                } else {
                    let takes_value = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    let v = if takes_value {
                        it.next().unwrap().clone()
                    } else {
                        "true".to_string()
                    };
                    a.flags.entry(name.to_string()).or_default().push(v);
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(String::as_str).collect()).unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

/// Help text for the `flexround` binary.
pub const USAGE: &str = "\
flexround — post-training quantization via learnable element-wise division
(reproduction of Lee et al., ICML 2023; see DESIGN.md)

USAGE:
  flexround <command> [flags]

COMMANDS:
  quantize   Run PTQ reconstruction on one model
             --model <name> --method <m> --bits <b> [--mode w|wa]
             [--rounding flexround|adaround]  rounding scheme (alias of
                                  --method; schemes live behind one trait —
                                  DESIGN.md §Rounding-Schemes)
             [--abits <b>] [--iters <n>] [--lr <f>] [--drop-p <f>]
             [--setting brecq|qdrop] [--calib-n <n>] [--seed <n>] [--eval]
             [--parallel-units]   reconstruct units against FP inputs,
                                  concurrently (native backend fans them
                                  out over the worker pool)
  eval       Evaluate a model (fp or after quantize with --load)
             --model <name> [--method…/--bits… as quantize]
  pipeline   Block-by-block reconstruction over transformer_block units
             (native, end to end): calibration → per-block FlexRound →
             perplexity report → optional packed export + engine forward
             --model <name> | --synthetic [--blocks <n>] [--width <d>]
             [--heads <h>] [--mlp <f>] [--seq <s>] [--calib-seqs <n>]
             [--eval-seqs <n>] [--chunk-seqs <n>] [--vocab <v>]
             --method <m> --bits <b> [--iters <n>] [--lr <f>] [--calib-n <n>]
             [--rounding flexround|adaround]  rounding scheme (alias of
                                       --method)
             [--act-bits <b>]  serve with W{bits}A{b}: static per-layer
                               activation grids calibrated from the recon
                               batches, integer-domain fused GEMM
             [--recon-input fp|quant]  propagate calibration activations at
                                       full precision or through the
                                       quantized chain (the paper's LLM
                                       protocol; default quant)
             [--cache-dir <dir>] [--cache-mb <n>]  spill activation chains
                                       over the byte budget to FXT files
             [--pack-out <file.fxt>] [--seed <n>]
             [--trace-out <file.json>] export per-phase span timings as
                                       Chrome trace_event JSON
  pack       Quantize, then export a bit-packed low-bit artifact (codes +
             per-row grids + biases; no FP weights inside)
             --model <name> --method <m> --bits <b> [--out <file.fxt>]
             [--rounding flexround|adaround]  rounding scheme (alias of
                                  --method)
             [--act-bits <b>]  also calibrate static activation grids →
                               a W{bits}A{b} artifact (stack layers carry
                               an `actq` record; served integer-domain)
             [other quantize flags]
  infer      Run the fused dequant-GEMM forward over a packed artifact
             --packed <file.fxt> | --synthetic [--units <n>] [--width <w>]
             [--bits <b>]
             [--rows <n>] [--seed <n>] [--workers <n>] [--out <file.fxt>]
  serve      Micro-batched serving loadgen over a packed artifact: coalesce
             single-row requests up to a deadline, one fused GEMM per batch;
             generation sessions run through the continuous-batching
             scheduler (paged KV pool), interleaved with row batches
             --packed <file.fxt> | --synthetic [--units/--width/--bits]
             [--requests <n>] [--clients <n>] [--max-batch <n>]
             [--deadline-ms <f>] [--workers <n>] [--compare]
             [--sessions <n>]     mix in n generation sessions (needs a
                                  generation-complete model; with --synthetic
                                  a block+lm-head model is built, as generate)
             [--pool-pages <n>] [--page-tokens <n>]  KV pool sizing
             [--max-active <n>]   concurrent-session bound
             [--prefill-chunk <n>] prompt rows prefilled per step
             [--metrics-addr <h:p>] serve /metrics (Prometheus text) and
                                  /healthz (JSON) on a sidecar thread for
                                  the run's lifetime; port 0 = ephemeral
             [--stats-json <file>] dump the final metrics-registry snapshot
                                  as JSON alongside the stderr stats
             [--trace-out <file.json>] export span timings as Chrome
                                  trace_event JSON (as pipeline/generate)
  generate   KV-cached autoregressive decode over a packed block model:
             prefill the prompt once, then one incremental step per token
             (greedy, or temperature/top-k sampling; token embeddings are
             tied to the packed lm head, so one artifact is all it needs)
             --packed <file.fxt> | --synthetic [--blocks <n>] [--width <d>]
             [--heads <h>] [--mlp <f>] [--seq <s>] [--vocab <v>] [--bits <b>]
             [--prompt-len <t>] [--max-new <n>] [--temp <f>] [--top-k <k>]
             [--seed <n>] [--workers <n>]
             [--compare]  also run the full-context recompute baseline and
                          verify the token streams match
             [--sessions <n>]  decode n sessions concurrently through the
                               continuous-batching scheduler (per-session
                               seeds; with --compare, each stream is checked
                               bit-identical to its solo decode)
             [--pool-pages <n>] [--page-tokens <n>] [--max-active <n>]
             [--prefill-chunk <n>]  scheduler sizing (as in serve)
             [--trace-out <file.json>] export span timings (sched steps,
                                  kernel batches) as Chrome trace_event JSON
  sweep      Run a whole experiment table from a config file
             --config configs/<exp>.toml [--set k=v …]
  figure     Emit grid-shift / histogram data for the paper's figures
             --model <name> --unit <u> --method <m> --bits <b> [--out csv]
  inspect    Print manifest facts (models, units, artifacts)
             [--model <name>]
  selftest   PJRT: load + execute a smoke subset of artifacts and verify
             numerics.  Native: reconstruct a synthetic unit from nothing.

GLOBAL FLAGS:
  --artifacts <dir>   artifact directory (default: artifacts/)
  --report <dir>      report output directory (default: reports/)
  --backend <b>       execution engine: native | pjrt | auto (default auto;
                      auto reports which engine it picked, and why, on
                      stderr — see DESIGN.md §Backends)
  --set k=v           config override (repeatable)
  --quiet             suppress progress logging

ENVIRONMENT:
  FLEXROUND_OBS=off   disable span tracing and hot-path kernel counters
                      (near-zero overhead; numerics are identical either way)
  FLEXROUND_FORCE_SCALAR=1  pin kernel dispatch to the scalar ISA arm
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn basic_parse() {
        let a = Args::parse(&sv(&["quantize", "--model", "m1", "--bits", "4", "--eval"])).unwrap();
        assert_eq!(a.command, "quantize");
        assert_eq!(a.flag("model"), Some("m1"));
        assert_eq!(a.usize_flag("bits", 0), 4);
        assert!(a.has("eval"));
        assert_eq!(a.flag("eval"), Some("true"));
    }

    #[test]
    fn eq_form_and_repeats() {
        let a = Args::parse(&sv(&["sweep", "--set", "a=1", "--set=b=2", "pos1"])).unwrap();
        assert_eq!(a.flag_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn flag_followed_by_flag_is_bool() {
        let a = Args::parse(&sv(&["eval", "--quiet", "--model", "m"])).unwrap();
        assert_eq!(a.flag("quiet"), Some("true"));
        assert_eq!(a.flag("model"), Some("m"));
    }

    #[test]
    fn no_command() {
        let a = Args::parse(&sv(&["--help"])).unwrap();
        assert_eq!(a.command, "");
        assert!(a.has("help"));
    }
}
