//! Chunked, disk-spillable activation cache (DESIGN.md
//! §Block-Reconstruction).
//!
//! The block-by-block pipeline keeps up to three activation chains alive
//! (FP targets, FP inputs, quantized-path inputs); at LLM calibration sizes
//! those no longer fit in RAM.  An [`ActivationCache`] holds an ordered list
//! of activation chunks and, once the in-memory total exceeds its byte
//! budget, spills the *oldest* in-memory chunk to a single-tensor FXT file
//! under the cache directory ([`crate::ser::fxt`] — the same container every
//! other artifact uses, so spilled chunks are inspectable with the normal
//! tooling).  Reads are transparent: [`ActivationCache::get`] reloads from
//! disk when needed, without promoting the chunk back into the budget.
//!
//! Without a cache directory the budget is ignored and everything stays in
//! memory — the small-model fast path.  Spill-file lifecycle: every exit
//! path — normal drop, an error `?`-propagated out of `run_pipeline`, or an
//! unwinding panic mid-stream — runs [`ActivationCache::purge`] (explicitly
//! or via `Drop`) and deletes the cache's spill files, so an aborted
//! pipeline leaves the cache directory empty.

use crate::ser::fxt;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-unique cache tags so concurrent caches (and pipeline stages)
/// never collide on spill-file names.
static NEXT_TAG: AtomicU64 = AtomicU64::new(0);

const SPILL_KEY: &str = "a";

enum Slot {
    Mem(Tensor),
    Disk(PathBuf),
}

/// An ordered store of activation chunks with a byte budget and optional
/// disk spill.
pub struct ActivationCache {
    budget_bytes: usize,
    dir: Option<PathBuf>,
    tag: u64,
    slots: Vec<Slot>,
    mem_bytes: usize,
    spilled: usize,
    /// index of the oldest chunk still in memory (spill frontier)
    frontier: usize,
}

impl ActivationCache {
    /// In-memory-only cache (no budget enforcement).
    pub fn unbounded() -> ActivationCache {
        ActivationCache::with_budget(usize::MAX, None)
    }

    /// Cache that spills to `dir` once the in-memory total exceeds
    /// `budget_bytes`.  With `dir = None` the budget is ignored.
    pub fn with_budget(budget_bytes: usize, dir: Option<&Path>) -> ActivationCache {
        ActivationCache {
            budget_bytes,
            dir: dir.map(Path::to_path_buf),
            tag: NEXT_TAG.fetch_add(1, Ordering::Relaxed),
            slots: Vec::new(),
            mem_bytes: 0,
            spilled: 0,
            frontier: 0,
        }
    }

    /// Build a cache from chunks already in hand (spilling as it goes).
    pub fn from_chunks(
        chunks: Vec<Tensor>,
        budget_bytes: usize,
        dir: Option<&Path>,
    ) -> Result<ActivationCache> {
        let mut c = ActivationCache::with_budget(budget_bytes, dir);
        for t in chunks {
            c.push(t)?;
        }
        Ok(c)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Chunks currently spilled to disk.
    pub fn spilled_chunks(&self) -> usize {
        self.spilled
    }

    /// Bytes currently held in memory.
    pub fn mem_bytes(&self) -> usize {
        self.mem_bytes
    }

    fn spill_path(&self, i: usize) -> PathBuf {
        let dir = self.dir.as_ref().expect("spill without a cache dir");
        dir.join(format!("actcache_{}_{}_{i:06}.fxt", std::process::id(), self.tag))
    }

    /// Append a chunk, spilling the oldest in-memory chunks until the budget
    /// holds again (the newest chunk itself may end up on disk when a single
    /// chunk exceeds the whole budget).
    pub fn push(&mut self, t: Tensor) -> Result<()> {
        self.mem_bytes += t.len() * 4;
        self.slots.push(Slot::Mem(t));
        if self.dir.is_some() {
            while self.mem_bytes > self.budget_bytes && self.frontier < self.slots.len() {
                let i = self.frontier;
                self.frontier += 1;
                let Slot::Mem(tensor) = &self.slots[i] else { continue };
                let _span = crate::obs::span("cache/spill");
                let path = self.spill_path(i);
                let mut m = BTreeMap::new();
                m.insert(SPILL_KEY.to_string(), tensor.clone());
                if let Err(e) = fxt::write(&path, &m) {
                    // a failed write may leave a partial file the Drop
                    // cleanup would never see (the slot stays Mem) — remove
                    // it here so an error path cannot leak
                    let _ = std::fs::remove_file(&path);
                    return Err(anyhow!("spilling activation chunk {i}: {e:#}"));
                }
                self.mem_bytes -= tensor.len() * 4;
                self.spilled += 1;
                crate::obs_counter!("flexround_cache_spills_total").inc();
                self.slots[i] = Slot::Disk(path);
            }
        }
        Ok(())
    }

    /// Delete every spill file and drop every chunk now.  Idempotent; also
    /// what [`Drop`] runs, so both an explicit teardown and any exit path —
    /// error returns and unwinding panics included — leave the cache
    /// directory empty.  The cache itself stays usable (empty) afterwards.
    pub fn purge(&mut self) {
        for s in &self.slots {
            if let Slot::Disk(path) = s {
                let _ = std::fs::remove_file(path);
            }
        }
        self.slots.clear();
        self.mem_bytes = 0;
        self.spilled = 0;
        self.frontier = 0;
    }

    /// Fetch chunk `i`: borrowed straight from memory (no copy for resident
    /// chunks — the streamed Adam loop reads a chunk per step), or owned
    /// after reloading from its spill file.
    pub fn get(&self, i: usize) -> Result<Cow<'_, Tensor>> {
        match self.slots.get(i) {
            None => bail!("activation cache has {} chunks, asked for {i}", self.slots.len()),
            Some(Slot::Mem(t)) => Ok(Cow::Borrowed(t)),
            Some(Slot::Disk(path)) => {
                let _span = crate::obs::span("cache/restore");
                crate::obs_counter!("flexround_cache_restores_total").inc();
                let mut m = fxt::read(path)?;
                let t = m
                    .remove(SPILL_KEY)
                    .ok_or_else(|| anyhow!("spill file {} lost its tensor", path.display()))?;
                Ok(Cow::Owned(t))
            }
        }
    }

    /// Total rows across all chunks (axis 0).
    pub fn total_rows(&self) -> Result<usize> {
        let mut n = 0;
        for i in 0..self.slots.len() {
            n += match &self.slots[i] {
                Slot::Mem(t) => t.shape().first().copied().unwrap_or(0),
                Slot::Disk(_) => self.get(i)?.shape().first().copied().unwrap_or(0),
            };
        }
        Ok(n)
    }
}

impl Drop for ActivationCache {
    fn drop(&mut self) {
        self.purge();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn chunk(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::from_f32((0..rows * cols).map(|_| rng.next_normal()).collect(), &[rows, cols])
            .unwrap()
    }

    #[test]
    fn unbounded_cache_round_trips() {
        let mut c = ActivationCache::unbounded();
        assert!(c.is_empty());
        let a = chunk(4, 8, 1);
        let b = chunk(2, 8, 2);
        c.push(a.clone()).unwrap();
        c.push(b.clone()).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.spilled_chunks(), 0);
        // resident chunks come back borrowed (no copy)
        assert!(matches!(c.get(0).unwrap(), std::borrow::Cow::Borrowed(_)));
        assert_eq!(c.get(0).unwrap().as_ref(), &a);
        assert_eq!(c.get(1).unwrap().as_ref(), &b);
        assert_eq!(c.total_rows().unwrap(), 6);
        assert!(c.get(2).is_err());
    }

    #[test]
    fn over_budget_chunks_spill_to_disk_and_read_back() {
        let dir = std::env::temp_dir()
            .join(format!("flexround_actcache_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // budget of ~1.5 chunks: pushing 4 chunks of 4×8 f32 (128 bytes each)
        // must spill at least two of them
        let mut c = ActivationCache::with_budget(192, Some(&dir));
        let chunks: Vec<Tensor> = (0..4).map(|i| chunk(4, 8, 10 + i as u64)).collect();
        for t in &chunks {
            c.push(t.clone()).unwrap();
        }
        assert!(
            c.spilled_chunks() >= 2,
            "expected ≥2 spilled chunks, got {}",
            c.spilled_chunks()
        );
        assert!(c.mem_bytes() <= 192, "budget violated: {} bytes in memory", c.mem_bytes());
        // every chunk — spilled or resident — reads back bit-identical
        for (i, want) in chunks.iter().enumerate() {
            assert_eq!(c.get(i).unwrap().as_ref(), want, "chunk {i} round trip");
        }
        // spill files vanish on drop
        let files = || {
            std::fs::read_dir(&dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref()
                        .unwrap()
                        .file_name()
                        .to_string_lossy()
                        .starts_with("actcache_")
                })
                .count()
        };
        assert!(files() >= 2);
        drop(c);
        assert_eq!(files(), 0, "spill files must be removed on drop");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_without_dir_stays_in_memory() {
        let mut c = ActivationCache::with_budget(1, None);
        c.push(chunk(4, 4, 3)).unwrap();
        c.push(chunk(4, 4, 4)).unwrap();
        assert_eq!(c.spilled_chunks(), 0);
        assert_eq!(c.len(), 2);
    }

    fn spill_files(dir: &std::path::Path) -> usize {
        std::fs::read_dir(dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("actcache_")
            })
            .count()
    }

    #[test]
    fn purge_removes_spill_files_and_resets_the_cache() {
        let dir = std::env::temp_dir()
            .join(format!("flexround_actcache_purge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut c = ActivationCache::with_budget(64, Some(&dir));
        for i in 0..4 {
            c.push(chunk(4, 8, 20 + i)).unwrap();
        }
        assert!(c.spilled_chunks() >= 2);
        assert!(spill_files(&dir) >= 2);
        c.purge();
        assert_eq!(spill_files(&dir), 0, "purge must delete every spill file");
        assert_eq!(c.len(), 0);
        assert_eq!(c.spilled_chunks(), 0);
        assert_eq!(c.mem_bytes(), 0);
        // the purged cache is still usable — and purge is idempotent
        c.purge();
        c.push(chunk(4, 8, 30)).unwrap();
        assert_eq!(c.len(), 1);
        drop(c);
        assert_eq!(spill_files(&dir), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn panic_mid_stream_still_cleans_spill_files() {
        // Satellite regression (PR 4): a pipeline that panics (or errors)
        // mid-stream must not leak FXT spill files — cleanup rides on Drop,
        // which unwinding runs.
        let dir = std::env::temp_dir()
            .join(format!("flexround_actcache_panic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dir2 = dir.clone();
        let result = std::panic::catch_unwind(move || {
            let mut c = ActivationCache::with_budget(64, Some(&dir2));
            for i in 0..4 {
                c.push(chunk(4, 8, 40 + i)).unwrap();
            }
            assert!(c.spilled_chunks() >= 2);
            panic!("forced mid-stream failure");
        });
        assert!(result.is_err(), "the forced panic must propagate");
        assert_eq!(
            spill_files(&dir),
            0,
            "spill files must be cleaned up when the owner unwinds"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
