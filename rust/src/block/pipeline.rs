//! The block-by-block reconstruction pipeline (DESIGN.md
//! §Block-Reconstruction).
//!
//! Drives the paper's LLM protocol natively: blocks reconstruct in manifest
//! order, each against the full-precision targets of its own inputs, with
//! the calibration activations propagated block-to-block in one of two
//! modes:
//!
//! * [`ReconInput::Quant`] — the paper's §3.1 protocol (and the LLM
//!   experiments' default): every block sees the *quantized-path*
//!   activations X̃ of its reconstructed predecessors, so error does not
//!   compound silently;
//! * [`ReconInput::Fp`] — AdaQuant-style full-precision inputs (one fewer
//!   activation chain, and the mode `--parallel-units` fans out).
//!
//! All activation chains live in [`ActivationCache`]s, so calibration sets
//! larger than RAM stream through with the overflow spilled to FXT files
//! under `--cache-dir`.  Reconstruction samples one cached chunk per Adam
//! step (then a row/sequence minibatch inside it) instead of concatenating
//! the whole calibration set — the pipeline never materializes more than a
//! few chunks at once.

use super::cache::ActivationCache;
use super::{block_def_for, BlockDef, BlockTensors, CANON_LAYERS};
use crate::coordinator::{Plan, QuantResult, Session, UnitState};
use crate::manifest::{LayerInfo, Manifest, ModelInfo, PackEntry, UnitInfo};
use crate::recon::{self, LayerDef, LayerSlots};
use crate::runtime::{native::stack_layer_defs, UnitCtx};
use crate::tensor::{qrange, Tensor};
use crate::util::{pool, rng::Pcg32};
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

/// Chunks advanced per backend call when streaming a chain through a unit:
/// bounds transient memory at `ADVANCE_GROUP` chunks while amortizing the
/// per-call Ŵ materialization across the group.
const ADVANCE_GROUP: usize = 8;

/// Which activations each block reconstructs against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReconInput {
    /// full-precision inputs (AdaQuant-style)
    Fp,
    /// quantized-path inputs X̃ (the paper's sequential protocol)
    Quant,
}

impl ReconInput {
    pub fn parse(s: &str) -> Result<ReconInput> {
        match s {
            "fp" => Ok(ReconInput::Fp),
            "quant" => Ok(ReconInput::Quant),
            other => bail!("unknown --recon-input {other:?} (expected fp or quant)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ReconInput::Fp => "fp",
            ReconInput::Quant => "quant",
        }
    }
}

/// Pipeline hyperparameters (weight-only by construction).
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub method: String,
    pub bits_w: u32,
    /// 0 → manifest default
    pub iters: usize,
    /// 0.0 → manifest default for the method
    pub lr: f64,
    /// 0 → all exported calibration rows
    pub calib_n: usize,
    pub seed: u64,
    pub recon_input: ReconInput,
    /// spill directory for the activation caches (None → all in memory)
    pub cache_dir: Option<PathBuf>,
    /// per-cache in-memory byte budget (0 → unbounded)
    pub cache_budget_bytes: usize,
    pub verbose: bool,
}

impl PipelineOpts {
    pub fn new(method: &str, bits_w: u32) -> PipelineOpts {
        PipelineOpts {
            method: method.to_string(),
            bits_w,
            iters: 0,
            lr: 0.0,
            calib_n: 0,
            seed: 7,
            recon_input: ReconInput::Quant,
            cache_dir: None,
            cache_budget_bytes: 0,
            verbose: false,
        }
    }
}

/// What a pipeline run produced: a standard [`QuantResult`] (so evaluation,
/// packed export, and serving all compose with it) plus cache telemetry.
pub struct PipelineOutcome {
    pub result: QuantResult,
    pub recon_input: ReconInput,
    /// chunks per activation chain
    pub chain_chunks: usize,
    /// chunk spills across every cache the run created
    pub spilled_chunks: usize,
}

/// Run the block-by-block reconstruction pipeline over `sess`'s model.
/// Works for any natively-executable unit kind (`transformer_block` blocks
/// sample whole sequences; `linear`/`mlp_relu` stacks sample rows).
pub fn run_pipeline(sess: &Session, opts: &PipelineOpts) -> Result<PipelineOutcome> {
    let mi = sess.model;
    let iters = if opts.iters == 0 { mi.iters_default } else { opts.iters };
    let lr = if opts.lr == 0.0 { mi.lr_for(&opts.method) } else { opts.lr };
    let b = mi.calib_batch;
    let calib_full = sess.dataset("calib_x")?;
    let calib_n = if opts.calib_n == 0 {
        calib_full.shape()[0]
    } else {
        opts.calib_n.min(calib_full.shape()[0])
    };
    let calib_n = (calib_n / b).max(1) * b;
    let calib = calib_full.slice_rows(0, calib_n)?;

    let budget = if opts.cache_budget_bytes == 0 { usize::MAX } else { opts.cache_budget_bytes };
    let dir = opts.cache_dir.as_deref();
    let chunks0 = sess.first_unit_inputs(&calib)?;
    let chain_chunks = chunks0.len();
    let mut spilled = 0usize;
    // only the quantized-input protocol needs a second copy of the chain
    let mut xq = match opts.recon_input {
        ReconInput::Quant => Some(ActivationCache::from_chunks(chunks0.clone(), budget, dir)?),
        ReconInput::Fp => None,
    };
    let mut fp = ActivationCache::from_chunks(chunks0, budget, dir)?;

    let mut rng = Pcg32::seeded(opts.seed);
    let learns = opts.method != "rtn" && iters > 0;
    if opts.method != "rtn" && iters == 0 && opts.verbose {
        eprintln!(
            "  [pipeline] iters resolved to 0 (no --iters and the manifest default is 0): \
             {} runs at its RTN init, no reconstruction",
            opts.method
        );
    }
    let mut states: Vec<UnitState> = Vec::with_capacity(mi.units.len());
    let mut recon_seconds = 0.0f64;
    let mut recon_steps = 0u64;

    for (ui, unit) in mi.units.iter().enumerate() {
        let cx = sess.unit_ctx(unit);
        // FP targets for this block, streamed in bounded chunk groups (one
        // backend call per group, so per-call setup work — Ŵ
        // materialization on the quantized chain below — amortizes without
        // unbounding memory)
        let mut y_fp = ActivationCache::with_budget(budget, dir);
        {
            let _span = crate::obs::span("pipeline/fp_targets");
            for start in (0..fp.len()).step_by(ADVANCE_GROUP) {
                let end = (start + ADVANCE_GROUP).min(fp.len());
                let xs: Vec<Tensor> =
                    (start..end).map(|i| Ok(fp.get(i)?.into_owned())).collect::<Result<_>>()?;
                for y in sess.backend.unit_forward_fp(&cx, &xs)? {
                    y_fp.push(y)?;
                }
            }
        }

        let bits_w = unit.bits_override.unwrap_or(opts.bits_w);
        let (params, entries) = sess.init_params(unit, &opts.method, "w", bits_w, 8)?;
        let mut st = UnitState {
            unit: unit.name.clone(),
            method: opts.method.clone(),
            params,
            entries,
            first_loss: f64::NAN,
            final_loss: f64::NAN,
            bits_w,
            abits: 8,
        };

        if learns {
            let _span = crate::obs::span("pipeline/reconstruct");
            let x_src = xq.as_ref().unwrap_or(&fp);
            let t0 = Instant::now();
            let r = reconstruct_streamed(
                sess,
                &cx,
                &st,
                x_src,
                &y_fp,
                iters,
                lr as f32,
                b,
                opts.verbose,
                rng.fork(ui as u64),
            )?;
            recon_seconds += t0.elapsed().as_secs_f64();
            recon_steps += r.steps;
            st.params = r.params;
            st.first_loss = r.first_loss;
            st.final_loss = r.final_loss;
            if opts.verbose {
                eprintln!(
                    "  [pipeline/{}-input] block {:<10} loss {:.6} → {:.6}",
                    opts.recon_input.label(),
                    unit.name,
                    st.first_loss,
                    st.final_loss
                );
            }
        }

        // advance the quantized chain through the learned block; grouped so
        // the backend fake-quantizes each layer's Ŵ once per group, not
        // once per chunk
        if let Some(xq_cache) = xq.as_mut() {
            let _span = crate::obs::span("pipeline/advance_q");
            let mut next = ActivationCache::with_budget(budget, dir);
            for start in (0..xq_cache.len()).step_by(ADVANCE_GROUP) {
                let end = (start + ADVANCE_GROUP).min(xq_cache.len());
                let xs: Vec<Tensor> = (start..end)
                    .map(|i| Ok(xq_cache.get(i)?.into_owned()))
                    .collect::<Result<_>>()?;
                for y in sess.advance_q(unit, &st, "w", &xs)? {
                    next.push(y)?;
                }
            }
            let old = std::mem::replace(xq_cache, next);
            spilled += old.spilled_chunks();
        }

        spilled += fp.spilled_chunks();
        fp = y_fp;
        states.push(st);
        crate::obs_counter!("flexround_pipeline_blocks_total").inc();
    }
    spilled += fp.spilled_chunks();
    if let Some(c) = &xq {
        spilled += c.spilled_chunks();
    }

    let mut plan = Plan::new(&mi.name, &opts.method);
    plan.bits_w = opts.bits_w;
    plan.iters = iters;
    plan.lr = lr;
    plan.calib_n = calib_n;
    plan.seed = opts.seed;
    plan.verbose = opts.verbose;
    Ok(PipelineOutcome {
        result: QuantResult { plan, units: states, recon_seconds, recon_steps },
        recon_input: opts.recon_input,
        chain_chunks,
        spilled_chunks: spilled,
    })
}

/// Unit geometry for the streamed loop: a contraction stack or one
/// transformer block.
enum Defs<'a> {
    Stack(Vec<LayerDef<'a>>),
    Block(BlockDef<'a>),
}

/// The streamed Adam loop: each step samples one cached chunk (uniformly),
/// then a minibatch inside it — rows for stacks, whole sequences for blocks.
/// Memory stays bounded by one chunk regardless of calibration-set size.
#[allow(clippy::too_many_arguments)]
fn reconstruct_streamed(
    sess: &Session,
    cx: &UnitCtx,
    st: &UnitState,
    xs: &ActivationCache,
    ys: &ActivationCache,
    iters: usize,
    lr: f32,
    batch_rows: usize,
    verbose: bool,
    mut rng: Pcg32,
) -> Result<recon::ReconResult> {
    if xs.is_empty() || xs.len() != ys.len() {
        bail!(
            "streamed recon: {} input chunks vs {} target chunks",
            xs.len(),
            ys.len()
        );
    }
    let defs = if cx.unit.kind == "transformer_block" {
        Defs::Block(block_def_for(cx)?)
    } else {
        Defs::Stack(stack_layer_defs(cx)?)
    };
    let slots: Vec<LayerSlots> = recon::map_pack(cx.unit, &st.method, &st.entries)?;
    let (qmin, qmax) = qrange(st.bits_w, sess.model.symmetric);
    let cfg = recon::ReconSettings {
        iters,
        lr,
        batch: batch_rows,
        qmin,
        qmax,
        workers: pool::default_workers(),
        verbose,
        tag: format!("{}/{}", sess.model.name, cx.unit.name),
        scheme: recon::scheme_for(&st.method)?,
    };
    recon::run_adam(&st.entries, &st.params, &cfg, &mut rng, |rng, params, t| {
        let ci = rng.below(xs.len() as u32) as usize;
        let xc = xs.get(ci)?;
        let yc = ys.get(ci)?;
        let rows = xc.shape()[0];
        let (xb, yb) = match &defs {
            Defs::Stack(_) => {
                let idx = rng.sample_indices(rows, cfg.batch.clamp(1, rows));
                (xc.gather_rows(&idx)?, yc.gather_rows(&idx)?)
            }
            Defs::Block(def) => {
                if rows % def.seq != 0 {
                    bail!(
                        "block {:?}: chunk of {rows} rows not a multiple of seq {}",
                        def.name,
                        def.seq
                    );
                }
                let nseq = rows / def.seq;
                let sidx = rng.sample_indices(nseq, (cfg.batch / def.seq).clamp(1, nseq));
                let ridx = super::seq_rows(&sidx, def.seq);
                (xc.gather_rows(&ridx)?, yc.gather_rows(&ridx)?)
            }
        };
        let beta = recon::rounding::beta_schedule(t, cfg.iters);
        match &defs {
            Defs::Stack(layers) => recon::loss_and_grads(
                cfg.scheme, layers, &slots, params, &xb, &yb, qmin, qmax, beta, cfg.workers,
            ),
            Defs::Block(def) => super::loss_and_grads(
                cfg.scheme, def, &slots, params, &xb, &yb, qmin, qmax, beta, cfg.workers,
            ),
        }
    })
}

// ---------------------------------------------------------------------------
// Synthetic transformer-block model (tests, benches, CLI `--synthetic`)
// ---------------------------------------------------------------------------

/// Shape of a synthetic block model.
#[derive(Clone, Debug)]
pub struct SyntheticBlockSpec {
    pub blocks: usize,
    /// hidden width
    pub d: usize,
    pub heads: usize,
    /// MLP inner width
    pub mlp: usize,
    /// rows per sequence
    pub seq: usize,
    /// calibration sequences
    pub calib_seqs: usize,
    /// evaluation sequences
    pub eval_seqs: usize,
    /// sequences per activation chunk (calib_batch = chunk_seqs · seq)
    pub chunk_seqs: usize,
    /// lm-head vocabulary size
    pub vocab: usize,
    pub bits: u32,
    pub seed: u64,
}

impl Default for SyntheticBlockSpec {
    fn default() -> Self {
        SyntheticBlockSpec {
            blocks: 2,
            d: 16,
            heads: 2,
            mlp: 32,
            seq: 4,
            calib_seqs: 8,
            eval_seqs: 4,
            chunk_seqs: 2,
            vocab: 24,
            bits: 4,
            seed: 7,
        }
    }
}

/// Everything `Session` needs for an in-memory synthetic transformer-block
/// LM: manifest + weights / init packs / datasets, plus a native `head/lm`
/// projection so perplexity evaluates without any PJRT artifact.
pub struct SyntheticBlockModel {
    pub man: Manifest,
    pub weights: BTreeMap<String, Tensor>,
    pub inits: BTreeMap<String, Tensor>,
    pub data: BTreeMap<String, Tensor>,
}

impl SyntheticBlockModel {
    /// Open a [`Session`] over this fixture with the given backend.
    pub fn session<'a>(&'a self, backend: &'a dyn crate::runtime::Backend) -> Session<'a> {
        Session {
            backend,
            man: &self.man,
            model: self.man.model("block_lm").expect("fixture model"),
            weights: self.weights.clone(),
            inits: self.inits.clone(),
            data: self.data.clone(),
        }
    }
}

/// Build a random `blocks`-deep transformer-block LM.  Evaluation labels are
/// the argmax of the full-precision logits (teacher labels), with the last
/// position of every sequence set to −1 (the native perplexity's ignore
/// index) — so FP perplexity is low and the quantized-vs-FP delta is a
/// meaningful signal.
pub fn synthetic_block_model(spec: &SyntheticBlockSpec) -> Result<SyntheticBlockModel> {
    if spec.blocks == 0 || spec.heads == 0 || spec.d % spec.heads != 0 {
        bail!("synthetic block model: blocks ≥ 1 and heads must divide d (spec {spec:?})");
    }
    if spec.chunk_seqs == 0
        || spec.calib_seqs % spec.chunk_seqs != 0
        || spec.eval_seqs % spec.chunk_seqs != 0
    {
        bail!(
            "synthetic block model: calib_seqs and eval_seqs must be multiples of \
             chunk_seqs (spec {spec:?})"
        );
    }
    let mut rng = Pcg32::seeded(spec.seed);
    let mut weights = BTreeMap::new();
    let mut inits = BTreeMap::new();
    let mut units = Vec::with_capacity(spec.blocks);
    let mut towers: Vec<BlockTensors> = Vec::with_capacity(spec.blocks);
    for ui in 0..spec.blocks {
        let uname = format!("blk{ui}");
        let bt = BlockTensors::random(spec.d, spec.heads, spec.mlp, spec.seq,
                                      spec.seed ^ (ui as u64 + 1));
        let (entries, params, _) = bt.flexround_pack(spec.bits);
        // weights / biases / layernorm extras under the standard key grammar
        for (li, lname) in CANON_LAYERS.iter().enumerate() {
            weights.insert(format!("w/{uname}/{lname}"), bt.w[li].clone());
            if let Some(bias) = &bt.b[li] {
                weights.insert(format!("b/{uname}/{lname}"), bias.clone());
            }
        }
        weights.insert(format!("p/{uname}/ln1.g"), bt.ln1_g.clone());
        weights.insert(format!("p/{uname}/ln1.b"), bt.ln1_b.clone());
        weights.insert(format!("p/{uname}/ln2.g"), bt.ln2_g.clone());
        weights.insert(format!("p/{uname}/ln2.b"), bt.ln2_b.clone());
        // init packs for every native method
        for (e, p) in entries.iter().zip(&params) {
            inits.insert(
                format!("init/{uname}/flexround/b{}/{}", spec.bits, e.name),
                p.clone(),
            );
            let key = e.name.rsplit('.').next().unwrap_or("");
            if key == "s1" || key == "zp" {
                inits.insert(
                    format!("init/{uname}/rtn/b{}/{}", spec.bits, e.name),
                    p.clone(),
                );
            }
        }
        let (ada_entries, ada_params, _) = bt.adaround_pack(spec.bits);
        for (e, p) in ada_entries.iter().zip(&ada_params) {
            inits.insert(
                format!("init/{uname}/adaround/b{}/{}", spec.bits, e.name),
                p.clone(),
            );
        }
        units.push(block_unit_info(&uname, spec));
        towers.push(bt);
    }

    // datasets
    let n_calib = spec.calib_seqs * spec.seq;
    let n_eval = spec.eval_seqs * spec.seq;
    let mk_x = |rng: &mut Pcg32, n: usize| -> Result<Tensor> {
        Tensor::from_f32((0..n * spec.d).map(|_| rng.next_normal()).collect(), &[n, spec.d])
    };
    let calib_x = mk_x(&mut rng, n_calib)?;
    let eval_x = mk_x(&mut rng, n_eval)?;
    let head = Tensor::from_f32(
        (0..spec.vocab * spec.d).map(|_| rng.next_normal() * 0.5).collect(),
        &[spec.vocab, spec.d],
    )?;

    // teacher labels: argmax of FP logits, −1 at each sequence's last row
    let mut h = eval_x.clone();
    for bt in &towers {
        h = super::forward_fp(&bt.def(), &h, 1)?;
    }
    let logits = h.matmul_nt(&head)?;
    let mut labels: Vec<i32> = logits.argmax_rows()?.iter().map(|&i| i as i32).collect();
    for s in 0..spec.eval_seqs {
        labels[(s + 1) * spec.seq - 1] = -1;
    }
    weights.insert("head/lm".to_string(), head);

    let mut data = BTreeMap::new();
    let mut datasets = BTreeMap::new();
    datasets.insert("calib_x".to_string(), vec![n_calib, spec.d]);
    datasets.insert("eval_x".to_string(), vec![n_eval, spec.d]);
    datasets.insert("eval_y".to_string(), vec![n_eval]);
    data.insert("calib_x".to_string(), calib_x);
    data.insert("eval_x".to_string(), eval_x);
    data.insert("eval_y".to_string(), Tensor::from_i32(labels, &[n_eval])?);

    let calib_batch = spec.chunk_seqs * spec.seq;
    let mut lr_default = BTreeMap::new();
    lr_default.insert("flexround".to_string(), 3e-3);
    lr_default.insert("adaround".to_string(), 1e-2);
    let model = ModelInfo {
        name: "block_lm".to_string(),
        kind: "block_lm".to_string(),
        task: "lm".to_string(),
        fp_metric: BTreeMap::new(),
        symmetric: true,
        per_channel: true,
        bits_w: vec![spec.bits],
        abits: vec![8],
        methods_w: vec!["rtn".to_string(), "flexround".to_string(), "adaround".to_string()],
        methods_wa: vec![],
        calib_n: n_calib,
        calib_batch,
        seq: Some(spec.seq),
        units,
        embed_artifact: None,
        head_artifacts: BTreeMap::new(),
        weights_file: "unused.fxt".to_string(),
        init_file: "unused.fxt".to_string(),
        data_file: "unused.fxt".to_string(),
        datasets,
        iters_default: 0,
        lr_default,
        drop_p_default: 0.0,
    };
    let mut models = BTreeMap::new();
    models.insert("block_lm".to_string(), model);
    let man = Manifest { dir: std::env::temp_dir(), calib_batch, models };
    Ok(SyntheticBlockModel { man, weights, inits, data })
}

fn block_unit_info(name: &str, spec: &SyntheticBlockSpec) -> UnitInfo {
    let dims: [(usize, usize); 6] = [
        (spec.d, spec.d),
        (spec.d, spec.d),
        (spec.d, spec.d),
        (spec.d, spec.d),
        (spec.mlp, spec.d),
        (spec.d, spec.mlp),
    ];
    let entry = |n: String, shape: Vec<usize>, learn: bool| PackEntry {
        name: n,
        shape,
        learnable: learn,
    };
    let mut flex = Vec::new();
    let mut rtn = Vec::new();
    let mut ada = Vec::new();
    let mut layers = Vec::new();
    for (li, lname) in CANON_LAYERS.iter().enumerate() {
        let (rows, cols) = dims[li];
        flex.extend([
            entry(format!("{lname}.s1"), vec![rows, 1], true),
            entry(format!("{lname}.s2"), vec![rows, cols], true),
            entry(format!("{lname}.s3"), vec![rows, 1], true),
            entry(format!("{lname}.s4"), vec![1, cols], true),
            entry(format!("{lname}.zp"), vec![rows, 1], false),
        ]);
        rtn.extend([
            entry(format!("{lname}.s1"), vec![rows, 1], false),
            entry(format!("{lname}.zp"), vec![rows, 1], false),
        ]);
        ada.extend([
            entry(format!("{lname}.s1"), vec![rows, 1], false),
            entry(format!("{lname}.v"), vec![rows, cols], true),
            entry(format!("{lname}.zp"), vec![rows, 1], false),
        ]);
        layers.push(LayerInfo {
            name: lname.to_string(),
            kind: "linear".to_string(),
            rows,
            cols,
            conv_shape: None,
            stride: 1,
        });
    }
    let mut packs = BTreeMap::new();
    packs.insert("flexround.w".to_string(), flex);
    packs.insert("rtn.w".to_string(), rtn);
    packs.insert("adaround.w".to_string(), ada);
    UnitInfo {
        name: name.to_string(),
        kind: "transformer_block".to_string(),
        bits_override: None,
        in_shape: vec![spec.seq, spec.d],
        out_shape: vec![spec.seq, spec.d],
        act_sites: 0,
        heads: spec.heads,
        layers,
        artifacts: BTreeMap::new(),
        packs,
    }
}

/// Full-calibration-set output MSE of the quantized chain vs the FP chain —
/// the pipeline's end-to-end quality metric (tests and the CLI report).
pub fn chain_mse(sess: &Session, result: &QuantResult, xs: &Tensor) -> Result<f64> {
    let q = sess.forward_q(result, xs)?;
    mse_vs_fp(sess, &q, xs)
}

/// [`chain_mse`] with the quantized chunks already forwarded — callers
/// holding a hoisted packed engine compute `q` themselves and skip a
/// redundant export/pack.
pub fn mse_vs_fp(sess: &Session, q: &[Tensor], xs: &Tensor) -> Result<f64> {
    let fp = sess.forward_fp(xs)?;
    if q.len() != fp.len() {
        bail!("chain mse: {} quantized chunks vs {} fp chunks", q.len(), fp.len());
    }
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for (a, b) in q.iter().zip(&fp) {
        acc += a.mse(b)? as f64 * a.len() as f64;
        n += a.len();
    }
    if n == 0 {
        return Err(anyhow!("chain mse over an empty dataset"));
    }
    Ok(acc / n as f64)
}
