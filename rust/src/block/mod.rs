//! Native transformer-block substrate (DESIGN.md §Block-Reconstruction).
//!
//! The paper's headline LLM result comes from "reconstructing the output in
//! a block-by-block manner": each transformer block is one reconstruction
//! unit, its six contraction weights (`wq wk wv wo up down`) fake-quantized
//! with FlexRound Eq. 2 while layernorms, softmax attention, GELU, and the
//! residual adds run in full precision.  This module provides that unit kind
//! natively:
//!
//! * [`BlockDef`] — borrowed views of one `transformer_block` unit (the six
//!   weights in canonical order, biases, layernorm parameters, head count,
//!   rows-per-sequence);
//! * [`forward_fp`] / [`forward_with`] — the pre-LN block forward
//!   (`x → LN → QKV → causal softmax attention → proj → +x → LN → GELU MLP
//!   → +`), FP weights or any substituted weight set (fake-quantized Ŵ);
//! * [`attn_forward`] / [`attn_backward`] — multi-head causal attention
//!   with cached probabilities, shared with the packed inference engine;
//! * [`attn_score_row`] / [`attn_score_segments`] — the single-query-row
//!   attention core both the full-context forward and the KV-cached
//!   incremental decode path ([`crate::infer::Engine::decode_step`]) are
//!   built from; the segmented variant walks a paged KV pool's page list
//!   ([`crate::sched`]) and `attn_score_row` delegates to it with one
//!   segment, so the contiguous, paged, and full-context paths all stay
//!   bit-identical by construction;
//! * [`loss_and_grads`] — output-MSE loss plus the full backward pass:
//!   activation cotangents through residuals / layernorm / GELU / softmax
//!   (all smooth, finite-difference-checked in `tensor::ops` and here),
//!   then [`recon::fq_backward`]'s closed-form STE (the Proposition 3.1
//!   reciprocal rule) into the per-layer FlexRound parameters;
//! * [`reconstruct_block`] — the Adam loop over calibration minibatches,
//!   sampling whole *sequences* (attention couples rows within a sequence,
//!   so row-level sampling would tear contexts apart).
//!
//! The sequential block-by-block driver (quantized-input propagation, the
//! disk-spillable activation cache) lives in [`pipeline`]; [`cache`] holds
//! the spill machinery.

pub mod cache;
pub mod pipeline;

pub use cache::ActivationCache;
pub use pipeline::{
    chain_mse, mse_vs_fp, run_pipeline, synthetic_block_model, PipelineOpts, PipelineOutcome,
    ReconInput, SyntheticBlockModel, SyntheticBlockSpec,
};

use crate::linalg;
use crate::manifest::PackEntry;
use crate::recon::{self, LayerSlots, ReconResult, ReconSettings, Rounding};
use crate::runtime::UnitCtx;
use crate::tensor::{
    gelu_bwd, layernorm_rows, layernorm_rows_bwd, minmax_scale, softmax_rows_bwd, Tensor,
};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};

/// Canonical layer names (and order) of a `transformer_block` unit: the
/// attention projections, then the GELU MLP pair.
pub const CANON_LAYERS: [&str; 6] = ["wq", "wk", "wv", "wo", "up", "down"];

/// Layernorm epsilon — shared by the native substrate and the packed
/// inference engine so both paths are bit-comparable.
pub const LN_EPS: f32 = 1e-5;

/// Borrowed views of one transformer block: everything the forward/backward
/// needs, nothing owned.
pub struct BlockDef<'a> {
    pub name: &'a str,
    /// attention heads (hidden width must divide evenly)
    pub heads: usize,
    /// rows per sequence: attention attends within consecutive `seq`-row
    /// groups of the activation matrix, causally
    pub seq: usize,
    /// hidden width
    pub d: usize,
    /// MLP inner width
    pub mlp: usize,
    /// the six contraction weights, [`CANON_LAYERS`] order
    pub w: [&'a Tensor; 6],
    /// per-layer biases, same order
    pub b: [Option<&'a Tensor>; 6],
    pub ln1_g: &'a Tensor,
    pub ln1_b: &'a Tensor,
    pub ln2_g: &'a Tensor,
    pub ln2_b: &'a Tensor,
}

/// Build a [`BlockDef`] from an engine unit context: canonical layer list,
/// weight shapes, layernorm extras (`p/{unit}/ln{1,2}.{g,b}` in the weights
/// FXT), head divisibility, and the model's `seq` are all validated here so
/// every downstream path gets one precise error.
pub fn block_def_for<'a>(cx: &UnitCtx<'a>) -> Result<BlockDef<'a>> {
    let unit = cx.unit;
    if unit.kind != "transformer_block" {
        bail!("block_def_for on unit {:?} of kind {:?}", unit.name, unit.kind);
    }
    let names: Vec<&str> = unit.layers.iter().map(|l| l.name.as_str()).collect();
    if names != CANON_LAYERS {
        bail!(
            "transformer_block unit {:?} must list layers {CANON_LAYERS:?} in order, \
             got {names:?}",
            unit.name
        );
    }
    let seq = cx.model.seq.ok_or_else(|| {
        anyhow!(
            "model {:?} has no \"seq\"; transformer_block attention needs the \
             rows-per-sequence length",
            cx.model.name
        )
    })?;
    if seq == 0 {
        bail!("model {:?}: seq must be ≥ 1", cx.model.name);
    }
    let heads = unit.heads.max(1);
    let d = unit.layers[0].rows;
    let mlp = unit.layers[4].rows;
    let expect: [(usize, usize); 6] = [(d, d), (d, d), (d, d), (d, d), (mlp, d), (d, mlp)];
    let mut w: Vec<&Tensor> = Vec::with_capacity(6);
    let mut b: Vec<Option<&Tensor>> = Vec::with_capacity(6);
    for (i, layer) in unit.layers.iter().enumerate() {
        if (layer.rows, layer.cols) != expect[i] {
            bail!(
                "transformer_block {:?}: layer {:?} is {}×{}, expected {}×{}",
                unit.name,
                layer.name,
                layer.rows,
                layer.cols,
                expect[i].0,
                expect[i].1
            );
        }
        let t = cx.weights.get(i).copied().flatten().ok_or_else(|| {
            anyhow!(
                "transformer_block {:?}: missing weights w/{}/{} in the model's FXT export",
                unit.name,
                unit.name,
                layer.name
            )
        })?;
        if t.shape() != &[layer.rows, layer.cols][..] {
            bail!(
                "transformer_block {:?}: weights for {:?} have shape {:?}, expected \
                 [{}, {}]",
                unit.name,
                layer.name,
                t.shape(),
                layer.rows,
                layer.cols
            );
        }
        w.push(t);
        b.push(cx.biases.get(i).copied().flatten());
    }
    if d % heads != 0 {
        bail!(
            "transformer_block {:?}: hidden width {d} not divisible by {heads} heads",
            unit.name
        );
    }
    let ln = |key: &str| -> Result<&'a Tensor> {
        let t = cx.extras.get(key).copied().ok_or_else(|| {
            anyhow!(
                "transformer_block {:?}: missing layernorm tensor p/{}/{key} in the \
                 weights FXT",
                unit.name,
                unit.name
            )
        })?;
        if t.len() != d {
            bail!(
                "transformer_block {:?}: p/{}/{key} has {} values, expected hidden \
                 width {d}",
                unit.name,
                unit.name,
                t.len()
            );
        }
        Ok(t)
    };
    Ok(BlockDef {
        name: &unit.name,
        heads,
        seq,
        d,
        mlp,
        w: [w[0], w[1], w[2], w[3], w[4], w[5]],
        b: [b[0], b[1], b[2], b[3], b[4], b[5]],
        ln1_g: ln("ln1.g")?,
        ln1_b: ln("ln1.b")?,
        ln2_g: ln("ln2.g")?,
        ln2_b: ln("ln2.b")?,
    })
}

// ---------------------------------------------------------------------------
// Multi-head causal attention
// ---------------------------------------------------------------------------

/// Multi-head causal softmax attention over `(n, d)` projections, attending
/// within consecutive `seq`-row groups.  Returns the context `(n, d)` plus
/// the cached attention probabilities — one row-stochastic, lower-triangular
/// `(seq, seq)` tensor per `(sequence, head)` in `s·heads + h` order — which
/// [`attn_backward`] consumes.
pub fn attn_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
    seq: usize,
) -> Result<(Tensor, Vec<Tensor>)> {
    attn_impl(q, k, v, heads, seq, true)
}

/// Forward-only attention: the context with **no** probability caches — the
/// serving/inference hot path ([`crate::infer::Engine`]), which never runs a
/// backward and should not allocate `nseq·heads` score tensors per call.
pub fn attn_ctx(q: &Tensor, k: &Tensor, v: &Tensor, heads: usize, seq: usize) -> Result<Tensor> {
    Ok(attn_impl(q, k, v, heads, seq, false)?.0)
}

/// One query row of one head against `count` cached key/value rows: scaled
/// dot-product scores, max-shifted softmax over positions `0..count`, and
/// the probability-weighted value sum accumulated into `out` (the head
/// width, pre-zeroed by the caller).
///
/// `kbuf`/`vbuf` are row-major `(rows ≥ count, stride)` buffers with this
/// head's channels at columns `c0..c0 + out.len()`; `probs[..count]`
/// receives the normalized attention row (entries past `count` are left
/// untouched).  This is the single attention core shared by the
/// full-context forward ([`attn_forward`], where `count` walks the causal
/// frontier row by row) and the incremental KV-cache decode path
/// ([`crate::infer::Engine::decode_step`], where the one new token attends
/// to everything cached) — sharing it is what makes prefill-then-decode
/// bit-identical to the full-context forward.
#[allow(clippy::too_many_arguments)]
pub fn attn_score_row(
    qi: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    stride: usize,
    c0: usize,
    count: usize,
    scale: f32,
    probs: &mut [f32],
    out: &mut [f32],
) {
    attn_score_segments(qi, &[(kbuf, vbuf, count)], stride, c0, count, scale, probs, out);
}

/// [`attn_score_row`] generalized to a *segmented* K/V walk: the cached
/// rows live in `segs` — an ordered list of `(k_rows, v_rows, rows)`
/// buffers, each row-major `(rows, stride)` with this head's channels at
/// columns `c0..c0 + out.len()` — covering positions `0..count` in order
/// (the final segment may hold more rows than `count` consumes).
///
/// This is the attention core of the paged KV pool
/// ([`crate::sched::PagedKvPool`]): a session's K/V rows are scattered
/// across fixed-size pages, so the scheduler's decode reads walk the page
/// list instead of one contiguous slice.  [`attn_score_row`] delegates here
/// with a single segment, which makes the contiguous and paged walks
/// bit-identical *by construction*: the scores, the max-shifted softmax,
/// and the value accumulation visit positions in the same order with the
/// same operations regardless of how the rows are cut into segments.
#[allow(clippy::too_many_arguments)]
pub fn attn_score_segments(
    qi: &[f32],
    segs: &[(&[f32], &[f32], usize)],
    stride: usize,
    c0: usize,
    count: usize,
    scale: f32,
    probs: &mut [f32],
    out: &mut [f32],
) {
    let dh = out.len();
    debug_assert!(qi.len() == dh && probs.len() >= count && count >= 1);
    debug_assert!(segs.iter().map(|s| s.2).sum::<usize>() >= count);
    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'k: for &(kseg, _, rows) in segs {
        for r in 0..rows {
            if j >= count {
                break 'k;
            }
            let kj = &kseg[r * stride + c0..r * stride + c0 + dh];
            // the crate-wide sequential contraction core: the same bits as
            // the gemv/GEMM kernels, so score rows never depend on the path
            let rj = linalg::dot(qi, kj) * scale;
            probs[j] = rj;
            mx = mx.max(rj);
            j += 1;
        }
    }
    debug_assert_eq!(j, count, "segments cover fewer than count rows");
    let mut sum = 0.0f32;
    for rj in probs.iter_mut().take(count) {
        *rj = (*rj - mx).exp();
        sum += *rj;
    }
    let inv = 1.0 / sum;
    for rj in probs.iter_mut().take(count) {
        *rj *= inv;
    }
    let mut j = 0usize;
    'v: for &(_, vseg, rows) in segs {
        for r in 0..rows {
            if j >= count {
                break 'v;
            }
            let vj = &vseg[r * stride + c0..r * stride + c0 + dh];
            let pij = probs[j];
            for (c, b) in out.iter_mut().zip(vj) {
                *c += pij * b;
            }
            j += 1;
        }
    }
}

fn attn_impl(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
    seq: usize,
    want_probs: bool,
) -> Result<(Tensor, Vec<Tensor>)> {
    let (n, d) = check_attn_shapes(q, k, v, heads, seq)?;
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let (qv, kv, vv) = (q.as_f32()?, k.as_f32()?, v.as_f32()?);
    let nseq = n / seq;
    let mut ctx = vec![0.0f32; n * d];
    let mut probs = Vec::with_capacity(if want_probs { nseq * heads } else { 0 });
    // scratch for the forward-only path: the ctx accumulation only ever
    // reads the freshly-written causal prefix of each row, so stale entries
    // past the frontier are harmless and the buffer needs no re-zeroing
    let mut scratch = vec![0.0f32; seq * seq];
    for s in 0..nseq {
        let base = s * seq;
        let kseq = &kv[base * d..(base + seq) * d];
        let vseq = &vv[base * d..(base + seq) * d];
        for h in 0..heads {
            let c0 = h * dh;
            let mut owned = if want_probs { Some(vec![0.0f32; seq * seq]) } else { None };
            let p: &mut [f32] = match owned.as_mut() {
                Some(v) => v,
                None => &mut scratch,
            };
            for i in 0..seq {
                let qi = &qv[(base + i) * d + c0..(base + i) * d + c0 + dh];
                // cached rows beyond the causal frontier stay exactly zero
                attn_score_row(
                    qi,
                    kseq,
                    vseq,
                    d,
                    c0,
                    i + 1,
                    scale,
                    &mut p[i * seq..(i + 1) * seq],
                    &mut ctx[(base + i) * d + c0..(base + i) * d + c0 + dh],
                );
            }
            if let Some(v) = owned {
                probs.push(Tensor::from_f32(v, &[seq, seq])?);
            }
        }
    }
    Ok((Tensor::from_f32(ctx, &[n, d])?, probs))
}

/// Backward of [`attn_forward`]: given `∂L/∂ctx`, return
/// `(∂L/∂q, ∂L/∂k, ∂L/∂v)` using the cached probabilities (softmax backward
/// runs off the forward output — masked entries carry zero probability and
/// therefore zero gradient).
pub fn attn_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &[Tensor],
    dctx: &Tensor,
    heads: usize,
    seq: usize,
) -> Result<(Tensor, Tensor, Tensor)> {
    let (n, d) = check_attn_shapes(q, k, v, heads, seq)?;
    if dctx.shape() != q.shape() {
        bail!("attn_backward: dctx {:?} vs q {:?}", dctx.shape(), q.shape());
    }
    let nseq = n / seq;
    if probs.len() != nseq * heads {
        bail!("attn_backward: {} prob tensors for {} (sequence, head) pairs", probs.len(), nseq * heads);
    }
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let (qv, kv, gv) = (q.as_f32()?, k.as_f32()?, dctx.as_f32()?);
    let vv = v.as_f32()?;
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    for s in 0..nseq {
        let base = s * seq;
        for h in 0..heads {
            let c0 = h * dh;
            let p = &probs[s * heads + h];
            if p.shape() != &[seq, seq][..] {
                bail!("attn_backward: prob tensor {:?}, expected [{seq}, {seq}]", p.shape());
            }
            let pv = p.as_f32()?;
            // dA[i][j] = dctx_i · v_j ;  dv_j += p[i][j] · dctx_i
            let mut da = vec![0.0f32; seq * seq];
            for i in 0..seq {
                let gi = &gv[(base + i) * d + c0..(base + i) * d + c0 + dh];
                for j in 0..=i {
                    let vj = &vv[(base + j) * d + c0..(base + j) * d + c0 + dh];
                    da[i * seq + j] = linalg::dot(gi, vj);
                    let pij = pv[i * seq + j];
                    let dvj = &mut dv[(base + j) * d + c0..(base + j) * d + c0 + dh];
                    for (o, a) in dvj.iter_mut().zip(gi) {
                        *o += pij * a;
                    }
                }
            }
            let ds = softmax_rows_bwd(p, &Tensor::from_f32(da, &[seq, seq])?)?;
            let dsv = ds.as_f32()?;
            // dq_i = scale · Σ_j ds[i][j] k_j ;  dk_j = scale · Σ_i ds[i][j] q_i
            for i in 0..seq {
                let qi = &qv[(base + i) * d + c0..(base + i) * d + c0 + dh];
                for j in 0..=i {
                    let dsij = scale * dsv[i * seq + j];
                    if dsij == 0.0 {
                        continue;
                    }
                    let kj = &kv[(base + j) * d + c0..(base + j) * d + c0 + dh];
                    let dqi = &mut dq[(base + i) * d + c0..(base + i) * d + c0 + dh];
                    for (o, b) in dqi.iter_mut().zip(kj) {
                        *o += dsij * b;
                    }
                    let dkj = &mut dk[(base + j) * d + c0..(base + j) * d + c0 + dh];
                    for (o, a) in dkj.iter_mut().zip(qi) {
                        *o += dsij * a;
                    }
                }
            }
        }
    }
    Ok((
        Tensor::from_f32(dq, &[n, d])?,
        Tensor::from_f32(dk, &[n, d])?,
        Tensor::from_f32(dv, &[n, d])?,
    ))
}

fn check_attn_shapes(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
    seq: usize,
) -> Result<(usize, usize)> {
    if q.ndim() != 2 || q.shape() != k.shape() || q.shape() != v.shape() {
        bail!(
            "attention: q/k/v shapes {:?}/{:?}/{:?} must be equal 2-D",
            q.shape(),
            k.shape(),
            v.shape()
        );
    }
    let (n, d) = (q.shape()[0], q.shape()[1]);
    if heads == 0 || seq == 0 || d % heads != 0 {
        bail!("attention: width {d} not divisible by {heads} heads (seq {seq})");
    }
    if n % seq != 0 {
        bail!("attention: {n} rows not a multiple of seq {seq}");
    }
    Ok((n, d))
}

// ---------------------------------------------------------------------------
// Block forward (FP and substituted-weight)
// ---------------------------------------------------------------------------

struct FwdCache {
    h1: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    probs: Vec<Tensor>,
    ctx: Tensor,
    x2: Tensor,
    mean2: Vec<f32>,
    rstd2: Vec<f32>,
    h2: Tensor,
    up_pre: Tensor,
    m: Tensor,
    y: Tensor,
}

fn forward_cached(
    def: &BlockDef,
    w: &[&Tensor],
    x: &Tensor,
    workers: usize,
    want_probs: bool,
) -> Result<FwdCache> {
    if w.len() != 6 {
        bail!("block forward: {} weight tensors for 6 layers", w.len());
    }
    if x.ndim() != 2 || x.shape()[1] != def.d {
        bail!("block {:?}: input {:?}, expected (n, {})", def.name, x.shape(), def.d);
    }
    if x.shape()[0] % def.seq != 0 {
        bail!(
            "block {:?}: {} input rows not a multiple of seq {}",
            def.name,
            x.shape()[0],
            def.seq
        );
    }
    let disp = linalg::Dispatch::new(workers);
    let proj = |xin: &Tensor, i: usize| -> Result<Tensor> {
        let mut y = xin.matmul_nt_with(w[i], &disp)?;
        let bias = def.b[i].map(|t| t.as_f32()).transpose()?;
        y.bias_relu_inplace(bias, false)?;
        Ok(y)
    };
    let (h1, _, _) = layernorm_rows(x, def.ln1_g.as_f32()?, def.ln1_b.as_f32()?, LN_EPS)?;
    let q = proj(&h1, 0)?;
    let k = proj(&h1, 1)?;
    let v = proj(&h1, 2)?;
    let (ctx, probs) = attn_impl(&q, &k, &v, def.heads, def.seq, want_probs)?;
    let attn = proj(&ctx, 3)?;
    let x2 = x.zip(&attn, |a, b| a + b)?;
    let (h2, mean2, rstd2) =
        layernorm_rows(&x2, def.ln2_g.as_f32()?, def.ln2_b.as_f32()?, LN_EPS)?;
    let up_pre = proj(&h2, 4)?;
    let m = up_pre.gelu();
    let down = proj(&m, 5)?;
    let y = x2.zip(&down, |a, b| a + b)?;
    Ok(FwdCache { h1, q, k, v, probs, ctx, x2, mean2, rstd2, h2, up_pre, m, y })
}

/// Block forward with an explicit weight set (fake-quantized Ŵ, or any
/// substitution) — layernorms, attention, GELU, biases and residuals stay
/// full-precision.  Forward-only: no backward caches are materialized.
pub fn forward_with(def: &BlockDef, w: &[&Tensor], x: &Tensor, workers: usize) -> Result<Tensor> {
    Ok(forward_cached(def, w, x, workers, false)?.y)
}

/// Full-precision block forward (the calibration-target path).
pub fn forward_fp(def: &BlockDef, x: &Tensor, workers: usize) -> Result<Tensor> {
    forward_with(def, &def.w, x, workers)
}

/// Materialize the six fake-quantized Ŵ from the current parameter pack.
pub fn block_whats(
    scheme: &dyn Rounding,
    def: &BlockDef,
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
) -> Result<Vec<Tensor>> {
    if slots.len() != 6 {
        bail!("block {:?}: {} slot groups for 6 layers", def.name, slots.len());
    }
    def.w
        .iter()
        .zip(slots)
        .map(|(w, s)| scheme.forward(w, &s.resolve(params), qmin, qmax))
        .collect()
}

/// Quantized block forward with the current parameter pack.
#[allow(clippy::too_many_arguments)]
pub fn forward_q(
    scheme: &dyn Rounding,
    def: &BlockDef,
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
    x: &Tensor,
    workers: usize,
) -> Result<Tensor> {
    let whats = block_whats(scheme, def, slots, params, qmin, qmax)?;
    let refs: Vec<&Tensor> = whats.iter().collect();
    forward_with(def, &refs, x, workers)
}

// ---------------------------------------------------------------------------
// Loss + gradients for one minibatch
// ---------------------------------------------------------------------------

/// Forward the minibatch through the fake-quantized block, compute
/// `L = mean((ŷ − y)²)`, and backpropagate — through the residual adds,
/// layernorm, GELU, the attention softmax, and finally the scheme's STE
/// backward (FlexRound's Proposition 3.1 closed form, or AdaRound's
/// rectified-sigmoid derivative with the β-annealed regularizer) — into
/// per-entry parameter gradients.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads(
    scheme: &dyn Rounding,
    def: &BlockDef,
    slots: &[LayerSlots],
    params: &[Tensor],
    xb: &Tensor,
    yb: &Tensor,
    qmin: f32,
    qmax: f32,
    beta: f64,
    workers: usize,
) -> Result<(f64, Vec<Option<Tensor>>)> {
    let whats = block_whats(scheme, def, slots, params, qmin, qmax)?;
    let refs: Vec<&Tensor> = whats.iter().collect();
    let cache = forward_cached(def, &refs, xb, workers, true)?;
    let yhat = &cache.y;
    let loss = yhat.mse(yb)? as f64;

    // ∂L/∂ŷ = 2(ŷ − y)/N
    let n_inv = 2.0 / yhat.len() as f32;
    let g = yhat.zip(yb, move |a, b| n_inv * (a - b))?;

    // backward matmuls run under the same dispatch budget as the forward
    // projections (they used to be unconditionally serial)
    let disp = linalg::Dispatch::new(workers);

    // ---- MLP path: y = x2 + gelu(h2·Ŵupᵀ + bup)·Ŵdownᵀ + bdown ----
    let d_down = g.matmul_tn_with(&cache.m, &disp)?; // ∂L/∂Ŵdown  (d, mlp)
    let dm = g.matmul_nn_with(&whats[5], &disp)?; // (n, mlp)
    let dup_pre = gelu_bwd(&cache.up_pre, &dm)?;
    let d_up = dup_pre.matmul_tn_with(&cache.h2, &disp)?; // ∂L/∂Ŵup  (mlp, d)
    let dh2 = dup_pre.matmul_nn_with(&whats[4], &disp)?; // (n, d)
    let (dx2_ln, _, _) = layernorm_rows_bwd(
        &cache.x2,
        def.ln2_g.as_f32()?,
        &cache.mean2,
        &cache.rstd2,
        &dh2,
    )?;
    // residual: x2 feeds both the MLP branch (via ln2) and y directly
    let dx2 = g.zip(&dx2_ln, |a, b| a + b)?;

    // ---- attention path: x2 = x + (attn(ln1(x))·Ŵoᵀ + bo) ----
    let d_wo = dx2.matmul_tn_with(&cache.ctx, &disp)?; // ∂L/∂Ŵo  (d, d)
    let dctx = dx2.matmul_nn_with(&whats[3], &disp)?; // (n, d)
    let (dq, dk, dv) =
        attn_backward(&cache.q, &cache.k, &cache.v, &cache.probs, &dctx, def.heads, def.seq)?;
    let d_wq = dq.matmul_tn_with(&cache.h1, &disp)?;
    let d_wk = dk.matmul_tn_with(&cache.h1, &disp)?;
    let d_wv = dv.matmul_tn_with(&cache.h1, &disp)?;

    // ---- STE into the scheme's rounding parameters, per layer ----
    let mut grads: Vec<Option<Tensor>> = params.iter().map(|_| None).collect();
    let dwhats = [d_wq, d_wk, d_wv, d_wo, d_up, d_down];
    for (i, dwhat) in dwhats.iter().enumerate() {
        let s = &slots[i];
        let fg = scheme.backward(def.w[i], &s.resolve(params), dwhat, qmin, qmax, beta)?;
        recon::scatter_grads(&mut grads, s, fg);
    }
    Ok((loss, grads))
}

// ---------------------------------------------------------------------------
// The per-block reconstruction loop
// ---------------------------------------------------------------------------

/// Expand sampled sequence indices into their row indices (`seq`
/// consecutive rows per sequence) — the sequence-minibatch gather shared by
/// [`reconstruct_block`] and the pipeline's streamed loop.
pub fn seq_rows(sidx: &[usize], seq: usize) -> Vec<usize> {
    let mut rows = Vec::with_capacity(sidx.len() * seq);
    for &s in sidx {
        rows.extend(s * seq..(s + 1) * seq);
    }
    rows
}

/// Learn one block's FlexRound parameters: [`recon::run_adam`] over random
/// calibration minibatches of whole sequences.  `cfg.batch` is in *rows*;
/// it is rounded down to whole sequences (at least one) because attention
/// couples the rows of a sequence.
#[allow(clippy::too_many_arguments)]
pub fn reconstruct_block(
    def: &BlockDef,
    slots: &[LayerSlots],
    entries: &[PackEntry],
    params0: &[Tensor],
    x: &Tensor,
    y: &Tensor,
    cfg: &ReconSettings,
    rng: &mut Pcg32,
) -> Result<ReconResult> {
    if x.shape()[0] != y.shape()[0] {
        bail!("calibration rows {} vs target rows {}", x.shape()[0], y.shape()[0]);
    }
    let n = x.shape()[0];
    if n % def.seq != 0 {
        bail!("block {:?}: {n} calibration rows not a multiple of seq {}", def.name, def.seq);
    }
    let nseq = n / def.seq;
    let batch_seqs = (cfg.batch / def.seq).clamp(1, nseq);
    recon::run_adam(entries, params0, cfg, rng, |rng, params, t| {
        let rows = seq_rows(&rng.sample_indices(nseq, batch_seqs), def.seq);
        let xb = x.gather_rows(&rows)?;
        let yb = y.gather_rows(&rows)?;
        let beta = recon::rounding::beta_schedule(t, cfg.iters);
        loss_and_grads(
            cfg.scheme, def, slots, params, &xb, &yb, cfg.qmin, cfg.qmax, beta, cfg.workers,
        )
    })
}

// ---------------------------------------------------------------------------
// Owned synthetic blocks (tests, benches, the CLI `--synthetic` path)
// ---------------------------------------------------------------------------

/// Owned tensors for one random transformer block — [`BlockTensors::def`]
/// borrows them as a [`BlockDef`].
pub struct BlockTensors {
    pub heads: usize,
    pub seq: usize,
    pub d: usize,
    pub mlp: usize,
    pub w: Vec<Tensor>,
    pub b: Vec<Option<Tensor>>,
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
}

impl BlockTensors {
    /// Random block with residual-friendly weight scale (`σ ≈ 0.4/√d`).
    pub fn random(d: usize, heads: usize, mlp: usize, seq: usize, seed: u64) -> BlockTensors {
        let mut rng = Pcg32::seeded(seed);
        let sigma = 0.4 / (d as f32).sqrt();
        let mut mat = |rows: usize, cols: usize| -> Tensor {
            Tensor::from_f32(
                (0..rows * cols).map(|_| rng.next_normal() * sigma).collect(),
                &[rows, cols],
            )
            .expect("block weight shape")
        };
        let w = vec![mat(d, d), mat(d, d), mat(d, d), mat(d, d), mat(mlp, d), mat(d, mlp)];
        let mut bias = |len: usize| -> Option<Tensor> {
            Some(
                Tensor::from_f32((0..len).map(|_| rng.next_normal() * 0.02).collect(), &[len])
                    .expect("bias shape"),
            )
        };
        let b = vec![bias(d), bias(d), bias(d), bias(d), bias(mlp), bias(d)];
        BlockTensors {
            heads,
            seq,
            d,
            mlp,
            w,
            b,
            ln1_g: Tensor::full(&[d], 1.0),
            ln1_b: Tensor::zeros(&[d]),
            ln2_g: Tensor::full(&[d], 1.0),
            ln2_b: Tensor::zeros(&[d]),
        }
    }

    /// Borrow as a [`BlockDef`] named "blk".
    pub fn def(&self) -> BlockDef<'_> {
        BlockDef {
            name: "blk",
            heads: self.heads,
            seq: self.seq,
            d: self.d,
            mlp: self.mlp,
            w: [&self.w[0], &self.w[1], &self.w[2], &self.w[3], &self.w[4], &self.w[5]],
            b: [
                self.b[0].as_ref(),
                self.b[1].as_ref(),
                self.b[2].as_ref(),
                self.b[3].as_ref(),
                self.b[4].as_ref(),
                self.b[5].as_ref(),
            ],
            ln1_g: &self.ln1_g,
            ln1_b: &self.ln1_b,
            ln2_g: &self.ln2_g,
            ln2_b: &self.ln2_b,
        }
    }

    /// FlexRound pack at the RTN init (per-row min/max s1, S2 = s3 = s4 = 1)
    /// for every layer: `(entries, params, slots)` in [`CANON_LAYERS`] order.
    pub fn flexround_pack(&self, bits: u32) -> (Vec<PackEntry>, Vec<Tensor>, Vec<LayerSlots>) {
        let mut entries = Vec::new();
        let mut params = Vec::new();
        let mut slots = Vec::new();
        for (li, name) in CANON_LAYERS.iter().enumerate() {
            let w = &self.w[li];
            let (rows, cols) = (w.shape()[0], w.shape()[1]);
            let wv = w.as_f32().expect("block weights are f32");
            let s1: Vec<f32> = (0..rows)
                .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], bits, true).0)
                .collect();
            let base = params.len();
            let entry = |k: &str, shape: &[usize], learn: bool| PackEntry {
                name: format!("{name}.{k}"),
                shape: shape.to_vec(),
                learnable: learn,
            };
            entries.extend([
                entry("s1", &[rows, 1], true),
                entry("s2", &[rows, cols], true),
                entry("s3", &[rows, 1], true),
                entry("s4", &[1, cols], true),
                entry("zp", &[rows, 1], false),
            ]);
            params.extend([
                Tensor::from_f32(s1, &[rows, 1]).expect("s1"),
                Tensor::full(&[rows, cols], 1.0),
                Tensor::full(&[rows, 1], 1.0),
                Tensor::full(&[1, cols], 1.0),
                Tensor::zeros(&[rows, 1]),
            ]);
            slots.push(LayerSlots {
                layer: li,
                s1: base,
                zp: base + 4,
                s2: Some(base + 1),
                s3: Some(base + 2),
                s4: Some(base + 3),
                v: None,
            });
        }
        (entries, params, slots)
    }

    /// AdaRound pack for every layer: frozen per-row RTN `s1`/`zp` plus the
    /// learnable rounding variable `V` at the RTN-fraction init
    /// (`(entries, params, slots)` in [`CANON_LAYERS`] order).
    pub fn adaround_pack(&self, bits: u32) -> (Vec<PackEntry>, Vec<Tensor>, Vec<LayerSlots>) {
        let mut entries = Vec::new();
        let mut params = Vec::new();
        let mut slots = Vec::new();
        for (li, name) in CANON_LAYERS.iter().enumerate() {
            let w = &self.w[li];
            let (rows, cols) = (w.shape()[0], w.shape()[1]);
            let wv = w.as_f32().expect("block weights are f32");
            let s1: Vec<f32> = (0..rows)
                .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], bits, true).0)
                .collect();
            let s1 = Tensor::from_f32(s1, &[rows, 1]).expect("s1");
            let v = crate::recon::rounding::adaround::init_v(w, &s1).expect("init v");
            let base = params.len();
            let entry = |k: &str, shape: &[usize], learn: bool| PackEntry {
                name: format!("{name}.{k}"),
                shape: shape.to_vec(),
                learnable: learn,
            };
            entries.extend([
                entry("s1", &[rows, 1], false),
                entry("v", &[rows, cols], true),
                entry("zp", &[rows, 1], false),
            ]);
            params.extend([s1, v, Tensor::zeros(&[rows, 1])]);
            slots.push(LayerSlots {
                layer: li,
                s1: base,
                zp: base + 2,
                s2: None,
                s3: None,
                s4: None,
                v: Some(base + 1),
            });
        }
        (entries, params, slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_x(n: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::from_f32((0..n * d).map(|_| rng.next_normal()).collect(), &[n, d]).unwrap()
    }

    #[test]
    fn attention_probs_are_causal_and_stochastic() {
        let (heads, seq, d) = (2usize, 4usize, 8usize);
        let q = random_x(2 * seq, d, 1);
        let k = random_x(2 * seq, d, 2);
        let v = random_x(2 * seq, d, 3);
        let (ctx, probs) = attn_forward(&q, &k, &v, heads, seq).unwrap();
        assert_eq!(ctx.shape(), &[2 * seq, d]);
        assert_eq!(probs.len(), 2 * heads);
        for p in &probs {
            let pv = p.as_f32().unwrap();
            for i in 0..seq {
                let row = &pv[i * seq..(i + 1) * seq];
                assert!((row[..=i].iter().sum::<f32>() - 1.0).abs() < 1e-5);
                for &masked in &row[i + 1..] {
                    assert_eq!(masked, 0.0, "future position leaked");
                }
            }
        }
        // first row attends only to itself → ctx row 0 = v row 0 (per head)
        let cv = ctx.as_f32().unwrap();
        let vv = v.as_f32().unwrap();
        for t in 0..d {
            assert!((cv[t] - vv[t]).abs() < 1e-6);
        }
        // the forward-only (scratch-buffer) path is bit-identical
        let ctx2 = attn_ctx(&q, &k, &v, heads, seq).unwrap();
        assert_eq!(ctx.as_f32().unwrap(), ctx2.as_f32().unwrap());
    }

    #[test]
    fn attention_backward_matches_finite_differences() {
        let (heads, seq, d, n) = (2usize, 3usize, 4usize, 6usize);
        let q = random_x(n, d, 11);
        let k = random_x(n, d, 12);
        let v = random_x(n, d, 13);
        let g = random_x(n, d, 14);
        let gv: Vec<f32> = g.as_f32().unwrap().to_vec();
        let (_, probs) = attn_forward(&q, &k, &v, heads, seq).unwrap();
        let (dq, dk, dv) = attn_backward(&q, &k, &v, &probs, &g, heads, seq).unwrap();

        let j = |qx: &Tensor, kx: &Tensor, vx: &Tensor| -> f64 {
            let (ctx, _) = attn_forward(qx, kx, vx, heads, seq).unwrap();
            ctx.as_f32().unwrap().iter().zip(&gv).map(|(&c, &gi)| c as f64 * gi as f64).sum()
        };
        let eps = 1e-3f32;
        let check = |which: &str, base: &Tensor, analytic: &Tensor,
                     f: &dyn Fn(&Tensor) -> f64| {
            let bv = base.as_f32().unwrap().to_vec();
            let av = analytic.as_f32().unwrap();
            for idx in 0..bv.len() {
                let mut hi = bv.clone();
                let mut lo = bv.clone();
                hi[idx] += eps;
                lo[idx] -= eps;
                let th = Tensor::from_f32(hi, base.shape()).unwrap();
                let tl = Tensor::from_f32(lo, base.shape()).unwrap();
                let num = (f(&th) - f(&tl)) / (2.0 * eps as f64);
                assert!(
                    (av[idx] as f64 - num).abs() < 5e-3 * (1.0 + num.abs()),
                    "{which}[{idx}]: analytic {} vs numeric {num}",
                    av[idx]
                );
            }
        };
        check("dq", &q, &dq, &|t| j(t, &k, &v));
        check("dk", &k, &dk, &|t| j(&q, t, &v));
        check("dv", &v, &dv, &|t| j(&q, &k, t));
    }

    #[test]
    fn block_forward_shapes_and_determinism() {
        let bt = BlockTensors::random(8, 2, 16, 4, 5);
        let def = bt.def();
        let x = random_x(8, 8, 7);
        let y1 = forward_fp(&def, &x, 1).unwrap();
        let y4 = forward_fp(&def, &x, 4).unwrap();
        assert_eq!(y1.shape(), &[8, 8]);
        assert_eq!(y1.as_f32().unwrap(), y4.as_f32().unwrap(), "worker count changed results");
        // rows not a multiple of seq are rejected
        assert!(forward_fp(&def, &random_x(6, 8, 9), 1).is_err());
    }

    #[test]
    fn block_reconstruction_improves_over_rtn_init() {
        let bt = BlockTensors::random(8, 2, 16, 4, 21);
        let def = bt.def();
        let (entries, params, slots) = bt.flexround_pack(3);
        let x = random_x(16 * 4, 8, 23);
        let y = forward_fp(&def, &x, 1).unwrap();
        let (qmin, qmax) = crate::tensor::qrange(3, true);
        let scheme = recon::scheme_for("flexround").unwrap();
        let before = forward_q(scheme, &def, &slots, &params, qmin, qmax, &x, 1)
            .unwrap()
            .mse(&y)
            .unwrap();
        let cfg = ReconSettings {
            iters: 120,
            lr: 3e-3,
            batch: 16,
            qmin,
            qmax,
            workers: 1,
            verbose: false,
            tag: "block".into(),
            scheme,
        };
        let mut rng = Pcg32::seeded(3);
        let r = reconstruct_block(&def, &slots, &entries, &params, &x, &y, &cfg, &mut rng)
            .unwrap();
        assert!(r.first_loss.is_finite() && r.final_loss.is_finite());
        let after = forward_q(scheme, &def, &slots, &r.params, qmin, qmax, &x, 1)
            .unwrap()
            .mse(&y)
            .unwrap();
        assert!(
            after < before,
            "block reconstruction should beat the RTN init: {before:.6} → {after:.6}"
        );
    }

    #[test]
    fn block_reconstruction_is_deterministic() {
        let bt = BlockTensors::random(8, 2, 16, 4, 31);
        let def = bt.def();
        let (entries, params, slots) = bt.flexround_pack(4);
        let x = random_x(8 * 4, 8, 33);
        let y = forward_fp(&def, &x, 1).unwrap();
        let (qmin, qmax) = crate::tensor::qrange(4, true);
        let cfg = ReconSettings {
            iters: 15,
            lr: 3e-3,
            batch: 8,
            qmin,
            qmax,
            workers: 2,
            verbose: false,
            tag: "det".into(),
            scheme: recon::scheme_for("flexround").unwrap(),
        };
        let run = || {
            let mut rng = Pcg32::seeded(9);
            reconstruct_block(&def, &slots, &entries, &params, &x, &y, &cfg, &mut rng).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.final_loss, b.final_loss);
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }

    #[test]
    fn block_adaround_reconstruction_runs_and_stays_on_grid() {
        // AdaRound through the block path: V learns under the annealed
        // regularizer and the hard export stays within the grid.
        let bt = BlockTensors::random(8, 2, 16, 4, 41);
        let def = bt.def();
        let (entries, params, slots) = bt.adaround_pack(3);
        let x = random_x(8 * 4, 8, 43);
        let y = forward_fp(&def, &x, 1).unwrap();
        let (qmin, qmax) = crate::tensor::qrange(3, true);
        let scheme = recon::scheme_for("adaround").unwrap();
        let cfg = ReconSettings {
            iters: 40,
            lr: 1e-2,
            batch: 16,
            qmin,
            qmax,
            workers: 1,
            verbose: false,
            tag: "ada-block".into(),
            scheme,
        };
        let mut rng = Pcg32::seeded(5);
        let r = reconstruct_block(&def, &slots, &entries, &params, &x, &y, &cfg, &mut rng)
            .unwrap();
        assert!(r.first_loss.is_finite() && r.final_loss.is_finite());
        // V moved (it is the only learnable slot)
        let v0 = params[slots[0].v.unwrap()].as_f32().unwrap();
        let v1 = r.params[slots[0].v.unwrap()].as_f32().unwrap();
        assert!(v0.iter().zip(v1).any(|(a, b)| a != b), "V never updated");
        for s in &slots {
            let codes = scheme
                .codes(def.w[s.layer], &s.resolve(&r.params), qmin, qmax)
                .unwrap();
            for c in codes.to_f32_vec() {
                assert!((qmin..=qmax).contains(&c), "code {c} off-grid");
            }
        }
    }
}
