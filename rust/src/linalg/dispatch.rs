//! The single parallel-dispatch policy behind every matmul (DESIGN.md
//! §Compute-Kernels).
//!
//! Before this module each kernel family carried its own ad-hoc serial
//! heuristic — `infer/kernels.rs` went serial below `n·rows·k < 2¹⁶`
//! mul-adds, the reconstruction matmuls below `m·r < 2¹⁴` *output elements*
//! (ignoring k entirely), and the backward-pass matmuls never parallelized
//! at all.  [`Dispatch`] replaces all of them: one flops threshold
//! ([`PAR_FLOPS_MIN`]), one fan-out mechanism (output-row panels over
//! [`crate::util::pool`] scoped workers, each writing its own disjoint
//! panel of the output buffer).
//!
//! Parallel results are bit-identical to serial ones *within an ISA arm*:
//! the panel split only decides *which worker* computes an output row —
//! every element keeps the same per-element reduction tree on either side
//! of the split (`linalg::micro` on the scalar arm, `linalg::simd` on the
//! AVX2 arm), so no reduction ever crosses a panel boundary.
//!
//! Since the SIMD PR, a [`Dispatch`] also carries *which* instruction-set
//! arm the kernels run on ([`Isa`]).  Constructors default to
//! [`Isa::active`] (runtime detection, `FLEXROUND_FORCE_SCALAR` override);
//! [`Dispatch::with_isa`] pins an explicit arm — that is how the
//! differential kernel-parity harness runs the same problem on both arms.

use super::simd::Isa;
use crate::util::pool;

/// Mul-adds below which every kernel stays serial.  The pool fan-out costs
/// tens of microseconds of spawn/join; a contraction this small finishes
/// faster than the fan-out itself.  One constant for the whole crate.
pub const PAR_FLOPS_MIN: usize = 1 << 16;

/// The crate-wide matmul dispatch policy: a worker budget, the ISA arm,
/// and the shared serial/parallel decision.  Construct with an explicit
/// worker count ([`Dispatch::new`], e.g. from a `--workers` flag), the
/// machine default ([`Dispatch::auto`]), or force serial execution
/// ([`Dispatch::serial`]); all three pick the ISA via [`Isa::active`],
/// overridable per-policy with [`Dispatch::with_isa`].
#[derive(Clone, Copy, Debug)]
pub struct Dispatch {
    workers: usize,
    isa: Isa,
}

impl Dispatch {
    /// Policy with an explicit worker budget (clamped to ≥ 1).
    pub fn new(workers: usize) -> Dispatch {
        Dispatch { workers: workers.max(1), isa: Isa::active() }
    }

    /// Always-serial policy (single worker).
    pub fn serial() -> Dispatch {
        Dispatch::new(1)
    }

    /// Policy sized to the machine ([`pool::default_workers`]).
    pub fn auto() -> Dispatch {
        Dispatch::new(pool::default_workers())
    }

    /// Same policy pinned to an explicit ISA arm (test/bench control; the
    /// production constructors all defer to [`Isa::active`]).
    pub fn with_isa(mut self, isa: Isa) -> Dispatch {
        self.isa = isa;
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The instruction-set arm kernels under this policy run on.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Whether the integer-domain fused GEMM may auto-select the i16-madd
    /// route under this policy: only on the AVX2 arm (the madd kernel's
    /// scalar emulation is bit-identical but slower than the i32 path
    /// there), and only while the `FLEXROUND_FORCE_NO_MADD` kill switch is
    /// not set — verify.sh's three-arm kernel differential uses that knob
    /// to pin the AVX2-f32/i32 routes as the middle arm.
    pub fn use_madd(&self) -> bool {
        self.isa == Isa::Avx2 && super::simd::madd_allowed()
    }

    /// The serial/parallel decision: split `rows` output rows into
    /// per-worker panels, or `None` when the problem should run serial —
    /// a single worker, too few rows to split (`rows < 2·workers`), or too
    /// little work to amortize the fan-out (`flops < PAR_FLOPS_MIN`).
    pub fn panels(&self, rows: usize, flops: usize) -> Option<Vec<(usize, usize)>> {
        if self.workers <= 1 || rows < 2 * self.workers || flops < PAR_FLOPS_MIN {
            return None;
        }
        let chunk = rows.div_ceil(self.workers);
        Some(
            (0..self.workers)
                .map(|w| (w * chunk, ((w + 1) * chunk).min(rows)))
                .filter(|(lo, hi)| lo < hi)
                .collect(),
        )
    }

    /// Run `kernel` over the `(rows, cols)` row-major output buffer `out`:
    /// in place when [`Dispatch::panels`] says serial, otherwise fanned out
    /// over the pool with each worker writing its own disjoint row panel.
    /// `kernel(lo, hi, panel)` computes global output rows `[lo, hi)` into
    /// `panel` (local row 0 = global row `lo`).
    pub fn run_rows(
        &self,
        rows: usize,
        cols: usize,
        flops: usize,
        out: &mut [f32],
        kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
    ) {
        debug_assert_eq!(out.len(), rows * cols);
        let panels = self.panels(rows, flops);
        // per-GEMM dispatch-decision counters (serial vs parallel, ISA arm);
        // this is an innermost hot path, so the kill switch gates them
        if crate::obs::enabled() {
            if panels.is_some() {
                crate::obs_counter!("flexround_dispatch_parallel_total").inc();
            } else {
                crate::obs_counter!("flexround_dispatch_serial_total").inc();
            }
            match self.isa {
                Isa::Scalar => crate::obs_counter!("flexround_dispatch_scalar_total").inc(),
                Isa::Avx2 => crate::obs_counter!("flexround_dispatch_avx2_total").inc(),
            }
        }
        match panels {
            None => kernel(0, rows, out),
            Some(ranges) => pool::par_panels(out, cols, &ranges, |(lo, hi), panel| {
                kernel(lo, hi, panel)
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_or_single_worker_stays_serial() {
        assert!(Dispatch::serial().panels(1024, usize::MAX).is_none());
        assert!(Dispatch::new(4).panels(7, usize::MAX).is_none(), "too few rows to split");
        assert!(Dispatch::new(4).panels(1024, PAR_FLOPS_MIN - 1).is_none(), "below threshold");
        assert!(Dispatch::new(0).workers() == 1, "worker budget clamps to 1");
    }

    #[test]
    fn isa_override_sticks() {
        let d = Dispatch::new(4).with_isa(Isa::Scalar);
        assert_eq!(d.isa(), Isa::Scalar);
        assert_eq!(d.workers(), 4);
        assert_eq!(Dispatch::serial().isa(), Isa::active(), "default arm is the active one");
    }

    #[test]
    fn panels_cover_rows_exactly_once() {
        let d = Dispatch::new(4);
        let ranges = d.panels(10, PAR_FLOPS_MIN).expect("should parallelize");
        assert_eq!(ranges.first().map(|r| r.0), Some(0));
        assert_eq!(ranges.last().map(|r| r.1), Some(10));
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0, "panels must tile contiguously");
        }
    }

    #[test]
    fn run_rows_serial_and_parallel_agree() {
        // kernel writes row index into every slot: panel offsets must line up
        let fill = |lo: usize, _hi: usize, panel: &mut [f32]| {
            for (i, row) in panel.chunks_mut(3).enumerate() {
                row.fill((lo + i) as f32);
            }
        };
        let mut serial = vec![0.0f32; 24 * 3];
        Dispatch::serial().run_rows(24, 3, usize::MAX, &mut serial, fill);
        let mut par = vec![0.0f32; 24 * 3];
        Dispatch::new(4).run_rows(24, 3, usize::MAX, &mut par, fill);
        assert_eq!(serial, par);
        assert_eq!(serial[23 * 3], 23.0);
    }
}
