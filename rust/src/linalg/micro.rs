//! Scalar register-tiled f32 micro-kernels (DESIGN.md §Compute-Kernels).
//!
//! Since the SIMD PR this family is the **scalar ISA arm**: always
//! available, selected by `FLEXROUND_FORCE_SCALAR` (or
//! `Dispatch::with_isa(Isa::Scalar)`), and the oracle the AVX2 kernels in
//! [`super::simd`] are differentially tested against.  Production matmuls
//! route through `super::simd`'s `Isa`-taking wrappers and land here on the
//! scalar arm.
//!
//! Every kernel here — the [`MR`]×[`NR`] register tile, the edge tiles, the
//! [`gemv_nt`]/[`gemv_nn`] single-row paths, and the shared [`dot`] core —
//! keeps **one accumulator per output element and sums the contraction axis
//! in ascending order**.  That single invariant is what makes the crate's
//! parity pins hold *by construction* instead of by tolerance:
//!
//! * serial ≡ parallel: row-panel fan-out never changes which products feed
//!   an element, or in what order;
//! * batch-1 gemv ≡ the same row of a batched GEMM (the prefill/decode
//!   bit-identity contract in `rust/tests/generate.rs`);
//! * blocked ≡ the naive triple-loop oracles, bit-for-bit
//!   (`rust/tests/kernels.rs`).
//!
//! The speedup over the naive loops comes from instruction-level
//! parallelism, not from reassociation: the tile holds MR·NR *independent*
//! accumulator chains in registers, so the CPU (and the auto-vectorizer,
//! which may vectorize across the NR accumulators without touching any
//! single chain's order) is never stalled on one chain's add latency, and
//! each k step streams only MR + NR values for MR·NR multiply-adds.

#![allow(clippy::too_many_arguments)]

/// Micro-tile rows (output rows per register block).
pub const MR: usize = 4;

/// Micro-tile columns (output columns per register block).
pub const NR: usize = 8;

/// Sequential dot product — THE canonical scalar contraction: one
/// accumulator, ascending index.  Shared verbatim by the gemv paths and
/// (element-wise) the register tiles; the attention score core reaches it
/// through the ISA-routed `linalg::dot` on the scalar arm.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Single-row `y = x · Bᵀ` (`x: k`, `B: (r, k)` row-major, `y: r`): one
/// [`dot`] per weight row, B streamed exactly once — the batch-1 fast path
/// behind decode-step projections and one-row lm-head chunks, where tile
/// bookkeeping would cost more than it buys.
#[inline]
pub fn gemv_nt(x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
    debug_assert!(x.len() == k && b.len() == r * k && out.len() == r);
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot(x, &b[j * k..j * k + k]);
    }
}

/// Single-row `y = x · B` (`x: k`, `B: (k, c)` row-major, `y: c`,
/// pre-zeroed): saxpy over B's rows, ascending `t` per element.
#[inline]
pub fn gemv_nn(x: &[f32], b: &[f32], k: usize, c: usize, out: &mut [f32]) {
    debug_assert!(x.len() == k && b.len() == k * c && out.len() == c);
    for (t, &xv) in x.iter().enumerate() {
        let brow = &b[t * c..t * c + c];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += xv * bv;
        }
    }
}

// ---------------------------------------------------------------------------
// NT: C[m, r] = A[m, k] · B[r, k]ᵀ   (both operands row-contiguous)
// ---------------------------------------------------------------------------

/// Blocked NT kernel over output rows `[mlo, mhi)`, writing the
/// `(mhi − mlo, r)` row panel `out` (overwrite semantics: every element is
/// assigned exactly once).
pub fn gemm_nt_panel(
    a: &[f32],
    b: &[f32],
    k: usize,
    r: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (mhi - mlo) * r);
    let mut i = mlo;
    let mut oi = 0usize;
    while i < mhi {
        let mr = MR.min(mhi - i);
        let mut j = 0usize;
        while j < r {
            let nr = NR.min(r - j);
            if mr == MR && nr == NR {
                tile_nt(a, b, k, r, i, j, out, oi);
            } else {
                tile_nt_edge(a, b, k, r, i, j, mr, nr, out, oi);
            }
            j += nr;
        }
        i += mr;
        oi += mr;
    }
}

#[inline]
fn tile_nt(a: &[f32], b: &[f32], k: usize, r: usize, i0: usize, j0: usize, out: &mut [f32], oi: usize) {
    let ar: [&[f32]; MR] = core::array::from_fn(|ii| &a[(i0 + ii) * k..(i0 + ii) * k + k]);
    let br: [&[f32]; NR] = core::array::from_fn(|jj| &b[(j0 + jj) * k..(j0 + jj) * k + k]);
    let mut acc = [[0.0f32; NR]; MR];
    for t in 0..k {
        let av = [ar[0][t], ar[1][t], ar[2][t], ar[3][t]];
        let bv = [br[0][t], br[1][t], br[2][t], br[3][t], br[4][t], br[5][t], br[6][t], br[7][t]];
        for (accrow, &a_t) in acc.iter_mut().zip(&av) {
            for (c, &b_t) in accrow.iter_mut().zip(&bv) {
                *c += a_t * b_t;
            }
        }
    }
    for (ii, accrow) in acc.iter().enumerate() {
        let orow = &mut out[(oi + ii) * r + j0..(oi + ii) * r + j0 + NR];
        for (o, &v) in orow.iter_mut().zip(accrow) {
            *o = v;
        }
    }
}

#[inline]
fn tile_nt_edge(
    a: &[f32],
    b: &[f32],
    k: usize,
    r: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    out: &mut [f32],
    oi: usize,
) {
    for ii in 0..mr {
        let arow = &a[(i0 + ii) * k..(i0 + ii) * k + k];
        for jj in 0..nr {
            let brow = &b[(j0 + jj) * k..(j0 + jj) * k + k];
            out[(oi + ii) * r + j0 + jj] = dot(arow, brow);
        }
    }
}

// ---------------------------------------------------------------------------
// NN: C[m, c] = A[m, k] · B[k, c]
// ---------------------------------------------------------------------------

/// Blocked NN kernel over output rows `[mlo, mhi)`, writing the
/// `(mhi − mlo, c)` row panel `out` (overwrite semantics: every element is
/// assigned exactly once).
pub fn gemm_nn_panel(
    a: &[f32],
    b: &[f32],
    k: usize,
    c: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (mhi - mlo) * c);
    let mut i = mlo;
    let mut oi = 0usize;
    while i < mhi {
        let mr = MR.min(mhi - i);
        let mut j = 0usize;
        while j < c {
            let nr = NR.min(c - j);
            if mr == MR && nr == NR {
                tile_nn(a, b, k, c, i, j, out, oi);
            } else {
                tile_nn_edge(a, b, k, c, i, j, mr, nr, out, oi);
            }
            j += nr;
        }
        i += mr;
        oi += mr;
    }
}

#[inline]
fn tile_nn(a: &[f32], b: &[f32], k: usize, c: usize, i0: usize, j0: usize, out: &mut [f32], oi: usize) {
    let ar: [&[f32]; MR] = core::array::from_fn(|ii| &a[(i0 + ii) * k..(i0 + ii) * k + k]);
    let mut acc = [[0.0f32; NR]; MR];
    for t in 0..k {
        let brow = &b[t * c + j0..t * c + j0 + NR];
        let av = [ar[0][t], ar[1][t], ar[2][t], ar[3][t]];
        for (accrow, &a_t) in acc.iter_mut().zip(&av) {
            for (acc_c, &b_t) in accrow.iter_mut().zip(brow) {
                *acc_c += a_t * b_t;
            }
        }
    }
    for (ii, accrow) in acc.iter().enumerate() {
        let orow = &mut out[(oi + ii) * c + j0..(oi + ii) * c + j0 + NR];
        for (o, &v) in orow.iter_mut().zip(accrow) {
            *o = v;
        }
    }
}

#[inline]
fn tile_nn_edge(
    a: &[f32],
    b: &[f32],
    k: usize,
    c: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    out: &mut [f32],
    oi: usize,
) {
    for ii in 0..mr {
        let arow = &a[(i0 + ii) * k..(i0 + ii) * k + k];
        for jj in 0..nr {
            let mut acc = 0.0f32;
            for (t, &av) in arow.iter().enumerate() {
                acc += av * b[t * c + j0 + jj];
            }
            out[(oi + ii) * c + j0 + jj] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// TN: C[m, c] = A[n, m]ᵀ · B[n, c]
// ---------------------------------------------------------------------------

/// Blocked TN kernel over output rows `[mlo, mhi)` (columns of A), writing
/// the `(mhi − mlo, c)` row panel `out` (overwrite semantics: every element
/// is assigned exactly once).
pub fn gemm_tn_panel(
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    c: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (mhi - mlo) * c);
    let mut i = mlo;
    let mut oi = 0usize;
    while i < mhi {
        let mr = MR.min(mhi - i);
        let mut j = 0usize;
        while j < c {
            let nr = NR.min(c - j);
            if mr == MR && nr == NR {
                tile_tn(a, b, n, m, c, i, j, out, oi);
            } else {
                tile_tn_edge(a, b, n, m, c, i, j, mr, nr, out, oi);
            }
            j += nr;
        }
        i += mr;
        oi += mr;
    }
}

#[inline]
fn tile_tn(
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    c: usize,
    i0: usize,
    j0: usize,
    out: &mut [f32],
    oi: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for t in 0..n {
        let acol = &a[t * m + i0..t * m + i0 + MR];
        let brow = &b[t * c + j0..t * c + j0 + NR];
        for (accrow, &a_t) in acc.iter_mut().zip(acol) {
            for (acc_c, &b_t) in accrow.iter_mut().zip(brow) {
                *acc_c += a_t * b_t;
            }
        }
    }
    for (ii, accrow) in acc.iter().enumerate() {
        let orow = &mut out[(oi + ii) * c + j0..(oi + ii) * c + j0 + NR];
        for (o, &v) in orow.iter_mut().zip(accrow) {
            *o = v;
        }
    }
}

#[inline]
fn tile_tn_edge(
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    c: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    out: &mut [f32],
    oi: usize,
) {
    for ii in 0..mr {
        for jj in 0..nr {
            let mut acc = 0.0f32;
            for t in 0..n {
                acc += a[t * m + i0 + ii] * b[t * c + j0 + jj];
            }
            out[(oi + ii) * c + j0 + jj] = acc;
        }
    }
}
