//! Runtime ISA dispatch + AVX2 SIMD micro-kernels (DESIGN.md
//! §Compute-Kernels).
//!
//! [`Isa`] is the capability probe: [`Isa::detect`] asks the CPU once
//! (AVX2 **and** FMA — the vector tiles fuse multiply-adds), and
//! [`Isa::active`] caches the process-wide choice, honoring the
//! `FLEXROUND_FORCE_SCALAR` environment override so the scalar arm stays
//! reachable on any machine (`verify.sh` runs the kernel-parity suite once
//! per arm).  The scalar tiles in [`micro`] are *retained as selectable
//! oracles*, not replaced: every routing function here takes an explicit
//! `Isa`, so tests and benches can pin either arm.
//!
//! ## The per-element contraction scheme (why the parity pins survive)
//!
//! The crate's bit-exactness pins (serial ≡ parallel, gemv ≡ batched row —
//! see [`micro`]'s module docs) survive vectorization because every AVX2
//! kernel gives each output element the *same* reduction tree regardless of
//! which tile or panel computes it:
//!
//! * NT orientation ([`dot`], [`gemv_nt`], [`gemm_nt_panel`]): one 8-lane
//!   accumulator per element, `fmadd` over ascending k-chunks of 8, one
//!   fixed horizontal-sum order, then a plain scalar `mul + add` tail for
//!   the `k mod 8` remainder — identical whether the element is computed
//!   alone (gemv), in a 1×4 strip, or in a 2×4 register tile;
//! * NN/TN orientation ([`gemv_nn`], [`gemm_nn_panel`], [`gemm_tn_panel`]):
//!   output columns vectorized 8-wide with a broadcast A element, `t`
//!   ascending, plain scalar `mul + add` for the `c mod 8` column tail —
//!   the treatment of column `j` depends only on `(j, c)`, never on the
//!   row panel that computes it.
//!
//! FMA *does* change bits versus the scalar tiles (one rounding per
//! multiply-add instead of two), so cross-arm comparisons are ULP-bounded
//! ([`crate::util::ulp`], `rust/tests/kernels.rs`), while every within-arm
//! identity stays exact.  The integer kernels ([`dot_i32`],
//! [`dot_i16_madd`]) have no such caveat: integer addition is associative,
//! so their results are bit-identical across arms, lane counts, and
//! chunkings — which is what lets the integer-domain fused GEMM
//! (`infer/kernels.rs`) promise bit-exactness instead of a tolerance.
//!
//! ## In-register weight decode
//!
//! The fused serving kernels used to decode packed weight codes through a
//! scalar per-row word walk (`PackedMatrix::unpack_row{,_i32}`), leaving
//! the hot path decode-bound.  [`unpack_codes_i32`] / [`unpack_codes_f32`]
//! / [`unpack_codes_i16`] move that decode into registers on the AVX2 arm:
//! a packed `u32` word is broadcast to all lanes, each lane right-shifts by
//! its own code offset (`_mm256_srlv_epi32`), masks to `bits`, and adds
//! `qmin` — 2/3/4/8-bit codes expand straight to i32/f32/i16 lanes with no
//! scratch f32 panel in between.  Per-word lane layouts:
//!
//! ```text
//!   bits=4 (8 codes/word):  shifts [0,4,…,28]            → one 8×i32 vector
//!   bits=2 (16 codes/word): shifts [0,2,…,14]/[16,…,30]  → two 8×i32 vectors
//!   bits=3 (10 codes/word): shifts [0,3,…,21]            → one vector + 2 scalar codes
//!   bits=8 (4 codes/word):  the byte stream IS the code stream (LSB-first
//!                           words, little-endian) → _mm256_cvtepu8_epi32/16
//! ```
//!
//! The scalar word walk is retained as the selectable oracle (and the
//! `Isa::Scalar` arm); both arms produce **identical** values — decode is
//! pure integer bit manipulation, and the f32 variant converts exact small
//! integers (`|code| < 2²⁴`), so even the f32 panels are bit-identical
//! across arms.  Partial trailing words always fall back to the scalar walk
//! so vector stores never touch out-of-bounds columns.

#![allow(clippy::too_many_arguments)]

use super::micro;

/// Instruction-set arm a kernel call should run on.
///
/// Construct via [`Isa::detect`] / [`Isa::active`]; the enum is `Copy` so a
/// [`super::Dispatch`] carries it by value.  Hand-constructing `Isa::Avx2`
/// on hardware without AVX2+FMA and passing it to a routing function is a
/// programming error (the AVX2 arm would execute unsupported instructions);
/// the routing shims `debug_assert` against it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The scalar register-tile family in [`micro`] — always available,
    /// and the oracle the SIMD arm is differentially tested against.
    Scalar,
    /// 256-bit AVX2 + FMA kernels (x86-64 only; compiled in everywhere but
    /// only ever *selected* after a successful CPUID probe).
    Avx2,
}

impl Isa {
    /// Probe the CPU: [`Isa::Avx2`] iff the hardware reports both `avx2`
    /// and `fma`.  Always [`Isa::Scalar`] off x86-64.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return Isa::Avx2;
            }
        }
        Isa::Scalar
    }

    /// The process-wide arm: [`Isa::detect`], unless the
    /// `FLEXROUND_FORCE_SCALAR` environment variable is set to anything
    /// other than empty or `0`.  Cached after the first call — every
    /// `Tensor::matmul_*` asks, and the answer cannot change mid-process.
    pub fn active() -> Isa {
        static ACTIVE: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();
        *ACTIVE.get_or_init(|| match std::env::var("FLEXROUND_FORCE_SCALAR") {
            Ok(v) if !v.is_empty() && v != "0" => Isa::Scalar,
            _ => Isa::detect(),
        })
    }

    /// Short name for bench rows and verify.sh failure messages.
    pub fn label(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
        }
    }
}

// ---------------------------------------------------------------------------
// Routing layer: safe functions taking an explicit Isa.  The scalar arm is
// `micro`; the AVX2 arm lives in the `avx2` module below, reached through
// per-op shims so non-x86-64 builds compile the same call sites.
// ---------------------------------------------------------------------------

/// Sequential dot product on the chosen arm.
#[inline]
pub fn dot(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => micro::dot(a, b),
        Isa::Avx2 => dot_avx2(a, b),
    }
}

/// Integer dot product `Σ a[t]·b[t]` in i32 on the chosen arm.  i32
/// addition is associative, so both arms (and any chunking) produce
/// identical bits.  The caller must bound `|a|·|b|·len` below `i32::MAX`
/// (see `infer::kernels::int_safe_k`); within that bound no lane or the
/// scalar tail can overflow.
#[inline]
pub fn dot_i32(isa: Isa, a: &[i32], b: &[i32]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot_i32_scalar(a, b),
        Isa::Avx2 => dot_i32_avx2(a, b),
    }
}

/// Integer dot product `Σ a[t]·b[t]` over i16 operands, accumulated in
/// i32, on the chosen arm.  The AVX2 arm runs `_mm256_madd_epi16`: 16
/// products per instruction, adjacent pairs summed into 8 i32 lanes — with
/// both operands bounded by `i16::MAX` in magnitude a pair-sum is
/// `≤ 2·32767² = 2_147_352_578 < i32::MAX`, so the instruction itself can
/// never overflow.  The caller must bound `|a|·|b|·len` below `i32::MAX`
/// exactly as for [`dot_i32`] (see `infer::kernels::int_safe_k`); within
/// that bound every lane partial and the scalar tail stay in range, and —
/// integer addition being associative — both arms and every chunking
/// produce identical bits.
#[inline]
pub fn dot_i16_madd(isa: Isa, a: &[i16], b: &[i16]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot_i16_scalar(a, b),
        Isa::Avx2 => dot_i16_madd_avx2(a, b),
    }
}

/// Whether the i16-madd fused route may be auto-selected: `true` unless
/// the `FLEXROUND_FORCE_NO_MADD` environment variable is set to anything
/// other than empty or `0`.  Cached after the first call, mirroring
/// [`Isa::active`] — the kill switch pins the integer fused GEMM to the
/// i32 `mullo` kernel so `verify.sh` can differentially test the madd
/// route against it (forced-scalar / AVX2-no-madd / auto, three arms).
pub fn madd_allowed() -> bool {
    static ALLOWED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ALLOWED.get_or_init(|| match std::env::var("FLEXROUND_FORCE_NO_MADD") {
        Ok(v) if !v.is_empty() && v != "0" => false,
        _ => true,
    })
}

/// Decode `cols` packed codes (LSB-first in `words`, `⌊32/bits⌋` codes per
/// word) into i32 values `qmin + u` on the chosen arm.  Both arms produce
/// identical values — see the module docs' in-register decode section.
/// `words` is one row of a `PackedMatrix` (`PackedMatrix::row_words`);
/// `out` must hold exactly `cols` elements.
#[inline]
pub fn unpack_codes_i32(isa: Isa, words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i32]) {
    debug_assert_eq!(out.len(), cols);
    debug_assert!(words.len() * (32 / bits) as usize >= cols);
    match isa {
        Isa::Scalar => unpack_codes_i32_scalar(words, cols, bits, qmin, out),
        Isa::Avx2 => unpack_i32_avx2(words, cols, bits, qmin, out),
    }
}

/// [`unpack_codes_i32`] with an f32 destination — the fused f32 panel
/// kernel's decode.  The int→f32 conversion is exact for every supported
/// grid (`|code| < 2²⁴`), so the decoded panel is bit-identical across
/// arms even though the downstream f32 contraction is not.
#[inline]
pub fn unpack_codes_f32(isa: Isa, words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), cols);
    debug_assert!(words.len() * (32 / bits) as usize >= cols);
    match isa {
        Isa::Scalar => unpack_codes_f32_scalar(words, cols, bits, qmin, out),
        Isa::Avx2 => unpack_f32_avx2(words, cols, bits, qmin, out),
    }
}

/// [`unpack_codes_i32`] with an i16 destination — the madd kernel's
/// decode, 16 codes per store.  The **caller** must guarantee every
/// decoded code fits i16 (`infer::kernels` gates the madd route on
/// `max|code| ≤ i16::MAX`); out-of-range grids would saturate on the AVX2
/// arm and wrap on the scalar arm.
#[inline]
pub fn unpack_codes_i16(isa: Isa, words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i16]) {
    debug_assert_eq!(out.len(), cols);
    debug_assert!(words.len() * (32 / bits) as usize >= cols);
    match isa {
        Isa::Scalar => unpack_codes_i16_scalar(words, cols, bits, qmin, out),
        Isa::Avx2 => unpack_i16_avx2(words, cols, bits, qmin, out),
    }
}

/// Single-row `y = x · Bᵀ` on the chosen arm (overwrite semantics).
#[inline]
pub fn gemv_nt(isa: Isa, x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
    debug_assert!(x.len() == k && b.len() == r * k && out.len() == r);
    match isa {
        Isa::Scalar => micro::gemv_nt(x, b, k, r, out),
        Isa::Avx2 => gemv_nt_avx2(x, b, k, r, out),
    }
}

/// Single-row `y = x · B` on the chosen arm.  `out` must be pre-zeroed:
/// the scalar arm accumulates (saxpy), the AVX2 arm assigns — both leave
/// `out = x · B` when it starts at zero.
#[inline]
pub fn gemv_nn(isa: Isa, x: &[f32], b: &[f32], k: usize, c: usize, out: &mut [f32]) {
    debug_assert!(x.len() == k && b.len() == k * c && out.len() == c);
    match isa {
        Isa::Scalar => micro::gemv_nn(x, b, k, c, out),
        Isa::Avx2 => gemv_nn_avx2(x, b, k, c, out),
    }
}

/// Blocked NT kernel over output rows `[mlo, mhi)` on the chosen arm
/// (overwrite semantics, same contract as [`micro::gemm_nt_panel`]).
#[inline]
pub fn gemm_nt_panel(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    k: usize,
    r: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => micro::gemm_nt_panel(a, b, k, r, mlo, mhi, out),
        Isa::Avx2 => gemm_nt_panel_avx2(a, b, k, r, mlo, mhi, out),
    }
}

/// Blocked NN kernel over output rows `[mlo, mhi)` on the chosen arm.
#[inline]
pub fn gemm_nn_panel(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    k: usize,
    c: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => micro::gemm_nn_panel(a, b, k, c, mlo, mhi, out),
        Isa::Avx2 => gemm_nn_panel_avx2(a, b, k, c, mlo, mhi, out),
    }
}

/// Blocked TN kernel over output rows `[mlo, mhi)` on the chosen arm.
#[inline]
pub fn gemm_tn_panel(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    c: usize,
    mlo: usize,
    mhi: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => micro::gemm_tn_panel(a, b, n, m, c, mlo, mhi, out),
        Isa::Avx2 => gemm_tn_panel_avx2(a, b, n, m, c, mlo, mhi, out),
    }
}

/// Scalar i32 dot — the always-available arm of [`dot_i32`].  Wrapping ops
/// make debug builds panic-free; within the caller's `int_safe_k` bound no
/// wrap can actually occur.
fn dot_i32_scalar(a: &[i32], b: &[i32]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc = acc.wrapping_add(x.wrapping_mul(y));
    }
    acc
}

/// Scalar i16 dot (i32 accumulation) — the always-available arm of
/// [`dot_i16_madd`].  Sequential wrapping adds are bit-identical to the
/// madd lane-sum because i32 addition is associative and both arms wrap.
fn dot_i16_scalar(a: &[i16], b: &[i16]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc = acc.wrapping_add((x as i32).wrapping_mul(y as i32));
    }
    acc
}

/// Scalar word walk — the always-available arm of [`unpack_codes_i32`] and
/// the oracle the in-register decode is differentially tested against.
/// Identical loop structure to `PackedMatrix::unpack_row_i32`.
fn unpack_codes_i32_scalar(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i32]) {
    let cpw = (32 / bits) as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for &w in words {
        if t >= cols {
            break;
        }
        let mut v = w;
        let lim = cpw.min(cols - t);
        for _ in 0..lim {
            out[t] = qmin + (v & mask) as i32;
            v >>= bits;
            t += 1;
        }
    }
}

/// Scalar word walk with an f32 destination (exact int→f32 conversion).
fn unpack_codes_f32_scalar(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [f32]) {
    let cpw = (32 / bits) as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for &w in words {
        if t >= cols {
            break;
        }
        let mut v = w;
        let lim = cpw.min(cols - t);
        for _ in 0..lim {
            out[t] = (qmin + (v & mask) as i32) as f32;
            v >>= bits;
            t += 1;
        }
    }
}

/// Scalar word walk with an i16 destination (codes must fit i16 — see
/// [`unpack_codes_i16`]).
fn unpack_codes_i16_scalar(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i16]) {
    let cpw = (32 / bits) as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for &w in words {
        if t >= cols {
            break;
        }
        let mut v = w;
        let lim = cpw.min(cols - t);
        for _ in 0..lim {
            out[t] = (qmin + (v & mask) as i32) as i16;
            v >>= bits;
            t += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 shims.  Each `*_avx2` function is the single safety boundary for
// its kernel: the unsafe AVX2 body may only be reached through a shim, and a
// shim may only be reached with `Isa::Avx2`, which `detect()` hands out
// after the CPUID probe.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod shims {
    use super::{avx2, Isa};

    #[inline]
    fn checked() {
        debug_assert!(Isa::detect() == Isa::Avx2, "Isa::Avx2 used on non-AVX2 hardware");
    }

    #[inline]
    pub(super) fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        checked();
        // SAFETY: Isa::Avx2 implies the CPUID probe confirmed avx2+fma.
        unsafe { avx2::dot(a, b) }
    }

    #[inline]
    pub(super) fn dot_i32_avx2(a: &[i32], b: &[i32]) -> i32 {
        checked();
        // SAFETY: as above.
        unsafe { avx2::dot_i32(a, b) }
    }

    #[inline]
    pub(super) fn dot_i16_madd_avx2(a: &[i16], b: &[i16]) -> i32 {
        checked();
        // SAFETY: as above.
        unsafe { avx2::dot_i16_madd(a, b) }
    }

    #[inline]
    pub(super) fn unpack_i32_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i32]) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::unpack_i32(words, cols, bits, qmin, out) }
    }

    #[inline]
    pub(super) fn unpack_f32_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [f32]) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::unpack_f32(words, cols, bits, qmin, out) }
    }

    #[inline]
    pub(super) fn unpack_i16_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i16]) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::unpack_i16(words, cols, bits, qmin, out) }
    }

    #[inline]
    pub(super) fn gemv_nt_avx2(x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::gemv_nt(x, b, k, r, out) }
    }

    #[inline]
    pub(super) fn gemv_nn_avx2(x: &[f32], b: &[f32], k: usize, c: usize, out: &mut [f32]) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::nn_row(x, b, c, out) }
    }

    #[inline]
    pub(super) fn gemm_nt_panel_avx2(
        a: &[f32],
        b: &[f32],
        k: usize,
        r: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::gemm_nt_panel(a, b, k, r, mlo, mhi, out) }
    }

    #[inline]
    pub(super) fn gemm_nn_panel_avx2(
        a: &[f32],
        b: &[f32],
        k: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::gemm_nn_panel(a, b, k, c, mlo, mhi, out) }
    }

    #[inline]
    pub(super) fn gemm_tn_panel_avx2(
        a: &[f32],
        b: &[f32],
        n: usize,
        m: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        checked();
        // SAFETY: as above.
        unsafe { avx2::gemm_tn_panel(a, b, n, m, c, mlo, mhi, out) }
    }
}

#[cfg(target_arch = "x86_64")]
use shims::{
    dot_avx2, dot_i16_madd_avx2, dot_i32_avx2, gemm_nn_panel_avx2, gemm_nt_panel_avx2,
    gemm_tn_panel_avx2, gemv_nn_avx2, gemv_nt_avx2, unpack_f32_avx2, unpack_i16_avx2,
    unpack_i32_avx2,
};

// Off x86-64, Isa::detect() never returns Avx2; the shims only exist so the
// routing match arms compile, and they defer to the scalar tiles.
#[cfg(not(target_arch = "x86_64"))]
mod shims_portable {
    use super::micro;

    #[inline]
    pub(super) fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        micro::dot(a, b)
    }

    #[inline]
    pub(super) fn dot_i32_avx2(a: &[i32], b: &[i32]) -> i32 {
        super::dot_i32_scalar(a, b)
    }

    #[inline]
    pub(super) fn dot_i16_madd_avx2(a: &[i16], b: &[i16]) -> i32 {
        super::dot_i16_scalar(a, b)
    }

    #[inline]
    pub(super) fn unpack_i32_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i32]) {
        super::unpack_codes_i32_scalar(words, cols, bits, qmin, out)
    }

    #[inline]
    pub(super) fn unpack_f32_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [f32]) {
        super::unpack_codes_f32_scalar(words, cols, bits, qmin, out)
    }

    #[inline]
    pub(super) fn unpack_i16_avx2(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i16]) {
        super::unpack_codes_i16_scalar(words, cols, bits, qmin, out)
    }

    #[inline]
    pub(super) fn gemv_nt_avx2(x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
        micro::gemv_nt(x, b, k, r, out)
    }

    #[inline]
    pub(super) fn gemv_nn_avx2(x: &[f32], b: &[f32], k: usize, c: usize, out: &mut [f32]) {
        micro::gemv_nn(x, b, k, c, out)
    }

    #[inline]
    pub(super) fn gemm_nt_panel_avx2(
        a: &[f32],
        b: &[f32],
        k: usize,
        r: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        micro::gemm_nt_panel(a, b, k, r, mlo, mhi, out)
    }

    #[inline]
    pub(super) fn gemm_nn_panel_avx2(
        a: &[f32],
        b: &[f32],
        k: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        micro::gemm_nn_panel(a, b, k, c, mlo, mhi, out)
    }

    #[inline]
    pub(super) fn gemm_tn_panel_avx2(
        a: &[f32],
        b: &[f32],
        n: usize,
        m: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        micro::gemm_tn_panel(a, b, n, m, c, mlo, mhi, out)
    }
}

#[cfg(not(target_arch = "x86_64"))]
use shims_portable::{
    dot_avx2, dot_i16_madd_avx2, dot_i32_avx2, gemm_nn_panel_avx2, gemm_nt_panel_avx2,
    gemm_tn_panel_avx2, gemv_nn_avx2, gemv_nt_avx2, unpack_f32_avx2, unpack_i16_avx2,
    unpack_i32_avx2,
};

// ---------------------------------------------------------------------------
// AVX2 kernel bodies.  Private: only reachable through the shims above.
// Every f32 kernel follows the per-element scheme in the module docs; the
// comments mark the two pieces that define an element's reduction tree
// (vector fmadd chain + fixed hsum, then the scalar tail).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    const LANES: usize = 8;

    /// Fixed horizontal-sum order for an 8-lane f32 accumulator:
    /// `((v0+v4)+(v1+v5)) + ((v2+v6)+(v3+v7))`.  Every NT-orientation
    /// element ends its vector chain with exactly this tree.
    ///
    /// # Safety
    /// Requires AVX2 (callers are `target_feature(avx2)` functions).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let q = _mm_add_ps(lo, hi);
        let dup = _mm_movehdup_ps(q);
        let s = _mm_add_ps(q, dup);
        let s = _mm_add_ss(s, _mm_movehl_ps(dup, s));
        _mm_cvtss_f32(s)
    }

    /// Lane sum of an 8-lane i32 accumulator.  Order is irrelevant (i32
    /// addition is associative) but kept fixed anyway.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0000_1110>(s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0000_0001>(s));
        _mm_cvtsi128_si32(s)
    }

    /// Canonical NT-orientation contraction: one vector accumulator,
    /// ascending k, `hsum`, scalar `mul + add` tail.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA are available.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len().min(b.len());
        let k8 = k - k % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut t = 0usize;
        while t < k8 {
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(t)), _mm256_loadu_ps(pb.add(t)), acc);
            t += LANES;
        }
        let mut s = hsum(acc);
        while t < k {
            s += *pa.add(t) * *pb.add(t);
            t += 1;
        }
        s
    }

    /// `Σ a·b` in i32: `mullo + add` over ascending k-chunks, lane sum,
    /// wrapping scalar tail (no overflow within the caller's safe-K bound).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i32(a: &[i32], b: &[i32]) -> i32 {
        let k = a.len().min(b.len());
        let k8 = k - k % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_si256();
        let mut t = 0usize;
        while t < k8 {
            let av = _mm256_loadu_si256(pa.add(t).cast());
            let bv = _mm256_loadu_si256(pb.add(t).cast());
            acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(av, bv));
            t += LANES;
        }
        let mut s = hsum_epi32(acc);
        while t < k {
            s = s.wrapping_add((*pa.add(t)).wrapping_mul(*pb.add(t)));
            t += 1;
        }
        s
    }

    /// `Σ a·b` over i16 operands via `_mm256_madd_epi16`: 16 products per
    /// instruction, adjacent pairs summed into 8 i32 lanes (a pair-sum is
    /// `≤ 2·32767² < i32::MAX`, so the instruction cannot overflow), lane
    /// sum, wrapping scalar tail.  Bit-identical to the scalar i16 dot by
    /// i32 associativity.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i16_madd(a: &[i16], b: &[i16]) -> i32 {
        let k = a.len().min(b.len());
        let k16 = k - k % 16;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_si256();
        let mut t = 0usize;
        while t < k16 {
            let av = _mm256_loadu_si256(pa.add(t).cast());
            let bv = _mm256_loadu_si256(pb.add(t).cast());
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            t += 16;
        }
        let mut s = hsum_epi32(acc);
        while t < k {
            s = s.wrapping_add((*pa.add(t) as i32).wrapping_mul(*pb.add(t) as i32));
            t += 1;
        }
        s
    }

    /// Decode (up to) 8 codes of one packed word into 8 i32 lanes: the
    /// word is broadcast, each lane right-shifts by its own code offset
    /// (`srlv`), masks to the code width, and adds `qmin`.  This is the
    /// in-register replacement for 8 iterations of the scalar word walk.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn codes8(w: u32, shifts: __m256i, mask: __m256i, qv: __m256i) -> __m256i {
        _mm256_add_epi32(
            _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w as i32), shifts), mask),
            qv,
        )
    }

    /// Narrow two 8×i32 vectors to one 16×i16 vector *in code order*:
    /// `packs_epi32` interleaves 64-bit blocks as `[v0.lo, v1.lo, v0.hi,
    /// v1.hi]`, so a `permute4x64` with block order `[0, 2, 1, 3]`
    /// restores `[v0, v1]`.  Saturating — callers guarantee every code
    /// fits i16, so saturation never fires.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn narrow16(v0: __m256i, v1: __m256i) -> __m256i {
        _mm256_permute4x64_epi64::<0b1101_1000>(_mm256_packs_epi32(v0, v1))
    }

    /// In-register decode of packed codes to i32 (the AVX2 arm of
    /// `unpack_codes_i32`).  Per-word lane layouts are in the module docs;
    /// after every vector loop `t` sits on a word boundary, so the shared
    /// scalar word-walk tail handles the remainder (including partial
    /// trailing words) without any out-of-bounds vector store.
    ///
    /// # Safety
    /// Caller must ensure AVX2; `out.len() == cols` and `words` must hold
    /// at least `ceil(cols / (32/bits))` words.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_i32(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i32]) {
        debug_assert_eq!(out.len(), cols);
        let qv = _mm256_set1_epi32(qmin);
        let po = out.as_mut_ptr();
        let mut t = 0usize;
        match bits {
            4 => {
                let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                let mask = _mm256_set1_epi32(0xF);
                while t + 8 <= cols {
                    _mm256_storeu_si256(po.add(t).cast(), codes8(words[t / 8], shifts, mask, qv));
                    t += 8;
                }
            }
            2 => {
                let lo = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                let hi = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
                let mask = _mm256_set1_epi32(0x3);
                while t + 16 <= cols {
                    let w = words[t / 16];
                    _mm256_storeu_si256(po.add(t).cast(), codes8(w, lo, mask, qv));
                    _mm256_storeu_si256(po.add(t + 8).cast(), codes8(w, hi, mask, qv));
                    t += 16;
                }
            }
            3 => {
                let shifts = _mm256_setr_epi32(0, 3, 6, 9, 12, 15, 18, 21);
                let mask = _mm256_set1_epi32(0x7);
                while t + 10 <= cols {
                    let w = words[t / 10];
                    _mm256_storeu_si256(po.add(t).cast(), codes8(w, shifts, mask, qv));
                    *po.add(t + 8) = qmin + ((w >> 24) & 0x7) as i32;
                    *po.add(t + 9) = qmin + ((w >> 27) & 0x7) as i32;
                    t += 10;
                }
            }
            8 => {
                // LSB-first packing into little-endian words means the byte
                // stream IS the code stream: widen 8 bytes per iteration.
                let pw = words.as_ptr().cast::<u8>();
                while t + 8 <= cols {
                    let bytes = _mm_loadl_epi64(pw.add(t).cast());
                    let v = _mm256_add_epi32(_mm256_cvtepu8_epi32(bytes), qv);
                    _mm256_storeu_si256(po.add(t).cast(), v);
                    t += 8;
                }
            }
            _ => {}
        }
        let cpw = (32 / bits) as usize;
        let mask = (1u32 << bits) - 1;
        while t < cols {
            let mut v = words[t / cpw];
            let lim = cpw.min(cols - t);
            for _ in 0..lim {
                *po.add(t) = qmin + (v & mask) as i32;
                v >>= bits;
                t += 1;
            }
        }
    }

    /// [`unpack_i32`] with an f32 destination: identical lane decode, one
    /// exact `cvtepi32_ps` before the store (every code has `|v| < 2²⁴`).
    ///
    /// # Safety
    /// Same contract as [`unpack_i32`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_f32(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), cols);
        let qv = _mm256_set1_epi32(qmin);
        let po = out.as_mut_ptr();
        let mut t = 0usize;
        match bits {
            4 => {
                let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                let mask = _mm256_set1_epi32(0xF);
                while t + 8 <= cols {
                    let v = codes8(words[t / 8], shifts, mask, qv);
                    _mm256_storeu_ps(po.add(t), _mm256_cvtepi32_ps(v));
                    t += 8;
                }
            }
            2 => {
                let lo = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                let hi = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
                let mask = _mm256_set1_epi32(0x3);
                while t + 16 <= cols {
                    let w = words[t / 16];
                    _mm256_storeu_ps(po.add(t), _mm256_cvtepi32_ps(codes8(w, lo, mask, qv)));
                    _mm256_storeu_ps(po.add(t + 8), _mm256_cvtepi32_ps(codes8(w, hi, mask, qv)));
                    t += 16;
                }
            }
            3 => {
                let shifts = _mm256_setr_epi32(0, 3, 6, 9, 12, 15, 18, 21);
                let mask = _mm256_set1_epi32(0x7);
                while t + 10 <= cols {
                    let w = words[t / 10];
                    let v = codes8(w, shifts, mask, qv);
                    _mm256_storeu_ps(po.add(t), _mm256_cvtepi32_ps(v));
                    *po.add(t + 8) = (qmin + ((w >> 24) & 0x7) as i32) as f32;
                    *po.add(t + 9) = (qmin + ((w >> 27) & 0x7) as i32) as f32;
                    t += 10;
                }
            }
            8 => {
                let pw = words.as_ptr().cast::<u8>();
                while t + 8 <= cols {
                    let bytes = _mm_loadl_epi64(pw.add(t).cast());
                    let v = _mm256_add_epi32(_mm256_cvtepu8_epi32(bytes), qv);
                    _mm256_storeu_ps(po.add(t), _mm256_cvtepi32_ps(v));
                    t += 8;
                }
            }
            _ => {}
        }
        let cpw = (32 / bits) as usize;
        let mask = (1u32 << bits) - 1;
        while t < cols {
            let mut v = words[t / cpw];
            let lim = cpw.min(cols - t);
            for _ in 0..lim {
                *po.add(t) = (qmin + (v & mask) as i32) as f32;
                v >>= bits;
                t += 1;
            }
        }
    }

    /// In-register decode straight to i16 lanes — the madd kernel's feed,
    /// 16 codes per 256-bit store (two decoded i32 vectors narrowed via
    /// [`narrow16`]; one vector + a 128-bit store for 3-bit words).
    ///
    /// # Safety
    /// Same contract as [`unpack_i32`]; additionally every decoded code
    /// must fit i16 (callers gate on `max|code| ≤ i16::MAX`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_i16(words: &[u32], cols: usize, bits: u32, qmin: i32, out: &mut [i16]) {
        debug_assert_eq!(out.len(), cols);
        let qv = _mm256_set1_epi32(qmin);
        let po = out.as_mut_ptr();
        let mut t = 0usize;
        match bits {
            4 => {
                let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                let mask = _mm256_set1_epi32(0xF);
                while t + 16 <= cols {
                    let v0 = codes8(words[t / 8], shifts, mask, qv);
                    let v1 = codes8(words[t / 8 + 1], shifts, mask, qv);
                    _mm256_storeu_si256(po.add(t).cast(), narrow16(v0, v1));
                    t += 16;
                }
            }
            2 => {
                let lo = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                let hi = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
                let mask = _mm256_set1_epi32(0x3);
                while t + 16 <= cols {
                    let w = words[t / 16];
                    let v = narrow16(codes8(w, lo, mask, qv), codes8(w, hi, mask, qv));
                    _mm256_storeu_si256(po.add(t).cast(), v);
                    t += 16;
                }
            }
            3 => {
                let shifts = _mm256_setr_epi32(0, 3, 6, 9, 12, 15, 18, 21);
                let mask = _mm256_set1_epi32(0x7);
                while t + 10 <= cols {
                    let w = words[t / 10];
                    let v = narrow16(codes8(w, shifts, mask, qv), _mm256_setzero_si256());
                    _mm_storeu_si128(po.add(t).cast(), _mm256_castsi256_si128(v));
                    *po.add(t + 8) = (qmin + ((w >> 24) & 0x7) as i32) as i16;
                    *po.add(t + 9) = (qmin + ((w >> 27) & 0x7) as i32) as i16;
                    t += 10;
                }
            }
            8 => {
                let qv16 = _mm256_set1_epi16(qmin as i16);
                let pw = words.as_ptr().cast::<u8>();
                while t + 16 <= cols {
                    let bytes = _mm_loadu_si128(pw.add(t).cast());
                    let v = _mm256_add_epi16(_mm256_cvtepu8_epi16(bytes), qv16);
                    _mm256_storeu_si256(po.add(t).cast(), v);
                    t += 16;
                }
            }
            _ => {}
        }
        let cpw = (32 / bits) as usize;
        let mask = (1u32 << bits) - 1;
        while t < cols {
            let mut v = words[t / cpw];
            let lim = cpw.min(cols - t);
            for _ in 0..lim {
                *po.add(t) = (qmin + (v & mask) as i32) as i16;
                v >>= bits;
                t += 1;
            }
        }
    }

    /// Four NT dots sharing one activation row: per-element chains are
    /// exactly [`dot`]'s (same fmadd order, same hsum, same tail).
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA; all four b-rows must have `x.len()`
    /// elements.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot4(x: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        let k = x.len();
        let k8 = k - k % LANES;
        let px = x.as_ptr();
        let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut t = 0usize;
        while t < k8 {
            let xv = _mm256_loadu_ps(px.add(t));
            a0 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p0.add(t)), a0);
            a1 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p1.add(t)), a1);
            a2 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p2.add(t)), a2);
            a3 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p3.add(t)), a3);
            t += LANES;
        }
        let mut s = [hsum(a0), hsum(a1), hsum(a2), hsum(a3)];
        while t < k {
            let xt = *px.add(t);
            s[0] += xt * *p0.add(t);
            s[1] += xt * *p1.add(t);
            s[2] += xt * *p2.add(t);
            s[3] += xt * *p3.add(t);
            t += 1;
        }
        s
    }

    /// The 2×4 NT register tile: eight vector accumulators, the same
    /// per-element chain as [`dot`]/[`dot4`].
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA; all six rows must have `x0.len()`
    /// elements.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot2x4(
        x0: &[f32],
        x1: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> ([f32; 4], [f32; 4]) {
        let k = x0.len();
        let k8 = k - k % LANES;
        let (px0, px1) = (x0.as_ptr(), x1.as_ptr());
        let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
        let mut a00 = _mm256_setzero_ps();
        let mut a01 = _mm256_setzero_ps();
        let mut a02 = _mm256_setzero_ps();
        let mut a03 = _mm256_setzero_ps();
        let mut a10 = _mm256_setzero_ps();
        let mut a11 = _mm256_setzero_ps();
        let mut a12 = _mm256_setzero_ps();
        let mut a13 = _mm256_setzero_ps();
        let mut t = 0usize;
        while t < k8 {
            let xv0 = _mm256_loadu_ps(px0.add(t));
            let xv1 = _mm256_loadu_ps(px1.add(t));
            let bv0 = _mm256_loadu_ps(p0.add(t));
            let bv1 = _mm256_loadu_ps(p1.add(t));
            let bv2 = _mm256_loadu_ps(p2.add(t));
            let bv3 = _mm256_loadu_ps(p3.add(t));
            a00 = _mm256_fmadd_ps(xv0, bv0, a00);
            a01 = _mm256_fmadd_ps(xv0, bv1, a01);
            a02 = _mm256_fmadd_ps(xv0, bv2, a02);
            a03 = _mm256_fmadd_ps(xv0, bv3, a03);
            a10 = _mm256_fmadd_ps(xv1, bv0, a10);
            a11 = _mm256_fmadd_ps(xv1, bv1, a11);
            a12 = _mm256_fmadd_ps(xv1, bv2, a12);
            a13 = _mm256_fmadd_ps(xv1, bv3, a13);
            t += LANES;
        }
        let mut s0 = [hsum(a00), hsum(a01), hsum(a02), hsum(a03)];
        let mut s1 = [hsum(a10), hsum(a11), hsum(a12), hsum(a13)];
        while t < k {
            let xt0 = *px0.add(t);
            let xt1 = *px1.add(t);
            let b0t = *p0.add(t);
            let b1t = *p1.add(t);
            let b2t = *p2.add(t);
            let b3t = *p3.add(t);
            s0[0] += xt0 * b0t;
            s0[1] += xt0 * b1t;
            s0[2] += xt0 * b2t;
            s0[3] += xt0 * b3t;
            s1[0] += xt1 * b0t;
            s1[1] += xt1 * b1t;
            s1[2] += xt1 * b2t;
            s1[3] += xt1 * b3t;
            t += 1;
        }
        (s0, s1)
    }

    /// Single-row `y = x · Bᵀ`: 1×4 strips of [`dot4`], [`dot`] for the
    /// row tail.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemv_nt(x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
        debug_assert!(x.len() == k && b.len() == r * k && out.len() == r);
        let mut j = 0usize;
        while j + 4 <= r {
            let s = dot4(
                x,
                &b[j * k..(j + 1) * k],
                &b[(j + 1) * k..(j + 2) * k],
                &b[(j + 2) * k..(j + 3) * k],
                &b[(j + 3) * k..(j + 4) * k],
            );
            out[j..j + 4].copy_from_slice(&s);
            j += 4;
        }
        while j < r {
            out[j] = dot(x, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }

    /// Blocked NT panel: 2×4 register tiles, odd-row remainder via the
    /// gemv scheme — both give every element the canonical chain, so the
    /// panel split never changes bits.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_nt_panel(
        a: &[f32],
        b: &[f32],
        k: usize,
        r: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (mhi - mlo) * r);
        let mut i = mlo;
        let mut oi = 0usize;
        while i + 2 <= mhi {
            let x0 = &a[i * k..(i + 1) * k];
            let x1 = &a[(i + 1) * k..(i + 2) * k];
            let (o0, rest) = out[oi * r..].split_at_mut(r);
            let o1 = &mut rest[..r];
            let mut j = 0usize;
            while j + 4 <= r {
                let (s0, s1) = dot2x4(
                    x0,
                    x1,
                    &b[j * k..(j + 1) * k],
                    &b[(j + 1) * k..(j + 2) * k],
                    &b[(j + 2) * k..(j + 3) * k],
                    &b[(j + 3) * k..(j + 4) * k],
                );
                o0[j..j + 4].copy_from_slice(&s0);
                o1[j..j + 4].copy_from_slice(&s1);
                j += 4;
            }
            while j < r {
                let brow = &b[j * k..(j + 1) * k];
                o0[j] = dot(x0, brow);
                o1[j] = dot(x1, brow);
                j += 1;
            }
            i += 2;
            oi += 2;
        }
        if i < mhi {
            gemv_nt(&a[i * k..(i + 1) * k], b, k, r, &mut out[oi * r..(oi + 1) * r]);
        }
    }

    /// One NN output row `out = x · B` (overwrite): columns vectorized
    /// 32-then-8 wide with broadcast `x[t]`, scalar `mul + add` column
    /// tail.  Column `j`'s chain depends only on `(j, c)`.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn nn_row(x: &[f32], b: &[f32], c: usize, out: &mut [f32]) {
        debug_assert!(b.len() == x.len() * c && out.len() == c);
        let pb = b.as_ptr();
        let po = out.as_mut_ptr();
        let c32 = c - c % 32;
        let mut j = 0usize;
        while j < c32 {
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            for (t, &xv) in x.iter().enumerate() {
                let xb = _mm256_set1_ps(xv);
                let base = pb.add(t * c + j);
                a0 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base), a0);
                a1 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(8)), a1);
                a2 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(16)), a2);
                a3 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(24)), a3);
            }
            _mm256_storeu_ps(po.add(j), a0);
            _mm256_storeu_ps(po.add(j + 8), a1);
            _mm256_storeu_ps(po.add(j + 16), a2);
            _mm256_storeu_ps(po.add(j + 24), a3);
            j += 32;
        }
        while j + 8 <= c {
            let mut acc = _mm256_setzero_ps();
            for (t, &xv) in x.iter().enumerate() {
                acc = _mm256_fmadd_ps(_mm256_set1_ps(xv), _mm256_loadu_ps(pb.add(t * c + j)), acc);
            }
            _mm256_storeu_ps(po.add(j), acc);
            j += 8;
        }
        while j < c {
            let mut s = 0.0f32;
            for (t, &xv) in x.iter().enumerate() {
                s += xv * *pb.add(t * c + j);
            }
            *po.add(j) = s;
            j += 1;
        }
    }

    /// Blocked NN panel: independent [`nn_row`] per output row.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_nn_panel(
        a: &[f32],
        b: &[f32],
        k: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (mhi - mlo) * c);
        for (oi, i) in (mlo..mhi).enumerate() {
            nn_row(&a[i * k..(i + 1) * k], b, c, &mut out[oi * c..(oi + 1) * c]);
        }
    }

    /// One TN output row (`out[j] = Σ_t a[t·m + i] · b[t·c + j]`): same
    /// column scheme as [`nn_row`] with a strided broadcast operand.
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn tn_row(a: &[f32], b: &[f32], n: usize, m: usize, c: usize, i: usize, out: &mut [f32]) {
        let pb = b.as_ptr();
        let po = out.as_mut_ptr();
        let c32 = c - c % 32;
        let mut j = 0usize;
        while j < c32 {
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            for t in 0..n {
                let xb = _mm256_set1_ps(a[t * m + i]);
                let base = pb.add(t * c + j);
                a0 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base), a0);
                a1 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(8)), a1);
                a2 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(16)), a2);
                a3 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(base.add(24)), a3);
            }
            _mm256_storeu_ps(po.add(j), a0);
            _mm256_storeu_ps(po.add(j + 8), a1);
            _mm256_storeu_ps(po.add(j + 16), a2);
            _mm256_storeu_ps(po.add(j + 24), a3);
            j += 32;
        }
        while j + 8 <= c {
            let mut acc = _mm256_setzero_ps();
            for t in 0..n {
                acc = _mm256_fmadd_ps(
                    _mm256_set1_ps(a[t * m + i]),
                    _mm256_loadu_ps(pb.add(t * c + j)),
                    acc,
                );
            }
            _mm256_storeu_ps(po.add(j), acc);
            j += 8;
        }
        while j < c {
            let mut s = 0.0f32;
            for t in 0..n {
                s += a[t * m + i] * *pb.add(t * c + j);
            }
            *po.add(j) = s;
            j += 1;
        }
    }

    /// Blocked TN panel: independent [`tn_row`] per output row (row `i` of
    /// the output is column `i` of A).
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_tn_panel(
        a: &[f32],
        b: &[f32],
        n: usize,
        m: usize,
        c: usize,
        mlo: usize,
        mhi: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (mhi - mlo) * c);
        for (oi, i) in (mlo..mhi).enumerate() {
            tn_row(a, b, n, m, c, i, &mut out[oi * c..(oi + 1) * c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    #[test]
    fn detect_and_active_are_stable() {
        assert_eq!(Isa::detect(), Isa::detect());
        assert_eq!(Isa::active(), Isa::active());
        assert_eq!(Isa::Scalar.label(), "scalar");
        assert_eq!(Isa::Avx2.label(), "avx2");
    }

    #[test]
    fn scalar_arm_is_micro_exactly() {
        let mut rng = Pcg32::seeded(11);
        let a = randv(&mut rng, 37);
        let b = randv(&mut rng, 37);
        assert_eq!(dot(Isa::Scalar, &a, &b), micro::dot(&a, &b));
    }

    #[test]
    fn simd_dot_short_inputs_equal_scalar_bitwise() {
        // k < 8 takes only the scalar tail on the AVX2 arm (the vector
        // accumulator hsum-folds to +0.0), so short dots are bit-identical
        // across arms — attention over short KV prefixes depends on this
        // being at least *close*; it happens to be exact.
        let mut rng = Pcg32::seeded(23);
        let isa = Isa::detect();
        for k in 0..8usize {
            let a = randv(&mut rng, k);
            let b = randv(&mut rng, k);
            assert_eq!(dot(isa, &a, &b), micro::dot(&a, &b), "k={k}");
        }
    }

    #[test]
    fn integer_dot_bit_identical_across_arms() {
        let mut rng = Pcg32::seeded(5);
        for k in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<i32> = (0..k).map(|_| rng.below(512) as i32 - 256).collect();
            let b: Vec<i32> = (0..k).map(|_| rng.below(512) as i32 - 256).collect();
            let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(dot_i32(Isa::Scalar, &a, &b) as i64, want, "scalar k={k}");
            assert_eq!(dot_i32(Isa::detect(), &a, &b) as i64, want, "detected k={k}");
        }
    }

    #[test]
    fn i16_madd_dot_bit_identical_across_arms() {
        let mut rng = Pcg32::seeded(17);
        for k in [0usize, 1, 15, 16, 17, 31, 32, 33, 100] {
            let a: Vec<i16> = (0..k).map(|_| rng.below(256) as i16 - 128).collect();
            let b: Vec<i16> = (0..k).map(|_| rng.below(256) as i16 - 128).collect();
            let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(dot_i16_madd(Isa::Scalar, &a, &b) as i64, want, "scalar k={k}");
            assert_eq!(dot_i16_madd(Isa::detect(), &a, &b) as i64, want, "detected k={k}");
        }
    }

    #[test]
    fn in_register_unpack_matches_scalar_walk_all_widths() {
        // One packed row per (bits, cols): random codes, decode on both
        // arms through all three destinations — the values must be
        // bit-identical (decode is pure integer bit manipulation).
        let mut rng = Pcg32::seeded(29);
        for bits in [2u32, 3, 4, 8] {
            let cpw = (32 / bits) as usize;
            let qmin = -(1i32 << (bits - 1));
            for cols in [0usize, 1, cpw - 1, cpw, cpw + 1, 3 * cpw + 3, 61, 64] {
                let words: Vec<u32> = (0..cols.div_ceil(cpw)).map(|_| rng.next_u32()).collect();
                let mut si = vec![0i32; cols];
                let mut vi = vec![0i32; cols];
                unpack_codes_i32(Isa::Scalar, &words, cols, bits, qmin, &mut si);
                unpack_codes_i32(Isa::detect(), &words, cols, bits, qmin, &mut vi);
                assert_eq!(si, vi, "i32 bits={bits} cols={cols}");
                let mut sf = vec![0f32; cols];
                let mut vf = vec![0f32; cols];
                unpack_codes_f32(Isa::Scalar, &words, cols, bits, qmin, &mut sf);
                unpack_codes_f32(Isa::detect(), &words, cols, bits, qmin, &mut vf);
                assert_eq!(sf, vf, "f32 bits={bits} cols={cols}");
                let mut sh = vec![0i16; cols];
                let mut vh = vec![0i16; cols];
                unpack_codes_i16(Isa::Scalar, &words, cols, bits, qmin, &mut sh);
                unpack_codes_i16(Isa::detect(), &words, cols, bits, qmin, &mut vh);
                assert_eq!(sh, vh, "i16 bits={bits} cols={cols}");
                for t in 0..cols {
                    assert_eq!(si[t], sf[t] as i32, "f32 exactness bits={bits} t={t}");
                    assert_eq!(si[t], sh[t] as i32, "i16 range bits={bits} t={t}");
                }
            }
        }
    }
}
