//! Unified blocked-GEMM kernel core (DESIGN.md §Compute-Kernels).
//!
//! Every matmul in the repo — FlexRound reconstruction forwards/backwards
//! (`Ŷ = X̃·Ŵᵀ` and its cotangents), block attention/MLP projections, the
//! fused dequant-GEMM serving path, KV-cached decode, and the eval lm-head
//! projection — bottoms out here:
//!
//! * [`micro`] — the register-tiled micro-kernel family ([`MR`]×[`NR`]
//!   accumulator tiles, shared [`dot`]/gemv cores) behind [`gemm_nt`],
//!   [`gemm_nn`] and [`gemm_tn`];
//! * [`dispatch`] — the single serial/parallel policy ([`Dispatch`]):
//!   one flops threshold ([`PAR_FLOPS_MIN`]), one output-row-panel fan-out
//!   over [`crate::util::pool`];
//! * batch-1 inputs skip tile bookkeeping entirely via the [`gemv_nt`] /
//!   [`gemv_nn`] fast paths — the decode hot loop is one row at a time;
//! * [`gemm_nt_ref`] / [`gemm_nn_ref`] / [`gemm_tn_ref`] — the naive triple
//!   loops the blocked kernels replaced, retained **only** as correctness
//!   oracles for `rust/tests/kernels.rs` and as the bench baseline for
//!   `cargo bench --bench kernels`.
//!
//! All kernels keep one accumulator per output element, contraction index
//! ascending, so blocked ≡ naive, serial ≡ parallel, and gemv ≡ batched-row
//! results are bit-identical (see `micro`'s module docs for why that
//! matters to the repo's parity pins).

pub mod dispatch;
pub mod micro;

pub use dispatch::{Dispatch, PAR_FLOPS_MIN};
pub use micro::{dot, gemv_nn, gemv_nt, MR, NR};

/// `C[m, r] = A[m, k] · B[r, k]ᵀ` — both operands row-contiguous (the
/// reconstruction and serving orientation).  Batch-1 dispatches to
/// [`gemv_nt`]; larger problems run the blocked kernel under `d`'s policy.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, r: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == m * k && b.len() == r * k);
    let mut out = vec![0.0f32; m * r];
    if m == 1 {
        micro::gemv_nt(a, b, k, r, &mut out);
        return out;
    }
    d.run_rows(m, r, m * k * r, &mut out, |lo, hi, panel| {
        micro::gemm_nt_panel(a, b, k, r, lo, hi, panel)
    });
    out
}

/// Serial blocked NT GEMM into a caller-owned buffer (`(m, r)` row-major;
/// **overwrite semantics** — every element of `out` is assigned exactly
/// once, so the caller need not zero it): the shared tile loop the fused
/// dequant kernel runs over its decoded weight-row panels
/// (`infer::kernels`).
pub fn gemm_nt_into(a: &[f32], b: &[f32], m: usize, k: usize, r: usize, out: &mut [f32]) {
    micro::gemm_nt_panel(a, b, k, r, 0, m, out)
}

/// `C[m, c] = A[m, k] · B[k, c]` (the activation-cotangent orientation
/// `∂L/∂X = G · Ŵ`).
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, c: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == m * k && b.len() == k * c);
    let mut out = vec![0.0f32; m * c];
    if m == 1 {
        micro::gemv_nn(a, b, k, c, &mut out);
        return out;
    }
    d.run_rows(m, c, m * k * c, &mut out, |lo, hi, panel| {
        micro::gemm_nn_panel(a, b, k, c, lo, hi, panel)
    });
    out
}

/// `C[m, c] = A[n, m]ᵀ · B[n, c]` (the weight-cotangent orientation
/// `∂L/∂Ŵ = Gᵀ · X`).
pub fn gemm_tn(a: &[f32], b: &[f32], n: usize, m: usize, c: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == n * m && b.len() == n * c);
    let mut out = vec![0.0f32; m * c];
    d.run_rows(m, c, n * m * c, &mut out, |lo, hi, panel| {
        micro::gemm_tn_panel(a, b, n, m, c, lo, hi, panel)
    });
    out
}

// ---------------------------------------------------------------------------
// Naive oracles — the `Tensor::matmul_*` triple loops these kernels
// replaced, retained for tests and benches only.  No production path calls
// these.  One deliberate difference from the pre-refactor loops: the old
// NN/TN kernels skipped `a == 0.0` terms, which the oracles (and the new
// kernels) do not — so `0·∞ = NaN` propagates instead of vanishing and
// `-0.0` sums are IEEE-exact.  The oracles pin the *plain-math* semantics,
// not the old sparse-skip behavior.
// ---------------------------------------------------------------------------

/// Naive NT triple loop (test oracle / bench baseline).
pub fn gemm_nt_ref(a: &[f32], b: &[f32], m: usize, k: usize, r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * r];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..r {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            out[i * r + j] = acc;
        }
    }
    out
}

/// Naive NN triple loop (test oracle / bench baseline).
pub fn gemm_nn_ref(a: &[f32], b: &[f32], m: usize, k: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * c];
    for i in 0..m {
        let orow = &mut out[i * c..(i + 1) * c];
        for t in 0..k {
            let av = a[i * k + t];
            let brow = &b[t * c..(t + 1) * c];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive TN triple loop (test oracle / bench baseline).
pub fn gemm_tn_ref(a: &[f32], b: &[f32], n: usize, m: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * c];
    for t in 0..n {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * c..(t + 1) * c];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * c..(i + 1) * c];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    #[test]
    fn blocked_matches_oracle_on_tile_edges() {
        // dims straddling the 4×8 tile: full tiles, row edge, column edge
        let mut rng = Pcg32::seeded(31);
        for (m, k, r) in [(4, 8, 8), (5, 3, 9), (1, 7, 13), (8, 16, 8), (3, 1, 1), (9, 5, 17)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, r * k);
            assert_eq!(
                gemm_nt(&a, &b, m, k, r, &Dispatch::serial()),
                gemm_nt_ref(&a, &b, m, k, r),
                "NT {m}×{k}·{r}ᵀ"
            );
            let bnn = randv(&mut rng, k * r);
            assert_eq!(
                gemm_nn(&a, &bnn, m, k, r, &Dispatch::serial()),
                gemm_nn_ref(&a, &bnn, m, k, r),
                "NN {m}×{k}·{k}×{r}"
            );
            let atn = randv(&mut rng, k * m);
            let btn = randv(&mut rng, k * r);
            assert_eq!(
                gemm_tn(&atn, &btn, k, m, r, &Dispatch::serial()),
                gemm_tn_ref(&atn, &btn, k, m, r),
                "TN ({k}×{m})ᵀ·{k}×{r}"
            );
        }
    }

    #[test]
    fn k_zero_yields_zeros() {
        let out = gemm_nt(&[], &[], 3, 0, 5, &Dispatch::auto());
        assert_eq!(out, vec![0.0; 15]);
        let out = gemm_tn(&[], &[], 0, 2, 2, &Dispatch::auto());
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn gemv_fast_path_equals_batched_row() {
        let mut rng = Pcg32::seeded(77);
        let (k, r) = (33, 21);
        let x = randv(&mut rng, k);
        let b = randv(&mut rng, r * k);
        let via_gemm = gemm_nt(&x, &b, 1, k, r, &Dispatch::auto());
        let mut via_gemv = vec![0.0f32; r];
        gemv_nt(&x, &b, k, r, &mut via_gemv);
        assert_eq!(via_gemm, via_gemv);
        // the same row inside a batch produces the same bits
        let mut batch = x.clone();
        batch.extend(randv(&mut rng, 2 * k));
        let full = gemm_nt(&batch, &b, 3, k, r, &Dispatch::serial());
        assert_eq!(&full[..r], via_gemv.as_slice(), "batch-1 ≡ batched row 0");
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, r) = (64, 48, 40); // above PAR_FLOPS_MIN
        assert!(m * k * r >= PAR_FLOPS_MIN);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, r * k);
        assert_eq!(
            gemm_nt(&a, &b, m, k, r, &Dispatch::serial()),
            gemm_nt(&a, &b, m, k, r, &Dispatch::new(4)),
        );
        let bnn = randv(&mut rng, k * r);
        assert_eq!(
            gemm_nn(&a, &bnn, m, k, r, &Dispatch::serial()),
            gemm_nn(&a, &bnn, m, k, r, &Dispatch::new(4)),
        );
        let atn = randv(&mut rng, k * m);
        assert_eq!(
            gemm_tn(&atn, &bnn, k, m, r, &Dispatch::serial()),
            gemm_tn(&atn, &bnn, k, m, r, &Dispatch::new(4)),
        );
    }

    #[test]
    fn dot_is_the_sequential_contraction() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), ((4.0 + 10.0) + 18.0));
        assert_eq!(dot(&[], &[]), 0.0);
    }
}
