//! Unified blocked-GEMM kernel core (DESIGN.md §Compute-Kernels).
//!
//! Every matmul in the repo — FlexRound reconstruction forwards/backwards
//! (`Ŷ = X̃·Ŵᵀ` and its cotangents), block attention/MLP projections, the
//! fused dequant-GEMM serving path, KV-cached decode, and the eval lm-head
//! projection — bottoms out here:
//!
//! * [`micro`] — the scalar register-tiled micro-kernel family
//!   ([`MR`]×[`NR`] accumulator tiles, shared [`dot`]/gemv cores): the
//!   always-available ISA arm *and* the selectable oracle the SIMD arm is
//!   differentially tested against;
//! * [`simd`] — the runtime ISA probe ([`Isa`]) plus the AVX2 kernels; every
//!   routing function there takes an explicit [`Isa`], and
//!   `FLEXROUND_FORCE_SCALAR` pins the whole process to the scalar arm;
//! * [`dispatch`] — the single serial/parallel policy ([`Dispatch`]):
//!   one flops threshold ([`PAR_FLOPS_MIN`]), one output-row-panel fan-out
//!   over [`crate::util::pool`], and (since the SIMD PR) the ISA arm the
//!   kernels run on ([`Dispatch::isa`]);
//! * batch-1 inputs skip tile bookkeeping entirely via the [`gemv_nt`] /
//!   [`gemv_nn`] fast paths — the decode hot loop is one row at a time;
//! * [`gemm_nt_ref`] / [`gemm_nn_ref`] / [`gemm_tn_ref`] — the naive triple
//!   loops the blocked kernels replaced, retained **only** as correctness
//!   oracles for `rust/tests/kernels.rs` and as the bench baseline for
//!   `cargo bench --bench kernels`.
//!
//! Within either ISA arm, every kernel gives each output element one fixed
//! reduction tree (scalar: one accumulator, contraction ascending; AVX2:
//! the per-element scheme in [`simd`]'s module docs), so serial ≡ parallel
//! and gemv ≡ batched-row stay bit-identical on both arms.  Blocked ≡ naive
//! is pinned with `==` on the *scalar* arm; the AVX2 arm is held to the
//! scalar oracle under a ULP budget instead, because FMA contracts each
//! multiply-add into one rounding (`rust/tests/kernels.rs`).

pub mod dispatch;
pub mod micro;
pub mod simd;

pub use dispatch::{Dispatch, PAR_FLOPS_MIN};
pub use micro::{MR, NR};
pub use simd::Isa;

/// Sequential dot product on the active ISA arm — THE canonical
/// contraction, shared by the gemv paths and the attention score core
/// (`block::attn_score_row`).  Pin an arm explicitly via [`simd::dot`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(Isa::active(), a, b)
}

/// Single-row `y = x · Bᵀ` on the active ISA arm (overwrite semantics) —
/// the batch-1 fast path behind decode-step projections and one-row
/// lm-head chunks.  Pin an arm explicitly via [`simd::gemv_nt`].
#[inline]
pub fn gemv_nt(x: &[f32], b: &[f32], k: usize, r: usize, out: &mut [f32]) {
    simd::gemv_nt(Isa::active(), x, b, k, r, out)
}

/// Single-row `y = x · B` on the active ISA arm (`out` pre-zeroed).  Pin an
/// arm explicitly via [`simd::gemv_nn`].
#[inline]
pub fn gemv_nn(x: &[f32], b: &[f32], k: usize, c: usize, out: &mut [f32]) {
    simd::gemv_nn(Isa::active(), x, b, k, c, out)
}

/// `C[m, r] = A[m, k] · B[r, k]ᵀ` — both operands row-contiguous (the
/// reconstruction and serving orientation).  Batch-1 dispatches to
/// [`gemv_nt`]; larger problems run the blocked kernel under `d`'s policy
/// (worker budget *and* ISA arm).
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, r: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == m * k && b.len() == r * k);
    let mut out = vec![0.0f32; m * r];
    if m == 1 {
        simd::gemv_nt(d.isa(), a, b, k, r, &mut out);
        return out;
    }
    d.run_rows(m, r, m * k * r, &mut out, |lo, hi, panel| {
        simd::gemm_nt_panel(d.isa(), a, b, k, r, lo, hi, panel)
    });
    out
}

/// Serial blocked NT GEMM on an explicit ISA arm into a caller-owned
/// buffer (`(m, r)` row-major; **overwrite semantics** — every element of
/// `out` is assigned exactly once, so the caller need not zero it): the
/// shared tile loop the fused dequant kernel runs over its decoded
/// weight-row panels (`infer::kernels`).
pub fn gemm_nt_into(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, r: usize, out: &mut [f32]) {
    simd::gemm_nt_panel(isa, a, b, k, r, 0, m, out)
}

/// `C[m, c] = A[m, k] · B[k, c]` (the activation-cotangent orientation
/// `∂L/∂X = G · Ŵ`).
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, c: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == m * k && b.len() == k * c);
    let mut out = vec![0.0f32; m * c];
    if m == 1 {
        simd::gemv_nn(d.isa(), a, b, k, c, &mut out);
        return out;
    }
    d.run_rows(m, c, m * k * c, &mut out, |lo, hi, panel| {
        simd::gemm_nn_panel(d.isa(), a, b, k, c, lo, hi, panel)
    });
    out
}

/// `C[m, c] = A[n, m]ᵀ · B[n, c]` (the weight-cotangent orientation
/// `∂L/∂Ŵ = Gᵀ · X`).
pub fn gemm_tn(a: &[f32], b: &[f32], n: usize, m: usize, c: usize, d: &Dispatch) -> Vec<f32> {
    debug_assert!(a.len() == n * m && b.len() == n * c);
    let mut out = vec![0.0f32; m * c];
    d.run_rows(m, c, n * m * c, &mut out, |lo, hi, panel| {
        simd::gemm_tn_panel(d.isa(), a, b, n, m, c, lo, hi, panel)
    });
    out
}

// ---------------------------------------------------------------------------
// Naive oracles — the `Tensor::matmul_*` triple loops these kernels
// replaced, retained for tests and benches only.  No production path calls
// these.  One deliberate difference from the pre-refactor loops: the old
// NN/TN kernels skipped `a == 0.0` terms, which the oracles (and the new
// kernels) do not — so `0·∞ = NaN` propagates instead of vanishing and
// `-0.0` sums are IEEE-exact.  The oracles pin the *plain-math* semantics,
// not the old sparse-skip behavior.
// ---------------------------------------------------------------------------

/// Naive NT triple loop (test oracle / bench baseline).
pub fn gemm_nt_ref(a: &[f32], b: &[f32], m: usize, k: usize, r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * r];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..r {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            out[i * r + j] = acc;
        }
    }
    out
}

/// Naive NN triple loop (test oracle / bench baseline).
pub fn gemm_nn_ref(a: &[f32], b: &[f32], m: usize, k: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * c];
    for i in 0..m {
        let orow = &mut out[i * c..(i + 1) * c];
        for t in 0..k {
            let av = a[i * k + t];
            let brow = &b[t * c..(t + 1) * c];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive TN triple loop (test oracle / bench baseline).
pub fn gemm_tn_ref(a: &[f32], b: &[f32], n: usize, m: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * c];
    for t in 0..n {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * c..(t + 1) * c];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * c..(i + 1) * c];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    #[test]
    fn blocked_matches_oracle_on_tile_edges() {
        // dims straddling the 4×8 tile: full tiles, row edge, column edge.
        // Exact `==` is a *scalar-arm* pin: the SIMD arm uses FMA, so it is
        // held to the oracle under a ULP budget in rust/tests/kernels.rs
        // instead.
        let scalar = Dispatch::serial().with_isa(Isa::Scalar);
        let mut rng = Pcg32::seeded(31);
        for (m, k, r) in [(4, 8, 8), (5, 3, 9), (1, 7, 13), (8, 16, 8), (3, 1, 1), (9, 5, 17)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, r * k);
            assert_eq!(
                gemm_nt(&a, &b, m, k, r, &scalar),
                gemm_nt_ref(&a, &b, m, k, r),
                "NT {m}×{k}·{r}ᵀ"
            );
            let bnn = randv(&mut rng, k * r);
            assert_eq!(
                gemm_nn(&a, &bnn, m, k, r, &scalar),
                gemm_nn_ref(&a, &bnn, m, k, r),
                "NN {m}×{k}·{k}×{r}"
            );
            let atn = randv(&mut rng, k * m);
            let btn = randv(&mut rng, k * r);
            assert_eq!(
                gemm_tn(&atn, &btn, k, m, r, &scalar),
                gemm_tn_ref(&atn, &btn, k, m, r),
                "TN ({k}×{m})ᵀ·{k}×{r}"
            );
        }
    }

    #[test]
    fn k_zero_yields_zeros() {
        let out = gemm_nt(&[], &[], 3, 0, 5, &Dispatch::auto());
        assert_eq!(out, vec![0.0; 15]);
        let out = gemm_tn(&[], &[], 0, 2, 2, &Dispatch::auto());
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn gemv_fast_path_equals_batched_row() {
        // per-arm identity: the gemv core and the tile family give an
        // element the same reduction tree on whichever arm is selected
        let mut rng = Pcg32::seeded(77);
        let (k, r) = (33, 21);
        let x = randv(&mut rng, k);
        let b = randv(&mut rng, r * k);
        let mut batch = x.clone();
        batch.extend(randv(&mut rng, 2 * k));
        for isa in [Isa::Scalar, Isa::detect()] {
            let d = Dispatch::serial().with_isa(isa);
            let via_gemm = gemm_nt(&x, &b, 1, k, r, &d);
            let mut via_gemv = vec![0.0f32; r];
            simd::gemv_nt(isa, &x, &b, k, r, &mut via_gemv);
            assert_eq!(via_gemm, via_gemv, "{} gemv ≠ m==1 gemm", isa.label());
            // the same row inside a batch produces the same bits
            let full = gemm_nt(&batch, &b, 3, k, r, &d);
            assert_eq!(&full[..r], via_gemv.as_slice(), "{} batch-1 ≡ row 0", isa.label());
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, r) = (64, 48, 40); // above PAR_FLOPS_MIN
        assert!(m * k * r >= PAR_FLOPS_MIN);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, r * k);
        let bnn = randv(&mut rng, k * r);
        let atn = randv(&mut rng, k * m);
        for isa in [Isa::Scalar, Isa::detect()] {
            let s = Dispatch::serial().with_isa(isa);
            let p = Dispatch::new(4).with_isa(isa);
            assert_eq!(
                gemm_nt(&a, &b, m, k, r, &s),
                gemm_nt(&a, &b, m, k, r, &p),
                "NT {}",
                isa.label()
            );
            assert_eq!(
                gemm_nn(&a, &bnn, m, k, r, &s),
                gemm_nn(&a, &bnn, m, k, r, &p),
                "NN {}",
                isa.label()
            );
            assert_eq!(
                gemm_tn(&atn, &bnn, k, m, r, &s),
                gemm_tn(&atn, &bnn, k, m, r, &p),
                "TN {}",
                isa.label()
            );
        }
    }

    #[test]
    fn dot_is_the_sequential_contraction() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), ((4.0 + 10.0) + 18.0));
        assert_eq!(dot(&[], &[]), 0.0);
    }
}
