//! Layered configuration: TOML-subset files + CLI `--set key=value`
//! overrides + typed accessors with defaults.
//!
//! The supported TOML subset covers what experiment configs need:
//! `[section]` headers (one level), `key = value` with strings, numbers,
//! booleans, and homogeneous inline arrays, plus `#` comments.  Keys are
//! addressed as `"section.key"`.

use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::Path;

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Layered key-value config; later layers override earlier ones.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse and merge a TOML-subset file.
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        self.load_str(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    pub fn load_str(&mut self, text: &str) -> Result<()> {
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            self.map.insert(full, val);
        }
        Ok(())
    }

    /// Apply a `key=value` CLI override (value parsed like a TOML value;
    /// bare words become strings).
    pub fn set_override(&mut self, kv: &str) -> Result<()> {
        let eq = kv.find('=').ok_or_else(|| anyhow!("override must be key=value: {kv:?}"))?;
        let key = kv[..eq].trim().to_string();
        let raw = kv[eq + 1..].trim();
        let val = parse_value(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.map.insert(key, val);
        Ok(())
    }

    pub fn set(&mut self, key: &str, val: Value) {
        self.map.insert(key.to_string(), val);
    }

    // ---- typed getters ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.map
            .get(key)
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.map.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.f64(key, default as f64) as usize
    }

    pub fn boolean(&self, key: &str, default: bool) -> bool {
        self.map.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    pub fn list_str(&self, key: &str) -> Option<Vec<String>> {
        match self.map.get(key)? {
            Value::List(v) => v.iter().map(|x| x.as_str().ok().map(str::to_string)).collect(),
            Value::Str(s) => Some(s.split(',').map(|t| t.trim().to_string()).collect()),
            _ => None,
        }
    }

    pub fn list_usize(&self, key: &str) -> Option<Vec<usize>> {
        match self.map.get(key)? {
            Value::List(v) => v.iter().map(|x| x.as_f64().ok().map(|n| n as usize)).collect(),
            Value::Num(n) => Some(vec![*n as usize]),
            Value::Str(s) => s.split(',').map(|t| t.trim().parse::<usize>().ok()).collect(),
            _ => None,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Value::List(vec![]));
        }
        let items: Result<Vec<Value>> = split_top(inner).iter().map(|t| parse_value(t.trim())).collect();
        return Ok(Value::List(items?));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("cannot parse value {s:?}"))
}

fn split_top(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let mut c = Config::new();
        c.load_str(
            r#"
# experiment config
name = "t2"            # inline comment
[quant]
bits = [2, 3, 4]
lr = 2e-3
qdrop = true
model = "tinymobilenet"
"#,
        )
        .unwrap();
        assert_eq!(c.str("name", ""), "t2");
        assert_eq!(c.list_usize("quant.bits").unwrap(), vec![2, 3, 4]);
        assert!((c.f64("quant.lr", 0.0) - 2e-3).abs() < 1e-12);
        assert!(c.boolean("quant.qdrop", false));
        assert_eq!(c.str("quant.model", ""), "tinymobilenet");
        assert_eq!(c.usize("quant.iters", 100), 100); // default
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::new();
        c.load_str("[a]\nx = 1\n").unwrap();
        c.set_override("a.x=5").unwrap();
        assert_eq!(c.usize("a.x", 0), 5);
        c.set_override("a.name=hello").unwrap();
        assert_eq!(c.str("a.name", ""), "hello");
        assert!(c.set_override("garbage").is_err());
    }

    #[test]
    fn comment_inside_string() {
        let mut c = Config::new();
        c.load_str("k = \"a#b\"\n").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }

    #[test]
    fn malformed_rejected() {
        let mut c = Config::new();
        assert!(c.load_str("[bad\n").is_err());
        assert!(c.load_str("novalue\n").is_err());
        assert!(c.load_str("k = @@\n").is_err());
    }

    #[test]
    fn list_of_strings() {
        let mut c = Config::new();
        c.load_str("methods = [\"rtn\", \"flexround\"]\n").unwrap();
        assert_eq!(c.list_str("methods").unwrap(), vec!["rtn", "flexround"]);
    }
}
