//! PCG32 pseudo-random generator (O'Neill 2014) + SplitMix64 seeding.
//!
//! Deterministic across platforms — calibration minibatch sampling, QDrop
//! seeds, and the property-test harness all flow from here, so a PTQ run is
//! exactly reproducible from its config seed.

/// PCG-XSH-RR 64/32.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// `n` distinct indices from [0, pop) (Fisher–Yates over a scratch vec
    /// when n is a large fraction, rejection otherwise).
    pub fn sample_indices(&mut self, pop: usize, n: usize) -> Vec<usize> {
        assert!(n <= pop);
        if n * 3 >= pop {
            let mut v: Vec<usize> = (0..pop).collect();
            for i in 0..n {
                let j = i + self.below((pop - i) as u32) as usize;
                v.swap(i, j);
            }
            v.truncate(n);
            v
        } else {
            let mut seen = std::collections::HashSet::with_capacity(n);
            let mut out = Vec::with_capacity(n);
            while out.len() < n {
                let i = self.below(pop as u32) as usize;
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = (self.next_f32()).max(1e-9);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fresh per-purpose stream derived from this generator (cheap fork).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ splitmix64(tag), tag | 1)
    }
}

/// SplitMix64 — seed scrambler.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(3);
        for &(pop, n) in &[(10usize, 10usize), (1000, 32), (50, 25)] {
            let idx = r.sample_indices(pop, n);
            assert_eq!(idx.len(), n);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), n);
            assert!(idx.iter().all(|&i| i < pop));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
