//! Streaming statistics + the micro-benchmark harness (criterion is not in
//! the vendored crate set, so `cargo bench` targets use this instead).

use std::time::{Duration, Instant};

/// Welford online mean/variance plus extrema.
#[derive(Clone, Debug, Default)]
pub struct Stream {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stream {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (nearest-rank, ceil convention).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
    samples[rank.saturating_sub(1).min(samples.len() - 1)]
}

/// Benchmark result (all times in seconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.min),
        )
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}µs", secs * 1e6)
    }
}

/// Time `f` adaptively: warm up, then run until `budget` is spent or
/// `max_iters` reached; reports robust percentiles.
pub fn bench(name: &str, budget: Duration, max_iters: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let w0 = Instant::now();
    f();
    let warm = w0.elapsed();
    let mut times = Vec::new();
    let start = Instant::now();
    let mut iters = 0;
    while iters < max_iters && (start.elapsed() < budget || iters < 3) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        iters += 1;
    }
    let _ = warm;
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let p50 = percentile(&mut times.clone(), 50.0);
    let p95 = percentile(&mut times, 95.0);
    BenchResult { name: name.to_string(), iters, mean, p50, p95, min }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford() {
        let mut s = Stream::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentiles() {
        let mut v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut v, 50.0), 50.0);
        assert_eq!(percentile(&mut v.clone(), 0.0), 1.0);
        assert_eq!(percentile(&mut v, 100.0), 100.0);
    }

    #[test]
    fn bench_runs() {
        let r = bench("noop", Duration::from_millis(5), 1000, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean >= 0.0);
        assert!(!r.report().is_empty());
    }
}
