//! Randomized property-test harness (proptest is not vendored).
//!
//! No shrinking — failures print the seed and case index so any run can be
//! reproduced exactly (`Pcg32` is platform-deterministic).  Used by the unit
//! tests to check quantizer invariants over thousands of random tensors.

use super::rng::Pcg32;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
    pub name: &'static str,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Self { cases: 256, seed: 0xF1E2_D3C4, name }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run `f` on `cases` independent generators; panic with a reproducible
    /// tag on the first failure.
    pub fn check(self, f: impl Fn(&mut Pcg32) -> Result<(), String>) {
        for case in 0..self.cases {
            let mut rng = Pcg32::new(self.seed ^ case as u64, 99);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property {:?} failed at case {case} (seed {:#x}): {msg}",
                    self.name, self.seed
                );
            }
        }
    }
}

/// Random weight-like vector: mixture of scales so quantizers see both
/// sub-unit and multi-unit magnitudes (the MobileNet-vs-ResNet regimes).
pub fn gen_weights(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let scale = match rng.below(4) {
        0 => 0.05,
        1 => 0.3,
        2 => 1.0,
        _ => 3.0,
    };
    (0..n).map(|_| rng.next_normal() * scale).collect()
}

/// Random (rows, cols) within a bound.
pub fn gen_dims(rng: &mut Pcg32, max: usize) -> (usize, usize) {
    (1 + rng.below(max as u32) as usize, 1 + rng.below(max as u32) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_passes_trivial() {
        Prop::new("trivial").cases(32).check(|rng| {
            let x = rng.next_f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn harness_reports_failure() {
        Prop::new("fails").cases(8).check(|_| Err("boom".into()));
    }

    #[test]
    fn generators_sane() {
        let mut rng = Pcg32::seeded(1);
        let w = gen_weights(&mut rng, 100);
        assert_eq!(w.len(), 100);
        let (r, c) = gen_dims(&mut rng, 16);
        assert!(r >= 1 && r <= 16 && c >= 1 && c <= 16);
    }
}
