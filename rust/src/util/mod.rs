//! Coordinator utilities built from scratch (the vendored crate set has no
//! rand / rayon / proptest): a PCG32 RNG, streaming statistics, a worker
//! thread pool, and a randomized property-test harness.

pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
