//! Coordinator utilities built from scratch (the vendored crate set has no
//! rand / rayon / proptest): a PCG32 RNG, streaming statistics, a worker
//! thread pool, a randomized property-test harness, and f32 ULP distance
//! for the SIMD differential kernel harness.

pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod ulp;
