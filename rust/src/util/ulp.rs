//! ULP (units-in-the-last-place) distance between f32 values — the
//! tolerance currency of the SIMD differential kernel harness
//! (`rust/tests/kernels.rs`).
//!
//! The AVX2 kernels fuse each multiply-add into one rounding (FMA), so
//! their results differ from the scalar oracles by a few last-place bits —
//! a *relative* error measure.  Absolute tolerances either drown small
//! outputs or reject large ones; ULP distance is scale-free.  The harness
//! pairs a small ULP budget with an absolute escape hatch proportional to
//! `Σ|aₜ·bₜ|` for catastrophically cancelled outputs, where relative error
//! is unbounded for *any* summation order and ULP distance is meaningless.

/// Map an f32 onto the integer line such that consecutive finite floats are
/// consecutive integers and ordering is preserved across zero (−0.0 and
/// +0.0 both land on 0).
fn monotone(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 == 0 {
        b as i64
    } else {
        -((b & 0x7fff_ffff) as i64)
    }
}

/// Bit-space distance between two f32 values in units of last place:
/// 0 for equal values (including `-0.0` vs `+0.0`), 1 for adjacent floats,
/// `u32::MAX` when either side is NaN.  Signs may differ — the distance
/// then counts through zero, so tiny straddling values stay close.
pub fn ulp_diff(a: f32, b: f32) -> u32 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u32::MAX;
    }
    let d = (monotone(a) - monotone(b)).unsigned_abs();
    d.min(u32::MAX as u64) as u32
}

/// Largest element-wise [`ulp_diff`] over two equal-length slices.
pub fn max_ulp(a: &[f32], b: &[f32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| ulp_diff(x, y)).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_floats_are_one_ulp_apart() {
        let x = 1.0f32;
        let next = f32::from_bits(x.to_bits() + 1);
        assert_eq!(ulp_diff(x, next), 1);
        assert_eq!(ulp_diff(next, x), 1, "symmetric");
        assert_eq!(ulp_diff(x, x), 0);
    }

    #[test]
    fn signed_zero_and_sign_straddle() {
        assert_eq!(ulp_diff(0.0, -0.0), 0, "±0.0 compare equal");
        let tiny = f32::from_bits(1); // smallest positive subnormal
        assert_eq!(ulp_diff(tiny, -tiny), 2, "distance counts through zero");
        assert_eq!(ulp_diff(tiny, 0.0), 1);
    }

    #[test]
    fn nan_and_infinity() {
        assert_eq!(ulp_diff(f32::NAN, 1.0), u32::MAX);
        assert_eq!(ulp_diff(1.0, f32::NAN), u32::MAX);
        assert_eq!(ulp_diff(f32::INFINITY, f32::MAX), 1, "inf is one past MAX");
        assert_eq!(ulp_diff(f32::INFINITY, f32::NEG_INFINITY), u32::MAX);
    }

    #[test]
    fn slice_max() {
        let a = [1.0f32, 2.0, 3.0];
        let mut b = a;
        assert_eq!(max_ulp(&a, &b), 0);
        b[1] = f32::from_bits(b[1].to_bits() + 3);
        assert_eq!(max_ulp(&a, &b), 3);
        assert_eq!(max_ulp(&[], &[]), 0);
    }
}
