//! A small scoped worker pool over std threads (rayon is not vendored).
//!
//! The PJRT client itself is single-threaded per executable here, but data
//! preparation, metric reduction, the analysis fan-outs (grid-shift
//! histograms over many layers), and the `linalg` dispatch policy's
//! output-row panels ([`par_panels`]) all parallelize across units.
//!
//! Scheduling is FIFO: jobs *start* in submission order, so a long-running
//! early job overlaps the tail instead of being picked up last (the queue
//! used to pop LIFO from the back of a `Vec`, which ran the first-submitted
//! — typically largest — job on the last free worker).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` closures on up to `workers` threads; returns results in job
/// order.  Jobs are *started* in submission (FIFO) order.  Panics in jobs
/// are propagated as Err strings.
pub fn run_jobs<T: Send + 'static>(
    workers: usize,
    jobs: Vec<Box<dyn FnOnce() -> T + Send>>,
) -> Vec<T> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let queue = Arc::new(Mutex::new(jobs.into_iter().enumerate().collect::<VecDeque<_>>()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            s.spawn(move || loop {
                let job = queue.lock().expect("queue poisoned").pop_front();
                match job {
                    Some((i, f)) => {
                        let r = f();
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("job dropped")).collect()
    })
}

/// Parallel map over a slice with index (FIFO by construction: workers pull
/// the next unclaimed index off a shared counter).
pub fn par_map<I: Sync, T: Send + 'static>(
    workers: usize,
    items: &[I],
    f: impl Fn(usize, &I) -> T + Sync + Send,
) -> Vec<T> {
    std::thread::scope(|s| {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = workers.clamp(1, n);
        let next = Arc::new(Mutex::new(0usize));
        let mut handles = Vec::new();
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let f = &f;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                loop {
                    let i = {
                        let mut g = next.lock().expect("poisoned");
                        let i = *g;
                        *g += 1;
                        i
                    };
                    if i >= n {
                        return out;
                    }
                    out.push((i, f(i, &items[i])));
                }
            }));
        }
        let mut all: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for h in handles {
            for (i, v) in h.join().expect("worker panicked") {
                all[i] = Some(v);
            }
        }
        all.into_iter().map(|o| o.expect("missing result")).collect()
    })
}

/// Run `f` over disjoint row panels of the `(rows, cols)` row-major buffer
/// `buf`, one scoped worker thread per range — the fan-out primitive behind
/// `linalg::Dispatch`.  `ranges` must be ascending and non-overlapping
/// (`linalg::Dispatch::panels` produces exactly that); each call
/// `f((lo, hi), panel)` owns the `&mut` sub-slice holding rows `[lo, hi)`,
/// so workers write results in place with no gather/copy step.
pub fn par_panels<F>(buf: &mut [f32], cols: usize, ranges: &[(usize, usize)], f: F)
where
    F: Fn((usize, usize), &mut [f32]) + Sync,
{
    if ranges.len() <= 1 {
        for &(lo, hi) in ranges {
            f((lo, hi), &mut buf[lo * cols..hi * cols]);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = buf;
        let mut consumed = 0usize;
        for &(lo, hi) in ranges {
            debug_assert!(lo >= consumed && hi >= lo);
            let r = std::mem::take(&mut rest);
            let (_, r) = r.split_at_mut((lo - consumed) * cols);
            let (panel, r) = r.split_at_mut((hi - lo) * cols);
            rest = r;
            consumed = hi;
            let f = &f;
            s.spawn(move || f((lo, hi), panel));
        }
    });
}

/// Number of workers to use by default.  Cached after the first call:
/// `available_parallelism` is a syscall, and the matmul dispatch policy
/// asks on every `Tensor::matmul_*` invocation.
pub fn default_workers() -> usize {
    static WORKERS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *WORKERS.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..50usize).map(|i| Box::new(move || i * 2) as _).collect();
        let out = run_jobs(4, jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_scheduling_order() {
        // Regression: the queue used to pop from the *back* of a Vec, so a
        // single worker ran jobs in reverse submission order.  With one
        // worker the start order is fully observable — it must be FIFO.
        let started = Arc::new(Mutex::new(Vec::new()));
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..16usize)
            .map(|i| {
                let started = Arc::clone(&started);
                Box::new(move || {
                    started.lock().unwrap().push(i);
                    i
                }) as _
            })
            .collect();
        let out = run_jobs(1, jobs);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
        assert_eq!(
            *started.lock().unwrap(),
            (0..16).collect::<Vec<_>>(),
            "jobs must start in submission order"
        );
    }

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(8, &items, |_, &x| x * x);
        assert_eq!(out, items.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_panels_writes_disjoint_rows_in_place() {
        let mut buf = vec![0.0f32; 10 * 2];
        let ranges = [(0usize, 3usize), (3, 7), (7, 10)];
        par_panels(&mut buf, 2, &ranges, |(lo, _hi), panel| {
            for (i, row) in panel.chunks_mut(2).enumerate() {
                row.fill((lo + i) as f32);
            }
        });
        let want: Vec<f32> = (0..10).flat_map(|i| [i as f32, i as f32]).collect();
        assert_eq!(buf, want);
        // single-range call runs inline on the caller's thread
        let mut one = vec![0.0f32; 4];
        par_panels(&mut one, 2, &[(0, 2)], |_, panel| panel.fill(1.0));
        assert_eq!(one, vec![1.0; 4]);
        par_panels(&mut one, 2, &[], |_, _| unreachable!("no ranges, no calls"));
    }

    #[test]
    fn empty() {
        let out: Vec<u8> = run_jobs(4, vec![]);
        assert!(out.is_empty());
        let out2: Vec<u8> = par_map(4, &[] as &[u8], |_, &x| x);
        assert!(out2.is_empty());
    }
}
