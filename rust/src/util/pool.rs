//! A small scoped worker pool over std threads (rayon is not vendored).
//!
//! The PJRT client itself is single-threaded per executable here, but data
//! preparation, metric reduction, and the analysis fan-outs (grid-shift
//! histograms over many layers) parallelize across units.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` closures on up to `workers` threads; returns results in job
/// order.  Panics in jobs are propagated as Err strings.
pub fn run_jobs<T: Send + 'static>(
    workers: usize,
    jobs: Vec<Box<dyn FnOnce() -> T + Send>>,
) -> Vec<T> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let queue = Arc::new(Mutex::new(jobs.into_iter().enumerate().collect::<Vec<_>>()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            s.spawn(move || loop {
                let job = queue.lock().expect("queue poisoned").pop();
                match job {
                    Some((i, f)) => {
                        let r = f();
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("job dropped")).collect()
    })
}

/// Parallel map over a slice with index.
pub fn par_map<I: Sync, T: Send + 'static>(
    workers: usize,
    items: &[I],
    f: impl Fn(usize, &I) -> T + Sync + Send,
) -> Vec<T> {
    std::thread::scope(|s| {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = workers.clamp(1, n);
        let next = Arc::new(Mutex::new(0usize));
        let mut handles = Vec::new();
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let f = &f;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                loop {
                    let i = {
                        let mut g = next.lock().expect("poisoned");
                        let i = *g;
                        *g += 1;
                        i
                    };
                    if i >= n {
                        return out;
                    }
                    out.push((i, f(i, &items[i])));
                }
            }));
        }
        let mut all: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for h in handles {
            for (i, v) in h.join().expect("worker panicked") {
                all[i] = Some(v);
            }
        }
        all.into_iter().map(|o| o.expect("missing result")).collect()
    })
}

/// Number of workers to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..50usize).map(|i| Box::new(move || i * 2) as _).collect();
        let out = run_jobs(4, jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(8, &items, |_, &x| x * x);
        assert_eq!(out, items.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let out: Vec<u8> = run_jobs(4, vec![]);
        assert!(out.is_empty());
        let out2: Vec<u8> = par_map(4, &[] as &[u8], |_, &x| x);
        assert!(out2.is_empty());
    }
}
