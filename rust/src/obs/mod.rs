//! Zero-dependency observability: metrics registry, RAII span tracing, and
//! the `/metrics` + `/healthz` HTTP endpoint.
//!
//! Everything here is std-only (the offline vendored build allows nothing
//! else) and built for hot paths measured in nanoseconds:
//!
//! * **Metrics** — process-wide named [`Counter`]s, [`Gauge`]s, and
//!   log-bucketed latency [`Hist`]ograms, registered once by name and
//!   updated with single relaxed atomics.  Call sites cache their handle
//!   in a `OnceLock` via [`obs_counter!`]/[`obs_gauge!`]/[`obs_hist!`] so
//!   the registry mutex is touched exactly once per site per process.
//!   [`snapshot`] copies everything out; [`render_prometheus`] emits the
//!   text exposition format and [`snapshot_json`] a JSON document.
//! * **Spans** — [`span`] returns an RAII guard that stamps wall-time into
//!   a bounded lock-free ring ([`trace`]), exportable as Chrome
//!   `trace_event` JSON via `--trace-out`.
//! * **Endpoint** — [`http::MetricsServer`] serves the registry over a
//!   minimal blocking `TcpListener` (`serve --metrics-addr`).
//!
//! ## Kill switch and overhead policy
//!
//! `FLEXROUND_OBS=off` (or `0`/`false`) disables spans and the per-call
//! counters on the innermost kernel paths; [`span`] then returns an inert
//! guard without reading the clock (`benches/obs.rs` asserts that path
//! stays in the nanosecond range, recorded in `BENCH_obs.json`).
//! Histogram recording and the per-step scheduler/serve metrics stay live
//! regardless — `ServeStats` percentiles are computed from them, and one
//! atomic per scheduler step is noise next to a batched forward.
//! Instrumentation never touches numerics: verify.sh re-runs the kernel
//! and scheduler differential parity gates under `FLEXROUND_OBS=off` to
//! prove bit-identity.

pub mod hist;
pub mod http;
pub mod trace;

pub use hist::{Hist, HistSnapshot};
pub use http::MetricsServer;
pub use trace::{span, write_chrome_trace, SpanGuard};

use crate::ser::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Kill switch

/// 0 = uninitialised, 1 = on, 2 = off.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Whether observability is live.  First call reads `FLEXROUND_OBS` once;
/// after that it is a single relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => init_enabled(),
    }
}

#[cold]
fn init_enabled() -> bool {
    let on = !matches!(
        std::env::var("FLEXROUND_OBS").as_deref(),
        Ok("off") | Ok("0") | Ok("false")
    );
    STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
    on
}

/// Force the switch, overriding the environment.  For benches and tests
/// that need to measure both modes inside one process.
pub fn set_enabled(on: bool) {
    STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Metric primitives

/// Monotonic counter (relaxed `fetch_add`).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depth, pages in use, …).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Registry

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

static REGISTRY: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn with_registry<R>(f: impl FnOnce(&mut BTreeMap<String, Metric>) -> R) -> R {
    let mut guard = registry().lock().unwrap_or_else(|p| p.into_inner());
    f(&mut guard)
}

/// Look up (registering on first use) the counter named `name`.  A name
/// already registered as a different metric kind is a programmer error.
pub fn counter(name: &str) -> Arc<Counter> {
    with_registry(|m| match m
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
    {
        Metric::Counter(c) => Arc::clone(c),
        _ => panic!("metric {name} already registered with a different kind"),
    })
}

/// Look up (registering on first use) the gauge named `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    with_registry(|m| match m
        .entry(name.to_string())
        .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
    {
        Metric::Gauge(g) => Arc::clone(g),
        _ => panic!("metric {name} already registered with a different kind"),
    })
}

/// Look up (registering on first use) the histogram named `name`.
pub fn histogram(name: &str) -> Arc<Hist> {
    with_registry(|m| match m
        .entry(name.to_string())
        .or_insert_with(|| Metric::Hist(Arc::new(Hist::new())))
    {
        Metric::Hist(h) => Arc::clone(h),
        _ => panic!("metric {name} already registered with a different kind"),
    })
}

/// Cache a registry [`Counter`] handle in a per-call-site `OnceLock`.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static H: std::sync::OnceLock<std::sync::Arc<$crate::obs::Counter>> =
            std::sync::OnceLock::new();
        &**H.get_or_init(|| $crate::obs::counter($name))
    }};
}

/// Cache a registry [`Gauge`] handle in a per-call-site `OnceLock`.
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static H: std::sync::OnceLock<std::sync::Arc<$crate::obs::Gauge>> =
            std::sync::OnceLock::new();
        &**H.get_or_init(|| $crate::obs::gauge($name))
    }};
}

/// Cache a registry [`Hist`] handle in a per-call-site `OnceLock`.
#[macro_export]
macro_rules! obs_hist {
    ($name:expr) => {{
        static H: std::sync::OnceLock<std::sync::Arc<$crate::obs::Hist>> =
            std::sync::OnceLock::new();
        &**H.get_or_init(|| $crate::obs::histogram($name))
    }};
}

// ---------------------------------------------------------------------------
// Snapshot + rendering

/// Point-in-time value of one registered metric.
#[derive(Clone, Debug)]
pub enum SnapValue {
    Counter(u64),
    Gauge(i64),
    Hist(HistSnapshot),
}

/// Copy every registered metric's current value.
pub fn snapshot() -> BTreeMap<String, SnapValue> {
    with_registry(|m| {
        m.iter()
            .map(|(name, metric)| {
                let v = match metric {
                    Metric::Counter(c) => SnapValue::Counter(c.get()),
                    Metric::Gauge(g) => SnapValue::Gauge(g.get()),
                    Metric::Hist(h) => SnapValue::Hist(h.snapshot()),
                };
                (name.clone(), v)
            })
            .collect()
    })
}

/// Scalar read of one metric by name (counters and gauges as-is,
/// histograms as their count).  `None` if never registered.
pub fn value(name: &str) -> Option<f64> {
    with_registry(|m| {
        m.get(name).map(|metric| match metric {
            Metric::Counter(c) => c.get() as f64,
            Metric::Gauge(g) => g.get() as f64,
            Metric::Hist(h) => h.count() as f64,
        })
    })
}

/// Render the whole registry in the Prometheus text exposition format
/// (version 0.0.4).  Histograms emit cumulative `_bucket{le=…}` lines for
/// every occupied bucket plus `+Inf`, `_sum`, `_count`, and convenience
/// `_p50`/`_p90`/`_p99` gauges so quantiles are readable without a query
/// engine.
pub fn render_prometheus() -> String {
    let mut out = String::new();
    for (name, v) in snapshot() {
        match v {
            SnapValue::Counter(c) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {c}\n"));
            }
            SnapValue::Gauge(g) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {g}\n"));
            }
            SnapValue::Hist(h) => {
                out.push_str(&format!("# TYPE {name} histogram\n"));
                for (le, cum) in h.cumulative() {
                    out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                }
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
                out.push_str(&format!("{name}_sum {}\n", h.sum));
                out.push_str(&format!("{name}_count {}\n", h.count));
                for (suffix, p) in [("p50", 50.0), ("p90", 90.0), ("p99", 99.0)] {
                    out.push_str(&format!(
                        "# TYPE {name}_{suffix} gauge\n{name}_{suffix} {}\n",
                        h.quantile(p)
                    ));
                }
            }
        }
    }
    out
}

/// The whole registry as one JSON object (the `serve --stats-json` body).
/// Histograms carry count/sum/mean plus the three headline quantiles.
pub fn snapshot_json() -> Json {
    let mut obj = BTreeMap::new();
    for (name, v) in snapshot() {
        let jv = match v {
            SnapValue::Counter(c) => Json::from_f64(c as f64),
            SnapValue::Gauge(g) => Json::from_f64(g as f64),
            SnapValue::Hist(h) => Json::object(vec![
                ("count", Json::from_f64(h.count as f64)),
                ("sum", Json::from_f64(h.sum)),
                ("mean", Json::from_f64(h.mean())),
                ("p50", Json::from_f64(h.quantile(50.0))),
                ("p90", Json::from_f64(h.quantile(90.0))),
                ("p99", Json::from_f64(h.quantile(99.0))),
            ]),
        };
        obj.insert(name, jv);
    }
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_hands_back_the_same_instance() {
        let a = counter("obs_test_requests_total");
        let b = counter("obs_test_requests_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(value("obs_test_requests_total"), Some(3.0));
        assert_eq!(value("obs_test_never_registered"), None);

        let g = gauge("obs_test_depth");
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn macros_cache_handles() {
        let c = obs_counter!("obs_test_macro_total");
        c.inc();
        obs_counter!("obs_test_macro_total");
        assert_eq!(counter("obs_test_macro_total").get(), 1);
        obs_gauge!("obs_test_macro_gauge").set(7);
        assert_eq!(gauge("obs_test_macro_gauge").get(), 7);
        obs_hist!("obs_test_macro_hist").record(1.0);
        assert_eq!(histogram("obs_test_macro_hist").count(), 1);
    }

    #[test]
    fn prometheus_exposition_well_formed() {
        counter("obs_test_expo_total").add(4);
        gauge("obs_test_expo_gauge").set(-2);
        let h = histogram("obs_test_expo_ms");
        for i in 0..100 {
            h.record(0.5 + i as f64 * 0.01);
        }
        let text = render_prometheus();
        assert!(text.contains("# TYPE obs_test_expo_total counter"));
        assert!(text.contains("obs_test_expo_total 4"));
        assert!(text.contains("obs_test_expo_gauge -2"));
        assert!(text.contains("# TYPE obs_test_expo_ms histogram"));
        assert!(text.contains("obs_test_expo_ms_count 100"));
        assert!(text.contains("obs_test_expo_ms_bucket{le=\"+Inf\"} 100"));
        // Every non-comment line is `name[{labels}] value` with a numeric value.
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (_, val) = line.rsplit_once(' ').expect("line has a value field");
            val.parse::<f64>().expect("value parses as a number");
        }
    }

    #[test]
    fn json_snapshot_carries_quantiles() {
        let h = histogram("obs_test_json_ms");
        h.record(2.0);
        let doc = snapshot_json();
        match &doc {
            Json::Obj(m) => match m.get("obs_test_json_ms") {
                Some(Json::Obj(hm)) => {
                    assert!(hm.contains_key("p50") && hm.contains_key("count"));
                }
                other => panic!("histogram rendered as {other:?}"),
            },
            _ => panic!("snapshot_json is not an object"),
        }
    }
}
