//! Fixed-bucket log-spaced latency histogram with atomic recording.
//!
//! Buckets are geometric: [`BUCKETS_PER_DECADE`] per power of ten spanning
//! [`LO`]..[`HI`], plus an underflow bucket (samples `< LO`, including zero
//! and negatives) and an overflow bucket (`>= HI`).  With 8 buckets per
//! decade adjacent bucket bounds differ by a ratio of `10^(1/8) ≈ 1.334`,
//! so a nearest-rank quantile read off the bucket counts lands within one
//! bucket width of the exact sorted-sample answer — tight enough for
//! p50/p90/p99 latency reporting at any time scale from nanoseconds to
//! minutes without per-histogram configuration.
//!
//! Recording is one `fetch_add` on the bucket plus a CAS loop folding the
//! sample into a bit-cast f64 running sum; there are no locks anywhere, so
//! histograms are safe to hammer from the batcher, scheduler, and worker
//! pool concurrently.  Readers take a [`HistSnapshot`] (a plain copy of the
//! counts) and do all quantile math on that, so in-flight recording never
//! skews a percentile mid-computation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced buckets per power of ten.
pub const BUCKETS_PER_DECADE: usize = 8;
/// Lower bound of the first finite bucket.
pub const LO: f64 = 1e-9;
/// Number of decades covered by the finite buckets.
pub const DECADES: usize = 12;
/// Finite bucket count (underflow/overflow slots come on top).
pub const NBUCKETS: usize = BUCKETS_PER_DECADE * DECADES;
/// Upper bound of the last finite bucket: `LO * 10^DECADES` = 1e3.
pub const HI: f64 = 1e3;

/// Total slots: underflow + finite buckets + overflow.
const SLOTS: usize = NBUCKETS + 2;

/// Lock-free log-bucketed histogram.  Construct via [`Hist::new`] or, for
/// registry-managed instances, [`crate::obs::histogram`].
pub struct Hist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: (0..SLOTS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
        }
    }

    /// Slot index for a sample: 0 = underflow, 1..=NBUCKETS finite,
    /// NBUCKETS+1 = overflow.  NaN is treated as underflow (it must land
    /// somewhere; a poisoned timer should not panic the server).
    fn slot(v: f64) -> usize {
        if !(v >= LO) {
            return 0;
        }
        if v >= HI {
            return NBUCKETS + 1;
        }
        let pos = (v.log10() - LO.log10()) * BUCKETS_PER_DECADE as f64;
        // log10 rounding at exact bucket bounds can land a hair outside
        // [0, NBUCKETS); clamp rather than trust float edges.
        1 + (pos.floor() as usize).min(NBUCKETS - 1)
    }

    /// Upper bound of slot `i` (finite slots only; `i` in 1..=NBUCKETS).
    fn upper(i: usize) -> f64 {
        LO * 10f64.powf(i as f64 / BUCKETS_PER_DECADE as f64)
    }

    /// Record one sample.  Always live — histograms back `ServeStats`
    /// percentiles, so the `FLEXROUND_OBS` kill switch does not gate them.
    pub fn record(&self, v: f64) {
        self.buckets[Self::slot(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current counts out for quantile math and rendering.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Immutable copy of a histogram's state at one instant.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistSnapshot {
    /// Samples recorded between `earlier` and `self` (`self` must be the
    /// later snapshot of the same histogram).  Lets several sequential
    /// workloads share one process-wide histogram and still report
    /// per-run percentiles.
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .zip(&earlier.buckets)
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum - earlier.sum,
        }
    }

    /// Nearest-rank quantile estimate, `p` in [0, 100].  Returns the
    /// geometric midpoint of the bucket holding the target rank, which is
    /// within one bucket-width ratio (`10^(1/8)`) of the exact sorted
    /// answer.  Empty histograms report 0.0, matching the legacy
    /// `ServeStats` convention for idle servers.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::representative(i);
            }
        }
        Self::representative(self.buckets.len() - 1)
    }

    /// Arithmetic mean of the recorded samples (exact: tracked sum/count).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Representative value for a slot: LO for underflow, HI for overflow,
    /// geometric midpoint of the bounds for finite buckets.
    fn representative(slot: usize) -> f64 {
        if slot == 0 {
            return LO;
        }
        if slot > NBUCKETS {
            return HI;
        }
        let hi = Hist::upper(slot);
        let lo = Hist::upper(slot - 1);
        (lo * hi).sqrt()
    }

    /// Iterate `(upper_bound, cumulative_count)` pairs over the finite
    /// buckets for Prometheus exposition; the caller appends the `+Inf`
    /// bucket from `count`.  Empty buckets are skipped except the final
    /// finite one, to keep `/metrics` output bounded.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate().take(NBUCKETS + 1) {
            cum += c;
            if c > 0 && i >= 1 {
                out.push((Hist::upper(i), cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    /// One-bucket-width ratio: adjacent bounds differ by 10^(1/8).
    const BUCKET_RATIO: f64 = 1.3335214321633242;

    fn exact_percentile(samples: &mut [f64], p: f64) -> f64 {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * samples.len() as f64).ceil().max(1.0) as usize;
        samples[rank - 1]
    }

    fn assert_within_bucket(est: f64, exact: f64, what: &str) {
        assert!(
            est >= exact / BUCKET_RATIO - 1e-12 && est <= exact * BUCKET_RATIO + 1e-12,
            "{what}: estimate {est} vs exact {exact} outside one bucket width"
        );
    }

    #[test]
    fn quantiles_match_sorted_reference_within_one_bucket() {
        // Three seeded shapes: uniform, log-uniform (heavy dynamic range),
        // and a bimodal latency-like mix.
        let mut rng = Pcg32::seeded(42);
        let mut uf = move || rng.next_f32() as f64;
        let shapes: Vec<(&str, Vec<f64>)> = vec![
            ("uniform", (0..5000).map(|_| 0.1 + 9.9 * uf()).collect()),
            ("loguniform", (0..5000).map(|_| 10f64.powf(-6.0 + 8.0 * uf())).collect()),
            (
                "bimodal",
                (0..5000)
                    .map(|_| if uf() < 0.9 { 0.002 + 0.001 * uf() } else { 0.5 + 0.2 * uf() })
                    .collect(),
            ),
        ];
        for (name, samples) in shapes {
            let h = Hist::new();
            for &s in &samples {
                h.record(s);
            }
            let snap = h.snapshot();
            assert_eq!(snap.count, samples.len() as u64);
            let mut sorted = samples.clone();
            for p in [50.0, 90.0, 99.0] {
                let exact = exact_percentile(&mut sorted, p);
                assert_within_bucket(snap.quantile(p), exact, &format!("{name} p{p}"));
            }
            let mean_exact: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
            assert!((snap.mean() - mean_exact).abs() < 1e-9 * mean_exact.abs().max(1.0));
        }
    }

    #[test]
    fn empty_and_single_sample_edges() {
        let h = Hist::new();
        let empty = h.snapshot();
        assert_eq!(empty.count, 0);
        assert_eq!(empty.quantile(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);

        h.record(0.0375);
        let one = h.snapshot();
        assert_eq!(one.count, 1);
        for p in [0.0, 50.0, 100.0] {
            assert_within_bucket(one.quantile(p), 0.0375, "single-sample");
        }

        // Out-of-range samples land in the sentinel buckets, not panics.
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(1e12);
        let snap = h.snapshot();
        assert_eq!(snap.count, 5);
        assert_eq!(snap.buckets[0], 3);
        assert_eq!(snap.buckets[NBUCKETS + 1], 1);
        assert_eq!(snap.quantile(100.0), HI);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Arc::new(Hist::new());
        let threads = 8u64;
        let per = 20_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Pcg32::seeded(100 + t);
                    for _ in 0..per {
                        h.record(10f64.powf(-4.0 + 6.0 * rng.next_f32() as f64));
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, threads * per);
        assert_eq!(snap.buckets.iter().sum::<u64>(), threads * per);
        assert!(snap.sum > 0.0 && snap.sum.is_finite());
    }

    #[test]
    fn delta_isolates_a_window() {
        let h = Hist::new();
        for _ in 0..100 {
            h.record(1.0);
        }
        let base = h.snapshot();
        for _ in 0..50 {
            h.record(100.0);
        }
        let d = h.snapshot().delta(&base);
        assert_eq!(d.count, 50);
        assert_within_bucket(d.quantile(50.0), 100.0, "delta p50");
        assert!((d.mean() - 100.0).abs() < 1e-6);
    }
}
