//! Minimal blocking HTTP endpoint for `/metrics` and `/healthz`.
//!
//! One `std::net::TcpListener` accept loop on one background thread, one
//! connection handled at a time, `Connection: close` on every response —
//! deliberately the smallest thing that a Prometheus scraper and a `curl`
//! health probe can talk to.  This is a metrics sidecar, not the inference
//! front end; the async HTTP server the ROADMAP asks for plugs into the
//! same registry later.
//!
//! `/metrics` renders [`super::render_prometheus`].  `/healthz` returns a
//! JSON document with status, uptime, the model info the caller passed to
//! [`MetricsServer::start`], and scheduler liveness read from the registry
//! (steps, active/queued sessions, pages in use, evictions).
//!
//! Shutdown is deterministic: [`MetricsServer::shutdown`] flips a flag and
//! self-connects to unblock `accept`, then joins the thread, so tests can
//! assert no listener lingers.

use crate::ser::json::{self, Json};
use crate::Result;
use anyhow::anyhow;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Handle to the background metrics endpoint.  Dropping it also shuts the
/// listener down (shutdown-by-hand is preferred so errors surface).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, or port 0 for an ephemeral
    /// port) and start serving.  `model_info` is echoed inside `/healthz`.
    pub fn start(addr: &str, model_info: Json) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding metrics endpoint {addr}: {e}"))?;
        let local =
            listener.local_addr().map_err(|e| anyhow!("metrics endpoint local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let t0 = Instant::now();
        let handle = std::thread::Builder::new()
            .name("obs-metrics".to_string())
            .spawn(move || serve_loop(listener, stop2, model_info, t0))
            .map_err(|e| anyhow!("spawning metrics endpoint thread: {e}"))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close the listener, and join the thread.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_and_join().map_err(|e| anyhow!("metrics endpoint shutdown: {e}"))
    }

    fn stop_and_join(&mut self) -> std::result::Result<(), String> {
        let Some(handle) = self.handle.take() else { return Ok(()) };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept(); an error just means the listener already died.
        let _ = TcpStream::connect(self.addr);
        handle.join().map_err(|_| "endpoint thread panicked".to_string())
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        let _ = self.stop_and_join();
    }
}

fn serve_loop(listener: TcpListener, stop: Arc<AtomicBool>, model_info: Json, t0: Instant) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = stream {
            // Per-connection errors (bad request, client hangup) are the
            // client's problem; the endpoint itself must keep serving.
            let _ = handle_conn(stream, &model_info, t0);
        }
    }
}

fn handle_conn(mut stream: TcpStream, model_info: &Json, t0: Instant) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut buf = [0u8; 4096];
    let mut n = 0usize;
    // Read until the end of the request head (we ignore bodies).
    while n < buf.len() {
        let got = stream.read(&mut buf[n..])?;
        if got == 0 {
            break;
        }
        n += got;
        if buf[..n].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..n]);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let path = path.split('?').next().unwrap_or(path);

    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "only GET is supported\n".to_string())
    } else {
        match path {
            "/metrics" => (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                super::render_prometheus(),
            ),
            "/healthz" => (
                "200 OK",
                "application/json",
                json::to_string(&healthz_json(model_info, t0), 2) + "\n",
            ),
            _ => ("404 Not Found", "text/plain", format!("no route for {path}\n")),
        }
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Build the `/healthz` body: status, uptime, model info, and scheduler
/// liveness read from whatever the scheduler has published so far.
fn healthz_json(model_info: &Json, t0: Instant) -> Json {
    let sched_val = |name: &str| Json::from_f64(super::value(name).unwrap_or(0.0));
    Json::object(vec![
        ("status", Json::from_str_val("ok")),
        ("uptime_secs", Json::from_f64(t0.elapsed().as_secs_f64())),
        ("model", model_info.clone()),
        (
            "scheduler",
            Json::object(vec![
                ("steps", sched_val("flexround_sched_steps_total")),
                ("active_sessions", sched_val("flexround_sched_active_sessions")),
                ("queued_sessions", sched_val("flexround_sched_queued_sessions")),
                ("pages_in_use", sched_val("flexround_sched_pages_in_use")),
                ("evictions", sched_val("flexround_sched_evictions_total")),
            ]),
        ),
    ])
}
