//! RAII span tracing into a bounded lock-free ring buffer.
//!
//! `let _s = obs::span("recon/adam_step");` stamps the span's start on
//! creation and writes one fixed-size record (name, start, duration,
//! thread id) into a global ring when the guard drops.  The ring is a
//! seqlock array: a writer claims a slot by CAS-ing its sequence number
//! to odd, fills the fields, and releases it back to even; a concurrent
//! writer that loses the CAS drops its event (bounded buffer — overwrite
//! and drop are both acceptable losses), and a reader discards any slot
//! whose sequence is odd or changes under it.  Nothing blocks, ever.
//!
//! Span names must be `&'static str` literals so a record is two words of
//! pointer/length plus three timestamps — no allocation on the hot path.
//! When the `FLEXROUND_OBS=off` kill switch is set, [`span`] returns an
//! inert guard without reading the clock; `benches/obs.rs` holds that
//! path to nanosecond cost.
//!
//! [`write_chrome_trace`] exports the ring as Chrome `trace_event` JSON
//! (load via chrome://tracing or https://ui.perfetto.dev).

use crate::ser::json::{self, Json};
use crate::Result;
use anyhow::anyhow;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Ring capacity (records). 32768 × 48 B ≈ 1.5 MB, enough for the tail of
/// any pipeline or serve run; older events are overwritten.
const RING_CAP: usize = 1 << 15;

struct Slot {
    /// Seqlock: even = stable, odd = writer active. 0 = never written.
    seq: AtomicU64,
    name_ptr: AtomicUsize,
    name_len: AtomicUsize,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    tid: AtomicU64,
}

struct Ring {
    slots: Vec<Slot>,
    head: AtomicU64,
}

static RING: OnceLock<Ring> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn ring() -> &'static Ring {
    RING.get_or_init(|| Ring {
        slots: (0..RING_CAP)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                name_ptr: AtomicUsize::new(0),
                name_len: AtomicUsize::new(0),
                start_ns: AtomicU64::new(0),
                dur_ns: AtomicU64::new(0),
                tid: AtomicU64::new(0),
            })
            .collect(),
        head: AtomicU64::new(0),
    })
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// RAII guard returned by [`span`]; records the span on drop.  Inert (no
/// clock reads, no ring writes) when observability is disabled.
pub struct SpanGuard {
    active: Option<(&'static str, Instant)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, t0)) = self.active.take() {
            let dur = t0.elapsed().as_nanos() as u64;
            let start = t0.checked_duration_since(epoch()).unwrap_or_default().as_nanos() as u64;
            record(name, start, dur);
        }
    }
}

/// Open a span; it closes (and is recorded) when the guard drops.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { active: None };
    }
    // Touch the epoch before reading the clock so start offsets are
    // non-negative even for the very first span in the process.
    epoch();
    SpanGuard { active: Some((name, Instant::now())) }
}

fn record(name: &'static str, start_ns: u64, dur_ns: u64) {
    let r = ring();
    let idx = (r.head.fetch_add(1, Ordering::Relaxed) % RING_CAP as u64) as usize;
    let slot = &r.slots[idx];
    let seq = slot.seq.load(Ordering::Relaxed);
    if seq & 1 == 1 {
        return; // another writer owns this slot right now; drop the event
    }
    if slot.seq.compare_exchange(seq, seq + 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
        return;
    }
    slot.name_ptr.store(name.as_ptr() as usize, Ordering::Relaxed);
    slot.name_len.store(name.len(), Ordering::Relaxed);
    slot.start_ns.store(start_ns, Ordering::Relaxed);
    slot.dur_ns.store(dur_ns, Ordering::Relaxed);
    TID.with(|t| slot.tid.store(*t, Ordering::Relaxed));
    slot.seq.store(seq + 2, Ordering::Release);
}

/// One completed span read back out of the ring.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
}

/// Snapshot the ring's stable records, oldest first.  Slots being written
/// concurrently are skipped; records never tear because each slot is
/// single-writer between its odd/even sequence transitions.
pub fn events() -> Vec<TraceEvent> {
    let r = ring();
    let mut out = Vec::new();
    for slot in &r.slots {
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 == 0 || s1 & 1 == 1 {
            continue;
        }
        let ptr = slot.name_ptr.load(Ordering::Relaxed);
        let len = slot.name_len.load(Ordering::Relaxed);
        let start = slot.start_ns.load(Ordering::Relaxed);
        let dur = slot.dur_ns.load(Ordering::Relaxed);
        let tid = slot.tid.load(Ordering::Relaxed);
        if slot.seq.load(Ordering::Acquire) != s1 || ptr == 0 {
            continue; // torn read: a writer slipped in; discard
        }
        // Safety: (ptr, len) came from a &'static str literal and the
        // seqlock check above proved they belong to one complete write.
        let name = unsafe {
            std::str::from_utf8_unchecked(std::slice::from_raw_parts(ptr as *const u8, len))
        };
        out.push(TraceEvent {
            name,
            ts_us: start as f64 / 1e3,
            dur_us: dur as f64 / 1e3,
            tid,
        });
    }
    out.sort_by(|a, b| a.ts_us.partial_cmp(&b.ts_us).unwrap());
    out
}

/// Serialize the ring as Chrome `trace_event` JSON.
pub fn chrome_trace_json() -> Json {
    let evs = events()
        .into_iter()
        .map(|e| {
            Json::object(vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str("flexround".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::from_f64(e.ts_us)),
                ("dur", Json::from_f64(e.dur_us)),
                ("pid", Json::from_f64(1.0)),
                ("tid", Json::from_f64(e.tid as f64)),
            ])
        })
        .collect();
    Json::object(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Write the Chrome trace to `path` (the `--trace-out` flag target).
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let doc = chrome_trace_json();
    let n = match &doc {
        Json::Obj(m) => match m.get("traceEvents") {
            Some(Json::Arr(a)) => a.len(),
            _ => 0,
        },
        _ => 0,
    };
    std::fs::write(path, json::to_string(&doc, 0) + "\n")
        .map_err(|e| anyhow!("writing trace to {}: {e}", path.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_land_in_ring_and_export() {
        {
            let _a = span("test/outer");
            let _b = span("test/inner");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let evs = events();
        assert!(evs.iter().any(|e| e.name == "test/outer"));
        assert!(evs.iter().any(|e| e.name == "test/inner"));
        let outer = evs.iter().find(|e| e.name == "test/outer").unwrap();
        assert!(outer.dur_us >= 1000.0, "outer span should cover the sleep");

        let dir = std::env::temp_dir().join(format!("obs_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let n = write_chrome_trace(&path).unwrap();
        assert!(n >= 2);
        let parsed = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        match &parsed {
            Json::Obj(m) => match m.get("traceEvents") {
                Some(Json::Arr(a)) => {
                    assert_eq!(a.len(), n);
                    for ev in a {
                        if let Json::Obj(e) = ev {
                            assert!(e.contains_key("name") && e.contains_key("ts") && e.contains_key("dur"));
                        } else {
                            panic!("trace event is not an object");
                        }
                    }
                }
                _ => panic!("missing traceEvents array"),
            },
            _ => panic!("trace file is not a JSON object"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_spans_never_tear() {
        let names: [&'static str; 4] = ["t/alpha", "t/beta", "t/gamma", "t/delta"];
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    for _ in 0..5000 {
                        let _s = span(names[i]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every readable record must carry one of the known names — a torn
        // ptr/len pair would produce garbage (or crash) here.
        for e in events() {
            assert!(!e.name.is_empty());
        }
    }
}
