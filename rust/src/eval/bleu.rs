//! BLEU-4 (sentence level, with +1 smoothing) over integer token sequences —
//! the Table 6 WebNLG metric.

use std::collections::HashMap;

/// n-gram counts of a sequence.
fn ngrams(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for i in 0..=seq.len() - n {
            *m.entry(&seq[i..i + n]).or_insert(0) += 1;
        }
    }
    m
}

/// Modified n-gram precision with add-one smoothing (Lin & Och 2004).
fn precision(hyp: &[i32], rf: &[i32], n: usize) -> f64 {
    let h = ngrams(hyp, n);
    let r = ngrams(rf, n);
    let total: usize = h.values().sum();
    if total == 0 {
        return 0.0;
    }
    let mut clipped = 0usize;
    for (g, &c) in &h {
        clipped += c.min(r.get(g).copied().unwrap_or(0));
    }
    (clipped as f64 + 1.0) / (total as f64 + 1.0)
}

/// Sentence BLEU-4 with brevity penalty; returns a value in [0, ~1].
pub fn bleu4(hyp: &[i32], rf: &[i32]) -> f64 {
    if hyp.is_empty() || rf.is_empty() {
        return if hyp.is_empty() && rf.is_empty() { 1.0 } else { 0.0 };
    }
    let mut logsum = 0.0;
    for n in 1..=4 {
        let p = precision(hyp, rf, n);
        if p <= 0.0 {
            return 0.0;
        }
        logsum += p.ln() / 4.0;
    }
    let bp = if hyp.len() >= rf.len() {
        1.0
    } else {
        (1.0 - rf.len() as f64 / hyp.len() as f64).exp()
    };
    bp * logsum.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_near_one() {
        let s = [5, 6, 7, 8, 9, 10];
        let b = bleu4(&s, &s);
        assert!(b > 0.8, "{b}");
    }

    #[test]
    fn disjoint_is_low() {
        let a = [1, 2, 3, 4, 5, 1, 2, 3, 4, 5];
        let b = [10, 11, 12, 13, 14, 15, 16, 17, 18, 19];
        // +1 smoothing keeps this non-zero but it must stay far below overlap
        assert!(bleu4(&a, &b) < 0.3, "{}", bleu4(&a, &b));
        assert!(bleu4(&a, &b) < bleu4(&b, &b));
    }

    #[test]
    fn partial_overlap_ordered() {
        let r = [5, 6, 7, 8, 9, 10];
        let h_good = [5, 6, 7, 8, 20, 21];
        let h_bad = [5, 20, 7, 21, 9, 22];
        assert!(bleu4(&h_good, &r) > bleu4(&h_bad, &r));
    }

    #[test]
    fn brevity_penalty() {
        let r = [5, 6, 7, 8, 9, 10, 11, 12];
        let short = [5, 6];
        let full: Vec<i32> = r.to_vec();
        assert!(bleu4(&short, &r) < bleu4(&full, &r));
    }

    #[test]
    fn range_is_sane() {
        // randomized: always within [0, 1]
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        for _ in 0..200 {
            let n1 = 1 + rng.below(12) as usize;
            let n2 = 1 + rng.below(12) as usize;
            let h: Vec<i32> = (0..n1).map(|_| rng.below(10) as i32).collect();
            let r: Vec<i32> = (0..n2).map(|_| rng.below(10) as i32).collect();
            let b = bleu4(&h, &r);
            assert!((0.0..=1.0 + 1e-9).contains(&b), "bleu {b}");
        }
    }
}
