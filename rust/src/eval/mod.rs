//! Evaluation of quantized (and full-precision) models: the metrics behind
//! every table of the paper — top-1/top-5 accuracy, perplexity, GLUE-style
//! task accuracy, span exact-match, BLEU over greedy generations, and
//! zero-shot multiple-choice scoring by length-normalized log-likelihood.
//!
//! CNN metrics are backend-agnostic (the unit chain ends at logits).
//! Encoder/decoder metrics run *head* artifacts and therefore require the
//! PJRT backend — those functions are gated on the `pjrt` feature.

pub mod bleu;

use crate::coordinator::{QuantResult, Session};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;

/// A bundle of named metrics.
pub type Metrics = BTreeMap<String, f64>;

/// Numerically-stable log-sum-exp of a logit row: `max + ln Σ exp(x − max)`.
/// The max shift is what keeps `exp` in range — `exp(88.8)` already
/// overflows f32, and quantized lm heads routinely emit logits far past
/// that.  Shared by the perplexity path ([`ppl_from_hidden`]) and the
/// sampling softmax (`infer::generate::sample_token`).
pub fn log_sum_exp(row: &[f32]) -> f32 {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !mx.is_finite() {
        // all −∞ (empty/fully-masked row) or a +∞ spike: the shift is
        // meaningless, the answer is the max itself
        return mx;
    }
    mx + row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln()
}

// ---------------------------------------------------------------------------
// Classification (CNNs — Tables 1/2/3/8/9/10/11, Figure 7)
// ---------------------------------------------------------------------------

/// Top-1/top-5 over logits chunks vs labels.
pub fn topk_accuracy(logits_chunks: &[Tensor], labels: &Tensor) -> Result<Metrics> {
    let labels = labels.as_i32()?;
    let mut n = 0usize;
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for chunk in logits_chunks {
        let preds = chunk.topk_rows(5)?;
        for row in preds {
            let y = labels[n] as usize;
            if row[0] == y {
                top1 += 1;
            }
            if row.contains(&y) {
                top5 += 1;
            }
            n += 1;
        }
    }
    if n != labels.len() {
        bail!("label count {} != logit rows {n}", labels.len());
    }
    let mut m = Metrics::new();
    m.insert("top1".into(), top1 as f64 / n as f64);
    m.insert("top5".into(), top5 as f64 / n as f64);
    Ok(m)
}

/// CNN evaluation: quantized chain ends at head_fc → logits.
pub fn eval_cnn(sess: &Session, result: &QuantResult) -> Result<Metrics> {
    let xs = sess.dataset("eval_x")?;
    let logits = sess.forward_q(result, xs)?;
    topk_accuracy(&logits, sess.dataset("eval_y")?)
}

pub fn eval_cnn_fp(sess: &Session) -> Result<Metrics> {
    let xs = sess.dataset("eval_x")?;
    let logits = sess.forward_fp(xs)?;
    topk_accuracy(&logits, sess.dataset("eval_y")?)
}

// ---------------------------------------------------------------------------
// NLU (encoders — Tables 4/12/15)
// ---------------------------------------------------------------------------

pub const NLU_TASKS: [&str; 3] = ["entail", "para", "accept"];

/// Accuracy per classification task + span exact-match.
#[cfg(feature = "pjrt")]
pub fn eval_encoder(sess: &Session, result: Option<&QuantResult>) -> Result<Metrics> {
    let rt = sess.runtime()?;
    let mut m = Metrics::new();
    for task in NLU_TASKS {
        let xs = sess.dataset(&format!("eval_{task}_x"))?;
        let h = match result {
            Some(r) => sess.forward_q(r, xs)?,
            None => sess.forward_fp(xs)?,
        };
        let head = sess.head(task)?;
        let ys = sess.dataset(&format!("eval_{task}_y"))?.as_i32()?;
        let mut correct = 0usize;
        let mut n = 0usize;
        for chunk in &h {
            let logits = head.run(rt, std::slice::from_ref(chunk), false)?;
            for p in logits[0].argmax_rows()? {
                if p == ys[n] as usize {
                    correct += 1;
                }
                n += 1;
            }
        }
        m.insert(task.to_string(), correct as f64 / n as f64);
    }
    // span task (SQuAD analog): exact match on (start, end)
    let xs = sess.dataset("eval_span_x")?;
    let h = match result {
        Some(r) => sess.forward_q(r, xs)?,
        None => sess.forward_fp(xs)?,
    };
    let head = sess.head("span")?;
    let lab = sess.dataset("eval_span_y")?;
    let labs = lab.as_i32()?;
    let mut em = 0usize;
    let mut n = 0usize;
    for chunk in &h {
        let out = head.run(rt, std::slice::from_ref(chunk), true)?;
        let s_pred = out[0].argmax_rows()?;
        let e_pred = out[1].argmax_rows()?;
        for (ps, pe) in s_pred.into_iter().zip(e_pred) {
            if ps == labs[2 * n] as usize && pe == labs[2 * n + 1] as usize {
                em += 1;
            }
            n += 1;
        }
    }
    m.insert("span_em".into(), em as f64 / n as f64);
    Ok(m)
}

// ---------------------------------------------------------------------------
// Language modeling (decoders — Tables 5/7/19/23/24)
// ---------------------------------------------------------------------------

/// Native perplexity over final hidden states: project through the
/// weights-FXT lm head (`head/lm`, a `(vocab, d)` matrix), log-softmax, and
/// average the NLL of the per-row labels.  Labels of −1 are ignored (each
/// sequence's last position has no next token).  This is the
/// block-reconstruction report path — no PJRT artifact involved, so the
/// quantized-vs-FP perplexity delta lands in the run report on any build.
pub fn eval_ppl_hidden(
    sess: &Session,
    result: Option<&QuantResult>,
    xs_name: &str,
    ys_name: &str,
) -> Result<f64> {
    let xs = sess.dataset(xs_name)?;
    let h = match result {
        Some(r) => sess.forward_q(r, xs)?,
        None => sess.forward_fp(xs)?,
    };
    ppl_from_hidden(sess, &h, ys_name)
}

/// [`eval_ppl_hidden`] with the hidden-state chunks already forwarded —
/// callers holding a hoisted packed engine (the pipeline report path)
/// compute `h` themselves and skip a redundant export/pack.
///
/// The `(rows, d) · (vocab, d)ᵀ` head projection below dominates this
/// function; it runs the crate-wide `linalg` dispatch — pool-parallel row
/// panels for calibration-sized chunks, the gemv fast path when a chunk
/// degenerates to a single row — instead of a private serial loop.
pub fn ppl_from_hidden(sess: &Session, h: &[Tensor], ys_name: &str) -> Result<f64> {
    let head = sess.weights.get("head/lm").ok_or_else(|| {
        anyhow::anyhow!(
            "model {} has no native lm head (weights-FXT key \"head/lm\")",
            sess.model.name
        )
    })?;
    if head.ndim() != 2 {
        bail!("head/lm must be a (vocab, d) matrix, got {:?}", head.shape());
    }
    let vocab = head.shape()[0];
    let ys = sess.dataset(ys_name)?.as_i32()?;
    let mut nll = 0.0f64;
    let mut cnt = 0usize;
    let mut row0 = 0usize;
    for chunk in h {
        let logits = chunk.matmul_nt(head)?;
        let lv = logits.as_f32()?;
        let rows = chunk.shape()[0];
        for i in 0..rows {
            let label = *ys.get(row0 + i).ok_or_else(|| {
                anyhow::anyhow!("{ys_name} has {} labels for ≥{} rows", ys.len(), row0 + i + 1)
            })?;
            if label < 0 {
                continue;
            }
            if label as usize >= vocab {
                bail!("label {label} outside the {vocab}-token head");
            }
            let row = &lv[i * vocab..(i + 1) * vocab];
            let lse = log_sum_exp(row);
            nll += (lse - row[label as usize]) as f64;
            cnt += 1;
        }
        row0 += rows;
    }
    if row0 != ys.len() {
        bail!("{ys_name} has {} labels for {row0} hidden rows", ys.len());
    }
    if cnt == 0 {
        bail!("{ys_name}: every label is ignored (−1); perplexity undefined");
    }
    Ok((nll / cnt as f64).exp())
}

/// Perplexity over a token dataset through the lm head.
#[cfg(feature = "pjrt")]
pub fn eval_ppl(sess: &Session, result: Option<&QuantResult>, dataset: &str) -> Result<f64> {
    let rt = sess.runtime()?;
    let xs = sess.dataset(dataset)?;
    let h = match result {
        Some(r) => sess.forward_q(r, xs)?,
        None => sess.forward_fp(xs)?,
    };
    let head = sess.head("lm")?;
    let b = sess.model.calib_batch;
    let mut nll = 0.0f64;
    let mut cnt = 0.0f64;
    for (i, chunk) in h.iter().enumerate() {
        let toks = xs.slice_rows(i * b, (i + 1) * b)?;
        let out = head.run(rt, &[chunk.clone(), toks], true)?;
        nll += out[0].sum() as f64;
        cnt += out[1].sum() as f64;
    }
    Ok((nll / cnt.max(1.0)).exp())
}

/// Per-sequence mean NLL (length-normalized) — the multiple-choice scorer.
#[cfg(feature = "pjrt")]
pub fn seq_scores(sess: &Session, result: Option<&QuantResult>, xs: &Tensor) -> Result<Vec<f64>> {
    let rt = sess.runtime()?;
    let h = match result {
        Some(r) => sess.forward_q(r, xs)?,
        None => sess.forward_fp(xs)?,
    };
    let head = sess.head("lm")?;
    let b = sess.model.calib_batch;
    let mut scores = Vec::with_capacity(xs.shape()[0]);
    for (i, chunk) in h.iter().enumerate() {
        let toks = xs.slice_rows(i * b, (i + 1) * b)?;
        let out = head.run(rt, &[chunk.clone(), toks], true)?;
        let nll = out[0].as_f32()?;
        let cnt = out[1].as_f32()?;
        for (s, c) in nll.iter().zip(cnt) {
            scores.push(-(*s as f64) / (*c as f64).max(1.0)); // higher = better
        }
    }
    Ok(scores)
}

pub const MC_TASKS: [&str; 3] = ["grammar", "copy", "parity"];
pub const MC_CHOICES: usize = 4;

/// Zero-shot multiple choice: pick the candidate with the best
/// length-normalized log-likelihood (the LLaMA protocol).
#[cfg(feature = "pjrt")]
pub fn eval_mc(sess: &Session, result: Option<&QuantResult>, task: &str) -> Result<f64> {
    let xs = sess.dataset(&format!("mc_{task}_x"))?;
    let ans = sess.dataset(&format!("mc_{task}_y"))?.as_i32()?;
    let scores = seq_scores(sess, result, xs)?;
    if scores.len() != ans.len() * MC_CHOICES {
        bail!("mc {task}: {} scores vs {} answers", scores.len(), ans.len());
    }
    let mut correct = 0usize;
    for (i, &a) in ans.iter().enumerate() {
        let s = &scores[i * MC_CHOICES..(i + 1) * MC_CHOICES];
        let pick = s
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if pick == a as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / ans.len() as f64)
}

// ---------------------------------------------------------------------------
// Data-to-text generation (dec_lora — Table 6): greedy decode + BLEU
// ---------------------------------------------------------------------------

/// Greedy-decode completions from `start` positions and BLEU them against
/// the references (the suffix of each eval sequence).
#[cfg(feature = "pjrt")]
pub fn eval_d2t_bleu(sess: &Session, result: Option<&QuantResult>, split: &str) -> Result<f64> {
    let rt = sess.runtime()?;
    let xs = sess.dataset(&format!("eval_{split}_x"))?;
    let starts = sess.dataset(&format!("eval_{split}_start"))?.as_i32()?;
    let n = xs.shape()[0];
    let seq = xs.shape()[1];
    let b = sess.model.calib_batch;
    let head = sess.head("logits")?;

    // working copy: prompts with completions zeroed
    let mut work: Vec<i32> = xs.as_i32()?.to_vec();
    for i in 0..n {
        for t in starts[i] as usize..seq {
            work[i * seq + t] = 0;
        }
    }
    let max_start = starts.iter().copied().min().unwrap_or(0) as usize;
    // iterative greedy fill from the earliest completion position
    for pos in max_start.saturating_sub(1)..seq - 1 {
        let cur = Tensor::from_i32(work.clone(), &[n, seq])?;
        let h = match result {
            Some(r) => sess.forward_q(r, &cur)?,
            None => sess.forward_fp(&cur)?,
        };
        for (ci, chunk) in h.iter().enumerate() {
            let logits = head.run(rt, std::slice::from_ref(chunk), false)?;
            let l = &logits[0]; // (b, seq, vocab)
            let vs = l.shape()[2];
            let lv = l.as_f32()?;
            for r in 0..b {
                let i = ci * b + r;
                if i >= n {
                    break;
                }
                // only fill positions that are part of the completion
                if pos + 1 >= starts[i] as usize && pos + 1 < seq {
                    let row = &lv[(r * seq + pos) * vs..(r * seq + pos + 1) * vs];
                    let mut best = 0usize;
                    for (j, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = j;
                        }
                    }
                    work[i * seq + pos + 1] = best as i32;
                }
            }
        }
    }

    // BLEU of generated completions vs references
    let refs = xs.as_i32()?;
    let mut bleu_sum = 0.0;
    for i in 0..n {
        let s = starts[i] as usize;
        let hyp: Vec<i32> = work[i * seq + s..(i + 1) * seq].iter().copied()
            .take_while(|&t| t != 0).collect();
        let rf: Vec<i32> = refs[i * seq + s..(i + 1) * seq].iter().copied()
            .take_while(|&t| t != 0).collect();
        bleu_sum += bleu::bleu4(&hyp, &rf);
    }
    Ok(100.0 * bleu_sum / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_counts() {
        let logits = Tensor::from_f32(
            vec![
                0.9, 0.1, 0.0, 0.0, 0.0, 0.0, // pred 0
                0.0, 0.8, 0.1, 0.0, 0.0, 0.0, // pred 1
                0.3, 0.2, 0.1, 0.05, 0.0, 0.9, // pred 5
            ],
            &[3, 6],
        )
        .unwrap();
        let labels = Tensor::from_i32(vec![0, 1, 0], &[3]).unwrap();
        let m = topk_accuracy(&[logits], &labels).unwrap();
        assert!((m["top1"] - 2.0 / 3.0).abs() < 1e-9);
        assert!((m["top5"] - 1.0).abs() < 1e-9); // label 0 is in top-5 of row 3
    }

    #[test]
    fn topk_rejects_mismatch() {
        let logits = Tensor::from_f32(vec![0.1, 0.9], &[1, 2]).unwrap();
        let labels = Tensor::from_i32(vec![0, 1], &[2]).unwrap();
        assert!(topk_accuracy(&[logits], &labels).is_err());
    }

    #[test]
    fn log_sum_exp_is_max_shifted() {
        // the ±90 range the satellite pins: exp(90) overflows f32, so the
        // naive (unshifted) sum is infinite while the shifted one is exact
        let row = [90.0f32, -90.0, 0.0];
        assert!(row.iter().map(|&v| v.exp()).sum::<f32>().is_infinite());
        let lse = log_sum_exp(&row);
        assert!(lse.is_finite());
        let want = 90.0 + (1.0f64 + (-90.0f64).exp() + (-180.0f64).exp()).ln();
        assert!((lse as f64 - want).abs() < 1e-3, "lse {lse} vs {want}");
        // degenerate rows stay well-defined
        assert_eq!(log_sum_exp(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), f32::NEG_INFINITY);
        assert!((log_sum_exp(&[0.0]) - 0.0).abs() < 1e-7);
    }

    #[test]
    fn ppl_survives_large_logits() {
        // Satellite regression (PR 4): perplexity over logits in the ±90
        // range must stay finite — an unshifted softmax cross-entropy
        // overflows exp() to inf and poisons the report.  The head is
        // scaled 60× so the synthetic LM's logits overflow a naive exp
        // while leaving the teacher argmax (the eval labels) unchanged.
        use crate::block::{synthetic_block_model, SyntheticBlockSpec};
        use crate::runtime::Native;
        let mut fx = synthetic_block_model(&SyntheticBlockSpec::default()).unwrap();
        let big = fx.weights["head/lm"].map(|v| v * 60.0);
        fx.weights.insert("head/lm".to_string(), big);
        let native = Native::new();
        let sess = fx.session(&native);
        let ppl = eval_ppl_hidden(&sess, None, "eval_x", "eval_y").unwrap();
        assert!(ppl.is_finite() && ppl >= 1.0, "perplexity must stay finite, got {ppl}");
    }
}
