//! Native reconstruction — learnable rounding with **no PJRT/XLA
//! dependency** (DESIGN.md §Native-Backend, §Rounding-Schemes).
//!
//! This module is the pure-Rust twin of the AOT reconstruction executables:
//! it minimizes the per-unit output MSE `‖X·Ŵᵀ − X·Wᵀ‖²/N` over calibration
//! minibatches with Adam ([`adam`]), exactly as AdaRound (Nagel et al.,
//! 2020) and EPTQ frame per-block reconstruction.  *How* `Ŵ` rounds onto
//! the integer grid — and how that rounding differentiates — is pluggable:
//! every scheme lives behind the [`rounding::Rounding`] trait
//! ([`rounding::FlexRound`] for the paper's Eq. 2 element-wise division and
//! its `rtn`/ablation variants, [`rounding::AdaRound`] for the additive
//! sigmoid-relaxed baseline), resolved once per run from the method string
//! by [`rounding::scheme_for`] and threaded through [`ReconSettings`].
//!
//! The FlexRound kernels (forward, i32 code export, and the closed-form STE
//! backward of Proposition 3.1 with the reciprocal-rule `S2` gradient) moved
//! verbatim into [`rounding::flexround`]; [`fq_forward`], [`fq_codes`], and
//! [`fq_backward`] re-export them under their historical names and the
//! golden-fixture test pins bit-identity through the trait.
//!
//! Rounding uses round-half-to-even to match `jnp.round` (the PJRT path and
//! the Python reference both round ties to even; `f32::round` in the rest of
//! the crate rounds ties away from zero, which only differs on exact
//! halves).
//!
//! Supported natively: weight-only mode on units whose layers are plain
//! contractions (`y = x · Ŵᵀ [+ b]`), optionally ReLU-separated
//! (`mlp_relu`), for methods `rtn`, `flexround`, `flexround_fixed_s1`,
//! `flexround_no_s34`, and `adaround`; `transformer_block` units build on
//! these kernels in [`crate::block`] (scheme forward/backward per
//! projection, attention and layernorm cotangents around them).  Anything
//! needing convolutions or learned (LSQ) activation quantization still runs
//! through the PJRT backend — see `runtime::Backend`; *static* activation
//! quantization is a pack-time concern ([`rounding::ActQuant`]).

pub mod adam;
pub mod rounding;

pub use adam::Adam;
pub use rounding::flexround::{fq_backward, fq_codes, fq_forward};
pub use rounding::{scheme_for, FqGrads, Rounding, SlotParams};

use crate::linalg;
use crate::manifest::{PackEntry, UnitInfo};
use crate::tensor::Tensor;
use crate::util::pool;
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::bail;

/// Round half to even (banker's rounding), matching `jnp.round` and the XLA
/// `round-nearest-even` op bit-for-bit.  Delegates to
/// [`f32::round_ties_even`] (stabilized in Rust 1.77); the hand-rolled
/// floor-based implementation it replaced survives as the property-test
/// oracle below, which pins agreement at negative exact halves and at
/// magnitudes past the f32 integer threshold (`2^23`, where every float is
/// already an integer).
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

// ---------------------------------------------------------------------------
// Parameter pack layout
// ---------------------------------------------------------------------------

/// Where one layer's rounding parameters live inside a flat parameter pack.
/// `None` slots mean "constant one" for FlexRound's divisor factors (e.g.
/// `rtn` has no S2 at all, the `flexround_no_s34` ablation freezes s3/s4 to
/// ones) and "absent" for scheme-specific extras (`v` exists only for
/// AdaRound).
#[derive(Clone, Debug)]
pub struct LayerSlots {
    /// index into `UnitInfo::layers`
    pub layer: usize,
    pub s1: usize,
    pub zp: usize,
    pub s2: Option<usize>,
    pub s3: Option<usize>,
    pub s4: Option<usize>,
    /// AdaRound's continuous rounding variable (shape of `W`)
    pub v: Option<usize>,
}

impl LayerSlots {
    /// Borrow this layer's parameters out of the flat pack.
    pub fn resolve<'a>(&self, params: &'a [Tensor]) -> SlotParams<'a> {
        SlotParams {
            s1: &params[self.s1],
            zp: &params[self.zp],
            s2: self.s2.map(|i| &params[i]),
            s3: self.s3.map(|i| &params[i]),
            s4: self.s4.map(|i| &params[i]),
            v: self.v.map(|i| &params[i]),
        }
    }
}

/// Map a pack-entry list onto per-layer slots for `method`, dispatching to
/// the scheme that owns the method string ([`rounding::scheme_for`]).
///
/// Entry names follow the build-path convention `"{layer}.{key}"`; `act*`
/// entries (LSQ activation steps) mean the pack was built for "wa" mode,
/// which the native backend does not execute.
pub fn map_pack(unit: &UnitInfo, method: &str, entries: &[PackEntry]) -> Result<Vec<LayerSlots>> {
    rounding::scheme_for(method)?.map_pack(unit, method, entries)
}

// ---------------------------------------------------------------------------
// Unit forward (fp + quantized) over contraction layers
// ---------------------------------------------------------------------------

/// One native-executable layer: a plain contraction `y = x · Wᵀ [+ b]`,
/// optionally followed by ReLU (for `mlp_relu` units, every layer but the
/// last).
pub struct LayerDef<'a> {
    pub name: &'a str,
    pub w: &'a Tensor,
    pub bias: Option<&'a Tensor>,
    pub relu_after: bool,
}

fn add_bias_relu(mut y: Tensor, bias: Option<&Tensor>, relu: bool) -> Result<Tensor> {
    let b = bias.map(|t| t.as_f32()).transpose()?;
    y.bias_relu_inplace(b, relu)?;
    Ok(y)
}

/// Full-precision unit forward: `x` through every layer's raw weights.
/// Matmuls go straight through the crate-wide [`crate::linalg::Dispatch`]
/// policy (serial vs output-row-panel fan-out, exact same result either
/// way).
pub fn unit_forward_fp(layers: &[LayerDef], x: &Tensor, workers: usize) -> Result<Tensor> {
    let disp = linalg::Dispatch::new(workers);
    let mut h = x.clone();
    for l in layers {
        h = add_bias_relu(h.matmul_nt_with(l.w, &disp)?, l.bias, l.relu_after)?;
    }
    Ok(h)
}

/// Materialize every layer's fake-quantized Ŵ once (callers forwarding many
/// activation chunks reuse these instead of re-running the scheme's forward
/// per chunk).
pub fn unit_whats(
    scheme: &dyn Rounding,
    layers: &[LayerDef],
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
) -> Result<Vec<Tensor>> {
    if layers.len() != slots.len() {
        bail!("{} layers vs {} slot groups", layers.len(), slots.len());
    }
    layers
        .iter()
        .zip(slots)
        .map(|(l, s)| scheme.forward(l.w, &s.resolve(params), qmin, qmax))
        .collect()
}

/// Forward `x` through pre-materialized fake-quantized weights.
pub fn unit_forward_what(
    layers: &[LayerDef],
    whats: &[Tensor],
    x: &Tensor,
    workers: usize,
) -> Result<Tensor> {
    let disp = linalg::Dispatch::new(workers);
    let mut h = x.clone();
    for (l, what) in layers.iter().zip(whats) {
        h = add_bias_relu(h.matmul_nt_with(what, &disp)?, l.bias, l.relu_after)?;
    }
    Ok(h)
}

/// Quantized unit forward with the current parameter pack.
pub fn unit_forward_q(
    scheme: &dyn Rounding,
    layers: &[LayerDef],
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
    x: &Tensor,
    workers: usize,
) -> Result<Tensor> {
    let whats = unit_whats(scheme, layers, slots, params, qmin, qmax)?;
    unit_forward_what(layers, &whats, x, workers)
}

/// Integer codes (i32) only, per layer — the packed-export hot path
/// (`Session::packed_model`): skips materializing Ŵ entirely.
pub fn export_codes(
    scheme: &dyn Rounding,
    layers: &[LayerDef],
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
) -> Result<Vec<Tensor>> {
    layers
        .iter()
        .zip(slots)
        .map(|(l, s)| scheme.codes(l.w, &s.resolve(params), qmin, qmax))
        .collect()
}

/// Fake-quantized weights + integer codes (i32) for every layer — native
/// analog of the `qw.*` export artifacts, feeding `quant::grid_shifts` and
/// the packed-weight export (`Session::packed_model`).  The grid is computed
/// **once** per layer inside [`Rounding::export`] (codes first, `Ŵ` derived
/// from those same codes), so a scheme cannot desync the two.
pub fn export_qw(
    scheme: &dyn Rounding,
    layers: &[LayerDef],
    slots: &[LayerSlots],
    params: &[Tensor],
    qmin: f32,
    qmax: f32,
) -> Result<Vec<(Tensor, Tensor)>> {
    layers
        .iter()
        .zip(slots)
        .map(|(l, s)| scheme.export(l.w, &s.resolve(params), qmin, qmax))
        .collect()
}

// ---------------------------------------------------------------------------
// Loss + gradients for one minibatch
// ---------------------------------------------------------------------------

/// Forward the minibatch, compute `L = mean((ŷ − y)²)`, and backpropagate
/// through the contraction stack into per-entry parameter gradients.
/// `beta` is the rounding-regularizer temperature for schemes that anneal
/// one ([`rounding::beta_schedule`]); FlexRound ignores it.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads(
    scheme: &dyn Rounding,
    layers: &[LayerDef],
    slots: &[LayerSlots],
    params: &[Tensor],
    xb: &Tensor,
    yb: &Tensor,
    qmin: f32,
    qmax: f32,
    beta: f64,
    workers: usize,
) -> Result<(f64, Vec<Option<Tensor>>)> {
    // Forward, caching per-layer inputs, pre-activations, and Ŵ.  Matmuls
    // (forward and backward) share one crate-wide dispatch policy.
    let disp = linalg::Dispatch::new(workers);
    let mut acts: Vec<Tensor> = vec![xb.clone()]; // acts[i] = input to layer i
    let mut pres: Vec<Tensor> = Vec::with_capacity(layers.len());
    let mut whats: Vec<Tensor> = Vec::with_capacity(layers.len());
    for (l, s) in layers.iter().zip(slots) {
        let what = scheme.forward(l.w, &s.resolve(params), qmin, qmax)?;
        let pre = add_bias_relu(
            acts.last().unwrap().matmul_nt_with(&what, &disp)?,
            l.bias,
            false,
        )?;
        let out = if l.relu_after { pre.map(|v| v.max(0.0)) } else { pre.clone() };
        pres.push(pre);
        whats.push(what);
        acts.push(out);
    }
    let yhat = acts.last().unwrap();
    let loss = yhat.mse(yb)? as f64;

    // ∂L/∂ŷ = 2(ŷ − y)/N
    let n_inv = 2.0 / yhat.len() as f32;
    let mut g = yhat.zip(yb, move |a, b| n_inv * (a - b))?;

    let mut grads: Vec<Option<Tensor>> = params.iter().map(|_| None).collect();
    for li in (0..layers.len()).rev() {
        let l = &layers[li];
        let s = &slots[li];
        if l.relu_after {
            g = g.zip(&pres[li], |gi, pre| if pre > 0.0 { gi } else { 0.0 })?;
        }
        // ∂L/∂Ŵ = Gᵀ · X  (r, c)
        let dwhat = g.matmul_tn_with(&acts[li], &disp)?;
        let fg = scheme.backward(l.w, &s.resolve(params), &dwhat, qmin, qmax, beta)?;
        scatter_grads(&mut grads, s, fg);
        if li > 0 {
            // ∂L/∂X = G · Ŵ  (n, c) feeds the next layer down.
            g = g.matmul_nn_with(&whats[li], &disp)?;
        }
    }
    Ok((loss, grads))
}

/// Place one layer's [`FqGrads`] into the flat per-entry gradient vector.
/// Shared by the stack backward above and the block backward
/// (`block::loss_and_grads`).
pub fn scatter_grads(grads: &mut [Option<Tensor>], s: &LayerSlots, fg: FqGrads) {
    grads[s.s1] = Some(fg.ds1);
    if let (Some(i), Some(d)) = (s.s2, fg.ds2) {
        grads[i] = Some(d);
    }
    if let (Some(i), Some(d)) = (s.s3, fg.ds3) {
        grads[i] = Some(d);
    }
    if let (Some(i), Some(d)) = (s.s4, fg.ds4) {
        grads[i] = Some(d);
    }
    if let (Some(i), Some(d)) = (s.v, fg.dv) {
        grads[i] = Some(d);
    }
}

// ---------------------------------------------------------------------------
// The per-unit reconstruction loop
// ---------------------------------------------------------------------------

pub struct ReconSettings {
    pub iters: usize,
    pub lr: f32,
    pub batch: usize,
    pub qmin: f32,
    pub qmax: f32,
    pub workers: usize,
    pub verbose: bool,
    /// label for progress lines, e.g. "model/unit"
    pub tag: String,
    /// the rounding scheme under reconstruction (resolved once from the
    /// method string by [`rounding::scheme_for`])
    pub scheme: &'static dyn Rounding,
}

pub struct ReconResult {
    pub params: Vec<Tensor>,
    pub first_loss: f64,
    pub final_loss: f64,
    pub steps: u64,
}

/// The shared Adam reconstruction driver: `cfg.iters` steps of
/// `step(rng, params, t) → (loss, grads)` with first/final-loss bookkeeping,
/// the positivity-clamped [`Adam`] update, and throttled progress logging.
/// Every minibatch-sampling strategy (row sampling here, sequence sampling
/// in `block::reconstruct_block`, chunk-streamed sampling in the pipeline)
/// is one closure over this loop — the bookkeeping exists exactly once.
/// The 1-based step index `t` feeds the regularizer annealing
/// ([`rounding::beta_schedule`]) of schemes that need it.
pub fn run_adam(
    entries: &[PackEntry],
    params0: &[Tensor],
    cfg: &ReconSettings,
    rng: &mut Pcg32,
    mut step: impl FnMut(&mut Pcg32, &[Tensor], usize) -> Result<(f64, Vec<Option<Tensor>>)>,
) -> Result<ReconResult> {
    let mut params: Vec<Tensor> = params0.to_vec();
    let mut opt = Adam::new(&params);
    let mut first_loss = f64::NAN;
    let mut final_loss = f64::NAN;
    // per-scheme step counter, resolved once per reconstruction run
    let scheme_steps =
        crate::obs::counter(&format!("flexround_recon_steps_{}_total", cfg.scheme.name()));
    for t in 1..=cfg.iters {
        let _span = crate::obs::span("recon/adam_step");
        let (loss, grads) = step(rng, &params, t)?;
        if t == 1 {
            first_loss = loss;
        }
        final_loss = loss;
        opt.step(t, cfg.lr, entries, &mut params, &grads)?;
        crate::obs_counter!("flexround_recon_steps_total").inc();
        scheme_steps.inc();
        if cfg.verbose && (t == 1 || t % 100 == 0 || t == cfg.iters) {
            eprintln!("    [{}] iter {t}/{} loss {loss:.6}", cfg.tag, cfg.iters);
        }
    }
    Ok(ReconResult { params, first_loss, final_loss, steps: cfg.iters as u64 })
}

/// Learn the pack parameters for one unit: Adam over random calibration
/// minibatches, loss/step bookkeeping identical to the PJRT loop.
pub fn reconstruct_unit(
    layers: &[LayerDef],
    slots: &[LayerSlots],
    entries: &[PackEntry],
    params0: &[Tensor],
    x: &Tensor,
    y: &Tensor,
    cfg: &ReconSettings,
    rng: &mut Pcg32,
) -> Result<ReconResult> {
    if x.shape()[0] != y.shape()[0] {
        bail!("calibration rows {} vs target rows {}", x.shape()[0], y.shape()[0]);
    }
    let n = x.shape()[0];
    let batch = cfg.batch.clamp(1, n);
    run_adam(entries, params0, cfg, rng, |rng, params, t| {
        let idx = rng.sample_indices(n, batch);
        let xb = x.gather_rows(&idx)?;
        let yb = y.gather_rows(&idx)?;
        let beta = rounding::beta_schedule(t, cfg.iters);
        loss_and_grads(
            cfg.scheme, layers, slots, params, &xb, &yb, cfg.qmin, cfg.qmax, beta, cfg.workers,
        )
    })
}

// ---------------------------------------------------------------------------
// Synthetic problems (selftest, benches, tests)
// ---------------------------------------------------------------------------

/// A self-contained single-layer reconstruction problem: weights, a
/// calibration set, FP targets, and a FlexRound pack initialized at the RTN
/// solution (per-row min/max s1, S2 = s3 = s4 = 1).
pub struct Synthetic {
    pub w: Tensor,
    pub x: Tensor,
    pub y: Tensor,
    pub entries: Vec<PackEntry>,
    pub params: Vec<Tensor>,
    pub qmin: f32,
    pub qmax: f32,
}

pub fn synthetic_problem(rows: usize, cols: usize, batch: usize, bits: u32, seed: u64) -> Synthetic {
    use crate::tensor::{minmax_scale, qrange};
    let mut rng = Pcg32::seeded(seed);
    let wv: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal() * 0.4).collect();
    let xv: Vec<f32> = (0..batch * cols).map(|_| rng.next_normal()).collect();
    let w = Tensor::from_f32(wv, &[rows, cols]).expect("w shape");
    let x = Tensor::from_f32(xv, &[batch, cols]).expect("x shape");
    let y = x.matmul_nt(&w).expect("targets");
    let (qmin, qmax) = qrange(bits, true);
    let mut s1 = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &w.as_f32().expect("f32")[r * cols..(r + 1) * cols];
        s1.push(minmax_scale(row, bits, true).0);
    }
    let entry = |name: &str, shape: &[usize], learnable: bool| PackEntry {
        name: name.to_string(),
        shape: shape.to_vec(),
        learnable,
    };
    let entries = vec![
        entry("fc.s1", &[rows, 1], true),
        entry("fc.s2", &[rows, cols], true),
        entry("fc.s3", &[rows, 1], true),
        entry("fc.s4", &[1, cols], true),
        entry("fc.zp", &[rows, 1], false),
    ];
    let params = vec![
        Tensor::from_f32(s1, &[rows, 1]).expect("s1"),
        Tensor::full(&[rows, cols], 1.0),
        Tensor::full(&[rows, 1], 1.0),
        Tensor::full(&[1, cols], 1.0),
        Tensor::zeros(&[rows, 1]),
    ];
    Synthetic { w, x, y, entries, params, qmin, qmax }
}

/// Slot layout matching [`synthetic_problem`]'s pack order.
pub fn synthetic_slots() -> Vec<LayerSlots> {
    vec![LayerSlots { layer: 0, s1: 0, zp: 4, s2: Some(1), s3: Some(2), s4: Some(3), v: None }]
}

/// [`synthetic_problem`] re-packed for AdaRound: same weights, calibration
/// set, targets, and grid, but the pack is `(s1 frozen, V learnable, zp)`
/// with `V` at the RTN-fraction init ([`rounding::adaround::init_v`]).
pub fn synthetic_problem_adaround(
    rows: usize,
    cols: usize,
    batch: usize,
    bits: u32,
    seed: u64,
) -> Synthetic {
    let p = synthetic_problem(rows, cols, batch, bits, seed);
    let entry = |name: &str, shape: &[usize], learnable: bool| PackEntry {
        name: name.to_string(),
        shape: shape.to_vec(),
        learnable,
    };
    let v = rounding::adaround::init_v(&p.w, &p.params[0]).expect("init v");
    let entries = vec![
        entry("fc.s1", &[rows, 1], false),
        entry("fc.v", &[rows, cols], true),
        entry("fc.zp", &[rows, 1], false),
    ];
    let params = vec![p.params[0].clone(), v, p.params[4].clone()];
    Synthetic { entries, params, ..p }
}

/// Slot layout matching [`synthetic_problem_adaround`]'s pack order.
pub fn synthetic_slots_adaround() -> Vec<LayerSlots> {
    vec![LayerSlots { layer: 0, s1: 0, zp: 2, s2: None, s3: None, s4: None, v: Some(1) }]
}

/// Artifact-free smoke test of the native engine: reconstruct one synthetic
/// unit and report the RTN-init vs learned full-batch MSE.  Returns
/// `(mse_rtn, mse_learned)`; errors if learning failed to improve.
pub fn native_selftest(verbose: bool) -> Result<(f64, f64)> {
    let p = synthetic_problem(16, 32, 256, 3, 7);
    let slots = synthetic_slots();
    let layers =
        [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
    let workers = pool::default_workers();
    let scheme = rounding::scheme_for("flexround")?;
    let before = unit_forward_q(scheme, &layers, &slots, &p.params, p.qmin, p.qmax, &p.x, workers)?
        .mse(&p.y)? as f64;
    let cfg = ReconSettings {
        iters: 300,
        lr: 4e-3,
        batch: 32,
        qmin: p.qmin,
        qmax: p.qmax,
        workers,
        verbose,
        tag: "selftest/fc".to_string(),
        scheme,
    };
    let mut rng = Pcg32::seeded(7);
    let r = reconstruct_unit(&layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng)?;
    let after = unit_forward_q(scheme, &layers, &slots, &r.params, p.qmin, p.qmax, &p.x, workers)?
        .mse(&p.y)? as f64;
    if !(after < before) {
        bail!("native selftest: reconstruction did not improve MSE ({before:.6} → {after:.6})");
    }
    Ok((before, after))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn ties_round_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        assert_eq!(round_ties_even(1.2), 1.0);
        assert_eq!(round_ties_even(-1.7), -2.0);
    }

    /// The pre-delegation floor-based implementation — kept as the oracle
    /// for the std delegation.
    fn round_ties_even_ref(x: f32) -> f32 {
        let f = x.floor();
        if x - f == 0.5 {
            if f.rem_euclid(2.0) == 0.0 {
                f
            } else {
                f + 1.0
            }
        } else {
            x.round()
        }
    }

    #[test]
    fn ties_negative_exact_halves() {
        // every representable half in [−64, 64): the tie must land on the
        // even neighbor, with the sign handled correctly
        for n in -64i32..64 {
            let x = n as f32 + 0.5; // exactly representable
            let r = round_ties_even(x);
            assert_eq!(r % 2.0, 0.0, "round_ties_even({x}) = {r} is odd");
            assert!((r - x).abs() <= 0.5, "round_ties_even({x}) = {r} not nearest");
            assert_eq!(r, round_ties_even_ref(x), "std vs reference at {x}");
            // negation symmetry: banker's rounding is odd-symmetric
            assert_eq!(round_ties_even(-x), -r, "sign asymmetry at {x}");
        }
    }

    #[test]
    fn ties_large_magnitudes_near_f32_integer_threshold() {
        // at |x| ≥ 2^23 every f32 is an integer: rounding is the identity
        let threshold = (1u32 << 23) as f32;
        for &x in &[
            threshold,
            threshold + 1.0,
            -threshold,
            -(threshold + 1.0),
            threshold * 1024.0,
            f32::MAX,
            f32::MIN,
        ] {
            assert_eq!(round_ties_even(x), x, "large magnitude {x} must be a fixed point");
            assert_eq!(round_ties_even(x), round_ties_even_ref(x));
        }
        // the last non-integer f32 scale: 2^23 − 0.5 is representable and
        // ties to the even 2^23
        let x = threshold - 0.5;
        assert_eq!(round_ties_even(x), threshold);
        assert_eq!(round_ties_even(-x), -threshold);
    }

    #[test]
    fn round_ties_even_agrees_with_reference_everywhere() {
        Prop::new("std round_ties_even ≡ floor-based reference").cases(4000).check(|rng| {
            // mix magnitudes: dense near the grid, sparse out to 2^24
            let x = match rng.below(3) {
                0 => (rng.next_f32() - 0.5) * 8.0,
                1 => (rng.next_f32() - 0.5) * 1e4,
                _ => (rng.next_f32() - 0.5) * 3e7,
            };
            // include exact halves often: snap a third of the cases
            let x = if rng.below(3) == 0 { x.floor() + 0.5 } else { x };
            let (got, want) = (round_ties_even(x), round_ties_even_ref(x));
            if got != want {
                return Err(format!("x = {x}: std {got} vs reference {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fq_all_ones_is_rtn() {
        // With S2 = s3 = s4 = 1 the forward is plain RTN (ties aside).
        let w = Tensor::from_f32(vec![0.31, -0.62, 0.08, 1.2, -0.9, 0.44], &[2, 3]).unwrap();
        let s1 = Tensor::from_f32(vec![0.1, 0.2], &[2, 1]).unwrap();
        let zp = Tensor::zeros(&[2, 1]);
        let what = fq_forward(&w, &s1, None, None, None, &zp, -8.0, 7.0).unwrap();
        let expect_r0 = crate::tensor::rtn(&w.as_f32().unwrap()[..3], 0.1, 0.0, -8.0, 7.0);
        let expect_r1 = crate::tensor::rtn(&w.as_f32().unwrap()[3..], 0.2, 0.0, -8.0, 7.0);
        let got = what.as_f32().unwrap();
        for (a, b) in got[..3].iter().zip(&expect_r0) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        for (a, b) in got[3..].iter().zip(&expect_r1) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn codes_on_grid_and_scaled_consistent() {
        Prop::new("fq codes integral and Ŵ = s1·(codes − zp)").cases(60).check(|rng| {
            let r = 1 + rng.below(5) as usize;
            let c = 1 + rng.below(8) as usize;
            let w = Tensor::from_f32(
                (0..r * c).map(|_| rng.next_normal()).collect(),
                &[r, c],
            )
            .map_err(|e| e.to_string())?;
            let s1 = Tensor::from_f32(
                (0..r).map(|_| 0.02 + rng.next_f32() * 0.3).collect(),
                &[r, 1],
            )
            .map_err(|e| e.to_string())?;
            let s2 = Tensor::from_f32(
                (0..r * c).map(|_| 0.8 + 0.4 * rng.next_f32()).collect(),
                &[r, c],
            )
            .map_err(|e| e.to_string())?;
            let zp = Tensor::from_f32(
                (0..r).map(|_| rng.below(5) as f32 - 2.0).collect(),
                &[r, 1],
            )
            .map_err(|e| e.to_string())?;
            let (qmin, qmax) = (-8.0, 7.0);
            let codes =
                fq_codes(&w, &s1, Some(&s2), None, None, &zp, qmin, qmax).map_err(|e| e.to_string())?;
            let what =
                fq_forward(&w, &s1, Some(&s2), None, None, &zp, qmin, qmax).map_err(|e| e.to_string())?;
            let cv = codes.to_f32_vec(); // codes export as i32 (packable)
            let wv = what.as_f32().map_err(|e| e.to_string())?;
            let s1v = s1.as_f32().map_err(|e| e.to_string())?;
            let zv = zp.as_f32().map_err(|e| e.to_string())?;
            for i in 0..r {
                for j in 0..c {
                    let k = i * c + j;
                    let code = cv[k];
                    if !(qmin..=qmax).contains(&code) || (code - code.round()).abs() > 1e-5 {
                        return Err(format!("code {code} off-grid"));
                    }
                    let expect = s1v[i] * (code - zv[i]);
                    if (wv[k] - expect).abs() > 1e-5 {
                        return Err(format!("Ŵ {} vs s1·(n−z) {expect}", wv[k]));
                    }
                }
            }
            Ok(())
        });
    }

    /// STE surrogate in f64: round(·) replaced by identity + the frozen
    /// offset `c0 = round(r₀) − r₀`, which makes the surrogate smooth,
    /// equal in value to the real forward at the base point, and equal in
    /// derivative to the straight-through estimator everywhere off the clip
    /// boundary.  Finite differences of this must match `fq_backward`.
    #[allow(clippy::too_many_arguments)]
    fn ste_surrogate(
        w: &[f64],
        r: usize,
        c: usize,
        s1: &[f64],
        s2: &[f64],
        s3: &[f64],
        s4: &[f64],
        zp: &[f64],
        c0: &[f64],
        g: &[f64],
        qmin: f64,
        qmax: f64,
    ) -> f64 {
        let mut acc = 0.0;
        for i in 0..r {
            for j in 0..c {
                let k = i * c + j;
                let div = s1[i] * s2[k] * s3[i] * s4[j];
                let n = w[k] / div + c0[k] + zp[i];
                let n_c = n.clamp(qmin, qmax);
                acc += g[k] * s1[i] * (n_c - zp[i]);
            }
        }
        acc
    }

    #[test]
    fn backward_matches_finite_differences() {
        Prop::new("STE grads vs finite differences").cases(25).check(|rng| {
            let (r, c) = (2 + rng.below(3) as usize, 2 + rng.below(4) as usize);
            let wv: Vec<f32> = (0..r * c).map(|_| rng.next_normal() * 0.5).collect();
            let s1v: Vec<f32> = (0..r).map(|_| 0.05 + 0.2 * rng.next_f32()).collect();
            let s2v: Vec<f32> = (0..r * c).map(|_| 0.85 + 0.3 * rng.next_f32()).collect();
            let s3v: Vec<f32> = (0..r).map(|_| 0.9 + 0.2 * rng.next_f32()).collect();
            let s4v: Vec<f32> = (0..c).map(|_| 0.9 + 0.2 * rng.next_f32()).collect();
            let zpv: Vec<f32> = vec![0.0; r];
            let gv: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
            // 5-bit grid: some elements clip, most don't.
            let (qmin, qmax) = (-16.0f32, 15.0f32);

            let w = Tensor::from_f32(wv.clone(), &[r, c]).unwrap();
            let s1 = Tensor::from_f32(s1v.clone(), &[r, 1]).unwrap();
            let s2 = Tensor::from_f32(s2v.clone(), &[r, c]).unwrap();
            let s3 = Tensor::from_f32(s3v.clone(), &[r, 1]).unwrap();
            let s4 = Tensor::from_f32(s4v.clone(), &[1, c]).unwrap();
            let zp = Tensor::from_f32(zpv.clone(), &[r, 1]).unwrap();
            let g = Tensor::from_f32(gv.clone(), &[r, c]).unwrap();
            let fg = fq_backward(&w, &s1, Some(&s2), Some(&s3), Some(&s4), &zp, &g, qmin, qmax)
                .map_err(|e| e.to_string())?;

            // f64 copies + frozen rounding offsets at the base point.
            let f64v = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
            let (wd, s1d, s2d, s3d, s4d, zpd, gd) = (
                f64v(&wv), f64v(&s1v), f64v(&s2v), f64v(&s3v), f64v(&s4v), f64v(&zpv), f64v(&gv),
            );
            let mut c0 = vec![0.0f64; r * c];
            let mut boundary = false;
            for i in 0..r {
                for j in 0..c {
                    let k = i * c + j;
                    let ratio = wd[k] / (s1d[i] * s2d[k] * s3d[i] * s4d[j]);
                    c0[k] = (round_ties_even(ratio as f32) as f64) - ratio;
                    let n = ratio + c0[k] + zpd[i];
                    // skip cases razor-close to the clip boundary (the STE
                    // mask flips there and finite differences straddle it)
                    if (n - qmin as f64).abs() < 2e-2 || (n - qmax as f64).abs() < 2e-2 {
                        boundary = true;
                    }
                }
            }
            if boundary {
                return Ok(());
            }

            let eval = |s1x: &[f64], s2x: &[f64], s3x: &[f64], s4x: &[f64]| {
                ste_surrogate(&wd, r, c, s1x, s2x, s3x, s4x, &zpd, &c0, &gd,
                              qmin as f64, qmax as f64)
            };
            let check = |analytic: f32, numeric: f64, what: &str| -> std::result::Result<(), String> {
                let tol = 2e-3 * numeric.abs().max(analytic.abs() as f64).max(1.0);
                if ((analytic as f64) - numeric).abs() > tol {
                    return Err(format!("{what}: analytic {analytic} vs numeric {numeric}"));
                }
                Ok(())
            };

            let ds1 = fg.ds1.as_f32().unwrap();
            for i in 0..r {
                let mut hi = s1d.clone();
                let mut lo = s1d.clone();
                let eps = (1e-4f64).max(1e-4 * s1d[i].abs());
                hi[i] += eps;
                lo[i] -= eps;
                let num = (eval(&hi, &s2d, &s3d, &s4d) - eval(&lo, &s2d, &s3d, &s4d)) / (2.0 * eps);
                check(ds1[i], num, "ds1")?;
            }
            let ds2 = fg.ds2.as_ref().unwrap().as_f32().unwrap();
            for k in 0..r * c {
                let mut hi = s2d.clone();
                let mut lo = s2d.clone();
                let eps = 1e-4;
                hi[k] += eps;
                lo[k] -= eps;
                let num = (eval(&s1d, &hi, &s3d, &s4d) - eval(&s1d, &lo, &s3d, &s4d)) / (2.0 * eps);
                check(ds2[k], num, "ds2 (reciprocal rule)")?;
            }
            let ds3 = fg.ds3.as_ref().unwrap().as_f32().unwrap();
            for i in 0..r {
                let mut hi = s3d.clone();
                let mut lo = s3d.clone();
                let eps = 1e-4;
                hi[i] += eps;
                lo[i] -= eps;
                let num = (eval(&s1d, &s2d, &hi, &s4d) - eval(&s1d, &s2d, &lo, &s4d)) / (2.0 * eps);
                check(ds3[i], num, "ds3")?;
            }
            let ds4 = fg.ds4.as_ref().unwrap().as_f32().unwrap();
            for j in 0..c {
                let mut hi = s4d.clone();
                let mut lo = s4d.clone();
                let eps = 1e-4;
                hi[j] += eps;
                lo[j] -= eps;
                let num = (eval(&s1d, &s2d, &s3d, &hi) - eval(&s1d, &s2d, &s3d, &lo)) / (2.0 * eps);
                check(ds4[j], num, "ds4")?;
            }
            Ok(())
        });
    }

    #[test]
    fn clipped_elements_zero_reciprocal_grad() {
        // A weight far outside the 2-bit grid saturates: the divisor path is
        // dead (inside = 0) so dS2 = 0, while ds1 keeps the (n_c − z) term.
        let w = Tensor::from_f32(vec![50.0], &[1, 1]).unwrap();
        let s1 = Tensor::from_f32(vec![1.0], &[1, 1]).unwrap();
        let s2 = Tensor::from_f32(vec![1.0], &[1, 1]).unwrap();
        let zp = Tensor::zeros(&[1, 1]);
        let g = Tensor::from_f32(vec![1.0], &[1, 1]).unwrap();
        let fg = fq_backward(&w, &s1, Some(&s2), None, None, &zp, &g, -2.0, 1.0).unwrap();
        assert_eq!(fg.ds2.unwrap().as_f32().unwrap()[0], 0.0);
        assert_eq!(fg.ds1.as_f32().unwrap()[0], 1.0); // n_c − z = qmax = 1
    }

    #[test]
    fn map_pack_layouts() {
        use crate::manifest::{LayerInfo, UnitInfo};
        use std::collections::BTreeMap;
        let unit = UnitInfo {
            name: "u0".into(),
            kind: "linear".into(),
            bits_override: None,
            in_shape: vec![4],
            out_shape: vec![2],
            act_sites: 0,
            heads: 1,
            layers: vec![LayerInfo {
                name: "fc".into(),
                kind: "linear".into(),
                rows: 2,
                cols: 4,
                conv_shape: None,
                stride: 1,
            }],
            artifacts: BTreeMap::new(),
            packs: BTreeMap::new(),
        };
        let e = |n: &str| PackEntry { name: n.into(), shape: vec![1], learnable: true };
        let entries =
            vec![e("fc.s1"), e("fc.s2"), e("fc.s3"), e("fc.s4"), e("fc.zp")];
        let s = map_pack(&unit, "flexround", &entries).unwrap();
        assert_eq!(s[0].s1, 0);
        assert_eq!(s[0].s2, Some(1));
        assert_eq!(s[0].s4, Some(3));
        assert_eq!(s[0].zp, 4);
        assert_eq!(s[0].v, None);
        // the no-s34 ablation freezes those factors to ones
        let s = map_pack(&unit, "flexround_no_s34", &entries).unwrap();
        assert_eq!(s[0].s3, None);
        assert_eq!(s[0].s4, None);
        // rtn needs only s1/zp
        let entries_rtn = vec![e("fc.s1"), e("fc.zp")];
        let s = map_pack(&unit, "rtn", &entries_rtn).unwrap();
        assert_eq!(s[0].s2, None);
        // adaround requires a V entry: fails on a FlexRound pack, resolves
        // its (s1, v, zp) layout on its own
        assert!(map_pack(&unit, "adaround", &entries).is_err());
        let entries_ada = vec![e("fc.s1"), e("fc.v"), e("fc.zp")];
        let s = map_pack(&unit, "adaround", &entries_ada).unwrap();
        assert_eq!(s[0].s1, 0);
        assert_eq!(s[0].v, Some(1));
        assert_eq!(s[0].zp, 2);
        assert_eq!(s[0].s2, None);
        // unknown methods name the scheme table
        assert!(map_pack(&unit, "lsq", &entries).is_err());
        let mut with_act = entries.clone();
        with_act.push(e("act0.step"));
        assert!(map_pack(&unit, "flexround", &with_act).is_err());
        assert!(map_pack(&unit, "adaround", &{
            let mut v = entries_ada.clone();
            v.push(e("act0.step"));
            v
        })
        .is_err());
    }

    #[test]
    fn dispatched_matmul_matches_serial() {
        // linalg::Dispatch fan-out is bit-identical to the serial kernel —
        // the invariant every recon matmul call site leans on now that they
        // go straight through `matmul_nt_with`.
        let mut rng = Pcg32::seeded(3);
        let a = Tensor::from_f32((0..64 * 48).map(|_| rng.next_normal()).collect(), &[64, 48])
            .unwrap();
        let b = Tensor::from_f32((0..96 * 48).map(|_| rng.next_normal()).collect(), &[96, 48])
            .unwrap();
        let serial = a.matmul_nt(&b).unwrap();
        let par = a.matmul_nt_with(&b, &linalg::Dispatch::new(4)).unwrap();
        assert_eq!(serial.shape(), par.shape());
        for (x, y) in serial.as_f32().unwrap().iter().zip(par.as_f32().unwrap()) {
            assert_eq!(x, y, "row-sliced parallel matmul must be bit-identical");
        }
    }

    #[test]
    fn selftest_improves_mse() {
        let (before, after) = native_selftest(false).unwrap();
        assert!(after < before * 0.9, "expected ≥10% MSE reduction: {before} → {after}");
    }

    #[test]
    fn reconstruction_is_deterministic() {
        let p = synthetic_problem(8, 12, 64, 4, 11);
        let slots = synthetic_slots();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let cfg = ReconSettings {
            iters: 25,
            lr: 3e-3,
            batch: 16,
            qmin: p.qmin,
            qmax: p.qmax,
            workers: 4,
            verbose: false,
            tag: "det".into(),
            scheme: scheme_for("flexround").unwrap(),
        };
        let run = || {
            let mut rng = Pcg32::seeded(5);
            reconstruct_unit(&layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng)
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.final_loss, b.final_loss);
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }

    #[test]
    fn adaround_reconstruction_improves_hard_rounding() {
        // AdaRound starts at the RTN-fraction init (soft forward ≈ FP) and
        // must land hard-rounded decisions that beat plain RTN on the
        // full-batch MSE.
        let p = synthetic_problem_adaround(16, 32, 256, 3, 7);
        let slots = synthetic_slots_adaround();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let scheme = scheme_for("adaround").unwrap();
        // RTN baseline: the same grid with all rounding decisions at ⌊·⌉
        let rtn = synthetic_problem(16, 32, 256, 3, 7);
        let rtn_what =
            fq_forward(&rtn.w, &rtn.params[0], None, None, None, &rtn.params[4], p.qmin, p.qmax)
                .unwrap();
        let mse_rtn = p.x.matmul_nt(&rtn_what).unwrap().mse(&p.y).unwrap() as f64;

        let cfg = ReconSettings {
            iters: 400,
            lr: 1e-2,
            batch: 32,
            qmin: p.qmin,
            qmax: p.qmax,
            workers: 1,
            verbose: false,
            tag: "ada".into(),
            scheme,
        };
        let mut rng = Pcg32::seeded(7);
        let r = reconstruct_unit(&layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng)
            .unwrap();
        // evaluate at the HARD export (what actually ships)
        let sp = slots[0].resolve(&r.params);
        let (what, _) = scheme.export(&p.w, &sp, p.qmin, p.qmax).unwrap();
        let mse_hard = p.x.matmul_nt(&what).unwrap().mse(&p.y).unwrap() as f64;
        assert!(
            mse_hard <= mse_rtn * 1.02,
            "adaround hard export should not lose to RTN: {mse_hard} vs {mse_rtn}"
        );
    }

    #[test]
    fn adaround_reconstruction_is_deterministic() {
        let p = synthetic_problem_adaround(8, 12, 64, 4, 11);
        let slots = synthetic_slots_adaround();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let cfg = ReconSettings {
            iters: 25,
            lr: 1e-2,
            batch: 16,
            qmin: p.qmin,
            qmax: p.qmax,
            workers: 4,
            verbose: false,
            tag: "ada-det".into(),
            scheme: scheme_for("adaround").unwrap(),
        };
        let run = || {
            let mut rng = Pcg32::seeded(5);
            reconstruct_unit(&layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng)
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.final_loss, b.final_loss);
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }

    #[test]
    fn mlp_relu_backprop_improves() {
        // Two-layer ReLU stack: checks the activation cotangent path.
        let mut rng = Pcg32::seeded(23);
        let w1 = Tensor::from_f32((0..12 * 8).map(|_| rng.next_normal() * 0.5).collect(), &[12, 8])
            .unwrap();
        let w2 = Tensor::from_f32((0..6 * 12).map(|_| rng.next_normal() * 0.5).collect(), &[6, 12])
            .unwrap();
        let x = Tensor::from_f32((0..96 * 8).map(|_| rng.next_normal()).collect(), &[96, 8])
            .unwrap();
        let layers = [
            LayerDef { name: "up", w: &w1, bias: None, relu_after: true },
            LayerDef { name: "down", w: &w2, bias: None, relu_after: false },
        ];
        let y = unit_forward_fp(&layers, &x, 1).unwrap();
        let p1 = synthetic_pack_for(&w1, "up", 3);
        let p2 = synthetic_pack_for(&w2, "down", 3);
        let mut entries = p1.0;
        let base = entries.len();
        entries.extend(p2.0);
        let mut params = p1.1;
        params.extend(p2.1);
        let slots = vec![
            LayerSlots { layer: 0, s1: 0, zp: 4, s2: Some(1), s3: Some(2), s4: Some(3), v: None },
            LayerSlots {
                layer: 1,
                s1: base,
                zp: base + 4,
                s2: Some(base + 1),
                s3: Some(base + 2),
                s4: Some(base + 3),
                v: None,
            },
        ];
        let scheme = scheme_for("flexround").unwrap();
        let cfg = ReconSettings {
            iters: 200,
            lr: 4e-3,
            batch: 32,
            qmin: -4.0,
            qmax: 3.0,
            workers: 1,
            verbose: false,
            tag: "mlp".into(),
            scheme,
        };
        let before = unit_forward_q(scheme, &layers, &slots, &params, -4.0, 3.0, &x, 1)
            .unwrap()
            .mse(&y)
            .unwrap();
        let mut r = Pcg32::seeded(2);
        let res =
            reconstruct_unit(&layers, &slots, &entries, &params, &x, &y, &cfg, &mut r).unwrap();
        let after = unit_forward_q(scheme, &layers, &slots, &res.params, -4.0, 3.0, &x, 1)
            .unwrap()
            .mse(&y)
            .unwrap();
        assert!(after < before, "mlp recon should improve: {before} → {after}");
    }

    /// FlexRound pack (entries, params) for one weight tensor at RTN init.
    fn synthetic_pack_for(w: &Tensor, layer: &str, bits: u32) -> (Vec<PackEntry>, Vec<Tensor>) {
        use crate::tensor::minmax_scale;
        let (rows, cols) = (w.shape()[0], w.shape()[1]);
        let wv = w.as_f32().unwrap();
        let s1: Vec<f32> = (0..rows)
            .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], bits, true).0)
            .collect();
        let entry = |k: &str, shape: &[usize], learn: bool| PackEntry {
            name: format!("{layer}.{k}"),
            shape: shape.to_vec(),
            learnable: learn,
        };
        (
            vec![
                entry("s1", &[rows, 1], true),
                entry("s2", &[rows, cols], true),
                entry("s3", &[rows, 1], true),
                entry("s4", &[1, cols], true),
                entry("zp", &[rows, 1], false),
            ],
            vec![
                Tensor::from_f32(s1, &[rows, 1]).unwrap(),
                Tensor::full(&[rows, cols], 1.0),
                Tensor::full(&[rows, 1], 1.0),
                Tensor::full(&[1, cols], 1.0),
                Tensor::zeros(&[rows, 1]),
            ],
        )
    }
}
