//! AdaRound ("Up or Down? Adaptive Rounding for Post-Training
//! Quantization", Nagel et al., 2020) as a [`Rounding`] impl — the additive
//! soft-rounding baseline FlexRound was designed to beat.
//!
//! Training-time forward (grid scale `s1` and zero point `z` frozen at their
//! RTN values; only the continuous rounding variable `V` learns):
//!
//! ```text
//!   h(V) = clip(1.2·σ(V) − 0.1, 0, 1)            (rectified sigmoid)
//!   Ŵ    = s1 · ( clip( ⌊W/s1⌋ + h(V) + z, qmin, qmax ) − z )
//! ```
//!
//! The backward is the straight-through estimator through the clip plus the
//! exact derivative of the rectified sigmoid, with the paper's annealed
//! rounding regularizer `f_reg(V) = Σ 1 − |2·h(V) − 1|^β` added directly to
//! the `V` cotangent (`β` from [`super::beta_schedule`]: high β early leaves
//! `h` free, low β late forces every `h` to commit to 0 or 1):
//!
//! ```text
//!   ∂Ŵ/∂V    = s1 · 1[inside] · h′(V)
//!   h′(V)    = 1.2·σ(V)·(1 − σ(V))   gated to 0 where h is rectified
//!   ∂f_reg/∂V = −2β·|2h − 1|^{β−1}·sign(2h − 1) · h′(V)
//! ```
//!
//! Export hard-rounds the learned decision: `⌊W/s1⌋ + 1[h(V) ≥ ½] + z`,
//! clipped — at convergence (V saturated by the regularizer) this equals the
//! soft forward, which is what the trait-conformance suite pins.

use super::{row_scale, FqGrads, Rounding, SlotParams};
use crate::manifest::{PackEntry, UnitInfo};
use crate::recon::LayerSlots;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};

/// Weight of the rounding regularizer relative to the reconstruction MSE
/// (the paper's λ; fixed — the annealing lives in β, not λ).
pub const REG_WEIGHT: f32 = 0.01;

/// The AdaRound scheme.
pub struct AdaRound;

/// Rectified sigmoid `h(V)` (Eq. 23 of the paper): stretches σ by 1.2 and
/// shifts by −0.1 so `h` actually *reaches* 0 and 1 at finite V.
#[inline]
pub fn rectified_sigmoid(v: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-v).exp());
    (1.2 * sig - 0.1).clamp(0.0, 1.0)
}

impl Rounding for AdaRound {
    fn name(&self) -> &'static str {
        "adaround"
    }

    /// Per layer: `{layer}.s1` (frozen grid), `{layer}.v` (learnable, shape
    /// of `W`), `{layer}.zp` (frozen).  No divisor factors.
    fn map_pack(
        &self,
        unit: &UnitInfo,
        _method: &str,
        entries: &[PackEntry],
    ) -> Result<Vec<LayerSlots>> {
        let mut out = Vec::with_capacity(unit.layers.len());
        for (li, layer) in unit.layers.iter().enumerate() {
            let find = |key: &str| -> Option<usize> {
                let want = format!("{}.{key}", layer.name);
                entries.iter().position(|e| e.name == want)
            };
            let s1 = find("s1")
                .ok_or_else(|| anyhow!("pack has no {}.s1 entry", layer.name))?;
            let zp = find("zp")
                .ok_or_else(|| anyhow!("pack has no {}.zp entry", layer.name))?;
            let v = find("v")
                .ok_or_else(|| anyhow!("pack has no {}.v entry (adaround)", layer.name))?;
            out.push(LayerSlots { layer: li, s1, zp, s2: None, s3: None, s4: None, v: Some(v) });
        }
        super::reject_act_entries(entries)?;
        Ok(out)
    }

    fn forward(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor> {
        let (r, c, wv, vv, s1v, zpv) = unpack(w, p)?;
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let (s1i, zpi) = (s1v.at(i), zpv.at(i));
            for j in 0..c {
                let k = i * c + j;
                let n = (wv[k] / s1i).floor() + rectified_sigmoid(vv[k]) + zpi;
                out[k] = s1i * (n.clamp(qmin, qmax) - zpi);
            }
        }
        Tensor::from_f32(out, &[r, c])
    }

    fn codes(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor> {
        let (r, c, wv, vv, s1v, zpv) = unpack(w, p)?;
        let mut out = vec![0i32; r * c];
        for i in 0..r {
            let (s1i, zpi) = (s1v.at(i), zpv.at(i));
            for j in 0..c {
                let k = i * c + j;
                let up = if rectified_sigmoid(vv[k]) >= 0.5 { 1.0 } else { 0.0 };
                let n = (wv[k] / s1i).floor() + up + zpi;
                out[k] = n.clamp(qmin, qmax).round() as i32;
            }
        }
        Tensor::from_i32(out, &[r, c])
    }

    fn backward(
        &self,
        w: &Tensor,
        p: &SlotParams,
        g: &Tensor,
        qmin: f32,
        qmax: f32,
        beta: f64,
    ) -> Result<FqGrads> {
        if w.shape() != g.shape() {
            bail!("adaround backward: w {:?} vs g {:?}", w.shape(), g.shape());
        }
        let (r, c, wv, vv, s1v, zpv) = unpack(w, p)?;
        let gv = g.as_f32()?;
        let beta = beta as f32;
        let mut dv = vec![0.0f32; r * c];
        for i in 0..r {
            let (s1i, zpi) = (s1v.at(i), zpv.at(i));
            for j in 0..c {
                let k = i * c + j;
                let sig = 1.0 / (1.0 + (-vv[k]).exp());
                let hraw = 1.2 * sig - 0.1;
                // h′ gates to zero where the rectifier is active — both the
                // task gradient and the regularizer flow through h(V)
                if hraw <= 0.0 || hraw >= 1.0 {
                    continue;
                }
                let hprime = 1.2 * sig * (1.0 - sig);
                let n = (wv[k] / s1i).floor() + hraw + zpi;
                let inside = n >= qmin && n <= qmax;
                let mut d = if inside { gv[k] * s1i * hprime } else { 0.0 };
                // ∂/∂V [ λ·(1 − |2h−1|^β) ] = −λ·2β·|2h−1|^{β−1}·sign(2h−1)·h′
                let t = 2.0 * hraw - 1.0;
                if t != 0.0 {
                    d -= REG_WEIGHT * 2.0 * beta * t.abs().powf(beta - 1.0) * t.signum() * hprime;
                }
                dv[k] = d;
            }
        }
        Ok(FqGrads {
            ds1: Tensor::zeros(p.s1.shape()),
            ds2: None,
            ds3: None,
            ds4: None,
            dv: Some(Tensor::from_f32(dv, &[r, c])?),
        })
    }
}

/// Validate shapes and borrow the f32 views every AdaRound kernel needs.
type Unpacked<'a> = (
    usize,
    usize,
    &'a [f32],
    &'a [f32],
    super::RowView<'a>,
    super::RowView<'a>,
);

fn unpack<'a>(w: &'a Tensor, p: &SlotParams<'a>) -> Result<Unpacked<'a>> {
    if w.ndim() != 2 {
        bail!("adaround: weights must be 2-D, got {:?}", w.shape());
    }
    let (r, c) = (w.shape()[0], w.shape()[1]);
    let v = p
        .v
        .ok_or_else(|| anyhow!("adaround: pack has no V slot"))?;
    if v.shape() != w.shape() {
        bail!("adaround: V shape {:?} vs W shape {:?}", v.shape(), w.shape());
    }
    Ok((r, c, w.as_f32()?, v.as_f32()?, row_scale(p.s1, r, "s1")?, row_scale(p.zp, r, "zp")?))
}

/// RTN-equivalent init for `V`: `h(v0) = w/s1 − ⌊w/s1⌋` (the fractional
/// remainder), inverted through the rectified sigmoid — so at init AdaRound
/// rounds exactly like RTN-with-floor+fraction and learning starts from the
/// same place the other schemes do.  Clamped so `h` starts strictly inside
/// (0, 1) and gradients flow everywhere.
pub fn init_v(w: &Tensor, s1: &Tensor) -> Result<Tensor> {
    if w.ndim() != 2 {
        bail!("adaround init_v: weights must be 2-D, got {:?}", w.shape());
    }
    let (r, c) = (w.shape()[0], w.shape()[1]);
    let wv = w.as_f32()?;
    let s1v = row_scale(s1, r, "s1")?;
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let s1i = s1v.at(i);
        for j in 0..c {
            let k = i * c + j;
            let ratio = wv[k] / s1i;
            let h = (ratio - ratio.floor()).clamp(0.01, 0.99);
            // invert h = 1.2σ(v) − 0.1  →  v = logit((h + 0.1)/1.2)
            let p = (h + 0.1) / 1.2;
            out[k] = (p / (1.0 - p)).ln();
        }
    }
    Tensor::from_f32(out, &[r, c])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rectified_sigmoid_saturates() {
        assert_eq!(rectified_sigmoid(-20.0), 0.0);
        assert_eq!(rectified_sigmoid(20.0), 1.0);
        let mid = rectified_sigmoid(0.0);
        assert!((mid - 0.5).abs() < 1e-6, "h(0) = {mid}");
    }

    #[test]
    fn init_v_reproduces_rtn_fraction() {
        let w = Tensor::from_f32(vec![0.31, -0.62, 0.08, 1.27], &[2, 2]).unwrap();
        let s1 = Tensor::from_f32(vec![0.1, 0.2], &[2, 1]).unwrap();
        let v = init_v(&w, &s1).unwrap();
        let wv = w.as_f32().unwrap();
        let s1v = [0.1f32, 0.2];
        for i in 0..2 {
            for j in 0..2 {
                let k = i * 2 + j;
                let ratio = wv[k] / s1v[i];
                let want = (ratio - ratio.floor()).clamp(0.01, 0.99);
                let got = rectified_sigmoid(v.as_f32().unwrap()[k]);
                assert!((got - want).abs() < 1e-5, "h(v0) {got} vs fraction {want}");
            }
        }
    }
}
