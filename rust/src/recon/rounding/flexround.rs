//! FlexRound (Lee et al., ICML 2023) as a [`Rounding`] impl — learnable
//! rounding by **element-wise division** (Eq. 2):
//!
//! ```text
//!   Ŵ = s1 · ( clip( ⌊ W / (s1 ⊙ S2 ⊙ s3 ⊙ s4) ⌉ + z, qmin, qmax ) − z )
//! ```
//!
//! The backward pass is the closed-form straight-through estimator of
//! Proposition 3.1, mirrored line-for-line from
//! `python/compile/kernels/ref.py::flexround_bwd`, including the
//! reciprocal-rule gradient `∂Ŵ/∂S2 ∝ −W/(S2²·…)` that lets FlexRound
//! exploit weight magnitudes:
//!
//! ```text
//!   r        = W / (s1 ⊙ S2 ⊙ s3 ⊙ s4)
//!   inside   = 1[qmin ≤ ⌊r⌉ + z ≤ qmax]
//!   ∂Ŵ/∂s1   = (n_c − z) − inside · r          (grid-size chain rule)
//!   common   = s1 · inside · (−r)
//!   ∂Ŵ/∂S2   = common / S2                      (reciprocal rule)
//!   ∂Ŵ/∂s3   = Σ_cols common / s3
//!   ∂Ŵ/∂s4   = Σ_rows common / s4
//! ```
//!
//! One impl serves four method strings: `flexround` (everything learns),
//! `flexround_fixed_s1` (s1 frozen by the manifest pack), `flexround_no_s34`
//! (s3/s4 slots dropped → constant one), and `rtn` (no divisor factors at
//! all — the kernel with every factor absent *is* round-to-nearest).
//!
//! These kernels moved here verbatim from `recon/mod.rs` in the trait
//! refactor; `recon::{fq_forward, fq_codes, fq_backward}` re-export them and
//! the golden-fixture test (`tests/native_recon.rs`) pins bit-identity.

use super::{opt_full, row_scale, FqGrads, Rounding, SlotParams};
use crate::manifest::{PackEntry, UnitInfo};
use crate::recon::{round_ties_even, LayerSlots};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, bail};

/// The FlexRound scheme (also serving `rtn` and the ablations).
pub struct FlexRound;

impl Rounding for FlexRound {
    fn name(&self) -> &'static str {
        "flexround"
    }

    /// Entry names follow the build-path convention `"{layer}.{key}"`.
    /// `None` slots mean "constant one" (`rtn` has no S2 at all, the
    /// `flexround_no_s34` ablation freezes s3/s4 to ones).
    fn map_pack(
        &self,
        unit: &UnitInfo,
        method: &str,
        entries: &[PackEntry],
    ) -> Result<Vec<LayerSlots>> {
        let drop_s34 = method == "flexround_no_s34";
        let mut out = Vec::with_capacity(unit.layers.len());
        for (li, layer) in unit.layers.iter().enumerate() {
            let find = |key: &str| -> Option<usize> {
                let want = format!("{}.{key}", layer.name);
                entries.iter().position(|e| e.name == want)
            };
            let s1 = find("s1")
                .ok_or_else(|| anyhow!("pack has no {}.s1 entry", layer.name))?;
            let zp = find("zp")
                .ok_or_else(|| anyhow!("pack has no {}.zp entry", layer.name))?;
            out.push(LayerSlots {
                layer: li,
                s1,
                zp,
                s2: find("s2"),
                s3: if drop_s34 { None } else { find("s3") },
                s4: if drop_s34 { None } else { find("s4") },
                v: None,
            });
        }
        super::reject_act_entries(entries)?;
        Ok(out)
    }

    fn forward(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor> {
        fq_forward(w, p.s1, p.s2, p.s3, p.s4, p.zp, qmin, qmax)
    }

    fn codes(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor> {
        fq_codes(w, p.s1, p.s2, p.s3, p.s4, p.zp, qmin, qmax)
    }

    fn backward(
        &self,
        w: &Tensor,
        p: &SlotParams,
        g: &Tensor,
        qmin: f32,
        qmax: f32,
        _beta: f64,
    ) -> Result<FqGrads> {
        fq_backward(w, p.s1, p.s2, p.s3, p.s4, p.zp, g, qmin, qmax)
    }
}

/// FlexRound fake-quant forward: `Ŵ` with `w: (r, c)`, `s1`/`zp`: per-tensor
/// or per-row, `s2: (r, c)`, `s3: (r, 1)`, `s4: (1, c)`; `None` factors are
/// ones (so all-None reproduces RTN).
pub fn fq_forward(
    w: &Tensor,
    s1: &Tensor,
    s2: Option<&Tensor>,
    s3: Option<&Tensor>,
    s4: Option<&Tensor>,
    zp: &Tensor,
    qmin: f32,
    qmax: f32,
) -> Result<Tensor> {
    fq_kernel(w, s1, s2, s3, s4, zp, qmin, qmax, false)
}

/// Integer grid codes after learning, as an **i32 tensor** — the packed
/// export path (`infer::packed` bit-packs these directly) and the
/// grid-shift analysis input (which reads them via `to_f32_vec`).
pub fn fq_codes(
    w: &Tensor,
    s1: &Tensor,
    s2: Option<&Tensor>,
    s3: Option<&Tensor>,
    s4: Option<&Tensor>,
    zp: &Tensor,
    qmin: f32,
    qmax: f32,
) -> Result<Tensor> {
    let t = fq_kernel(w, s1, s2, s3, s4, zp, qmin, qmax, true)?;
    let v: Vec<i32> = t.as_f32()?.iter().map(|&x| x.round() as i32).collect();
    Tensor::from_i32(v, t.shape())
}

#[allow(clippy::too_many_arguments)]
fn fq_kernel(
    w: &Tensor,
    s1: &Tensor,
    s2: Option<&Tensor>,
    s3: Option<&Tensor>,
    s4: Option<&Tensor>,
    zp: &Tensor,
    qmin: f32,
    qmax: f32,
    codes: bool,
) -> Result<Tensor> {
    if w.ndim() != 2 {
        bail!("fq: weights must be 2-D, got {:?}", w.shape());
    }
    let (r, c) = (w.shape()[0], w.shape()[1]);
    let wv = w.as_f32()?;
    let s1v = row_scale(s1, r, "s1")?;
    let zpv = row_scale(zp, r, "zp")?;
    let s2v = opt_full(s2, r * c, "s2")?;
    let s3t = s3.map(|t| row_scale(t, r, "s3")).transpose()?;
    let s4v = opt_full(s4, c, "s4")?;
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let s1i = s1v.at(i);
        let zpi = zpv.at(i);
        let s3i = s3t.as_ref().map(|t| t.at(i)).unwrap_or(1.0);
        for j in 0..c {
            let k = i * c + j;
            let div = s1i
                * s2v.map(|v| v[k]).unwrap_or(1.0)
                * s3i
                * s4v.map(|v| v[j]).unwrap_or(1.0);
            let n = round_ties_even(wv[k] / div) + zpi;
            let n_c = n.clamp(qmin, qmax);
            out[k] = if codes { n_c } else { s1i * (n_c - zpi) };
        }
    }
    Tensor::from_f32(out, &[r, c])
}

/// Closed-form STE backward (Proposition 3.1).  See the module doc for the
/// gradient table; `ds1` collapses to the parameter's own shape (per-tensor
/// `(1,1)` or per-row `(r,1)`).
#[allow(clippy::too_many_arguments)]
pub fn fq_backward(
    w: &Tensor,
    s1: &Tensor,
    s2: Option<&Tensor>,
    s3: Option<&Tensor>,
    s4: Option<&Tensor>,
    zp: &Tensor,
    g: &Tensor,
    qmin: f32,
    qmax: f32,
) -> Result<FqGrads> {
    if w.shape() != g.shape() || w.ndim() != 2 {
        bail!("fq_backward: w {:?} vs g {:?}", w.shape(), g.shape());
    }
    let (r, c) = (w.shape()[0], w.shape()[1]);
    let wv = w.as_f32()?;
    let gv = g.as_f32()?;
    let s1v = row_scale(s1, r, "s1")?;
    let zpv = row_scale(zp, r, "zp")?;
    let s2v = opt_full(s2, r * c, "s2")?;
    let s3t = s3.map(|t| row_scale(t, r, "s3")).transpose()?;
    let s4v = opt_full(s4, c, "s4")?;

    let mut ds1_rows = vec![0.0f32; r];
    let mut ds2 = s2v.map(|_| vec![0.0f32; r * c]);
    let mut ds3_rows = s3t.as_ref().map(|_| vec![0.0f32; r]);
    let mut ds4_cols = s4v.map(|_| vec![0.0f32; c]);

    for i in 0..r {
        let s1i = s1v.at(i);
        let zpi = zpv.at(i);
        let s3i = s3t.as_ref().map(|t| t.at(i)).unwrap_or(1.0);
        for j in 0..c {
            let k = i * c + j;
            let s2k = s2v.map(|v| v[k]).unwrap_or(1.0);
            let s4j = s4v.map(|v| v[j]).unwrap_or(1.0);
            let div = s1i * s2k * s3i * s4j;
            let ratio = wv[k] / div;
            let n = round_ties_even(ratio) + zpi;
            let inside = if n >= qmin && n <= qmax { 1.0f32 } else { 0.0 };
            let n_c = n.clamp(qmin, qmax);
            ds1_rows[i] += gv[k] * ((n_c - zpi) - inside * ratio);
            let common = gv[k] * s1i * inside * (-ratio);
            if let Some(d) = ds2.as_mut() {
                d[k] = common / s2k;
            }
            if let Some(d) = ds3_rows.as_mut() {
                d[i] += common / s3i;
            }
            if let Some(d) = ds4_cols.as_mut() {
                d[j] += common / s4j;
            }
        }
    }

    let ds1 = if s1.len() == 1 {
        Tensor::from_f32(vec![ds1_rows.iter().sum()], s1.shape())?
    } else {
        Tensor::from_f32(ds1_rows, s1.shape())?
    };
    Ok(FqGrads {
        ds1,
        ds2: match (ds2, s2) {
            (Some(d), Some(t)) => Some(Tensor::from_f32(d, t.shape())?),
            _ => None,
        },
        ds3: match (ds3_rows, s3) {
            (Some(d), Some(t)) => Some(Tensor::from_f32(d, t.shape())?),
            _ => None,
        },
        ds4: match (ds4_cols, s4) {
            (Some(d), Some(t)) => Some(Tensor::from_f32(d, t.shape())?),
            _ => None,
        },
        dv: None,
    })
}
