//! Per-tensor **static** activation quantization — the piece that turns a
//! low-bit weight pack into a W4A8 artifact (DESIGN.md §Rounding-Schemes,
//! "W4A8 data flow").
//!
//! Nothing is learned: the range is calibrated once from reconstruction
//! activations (asymmetric min/max over every calibration chunk, zero always
//! representable), then frozen into the packed artifact next to the weight
//! codes.  At serve time the engine quantizes each layer input onto this
//! grid and the fused GEMM runs **entirely in the integer domain**
//! (`infer::kernels::gemm_fused_act_int`): `Σ code_x · code_w` in i32, one
//! dequant per output element.  The fake-quant view ([`ActQuant::fake_quant`])
//! is the f32 reference the integer path is pinned against (≤ 1e-4).

use crate::tensor::{qrange, Tensor};
use crate::Result;
use anyhow::bail;

/// A calibrated per-tensor activation grid: `x̂ = step · (code − zp)` with
/// `code = clip(⌊x/step⌉ + zp, 0, 2^abits − 1)` (asymmetric, unsigned).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuant {
    pub abits: u32,
    pub step: f32,
    pub zp: f32,
}

impl ActQuant {
    /// Build the grid from an observed activation range.  Mirrors the
    /// init-pack math of the LSQ step seed (`Session::init_params`): the
    /// range is widened to include zero, `step` floors at 1e-6, and the zero
    /// point lands on the grid.
    pub fn calibrate(lo: f32, hi: f32, abits: u32) -> ActQuant {
        let (qmin, qmax) = qrange(abits, false);
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let step = ((hi - lo) / (qmax - qmin)).max(1e-6);
        let zp = (-lo / step).round().clamp(qmin, qmax);
        ActQuant { abits, step, zp }
    }

    /// Calibrate from activation chunks (the reconstruction batches): one
    /// global min/max over every element of every chunk.
    pub fn from_chunks<'a>(
        chunks: impl IntoIterator<Item = &'a Tensor>,
        abits: u32,
    ) -> Result<ActQuant> {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        let mut seen = false;
        for t in chunks {
            for &x in t.as_f32()? {
                lo = lo.min(x);
                hi = hi.max(x);
                seen = true;
            }
        }
        if !seen {
            bail!("act-quant calibration over an empty activation set");
        }
        Ok(ActQuant::calibrate(lo, hi, abits))
    }

    /// The unsigned integer code range `[0, 2^abits − 1]`.
    pub fn code_range(&self) -> (f32, f32) {
        qrange(self.abits, false)
    }

    /// Quantize a slice of activations to integer codes.
    pub fn codes(&self, x: &[f32]) -> Vec<i32> {
        let (qmin, qmax) = self.code_range();
        x.iter()
            .map(|&v| (v / self.step).round().clamp(qmin - self.zp, qmax - self.zp) + self.zp)
            .map(|c| c as i32)
            .collect()
    }

    /// The f32 fake-quant view `x̂ = step · (code − zp)` — the reference the
    /// integer-domain GEMM is pinned against.
    pub fn fake_quant(&self, x: &Tensor) -> Result<Tensor> {
        let xv = x.as_f32()?;
        let codes = self.codes(xv);
        let out: Vec<f32> = codes.iter().map(|&c| self.step * (c as f32 - self.zp)).collect();
        Tensor::from_f32(out, x.shape())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_grid_represents_zero_and_range() {
        let q = ActQuant::calibrate(-1.5, 3.0, 8);
        // zero is exactly on the grid
        assert_eq!(q.step * (q.zp - q.zp), 0.0);
        let codes = q.codes(&[0.0]);
        assert_eq!(q.step * (codes[0] as f32 - q.zp), 0.0);
        // endpoints round-trip within one step
        for &x in &[-1.5f32, 0.0, 1.0, 3.0] {
            let c = q.codes(&[x])[0] as f32;
            let xhat = q.step * (c - q.zp);
            assert!((xhat - x).abs() <= q.step * 0.5 + 1e-6, "{x} → {xhat} (step {})", q.step);
        }
    }

    #[test]
    fn codes_stay_in_unsigned_range() {
        let q = ActQuant::calibrate(-0.2, 0.9, 8);
        let xs: Vec<f32> = (-100..100).map(|i| i as f32 * 0.05).collect();
        for c in q.codes(&xs) {
            assert!((0..=255).contains(&c), "code {c} outside u8 range");
        }
    }

    #[test]
    fn all_positive_range_still_includes_zero() {
        let q = ActQuant::calibrate(0.5, 2.0, 8);
        assert_eq!(q.zp, 0.0, "lo widened to 0 → zp at 0, got {}", q.zp);
        assert_eq!(q.codes(&[0.0])[0], 0);
    }

    #[test]
    fn from_chunks_spans_all_chunks() {
        let a = Tensor::from_f32(vec![-1.0, 0.5], &[1, 2]).unwrap();
        let b = Tensor::from_f32(vec![2.0, 0.1], &[1, 2]).unwrap();
        let q = ActQuant::from_chunks([&a, &b], 8).unwrap();
        let full = ActQuant::calibrate(-1.0, 2.0, 8);
        assert_eq!(q, full);
        assert!(ActQuant::from_chunks(std::iter::empty::<&Tensor>(), 8).is_err());
    }
}
