//! Pluggable rounding schemes behind one [`Rounding`] trait
//! (DESIGN.md §Rounding-Schemes).
//!
//! PR 1–8 grew the reconstruction stack around exactly one learner —
//! FlexRound's element-wise division (Eq. 2) — with its forward/backward
//! hard-wired through `recon`, `block`, and the coordinator.  This module is
//! the seam that lets the same Adam loop, block pipeline, packed export, and
//! sweep harness drive *any* learnable rounding scheme:
//!
//! * [`flexround::FlexRound`] — the paper's scheme (and the `rtn` /
//!   `flexround_fixed_s1` / `flexround_no_s34` ablations, which are the same
//!   kernel with factors frozen or absent).  Routing FlexRound through the
//!   trait is **bit-identical** to the pre-trait code: the kernels moved
//!   here verbatim and the golden-fixture test pins them.
//! * [`adaround::AdaRound`] — the additive-perturbation baseline
//!   ("Up or Down? Adaptive Rounding for Post-Training Quantization",
//!   Nagel et al., 2020): a sigmoid-relaxed soft rounding `h(V)` learned
//!   under the annealed rounding regularizer, hard-rounded at export.
//! * [`actquant::ActQuant`] — per-tensor *static* activation quantization
//!   calibrated from reconstruction batches; not a `Rounding` impl (nothing
//!   is learned) but the piece that turns a 4-bit weight pack into a W4A8
//!   artifact served by the integer-domain fused kernels.
//!
//! The scheme travels as `&'static dyn Rounding` (resolved once from the
//! method string by [`scheme_for`]), so threading it through
//! [`super::ReconSettings`], the block pipeline, and the backends costs one
//! pointer — no per-element dispatch: every trait method works on whole
//! weight tensors.

pub mod actquant;
pub mod adaround;
pub mod flexround;

pub use actquant::ActQuant;
pub use adaround::AdaRound;
pub use flexround::FlexRound;

use super::LayerSlots;
use crate::manifest::{PackEntry, UnitInfo};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::bail;

/// One layer's rounding parameters, resolved from a flat parameter pack via
/// [`LayerSlots::resolve`].  `None` factors mean "constant one" (FlexRound
/// ablations) or "not used by this scheme" (AdaRound has no `S2`/`s3`/`s4`;
/// FlexRound has no `V`).
pub struct SlotParams<'a> {
    /// per-row (or per-tensor) grid scale — every scheme has one
    pub s1: &'a Tensor,
    /// zero point, same broadcast as `s1`
    pub zp: &'a Tensor,
    /// FlexRound's full-shape divisor factor
    pub s2: Option<&'a Tensor>,
    /// FlexRound's per-row divisor factor
    pub s3: Option<&'a Tensor>,
    /// FlexRound's per-column divisor factor
    pub s4: Option<&'a Tensor>,
    /// AdaRound's continuous rounding variable (shape of `W`)
    pub v: Option<&'a Tensor>,
}

/// STE cotangents for the learnable factors, given the output cotangent `g`
/// (shape of `w`).  Shapes mirror the parameters; `ds1` collapses to the
/// parameter's own shape (per-tensor `(1,1)` or per-row `(r,1)`).  Schemes
/// fill only the slots they own: FlexRound sets `ds1`/`ds2`/`ds3`/`ds4`,
/// AdaRound sets `dv` (its `s1` is frozen, `ds1` is zeros).
pub struct FqGrads {
    pub ds1: Tensor,
    pub ds2: Option<Tensor>,
    pub ds3: Option<Tensor>,
    pub ds4: Option<Tensor>,
    pub dv: Option<Tensor>,
}

/// A learnable rounding scheme: how weights round onto the integer grid
/// during reconstruction, how the learned rounding differentiates, and how
/// it exports to packed integer codes.
///
/// Contract every implementation must honor (pinned by the conformance
/// suite in `tests/rounding.rs`):
///
/// * `codes` lie on the integer grid `[qmin, qmax]` at every bit-width;
/// * `export` computes the grid **once**: `Ŵ = s1 · (codes − zp)` is derived
///   from the same codes the packer writes, so a scheme cannot desync its
///   exported weights from its exported codes;
/// * at convergence (rounding decisions saturated), the training-time
///   `forward` equals the exported `Ŵ` — soft rounding must collapse to the
///   hard export it claims to be learning.
pub trait Rounding: Sync + Send {
    /// Scheme label for metrics, logs, and bench rows.
    fn name(&self) -> &'static str;

    /// Map a pack-entry list onto per-layer slots for `method` (a scheme
    /// may serve several method strings — FlexRound also handles `rtn` and
    /// the ablations, which differ only in which slots exist / learn).
    fn map_pack(
        &self,
        unit: &UnitInfo,
        method: &str,
        entries: &[PackEntry],
    ) -> Result<Vec<LayerSlots>>;

    /// Training-time fake-quant forward: `Ŵ` with the scheme's current
    /// (possibly soft) rounding decisions.
    fn forward(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor>;

    /// Integer grid codes as an **i32 tensor** — hard rounding decisions
    /// (the packed export bit-packs these directly).
    fn codes(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<Tensor>;

    /// Cotangents of the learnable factors given the output cotangent `g`.
    /// `beta` is the annealed rounding-regularizer temperature
    /// ([`beta_schedule`]); FlexRound's closed-form STE ignores it.
    fn backward(
        &self,
        w: &Tensor,
        p: &SlotParams,
        g: &Tensor,
        qmin: f32,
        qmax: f32,
        beta: f64,
    ) -> Result<FqGrads>;

    /// Export `(Ŵ, codes)` for packing and the figure pipeline.  The grid is
    /// computed exactly once: `codes` via [`Rounding::codes`], then
    /// `Ŵ = s1 · (codes − zp)` derived from those same codes.
    fn export(&self, w: &Tensor, p: &SlotParams, qmin: f32, qmax: f32) -> Result<(Tensor, Tensor)> {
        let codes = self.codes(w, p, qmin, qmax)?;
        let what = scale_codes(&codes, p.s1, p.zp)?;
        Ok((what, codes))
    }
}

/// Resolve the scheme implementation for a method string.  Static objects —
/// the scheme travels as a plain reference.
pub fn scheme_for(method: &str) -> Result<&'static dyn Rounding> {
    match method {
        "rtn" | "flexround" | "flexround_fixed_s1" | "flexround_no_s34" => Ok(&FlexRound),
        "adaround" => Ok(&AdaRound),
        other => bail!(
            "native backend has no rounding scheme for method {other:?} \
             (supported: rtn, flexround, flexround_fixed_s1, flexround_no_s34, adaround); \
             use --backend pjrt"
        ),
    }
}

/// Annealing schedule for the rounding-regularizer temperature β ("Up or
/// Down?", §4): hold `BETA_HI` through the warmup fraction, then cosine-decay
/// to `BETA_LO`.  High β leaves `h(V)` free to move; low β forces the
/// rounding decisions to commit to 0/1 so the soft forward collapses onto
/// the hard export.  This is the canonical copy; `coordinator::beta_schedule`
/// delegates here.
pub fn beta_schedule(t: usize, iters: usize) -> f64 {
    const BETA_HI: f64 = 20.0;
    const BETA_LO: f64 = 2.0;
    const WARMUP: f64 = 0.2;
    if iters == 0 {
        return BETA_LO;
    }
    let warm = (iters as f64 * WARMUP).floor() as usize;
    if t < warm {
        return BETA_HI;
    }
    let span = (iters - warm).max(1) as f64;
    let frac = ((t - warm) as f64 / span).clamp(0.0, 1.0);
    BETA_LO + 0.5 * (BETA_HI - BETA_LO) * (1.0 + (std::f64::consts::PI * frac).cos())
}

/// `Ŵ = s1 · (codes − zp)` with `s1`/`zp` per-tensor or per-row — the single
/// codes→weights scaling every scheme's export shares.
pub fn scale_codes(codes: &Tensor, s1: &Tensor, zp: &Tensor) -> Result<Tensor> {
    if codes.ndim() != 2 {
        bail!("scale_codes: codes must be 2-D, got {:?}", codes.shape());
    }
    let (r, c) = (codes.shape()[0], codes.shape()[1]);
    let cv = codes.to_f32_vec();
    let s1v = row_scale(s1, r, "s1")?;
    let zpv = row_scale(zp, r, "zp")?;
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let (s1i, zpi) = (s1v.at(i), zpv.at(i));
        for j in 0..c {
            let k = i * c + j;
            out[k] = s1i * (cv[k] - zpi);
        }
    }
    Tensor::from_f32(out, &[r, c])
}

// ---------------------------------------------------------------------------
// Shared parameter views (per-row broadcast, full-shape factors)
// ---------------------------------------------------------------------------

/// A per-row (or broadcast per-tensor) factor view.
pub(crate) struct RowView<'a> {
    v: &'a [f32],
    broadcast: bool,
}

impl RowView<'_> {
    #[inline]
    pub(crate) fn at(&self, row: usize) -> f32 {
        if self.broadcast {
            self.v[0]
        } else {
            self.v[row]
        }
    }
}

pub(crate) fn row_scale<'a>(t: &'a Tensor, rows: usize, what: &str) -> Result<RowView<'a>> {
    let v = t.as_f32()?;
    if v.len() != 1 && v.len() != rows {
        bail!("{what}: expected 1 or {rows} values, got {}", v.len());
    }
    Ok(RowView { v, broadcast: v.len() == 1 })
}

pub(crate) fn opt_full<'a>(t: Option<&'a Tensor>, n: usize, what: &str) -> Result<Option<&'a [f32]>> {
    match t {
        None => Ok(None),
        Some(t) => {
            let v = t.as_f32()?;
            if v.len() != n {
                bail!("{what}: expected {n} values, got {}", v.len());
            }
            Ok(Some(v))
        }
    }
}

/// Reject "wa"-mode packs: LSQ activation-step entries mean the pack was
/// built for the PJRT path's learned activation quantization, which no
/// native scheme executes.  (Static activation quantization — [`ActQuant`] —
/// is attached at pack time, not carried as pack entries.)
pub(crate) fn reject_act_entries(entries: &[PackEntry]) -> Result<()> {
    for e in entries {
        if e.name.starts_with("act") {
            bail!(
                "pack entry {:?}: activation quantization (\"wa\" mode) is not \
                 supported by the native backend; use --backend pjrt",
                e.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_lookup() {
        assert_eq!(scheme_for("flexround").unwrap().name(), "flexround");
        assert_eq!(scheme_for("rtn").unwrap().name(), "flexround");
        assert_eq!(scheme_for("flexround_no_s34").unwrap().name(), "flexround");
        assert_eq!(scheme_for("adaround").unwrap().name(), "adaround");
        assert!(scheme_for("lsq").is_err());
    }

    #[test]
    fn beta_anneals_and_is_monotone_after_warmup() {
        let iters = 100;
        assert_eq!(beta_schedule(1, iters), 20.0);
        assert_eq!(beta_schedule(19, iters), 20.0);
        let end = beta_schedule(iters, iters);
        assert!((end - 2.0).abs() < 1e-9, "β must land at BETA_LO, got {end}");
        let mut prev = beta_schedule(20, iters);
        for t in 21..=iters {
            let b = beta_schedule(t, iters);
            assert!(b <= prev + 1e-12, "β must not increase: t={t} {b} > {prev}");
            prev = b;
        }
    }

    #[test]
    fn scale_codes_per_row_and_broadcast() {
        let codes = Tensor::from_i32(vec![1, 2, -3, 4], &[2, 2]).unwrap();
        let s1 = Tensor::from_f32(vec![0.5, 2.0], &[2, 1]).unwrap();
        let zp = Tensor::from_f32(vec![1.0, 0.0], &[2, 1]).unwrap();
        let w = scale_codes(&codes, &s1, &zp).unwrap();
        assert_eq!(w.as_f32().unwrap(), &[0.0, 0.5, -6.0, 8.0]);
        let s1b = Tensor::from_f32(vec![2.0], &[1, 1]).unwrap();
        let zpb = Tensor::zeros(&[1, 1]);
        let w = scale_codes(&codes, &s1b, &zpb).unwrap();
        assert_eq!(w.as_f32().unwrap(), &[2.0, 4.0, -6.0, 8.0]);
    }
}
