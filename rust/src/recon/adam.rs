//! Adam optimizer for the native reconstruction loop.
//!
//! Mirrors the in-graph optimizer of the AOT build path
//! (`python/compile/quant.py::adam_update` / `graphs.py::recon_step_fn`)
//! exactly: β₁ = 0.9, β₂ = 0.999, ε = 1e-8, bias-corrected moments, and the
//! positivity clamp `max(p, 1e-6)` on every divisor-like parameter
//! (`s1`/`s2`/`s3`/`s4`/`step`) so the element-wise division of Eq. 2 never
//! crosses zero during learning.

use crate::manifest::PackEntry;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::bail;

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Parameters whose pack-entry key must stay strictly positive (they sit in
/// the denominator of `W / (s1 ⊙ S2 ⊙ s3 ⊙ s4)` or are an LSQ step size).
pub fn positive_key(entry_name: &str) -> bool {
    matches!(
        entry_name.rsplit('.').next().unwrap_or(""),
        "s1" | "s2" | "s3" | "s4" | "step"
    )
}

/// First/second-moment state, one slot per pack entry.
pub struct Adam {
    m: Vec<Tensor>,
    v: Vec<Tensor>,
}

impl Adam {
    pub fn new(params: &[Tensor]) -> Adam {
        Adam {
            m: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
            v: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
        }
    }

    /// One update at (1-based) step `t`.  `grads[i] = None` leaves slot `i`
    /// untouched (frozen factors, non-learnable entries).
    pub fn step(
        &mut self,
        t: usize,
        lr: f32,
        entries: &[PackEntry],
        params: &mut [Tensor],
        grads: &[Option<Tensor>],
    ) -> Result<()> {
        if params.len() != grads.len() || params.len() != entries.len() {
            bail!(
                "adam: {} params vs {} grads vs {} entries",
                params.len(),
                grads.len(),
                entries.len()
            );
        }
        let b1t = 1.0 - ADAM_B1.powi(t as i32);
        let b2t = 1.0 - ADAM_B2.powi(t as i32);
        for i in 0..params.len() {
            let g = match (&grads[i], entries[i].learnable) {
                (Some(g), true) => g,
                _ => continue,
            };
            if g.shape() != params[i].shape() {
                bail!(
                    "adam: grad shape {:?} vs param shape {:?} for {:?}",
                    g.shape(),
                    params[i].shape(),
                    entries[i].name
                );
            }
            let clamp = positive_key(&entries[i].name);
            let gv = g.as_f32()?;
            let mv = self.m[i].as_f32_mut()?;
            let vv = self.v[i].as_f32_mut()?;
            let pv = params[i].as_f32_mut()?;
            for j in 0..pv.len() {
                let m2 = ADAM_B1 * mv[j] + (1.0 - ADAM_B1) * gv[j];
                let v2 = ADAM_B2 * vv[j] + (1.0 - ADAM_B2) * gv[j] * gv[j];
                mv[j] = m2;
                vv[j] = v2;
                let mut p2 = pv[j] - lr * (m2 / b1t) / ((v2 / b2t).sqrt() + ADAM_EPS);
                if clamp {
                    p2 = p2.max(1e-6);
                }
                pv[j] = p2;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, shape: &[usize], learnable: bool) -> PackEntry {
        PackEntry { name: name.to_string(), shape: shape.to_vec(), learnable }
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (p - 3)² from p = 0
        let entries = vec![entry("fc.v", &[1, 1], true)];
        let mut params = vec![Tensor::zeros(&[1, 1])];
        let mut opt = Adam::new(&params);
        for t in 1..=2000 {
            let p = params[0].as_f32().unwrap()[0];
            let g = Tensor::from_f32(vec![2.0 * (p - 3.0)], &[1, 1]).unwrap();
            opt.step(t, 0.05, &entries, &mut params, &[Some(g)]).unwrap();
        }
        let p = params[0].as_f32().unwrap()[0];
        assert!((p - 3.0).abs() < 1e-2, "adam did not converge: {p}");
    }

    #[test]
    fn frozen_and_positive_slots() {
        let entries = vec![
            entry("fc.s2", &[1, 1], true),
            entry("fc.zp", &[1, 1], false),
        ];
        let mut params = vec![Tensor::full(&[1, 1], 1e-6), Tensor::full(&[1, 1], 2.0)];
        let mut opt = Adam::new(&params);
        let g = Tensor::full(&[1, 1], 100.0);
        opt.step(1, 1.0, &entries, &mut params, &[Some(g.clone()), Some(g)]).unwrap();
        // s2 was pushed hard negative but clamps at the positivity floor
        assert!(params[0].as_f32().unwrap()[0] >= 1e-6);
        // zp is not learnable — untouched
        assert_eq!(params[1].as_f32().unwrap()[0], 2.0);
    }

    #[test]
    fn positive_key_detection() {
        assert!(positive_key("conv.s1"));
        assert!(positive_key("act0.step"));
        assert!(!positive_key("conv.zp"));
        assert!(!positive_key("conv.v"));
    }
}
