//! Typed view over `artifacts/manifest.json` — the system description the
//! AOT build (`python/compile/aot.py`) writes for the coordinator.

use crate::ser::json::{self, Json};
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One quantizable layer of a unit (canonical 2D view).
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub name: String,
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub conv_shape: Option<Vec<usize>>,
    pub stride: usize,
}

/// One pack entry: a flat parameter slot of a (unit, method, mode).
#[derive(Clone, Debug)]
pub struct PackEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub learnable: bool,
}

/// One reconstruction unit.
#[derive(Clone, Debug)]
pub struct UnitInfo {
    pub name: String,
    pub kind: String,
    pub bits_override: Option<u32>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub act_sites: usize,
    /// attention heads (`transformer_block` units; 1 elsewhere)
    pub heads: usize,
    pub layers: Vec<LayerInfo>,
    /// artifact key (e.g. "recon.flexround.w") → file name
    pub artifacts: BTreeMap<String, String>,
    /// "method.mode" → flat parameter ordering
    pub packs: BTreeMap<String, Vec<PackEntry>>,
}

impl UnitInfo {
    pub fn artifact(&self, key: &str) -> Result<&str> {
        self.artifacts
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("unit {:?} has no artifact {key:?}", self.name))
    }

    pub fn pack(&self, method: &str, mode: &str) -> Result<&[PackEntry]> {
        self.packs
            .get(&format!("{method}.{mode}"))
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("unit {:?} has no pack {method}.{mode}", self.name))
    }
}

/// One model entry.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String,
    pub task: String,
    pub fp_metric: BTreeMap<String, f64>,
    pub symmetric: bool,
    pub per_channel: bool,
    pub bits_w: Vec<u32>,
    pub abits: Vec<u32>,
    pub methods_w: Vec<String>,
    pub methods_wa: Vec<String>,
    pub calib_n: usize,
    pub calib_batch: usize,
    pub seq: Option<usize>,
    pub units: Vec<UnitInfo>,
    pub embed_artifact: Option<String>,
    pub head_artifacts: BTreeMap<String, String>,
    pub weights_file: String,
    pub init_file: String,
    pub data_file: String,
    pub datasets: BTreeMap<String, Vec<usize>>,
    pub iters_default: usize,
    pub lr_default: BTreeMap<String, f64>,
    pub drop_p_default: f64,
}

impl ModelInfo {
    pub fn unit(&self, name: &str) -> Result<&UnitInfo> {
        self.units
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| anyhow!("model {:?} has no unit {name:?}", self.name))
    }

    pub fn lr_for(&self, method: &str) -> f64 {
        self.lr_default.get(method).copied().unwrap_or(1e-3)
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub calib_batch: usize,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("reading {} (run `make artifacts` first): {e}", path.display()))?;
        let v = json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, mv) in v.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, mv)
                .map_err(|e| anyhow!("model {name}: {e}"))?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            calib_batch: v.get("calib_batch")?.usize()?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model {name:?} in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, v: &Json) -> Result<ModelInfo> {
    let mut fp_metric = BTreeMap::new();
    if let Some(m) = v.opt("fp_metric") {
        for (k, x) in m.obj()? {
            if let Json::Num(n) = x {
                fp_metric.insert(k.clone(), *n);
            }
        }
    }
    let hyper = v.get("hyper")?;
    let mut lr_default = BTreeMap::new();
    for (k, x) in hyper.get("lr")?.obj()? {
        lr_default.insert(k.clone(), x.num()?);
    }
    let mut units = Vec::new();
    for uv in v.get("units")?.arr()? {
        units.push(parse_unit(uv)?);
    }
    let mut head_artifacts = BTreeMap::new();
    if let Some(h) = v.opt("head_artifacts") {
        for (k, x) in h.obj()? {
            head_artifacts.insert(k.clone(), x.str()?.to_string());
        }
    }
    let mut datasets = BTreeMap::new();
    for (k, x) in v.get("datasets")?.obj()? {
        datasets.insert(k.clone(), x.usize_vec()?);
    }
    Ok(ModelInfo {
        name: name.to_string(),
        kind: v.get("kind")?.str()?.to_string(),
        task: v.opt("task").and_then(|t| t.str().ok()).unwrap_or("").to_string(),
        fp_metric,
        symmetric: v.get("symmetric")?.boolean()?,
        per_channel: v.get("per_channel")?.boolean()?,
        bits_w: v.get("bits_w")?.usize_vec()?.iter().map(|&b| b as u32).collect(),
        abits: v.get("abits")?.usize_vec()?.iter().map(|&b| b as u32).collect(),
        methods_w: v.get("methods_w")?.str_vec()?,
        methods_wa: v.get("methods_wa")?.str_vec()?,
        calib_n: v.get("calib_n")?.usize()?,
        calib_batch: v.get("calib_batch")?.usize()?,
        seq: v.opt("seq").and_then(|s| s.usize().ok()),
        units,
        embed_artifact: v.opt("embed_artifact").and_then(|s| s.str().ok()).map(str::to_string),
        head_artifacts,
        weights_file: v.get("weights_file")?.str()?.to_string(),
        init_file: v.get("init_file")?.str()?.to_string(),
        data_file: v.get("data_file")?.str()?.to_string(),
        datasets,
        iters_default: hyper.get("iters")?.usize()?,
        lr_default,
        drop_p_default: hyper.get("drop_p")?.num()?,
    })
}

fn parse_unit(v: &Json) -> Result<UnitInfo> {
    let mut layers = Vec::new();
    for lv in v.get("layers")?.arr()? {
        layers.push(LayerInfo {
            name: lv.get("name")?.str()?.to_string(),
            kind: lv.get("kind")?.str()?.to_string(),
            rows: lv.get("rows")?.usize()?,
            cols: lv.get("cols")?.usize()?,
            conv_shape: lv.opt("conv_shape").map(|c| c.usize_vec()).transpose()?,
            stride: lv.get("stride")?.usize()?,
        });
    }
    let mut artifacts = BTreeMap::new();
    for (k, x) in v.get("artifacts")?.obj()? {
        artifacts.insert(k.clone(), x.str()?.to_string());
    }
    let mut packs = BTreeMap::new();
    for (k, x) in v.get("packs")?.obj()? {
        let mut entries = Vec::new();
        for ev in x.arr()? {
            entries.push(PackEntry {
                name: ev.get("name")?.str()?.to_string(),
                shape: ev.get("shape")?.usize_vec()?,
                learnable: ev.get("learnable")?.boolean()?,
            });
        }
        packs.insert(k.clone(), entries);
    }
    Ok(UnitInfo {
        name: v.get("name")?.str()?.to_string(),
        kind: v.get("kind")?.str()?.to_string(),
        bits_override: v.opt("bits_override").and_then(|b| b.usize().ok()).map(|b| b as u32),
        in_shape: v.get("in_shape")?.usize_vec()?,
        out_shape: v.get("out_shape")?.usize_vec()?,
        act_sites: v.get("act_sites")?.usize()?,
        heads: v.opt("heads").and_then(|h| h.usize().ok()).unwrap_or(1).max(1),
        layers,
        artifacts,
        packs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let text = r#"{
          "calib_batch": 32,
          "models": {
            "m": {
              "kind": "cnn", "task": "image", "fp_metric": {"top1": 0.9},
              "symmetric": true, "per_channel": false,
              "bits_w": [4], "abits": [8],
              "methods_w": ["rtn"], "methods_wa": [],
              "calib_n": 64, "calib_batch": 32,
              "hyper": {"iters": 10, "lr": {"flexround": 0.002}, "drop_p": 0.5},
              "datasets": {"calib_x": [64, 12, 12, 3]},
              "weights_file": "m.weights.fxt", "init_file": "m.init.fxt",
              "data_file": "m.data.fxt",
              "units": [{
                "name": "stem", "kind": "stem_conv", "bits_override": 8,
                "in_shape": [12,12,3], "out_shape": [12,12,16], "act_sites": 1,
                "layers": [{"name":"conv","kind":"conv","rows":16,"cols":27,
                            "conv_shape":[3,3,3,16],"stride":1}],
                "artifacts": {"fp": "m.fp.stem.hlo.txt"},
                "packs": {"rtn.w": [{"name":"conv.s1","shape":[1,1],"learnable":false}]}
              }]
            }
          }
        }"#;
        let dir = std::env::temp_dir().join("fx_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let mi = m.model("m").unwrap();
        assert_eq!(mi.units.len(), 1);
        assert_eq!(mi.units[0].bits_override, Some(8));
        assert_eq!(mi.units[0].heads, 1, "heads defaults to 1 when absent");
        assert_eq!(mi.units[0].layers[0].conv_shape.as_deref(), Some(&[3, 3, 3, 16][..]));
        assert_eq!(mi.unit("stem").unwrap().artifact("fp").unwrap(), "m.fp.stem.hlo.txt");
        assert!(mi.unit("nope").is_err());
        assert_eq!(mi.lr_for("flexround"), 0.002);
        assert_eq!(mi.lr_for("unknown"), 1e-3);
        assert_eq!(mi.fp_metric["top1"], 0.9);
    }
}
