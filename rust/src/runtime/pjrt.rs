//! PJRT engine: load `artifacts/*.hlo.txt`, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client).  Two execution paths:
//!
//! * [`Exec::run`] — host literals in, host tensors out.  Multi-output
//!   graphs (lowered with `return_tuple=True`) come back as one tuple
//!   literal which is decomposed here.
//! * [`Exec::run_b`] / [`DeviceBuf`] — device-buffer chaining for the unit
//!   pipeline: single-output graphs (`return_tuple=False`) produce a bare
//!   array buffer that feeds the next executable without a host round-trip.
//!   This is the L3 hot-path optimization (see EXPERIMENTS.md §Perf).
//!
//! Executables are cached by file name (compile once per process).
//! [`Pjrt`] wraps the raw [`Runtime`] and implements
//! [`Backend`](super::Backend): unit forwards load the `fp`/`q.*`
//! artifacts, reconstruction drives the AOT `recon.*` executables (fwd +
//! STE bwd + in-graph Adam fused into one graph).

use super::{Backend, QView, ReconOutcome, ReconTask, UnitCtx};
use crate::coordinator::beta_schedule;
use crate::manifest::PackEntry;
use crate::tensor::{qrange, DType, Tensor};
use crate::Result;
use anyhow::{anyhow, bail};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

/// A device-resident buffer (output of a single-output executable).
pub struct DeviceBuf(pub xla::PjRtBuffer);

/// Shared PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
    pub stats: RefCell<RtStats>,
}

/// Runtime counters for the perf report.
#[derive(Default, Debug, Clone)]
pub struct RtStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    pub cache_hits: u64,
}

/// One compiled executable.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RtStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(file) {
            self.stats.borrow_mut().cache_hits += 1;
            return Ok(Rc::clone(e));
        }
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += t0.elapsed().as_secs_f64();
        }
        let rc = Rc::new(Exec { exe, name: file.to_string() });
        self.cache.borrow_mut().insert(file.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    /// Upload a host tensor to the device (for buffer-path chaining).
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuf> {
        let lit = to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        Ok(DeviceBuf(buf))
    }

    fn note_exec(&self, t0: Instant) {
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
    }
}

impl Exec {
    /// Literal path: host tensors in → host tensors out.  `tuple_out` must
    /// match how the artifact was lowered (recon/qw/lm-head → true).
    pub fn run(&self, rt: &Runtime, inputs: &[Tensor], tuple_out: bool) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let res = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        collect_outputs(res, tuple_out, &self.name)
    }

    /// Buffer path: device buffers in → device buffers out (no host copy).
    pub fn run_b(&self, rt: &Runtime, inputs: &[&DeviceBuf]) -> Result<Vec<DeviceBuf>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.0).collect();
        let t0 = Instant::now();
        let res = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("execute_b {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        let mut replica = res
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no replica output", self.name))?;
        Ok(replica.drain(..).map(DeviceBuf).collect())
    }

    /// Mixed path: host inputs, device outputs (for starting a chain).
    pub fn run_to_device(&self, rt: &Runtime, inputs: &[Tensor]) -> Result<Vec<DeviceBuf>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let res = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        let mut replica = res
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no replica output", self.name))?;
        Ok(replica.drain(..).map(DeviceBuf).collect())
    }
}

impl DeviceBuf {
    /// Copy to host.
    pub fn fetch(&self) -> Result<Tensor> {
        let lit = self
            .0
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        from_literal(&lit)
    }
}

fn collect_outputs(
    res: Vec<Vec<xla::PjRtBuffer>>,
    tuple_out: bool,
    name: &str,
) -> Result<Vec<Tensor>> {
    let replica = res
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("{name}: no replica output"))?;
    let mut out = Vec::new();
    for buf in replica {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        if tuple_out {
            for el in lit.to_tuple().map_err(|e| anyhow!("{name}: to_tuple: {e:?}"))? {
                out.push(from_literal(&el)?);
            }
        } else {
            out.push(from_literal(&lit)?);
        }
    }
    Ok(out)
}

/// Tensor → xla Literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t.dtype() {
        DType::F32 => {
            let v = t.as_f32()?;
            if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        }
        DType::I32 => {
            let v = t.as_i32()?;
            if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        }
    };
    Ok(lit)
}

/// xla Literal → Tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(v, &dims)
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(v, &dims)
        }
        xla::ElementType::Pred => {
            let conv = lit
                .convert(xla::PrimitiveType::S32)
                .map_err(|e| anyhow!("convert pred: {e:?}"))?;
            let v = conv.to_vec::<i32>().map_err(|e| anyhow!("to_vec pred: {e:?}"))?;
            Tensor::from_i32(v, &dims)
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}

impl RtStats {
    pub fn summary(&self) -> String {
        format!(
            "compiles={} ({:.2}s) cache_hits={} executions={} ({:.2}s, {:.3}ms avg)",
            self.compiles,
            self.compile_secs,
            self.cache_hits,
            self.executions,
            self.execute_secs,
            if self.executions > 0 { self.execute_secs * 1e3 / self.executions as f64 } else { 0.0 },
        )
    }
}

// ---------------------------------------------------------------------------
// The Backend implementation
// ---------------------------------------------------------------------------

/// The artifact-executing engine: a thin [`Backend`] shell around
/// [`Runtime`].  Derefs to it so perf counters and raw artifact loading
/// (`rt.load(..)`, `rt.stats`) stay reachable.
pub struct Pjrt {
    rt: Runtime,
}

impl Pjrt {
    pub fn new(artifact_dir: &Path) -> Result<Pjrt> {
        Ok(Pjrt { rt: Runtime::new(artifact_dir)? })
    }
}

impl std::ops::Deref for Pjrt {
    type Target = Runtime;

    fn deref(&self) -> &Runtime {
        &self.rt
    }
}

/// Parameters that are *live* in a forward-only (q/qw) executable.
///
/// The ablation `flexround_no_s34` replaces s3/s4 with constant ones in the
/// forward, so `jax.jit` pruned those slots out of the compiled signature —
/// mirror that here (recon executables still take them: they round-trip
/// through the Adam state outputs).
fn live_params(method: &str, entries: &[PackEntry], params: &[Tensor]) -> Vec<Tensor> {
    entries
        .iter()
        .zip(params)
        .filter(|(e, _)| {
            !(method == "flexround_no_s34"
                && (e.name.ends_with(".s3") || e.name.ends_with(".s4")))
        })
        .map(|(_, p)| p.clone())
        .collect()
}

fn q_scalars(symmetric: bool, q: &QView) -> Vec<Tensor> {
    let (qmin_w, qmax_w) = qrange(q.bits_w, symmetric);
    let mut v = vec![Tensor::scalar(qmin_w), Tensor::scalar(qmax_w)];
    if q.mode == "wa" {
        let (qmin_a, qmax_a) = qrange(q.abits, false);
        v.push(Tensor::scalar(qmin_a));
        v.push(Tensor::scalar(qmax_a));
    }
    v
}

impl Backend for Pjrt {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn summary(&self) -> String {
        format!("platform={} {}", self.rt.platform(), self.rt.stats.borrow().summary())
    }

    fn unit_forward_fp(&self, cx: &UnitCtx, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.rt.load(cx.unit.artifact("fp")?)?;
        chunks
            .iter()
            .map(|c| {
                Ok(exe
                    .run(&self.rt, std::slice::from_ref(c), false)?
                    .into_iter()
                    .next()
                    .unwrap())
            })
            .collect()
    }

    /// Input-liveness note: `jax.jit` prunes arguments that are dead in the
    /// lowered graph, so weight-only ("w") executables do not take the
    /// activation-quant scalars — the assembly below mirrors exactly what
    /// the AOT build kept (PJRT rejects any arity mismatch loudly).
    fn unit_forward_q(&self, cx: &UnitCtx, q: &QView, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self
            .rt
            .load(cx.unit.artifact(&format!("q.{}.{}", q.method, q.mode))?)?;
        let scal = q_scalars(cx.model.symmetric, q);
        let live = live_params(q.method, q.entries, q.params);
        chunks
            .iter()
            .map(|c| {
                let mut inputs = vec![c.clone()];
                inputs.extend(scal.iter().cloned());
                inputs.extend(live.iter().cloned());
                Ok(exe.run(&self.rt, &inputs, false)?.into_iter().next().unwrap())
            })
            .collect()
    }

    fn reconstruct(&self, task: &ReconTask) -> Result<ReconOutcome> {
        let cx = &task.cx;
        let t0 = Instant::now();
        let exe = self
            .rt
            .load(cx.unit.artifact(&format!("recon.{}.{}", task.method, task.mode))?)?;
        let (qmin_w, qmax_w) = qrange(task.bits_w, cx.model.symmetric);
        let (qmin_a, qmax_a) = qrange(task.abits, false);
        let wa = task.mode == "wa";
        let has_beta = task.method == "adaround";
        let mut params = task.params.clone();
        // Adam state starts at zero
        let mut m: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let mut v = m.clone();
        let x_all = Tensor::concat_rows(&task.x)?;
        let y_all = Tensor::concat_rows(&task.y)?;
        let n = x_all.shape()[0];
        let mut rng = task.rng.clone();
        let mut first_loss = f64::NAN;
        let mut final_loss = f64::NAN;

        for t in 1..=task.iters {
            let idx = rng.sample_indices(n, task.batch);
            let xb = x_all.gather_rows(&idx)?;
            let yb = y_all.gather_rows(&idx)?;
            let beta = beta_schedule(t, task.iters);
            let seed = (rng.next_u32() & 0x7FFF_FFFF) as i32;
            // same liveness rule as unit_forward_q: jit pruned the scalars
            // that are dead in this (method, mode) — qmin_a/qmax_a/
            // drop_p/seed in "w" mode, beta for non-AdaRound methods.
            let mut inputs = vec![
                xb,
                yb,
                Tensor::scalar(qmin_w),
                Tensor::scalar(qmax_w),
            ];
            if wa {
                inputs.push(Tensor::scalar(qmin_a));
                inputs.push(Tensor::scalar(qmax_a));
                inputs.push(Tensor::scalar(task.drop_p as f32));
            }
            if has_beta {
                inputs.push(Tensor::scalar(beta as f32));
            }
            inputs.push(Tensor::scalar(task.lr as f32));
            inputs.push(Tensor::scalar(t as f32));
            if wa {
                inputs.push(Tensor::scalar_i32(seed));
            }
            inputs.extend(params.iter().cloned());
            inputs.extend(m.iter().cloned());
            inputs.extend(v.iter().cloned());
            let out = exe.run(&self.rt, &inputs, true)?;
            let np = params.len();
            if out.len() != 1 + 3 * np {
                bail!(
                    "recon {}: expected {} outputs, got {}",
                    cx.unit.name,
                    1 + 3 * np,
                    out.len()
                );
            }
            let loss = out[0].item()? as f64;
            if t == 1 {
                first_loss = loss;
            }
            final_loss = loss;
            let mut it = out.into_iter();
            let _ = it.next();
            params = it.by_ref().take(np).collect();
            m = it.by_ref().take(np).collect();
            v = it.by_ref().take(np).collect();
            if task.verbose && (t == 1 || t % 100 == 0 || t == task.iters) {
                eprintln!(
                    "    [{}/{}] iter {t}/{} loss {loss:.6}",
                    cx.model.name, cx.unit.name, task.iters
                );
            }
        }
        Ok(ReconOutcome {
            params,
            first_loss,
            final_loss,
            steps: task.iters as u64,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn export_qw(&self, cx: &UnitCtx, q: &QView) -> Result<Vec<(Tensor, Tensor)>> {
        let exe = self.rt.load(cx.unit.artifact(&format!("qw.{}", q.method))?)?;
        let (qmin_w, qmax_w) = qrange(q.bits_w, cx.model.symmetric);
        // qw artifacts were lowered against the "w" pack (no act entries);
        // derive its length from the state's own pack so wa-only models
        // (whose manifest records no "w" pack) still export correctly —
        // the weight entries are a strict prefix of the wa pack.
        let n_w = q.entries.iter().filter(|e| !e.name.starts_with("act")).count();
        let mut inputs = vec![Tensor::scalar(qmin_w), Tensor::scalar(qmax_w)];
        inputs.extend(live_params(q.method, &q.entries[..n_w], &q.params[..n_w]));
        let out = exe.run(&self.rt, &inputs, true)?;
        if out.len() != 2 * cx.unit.layers.len() {
            bail!(
                "qw {}: expected {} outputs, got {}",
                cx.unit.name,
                2 * cx.unit.layers.len(),
                out.len()
            );
        }
        let mut res = Vec::new();
        let mut it = out.into_iter();
        while let (Some(w), Some(c)) = (it.next(), it.next()) {
            res.push((w, c));
        }
        Ok(res)
    }

    fn as_pjrt(&self) -> Option<&Runtime> {
        Some(&self.rt)
    }
}
