//! Native engine: pure-Rust learnable-rounding reconstruction (no
//! artifacts, no PJRT).  A thin [`Backend`] shell over [`crate::recon`] —
//! the rounding scheme (FlexRound, AdaRound, …) is resolved per task from
//! the method string via [`recon::scheme_for`]; see DESIGN.md
//! §Native-Backend for the execution model and its limits (weight-only
//! mode, contraction-shaped units).

use super::{Backend, QView, ReconOutcome, ReconTask, UnitCtx};
use crate::block::{self, BlockDef};
use crate::recon::{self, LayerDef};
use crate::tensor::{qrange, Tensor};
use crate::util::pool;
use crate::Result;
use anyhow::{anyhow, bail};
use std::sync::Mutex;
use std::time::Instant;

/// Unit kinds the native engine can execute: plain contraction stacks
/// (optionally ReLU-separated) and transformer blocks.  This list is the
/// single source of truth — the packed-export eligibility check
/// (`Session::check_packable`) and the block pipeline route through
/// [`native_unit_kind`] rather than re-spelling the strings.
pub const NATIVE_KINDS: [&str; 3] = ["linear", "mlp_relu", "transformer_block"];

/// The shared supported-unit-kind predicate.
pub fn native_unit_kind(kind: &str) -> bool {
    NATIVE_KINDS.contains(&kind)
}

/// Contraction kinds whose layers form a *sequential stack* (everything in
/// [`NATIVE_KINDS`] except `transformer_block`, whose six layers wire into
/// attention + MLP instead).
fn stack_kind(kind: &str) -> bool {
    kind == "linear" || kind == "mlp_relu"
}

/// Per-layer [`LayerDef`] views for a sequential contraction stack — shared
/// by the [`Native`] engine and the block pipeline's streamed recon loop.
pub fn stack_layer_defs<'a>(cx: &UnitCtx<'a>) -> Result<Vec<LayerDef<'a>>> {
    if !stack_kind(&cx.unit.kind) {
        bail!(
            "native backend cannot execute unit {:?} of kind {:?} as a contraction \
             stack (supported kinds: {NATIVE_KINDS:?}); use --backend pjrt with AOT \
             artifacts",
            cx.unit.name,
            cx.unit.kind
        );
    }
    layer_weight_defs(cx)
}

/// Per-layer weight/bias views without any executability check (enough for
/// weight export — works for blocks too, whose layers are canonical 2-D
/// contractions).
fn layer_weight_defs<'a>(cx: &UnitCtx<'a>) -> Result<Vec<LayerDef<'a>>> {
    let relu_between = cx.unit.kind == "mlp_relu";
    let n = cx.unit.layers.len();
    let mut out = Vec::with_capacity(n);
    for (i, layer) in cx.unit.layers.iter().enumerate() {
        let w = cx
            .weights
            .get(i)
            .copied()
            .flatten()
            .ok_or_else(|| {
                anyhow!(
                    "native backend: missing weights w/{}/{} in the model's FXT export",
                    cx.unit.name,
                    layer.name
                )
            })?;
        if w.shape() != &[layer.rows, layer.cols][..] {
            bail!(
                "native backend: weights for {}/{} have shape {:?}, expected the \
                 canonical 2-D layout [{}, {}]",
                cx.unit.name,
                layer.name,
                w.shape(),
                layer.rows,
                layer.cols
            );
        }
        out.push(LayerDef {
            name: &layer.name,
            w,
            bias: cx.biases.get(i).copied().flatten(),
            relu_after: relu_between && i + 1 < n,
        });
    }
    Ok(out)
}

#[derive(Default, Clone, Debug)]
pub struct NativeStats {
    pub units: u64,
    pub steps: u64,
    pub recon_secs: f64,
    pub forwards: u64,
}

/// The artifact-free engine.  `Sync` by construction (counters behind a
/// mutex), so [`Backend::reconstruct_many`] can fan independent units out
/// over the [`pool`] worker threads.
pub struct Native {
    pub workers: usize,
    stats: Mutex<NativeStats>,
}

impl Default for Native {
    fn default() -> Self {
        Native::new()
    }
}

impl Native {
    pub fn new() -> Native {
        Native::with_workers(pool::default_workers())
    }

    pub fn with_workers(workers: usize) -> Native {
        Native { workers: workers.max(1), stats: Mutex::new(NativeStats::default()) }
    }

    pub fn stats(&self) -> NativeStats {
        self.stats.lock().expect("stats lock").clone()
    }

    /// The block view of a `transformer_block` unit context.
    fn block_def<'a>(&self, cx: &UnitCtx<'a>) -> Result<BlockDef<'a>> {
        block::block_def_for(cx)
    }

    fn reconstruct_with(&self, task: &ReconTask, workers: usize) -> Result<ReconOutcome> {
        if task.mode != "w" {
            bail!(
                "native backend supports weight-only mode; \"{}\" (activation \
                 quantization) needs --backend pjrt",
                task.mode
            );
        }
        let cx = &task.cx;
        let slots = recon::map_pack(cx.unit, &task.method, &task.entries)?;
        let (qmin, qmax) = qrange(task.bits_w, cx.model.symmetric);
        let x_all = Tensor::concat_rows(&task.x)?;
        let y_all = Tensor::concat_rows(&task.y)?;
        let cfg = recon::ReconSettings {
            iters: task.iters,
            lr: task.lr as f32,
            batch: task.batch,
            qmin,
            qmax,
            workers,
            verbose: task.verbose,
            tag: format!("{}/{}", cx.model.name, cx.unit.name),
            scheme: recon::scheme_for(&task.method)?,
        };
        let mut rng = task.rng.clone();
        let t0 = Instant::now();
        let r = if cx.unit.kind == "transformer_block" {
            let def = self.block_def(cx)?;
            block::reconstruct_block(
                &def, &slots, &task.entries, &task.params, &x_all, &y_all, &cfg, &mut rng,
            )?
        } else {
            let layers = stack_layer_defs(cx)?;
            recon::reconstruct_unit(
                &layers, &slots, &task.entries, &task.params, &x_all, &y_all, &cfg, &mut rng,
            )?
        };
        let seconds = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().expect("stats lock");
            s.units += 1;
            s.steps += r.steps;
            s.recon_secs += seconds;
        }
        Ok(ReconOutcome {
            params: r.params,
            first_loss: r.first_loss,
            final_loss: r.final_loss,
            steps: r.steps,
            seconds,
        })
    }
}

impl Backend for Native {
    fn name(&self) -> &'static str {
        "native"
    }

    fn summary(&self) -> String {
        let s = self.stats();
        let ms = if s.steps > 0 { s.recon_secs * 1e3 / s.steps as f64 } else { 0.0 };
        format!(
            "native: units={} steps={} ({:.2}s, {ms:.3}ms/step) forwards={} workers={}",
            s.units, s.steps, s.recon_secs, s.forwards, self.workers
        )
    }

    fn unit_forward_fp(&self, cx: &UnitCtx, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        self.stats.lock().expect("stats lock").forwards += chunks.len() as u64;
        if cx.unit.kind == "transformer_block" {
            let def = self.block_def(cx)?;
            return chunks.iter().map(|c| block::forward_fp(&def, c, self.workers)).collect();
        }
        let layers = stack_layer_defs(cx)?;
        chunks
            .iter()
            .map(|c| recon::unit_forward_fp(&layers, c, self.workers))
            .collect()
    }

    fn unit_forward_q(&self, cx: &UnitCtx, q: &QView, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        if q.mode != "w" {
            bail!("native backend supports weight-only mode; use --backend pjrt for \"wa\"");
        }
        let scheme = recon::scheme_for(q.method)?;
        let slots = recon::map_pack(cx.unit, q.method, q.entries)?;
        let (qmin, qmax) = qrange(q.bits_w, cx.model.symmetric);
        self.stats.lock().expect("stats lock").forwards += chunks.len() as u64;
        if cx.unit.kind == "transformer_block" {
            let def = self.block_def(cx)?;
            // Ŵ once per layer; only attention + contractions repeat per chunk.
            let whats = block::block_whats(scheme, &def, &slots, q.params, qmin, qmax)?;
            let refs: Vec<&Tensor> = whats.iter().collect();
            return chunks
                .iter()
                .map(|c| block::forward_with(&def, &refs, c, self.workers))
                .collect();
        }
        let layers = stack_layer_defs(cx)?;
        // Ŵ once per layer; only the contractions repeat per chunk.
        let whats = recon::unit_whats(scheme, &layers, &slots, q.params, qmin, qmax)?;
        chunks
            .iter()
            .map(|c| recon::unit_forward_what(&layers, &whats, c, self.workers))
            .collect()
    }

    fn reconstruct(&self, task: &ReconTask) -> Result<ReconOutcome> {
        self.reconstruct_with(task, self.workers)
    }

    /// Independent units fan out across the pool; each unit then runs its
    /// inner loops serially (no nested parallelism).
    fn reconstruct_many(&self, tasks: &[ReconTask]) -> Result<Vec<ReconOutcome>> {
        if tasks.len() <= 1 || self.workers <= 1 {
            return tasks.iter().map(|t| self.reconstruct(t)).collect();
        }
        let results = pool::par_map(self.workers.min(tasks.len()), tasks, |_, t| {
            self.reconstruct_with(t, 1)
        });
        results.into_iter().collect()
    }

    fn export_qw(&self, cx: &UnitCtx, q: &QView) -> Result<Vec<(Tensor, Tensor)>> {
        let layers = layer_weight_defs(cx)?;
        let scheme = recon::scheme_for(q.method)?;
        let slots = recon::map_pack(cx.unit, q.method, q.entries)?;
        let (qmin, qmax) = qrange(q.bits_w, cx.model.symmetric);
        recon::export_qw(scheme, &layers, &slots, q.params, qmin, qmax)
    }

    /// Codes without the Ŵ materialization (half the export work).
    fn export_codes(&self, cx: &UnitCtx, q: &QView) -> Result<Vec<Tensor>> {
        let layers = layer_weight_defs(cx)?;
        let scheme = recon::scheme_for(q.method)?;
        let slots = recon::map_pack(cx.unit, q.method, q.entries)?;
        let (qmin, qmax) = qrange(q.bits_w, cx.model.symmetric);
        recon::export_codes(scheme, &layers, &slots, q.params, qmin, qmax)
    }
}
