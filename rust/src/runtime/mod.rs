//! Execution engines behind the PTQ coordinator (DESIGN.md §Backends).
//!
//! The coordinator drives everything through the [`Backend`] trait; two
//! engines implement it:
//!
//! * [`Native`] — pure-Rust reconstruction via [`crate::recon`]: forward
//!   fake-quant by element-wise division, closed-form STE backward, Adam.
//!   No artifacts, no PJRT — the crate is self-contained.  Independent
//!   units fan out over the [`crate::util::pool`] worker threads (the
//!   `--parallel-units` FP-input scenario).
//! * [`Pjrt`] (feature `pjrt`) — wraps the original [`Runtime`], which
//!   loads `artifacts/*.hlo.txt`, compiles them once through the PJRT C
//!   API, and executes the AOT reconstruction/forward graphs.  Device-buffer
//!   chaining ([`pjrt::Exec::run_b`]) keeps the unit pipeline off the host —
//!   the L3 hot-path optimization benchmarked in EXPERIMENTS.md §Perf,
//!   alongside native-vs-PJRT per-unit reconstruction timings.
//!
//! `flexround --backend {auto|native|pjrt}` selects the engine; `auto`
//! prefers PJRT when compiled in and the artifact dir is usable, else falls
//! back to native.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::Native;
#[cfg(feature = "pjrt")]
pub use pjrt::{from_literal, to_literal, DeviceBuf, Exec, Pjrt, RtStats, Runtime};

use crate::manifest::{ModelInfo, PackEntry, UnitInfo};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use crate::Result;

/// Everything an engine needs to know about one unit: the manifest entry
/// plus the host-side weight/bias tensors (`None` where the weights FXT has
/// no entry for a layer — the PJRT engine does not need them, the native
/// engine errors if they are missing).
pub struct UnitCtx<'a> {
    pub model: &'a ModelInfo,
    pub unit: &'a UnitInfo,
    /// per-layer `w/{unit}/{layer}` tensors, in layer order
    pub weights: Vec<Option<&'a Tensor>>,
    /// per-layer `b/{unit}/{layer}` tensors, in layer order
    pub biases: Vec<Option<&'a Tensor>>,
    /// unit-level non-quantized parameters (`p/{unit}/{name}` in the
    /// weights FXT, keyed by `{name}`) — layernorm gains/biases for
    /// `transformer_block` units; empty elsewhere
    pub extras: std::collections::BTreeMap<String, &'a Tensor>,
}

/// A view of one unit's learned quantization state, enough to run the
/// quantized forward or the weight export.
pub struct QView<'a> {
    pub method: &'a str,
    pub mode: &'a str,
    pub bits_w: u32,
    pub abits: u32,
    pub params: &'a [Tensor],
    pub entries: &'a [PackEntry],
}

/// One unit's reconstruction job: calibration chunks, FP targets, the
/// initial parameter pack, and the hyperparameters already resolved by the
/// coordinator (manifest defaults applied).
pub struct ReconTask<'a> {
    pub cx: UnitCtx<'a>,
    pub method: String,
    pub mode: String,
    pub bits_w: u32,
    pub abits: u32,
    pub iters: usize,
    pub lr: f64,
    pub drop_p: f64,
    /// minibatch rows per Adam step
    pub batch: usize,
    pub verbose: bool,
    pub entries: Vec<PackEntry>,
    pub params: Vec<Tensor>,
    /// quantized-path input chunks X̃ (or FP inputs in `--parallel-units`)
    pub x: Vec<Tensor>,
    /// full-precision target chunks Y
    pub y: Vec<Tensor>,
    /// per-unit random stream (minibatch sampling, QDrop seeds)
    pub rng: Pcg32,
}

/// What a reconstruction returned.
pub struct ReconOutcome {
    pub params: Vec<Tensor>,
    pub first_loss: f64,
    pub final_loss: f64,
    pub steps: u64,
    pub seconds: f64,
}

/// An execution engine for per-unit reconstruction and unit forwards.
///
/// Object-safe: the coordinator holds `&dyn Backend` and never knows which
/// engine it drives.  [`Backend::reconstruct_many`] exists so engines with
/// thread-safe state (native) can fan independent units out over the worker
/// pool; the default implementation is sequential.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Human-readable perf counters (compile/execute or step/second totals).
    fn summary(&self) -> String;

    /// Full-precision forward of `unit` over activation chunks.
    fn unit_forward_fp(&self, cx: &UnitCtx, chunks: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Quantized forward with learned parameters.
    fn unit_forward_q(&self, cx: &UnitCtx, q: &QView, chunks: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Learn one unit's parameters by output-MSE reconstruction.
    fn reconstruct(&self, task: &ReconTask) -> Result<ReconOutcome>;

    /// Reconstruct several *independent* units (the FP-input scenario).
    fn reconstruct_many(&self, tasks: &[ReconTask]) -> Result<Vec<ReconOutcome>> {
        tasks.iter().map(|t| self.reconstruct(t)).collect()
    }

    /// Export `(Ŵ, integer codes)` per layer for figures/analysis and the
    /// packed-weight export.  The native engine emits i32 code tensors
    /// (bit-packable as-is); PJRT artifacts emit f32 — consumers read codes
    /// through `to_f32_vec` / `infer::PackedMatrix::from_tensors`, which
    /// accept both.
    fn export_qw(&self, cx: &UnitCtx, q: &QView) -> Result<Vec<(Tensor, Tensor)>>;

    /// Integer codes only — the packed-export path.  The default lowers to
    /// [`Backend::export_qw`] and drops Ŵ; engines that can skip the Ŵ
    /// materialization entirely (native) override it.
    fn export_codes(&self, cx: &UnitCtx, q: &QView) -> Result<Vec<Tensor>> {
        Ok(self.export_qw(cx, q)?.into_iter().map(|(_, codes)| codes).collect())
    }

    /// Downcast hook: the PJRT runtime, when this engine wraps one (heads,
    /// embeds, and raw artifact execution still need it).
    #[cfg(feature = "pjrt")]
    fn as_pjrt(&self) -> Option<&Runtime> {
        None
    }
}
