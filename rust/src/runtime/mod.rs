//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client).  Two execution paths:
//!
//! * [`Exec::run`] — host literals in, host tensors out.  Multi-output
//!   graphs (lowered with `return_tuple=True`) come back as one tuple
//!   literal which is decomposed here.
//! * [`Exec::run_b`] / [`DeviceBuf`] — device-buffer chaining for the unit
//!   pipeline: single-output graphs (`return_tuple=False`) produce a bare
//!   array buffer that feeds the next executable without a host round-trip.
//!   This is the L3 hot-path optimization (see EXPERIMENTS.md §Perf).
//!
//! Executables are cached by file name (compile once per process).

use crate::tensor::{DType, Tensor};
use crate::Result;
use anyhow::{anyhow, bail};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

/// A device-resident buffer (output of a single-output executable).
pub struct DeviceBuf(pub xla::PjRtBuffer);

/// Shared PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
    pub stats: RefCell<RtStats>,
}

/// Runtime counters for the perf report.
#[derive(Default, Debug, Clone)]
pub struct RtStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    pub cache_hits: u64,
}

/// One compiled executable.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RtStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(file) {
            self.stats.borrow_mut().cache_hits += 1;
            return Ok(Rc::clone(e));
        }
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += t0.elapsed().as_secs_f64();
        }
        let rc = Rc::new(Exec { exe, name: file.to_string() });
        self.cache.borrow_mut().insert(file.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    /// Upload a host tensor to the device (for buffer-path chaining).
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuf> {
        let lit = to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        Ok(DeviceBuf(buf))
    }

    fn note_exec(&self, t0: Instant) {
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
    }
}

impl Exec {
    /// Literal path: host tensors in → host tensors out.  `tuple_out` must
    /// match how the artifact was lowered (recon/qw/lm-head → true).
    pub fn run(&self, rt: &Runtime, inputs: &[Tensor], tuple_out: bool) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let res = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        collect_outputs(res, tuple_out, &self.name)
    }

    /// Buffer path: device buffers in → device buffers out (no host copy).
    pub fn run_b(&self, rt: &Runtime, inputs: &[&DeviceBuf]) -> Result<Vec<DeviceBuf>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.0).collect();
        let t0 = Instant::now();
        let res = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("execute_b {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        let mut replica = res
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no replica output", self.name))?;
        Ok(replica.drain(..).map(DeviceBuf).collect())
    }

    /// Mixed path: host inputs, device outputs (for starting a chain).
    pub fn run_to_device(&self, rt: &Runtime, inputs: &[Tensor]) -> Result<Vec<DeviceBuf>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let res = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        rt.note_exec(t0);
        let mut replica = res
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no replica output", self.name))?;
        Ok(replica.drain(..).map(DeviceBuf).collect())
    }
}

impl DeviceBuf {
    /// Copy to host.
    pub fn fetch(&self) -> Result<Tensor> {
        let lit = self
            .0
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        from_literal(&lit)
    }
}

fn collect_outputs(
    res: Vec<Vec<xla::PjRtBuffer>>,
    tuple_out: bool,
    name: &str,
) -> Result<Vec<Tensor>> {
    let replica = res
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("{name}: no replica output"))?;
    let mut out = Vec::new();
    for buf in replica {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        if tuple_out {
            for el in lit.to_tuple().map_err(|e| anyhow!("{name}: to_tuple: {e:?}"))? {
                out.push(from_literal(&el)?);
            }
        } else {
            out.push(from_literal(&lit)?);
        }
    }
    Ok(out)
}

/// Tensor → xla Literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t.dtype() {
        DType::F32 => {
            let v = t.as_f32()?;
            if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        }
        DType::I32 => {
            let v = t.as_i32()?;
            if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        }
    };
    Ok(lit)
}

/// xla Literal → Tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(v, &dims)
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(v, &dims)
        }
        xla::ElementType::Pred => {
            let conv = lit
                .convert(xla::PrimitiveType::S32)
                .map_err(|e| anyhow!("convert pred: {e:?}"))?;
            let v = conv.to_vec::<i32>().map_err(|e| anyhow!("to_vec pred: {e:?}"))?;
            Tensor::from_i32(v, &dims)
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}

impl RtStats {
    pub fn summary(&self) -> String {
        format!(
            "compiles={} ({:.2}s) cache_hits={} executions={} ({:.2}s, {:.3}ms avg)",
            self.compiles,
            self.compile_secs,
            self.cache_hits,
            self.executions,
            self.execute_secs,
            if self.executions > 0 { self.execute_secs * 1e3 / self.executions as f64 } else { 0.0 },
        )
    }
}
