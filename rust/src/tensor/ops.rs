//! Element-wise and reduction operations over [`Tensor`], plus the Rust-side
//! reference quantizers used by tests and the grid-shift analysis.

use super::{DType, Tensor};
use crate::linalg::{self, Dispatch};
use crate::Result;
use anyhow::bail;

impl Tensor {
    /// Element-wise map over f32 values (i32 tensors are converted).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        let data: Vec<f32> = self.to_f32_vec().into_iter().map(f).collect();
        Tensor::from_f32(data, self.shape()).expect("same shape")
    }

    /// Element-wise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape() != other.shape() {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        let data: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| f(x, y)).collect();
        Tensor::from_f32(data, self.shape())
    }

    pub fn sum(&self) -> f32 {
        self.to_f32_vec().iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        self.sum() / self.len() as f32
    }

    pub fn min(&self) -> f32 {
        self.to_f32_vec().iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.to_f32_vec().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.to_f32_vec().iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// In-place row-broadcast bias add + optional ReLU on a 2-D tensor —
    /// the shared contraction epilogue (recon unit forwards and the packed
    /// inference engine).
    pub fn bias_relu_inplace(&mut self, bias: Option<&[f32]>, relu: bool) -> Result<()> {
        if self.ndim() != 2 {
            bail!("bias_relu_inplace on {:?}", self.shape());
        }
        let (n, r) = (self.shape()[0], self.shape()[1]);
        let yv = self.as_f32_mut()?;
        if let Some(b) = bias {
            if b.len() != r {
                bail!("bias of {} values on output width {r}", b.len());
            }
            for i in 0..n {
                for (v, bj) in yv[i * r..(i + 1) * r].iter_mut().zip(b) {
                    *v += bj;
                }
            }
        }
        if relu {
            for v in yv.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(())
    }

    /// Largest absolute element-wise difference — the parity metric between
    /// kernel implementations (fused packed GEMM vs the f32 paths).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("max_abs_diff shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        Ok(a.iter().zip(&b).fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs())))
    }

    /// Mean squared difference — the reconstruction-loss metric.
    pub fn mse(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("mse shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        let s: f32 = a.iter().zip(&b).map(|(&x, &y)| (x - y) * (x - y)).sum();
        Ok(s / a.len().max(1) as f32)
    }

    /// Row-wise argmax over a 2-D tensor (logits → predictions).  Ties
    /// break toward the **lowest** index and NaNs are never selected —
    /// the same deterministic contract as `infer::generate::sample_token`'s
    /// greedy path (break ties by token id), so argmax-based eval and
    /// greedy decode agree on which token a tied logit row names.
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.ndim() != 2 {
            bail!("argmax_rows on {:?}", self.shape());
        }
        let (n, c) = (self.shape()[0], self.shape()[1]);
        let v = self.to_f32_vec();
        Ok((0..n)
            .map(|i| {
                let row = &v[i * c..(i + 1) * c];
                let mut best = 0usize;
                for (j, &x) in row.iter().enumerate() {
                    let b = row[best];
                    // strict > keeps the first maximum; a NaN never wins
                    // over a number (and an all-NaN row stays at index 0)
                    if (b.is_nan() && !x.is_nan()) || x > b {
                        best = j;
                    }
                }
                best
            })
            .collect())
    }

    /// `A · Bᵀ` for `A: (m, k)`, `B: (r, k)` → `(m, r)`.  The native
    /// reconstruction hot path (`Ŷ = X̃ · Ŵᵀ`), routed through the blocked
    /// [`crate::linalg`] kernel core under the machine-default dispatch
    /// policy (single rows take the gemv fast path, big problems fan out
    /// over the pool — results are bit-identical either way).
    pub fn matmul_nt(&self, b: &Tensor) -> Result<Tensor> {
        self.matmul_nt_with(b, &Dispatch::auto())
    }

    /// [`Tensor::matmul_nt`] under an explicit dispatch policy (callers
    /// that manage their own parallelism budget, e.g. the reconstruction
    /// loop's `--workers`).
    pub fn matmul_nt_with(&self, b: &Tensor, d: &Dispatch) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[1] != b.shape()[1] {
            bail!("matmul_nt shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (m, k) = (self.shape()[0], self.shape()[1]);
        let r = b.shape()[0];
        let out = linalg::gemm_nt(self.as_f32()?, b.as_f32()?, m, k, r, d);
        Tensor::from_f32(out, &[m, r])
    }

    /// `A · B` for `A: (m, k)`, `B: (k, c)` → `(m, c)`  (activation
    /// cotangent: `∂L/∂X = G · Ŵ`), on the blocked [`crate::linalg`] core.
    pub fn matmul_nn(&self, b: &Tensor) -> Result<Tensor> {
        self.matmul_nn_with(b, &Dispatch::auto())
    }

    /// [`Tensor::matmul_nn`] under an explicit dispatch policy.
    pub fn matmul_nn_with(&self, b: &Tensor, d: &Dispatch) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[1] != b.shape()[0] {
            bail!("matmul_nn shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (m, k) = (self.shape()[0], self.shape()[1]);
        let c = b.shape()[1];
        let out = linalg::gemm_nn(self.as_f32()?, b.as_f32()?, m, k, c, d);
        Tensor::from_f32(out, &[m, c])
    }

    /// `Aᵀ · B` for `A: (n, m)`, `B: (n, c)` → `(m, c)`  (weight cotangent:
    /// `∂L/∂Ŵ = Gᵀ · X`), on the blocked [`crate::linalg`] core.
    pub fn matmul_tn(&self, b: &Tensor) -> Result<Tensor> {
        self.matmul_tn_with(b, &Dispatch::auto())
    }

    /// [`Tensor::matmul_tn`] under an explicit dispatch policy.
    pub fn matmul_tn_with(&self, b: &Tensor, d: &Dispatch) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[0] != b.shape()[0] {
            bail!("matmul_tn shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (n, m) = (self.shape()[0], self.shape()[1]);
        let c = b.shape()[1];
        let out = linalg::gemm_tn(self.as_f32()?, b.as_f32()?, n, m, c, d);
        Tensor::from_f32(out, &[m, c])
    }

    /// Row sums of a 2-D tensor → `(r, 1)`.
    pub fn row_sum(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            bail!("row_sum on {:?}", self.shape());
        }
        let (r, c) = (self.shape()[0], self.shape()[1]);
        let v = self.as_f32()?;
        let out: Vec<f32> = (0..r).map(|i| v[i * c..(i + 1) * c].iter().sum()).collect();
        Tensor::from_f32(out, &[r, 1])
    }

    /// Column sums of a 2-D tensor → `(1, c)`.
    pub fn col_sum(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            bail!("col_sum on {:?}", self.shape());
        }
        let (r, c) = (self.shape()[0], self.shape()[1]);
        let v = self.as_f32()?;
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for j in 0..c {
                out[j] += v[i * c + j];
            }
        }
        Tensor::from_f32(out, &[1, c])
    }

    /// Row-wise numerically-stable softmax over a 2-D tensor (attention
    /// probabilities, logit→probability conversion).
    pub fn softmax_rows(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            bail!("softmax_rows on {:?}", self.shape());
        }
        let (n, c) = (self.shape()[0], self.shape()[1]);
        let v = self.to_f32_vec();
        let mut out = vec![0.0f32; n * c];
        for i in 0..n {
            let row = &v[i * c..(i + 1) * c];
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let orow = &mut out[i * c..(i + 1) * c];
            let mut sum = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                *o = (x - mx).exp();
                sum += *o;
            }
            let inv = 1.0 / sum;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
        Tensor::from_f32(out, self.shape())
    }

    /// GELU activation (tanh approximation, the GPT-2 form) — the
    /// transformer-block MLP nonlinearity.
    pub fn gelu(&self) -> Tensor {
        self.map(gelu_scalar)
    }

    /// Top-k indices per row (descending) — for top-5 accuracy.
    pub fn topk_rows(&self, k: usize) -> Result<Vec<Vec<usize>>> {
        if self.ndim() != 2 {
            bail!("topk_rows on {:?}", self.shape());
        }
        let (n, c) = (self.shape()[0], self.shape()[1]);
        let v = self.to_f32_vec();
        Ok((0..n)
            .map(|i| {
                let row = &v[i * c..(i + 1) * c];
                let mut idx: Vec<usize> = (0..c).collect();
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal));
                idx.truncate(k);
                idx
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Transformer-block primitives: GELU / softmax / layernorm backwards.  The
// forward halves live on `Tensor` ([`Tensor::softmax_rows`], [`Tensor::gelu`],
// [`layernorm_rows`]); the backwards are free functions so `block::`'s
// closed-form STE backprop (and its finite-difference gradchecks) can drive
// them with explicit caches.
// ---------------------------------------------------------------------------

fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    const A: f32 = 0.044_715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// d gelu(x)/dx for the tanh approximation (smooth everywhere, so plain
/// finite differences validate it).
fn gelu_grad_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    const A: f32 = 0.044_715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// GELU backward: `dx = gy ⊙ gelu'(x)` with `x` the *pre-activation*.
pub fn gelu_bwd(x: &Tensor, gy: &Tensor) -> Result<Tensor> {
    x.zip(gy, |xi, gi| gi * gelu_grad_scalar(xi))
}

/// Softmax backward from the forward *output* `y` (row-wise probabilities):
/// `dx = y ⊙ (gy − Σ_row gy ⊙ y)`.  Rows of `y` that are all zero (masked
/// attention rows) propagate zero gradient, which is exactly right.
pub fn softmax_rows_bwd(y: &Tensor, gy: &Tensor) -> Result<Tensor> {
    if y.shape() != gy.shape() || y.ndim() != 2 {
        bail!("softmax_rows_bwd: y {:?} vs gy {:?}", y.shape(), gy.shape());
    }
    let (n, c) = (y.shape()[0], y.shape()[1]);
    let yv = y.as_f32()?;
    let gv = gy.as_f32()?;
    let mut out = vec![0.0f32; n * c];
    for i in 0..n {
        let yr = &yv[i * c..(i + 1) * c];
        let gr = &gv[i * c..(i + 1) * c];
        let dot: f32 = yr.iter().zip(gr).map(|(&a, &b)| a * b).sum();
        for ((o, &yj), &gj) in out[i * c..(i + 1) * c].iter_mut().zip(yr).zip(gr) {
            *o = yj * (gj - dot);
        }
    }
    Tensor::from_f32(out, y.shape())
}

/// Row-wise layernorm `y = gain ⊙ (x − μ)/√(σ² + eps) + bias` over a 2-D
/// tensor; returns `(y, mean, rstd)` — the per-row statistics are the
/// backward pass's cache.
pub fn layernorm_rows(
    x: &Tensor,
    gain: &[f32],
    bias: &[f32],
    eps: f32,
) -> Result<(Tensor, Vec<f32>, Vec<f32>)> {
    if x.ndim() != 2 {
        bail!("layernorm_rows on {:?}", x.shape());
    }
    let (n, c) = (x.shape()[0], x.shape()[1]);
    if gain.len() != c || bias.len() != c {
        bail!("layernorm_rows: gain/bias of {}/{} values on width {c}", gain.len(), bias.len());
    }
    let xv = x.as_f32()?;
    let mut out = vec![0.0f32; n * c];
    let mut mean = vec![0.0f32; n];
    let mut rstd = vec![0.0f32; n];
    for i in 0..n {
        let row = &xv[i * c..(i + 1) * c];
        let mu = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let rs = 1.0 / (var + eps).sqrt();
        mean[i] = mu;
        rstd[i] = rs;
        for (((o, &xj), &g), &b) in
            out[i * c..(i + 1) * c].iter_mut().zip(row).zip(gain).zip(bias)
        {
            *o = g * (xj - mu) * rs + b;
        }
    }
    Ok((Tensor::from_f32(out, x.shape())?, mean, rstd))
}

/// Layernorm backward with the cached `(mean, rstd)` from
/// [`layernorm_rows`]; returns `(dx, dgain, dbias)`.
pub fn layernorm_rows_bwd(
    x: &Tensor,
    gain: &[f32],
    mean: &[f32],
    rstd: &[f32],
    gy: &Tensor,
) -> Result<(Tensor, Vec<f32>, Vec<f32>)> {
    if x.shape() != gy.shape() || x.ndim() != 2 {
        bail!("layernorm_rows_bwd: x {:?} vs gy {:?}", x.shape(), gy.shape());
    }
    let (n, c) = (x.shape()[0], x.shape()[1]);
    if gain.len() != c || mean.len() != n || rstd.len() != n {
        bail!("layernorm_rows_bwd: cache sizes {}/{}/{} vs ({n}, {c})",
              gain.len(), mean.len(), rstd.len());
    }
    let xv = x.as_f32()?;
    let gv = gy.as_f32()?;
    let mut dx = vec![0.0f32; n * c];
    let mut dgain = vec![0.0f32; c];
    let mut dbias = vec![0.0f32; c];
    for i in 0..n {
        let row = &xv[i * c..(i + 1) * c];
        let gr = &gv[i * c..(i + 1) * c];
        // x̂ and dx̂ = gy ⊙ gain; dx = rstd·(dx̂ − mean(dx̂) − x̂·mean(dx̂ ⊙ x̂))
        let mut m1 = 0.0f32; // mean of dx̂
        let mut m2 = 0.0f32; // mean of dx̂ ⊙ x̂
        for ((&xj, &gj), &gnj) in row.iter().zip(gr).zip(gain) {
            let xh = (xj - mean[i]) * rstd[i];
            let dxh = gj * gnj;
            m1 += dxh;
            m2 += dxh * xh;
        }
        m1 /= c as f32;
        m2 /= c as f32;
        for ((((o, &xj), &gj), &gnj), (dg, db)) in dx[i * c..(i + 1) * c]
            .iter_mut()
            .zip(row)
            .zip(gr)
            .zip(gain)
            .zip(dgain.iter_mut().zip(dbias.iter_mut()))
        {
            let xh = (xj - mean[i]) * rstd[i];
            let dxh = gj * gnj;
            *o = rstd[i] * (dxh - m1 - xh * m2);
            *dg += gj * xh;
            *db += gj;
        }
    }
    Ok((Tensor::from_f32(dx, x.shape())?, dgain, dbias))
}

// ---------------------------------------------------------------------------
// Reference quantization math (mirrors python/compile/kernels/ref.py; the
// pytest/cargo cross-check pins these against the Pallas kernels).
// ---------------------------------------------------------------------------

/// Integer grid range for a bit-width (symmetric = signed two's complement).
pub fn qrange(bits: u32, symmetric: bool) -> (f32, f32) {
    if symmetric {
        (-(2f32.powi(bits as i32 - 1)), 2f32.powi(bits as i32 - 1) - 1.0)
    } else {
        (0.0, 2f32.powi(bits as i32) - 1.0)
    }
}

/// Min/max calibration of (s1, zero_point) for per-tensor quantization.
pub fn minmax_scale(w: &[f32], bits: u32, symmetric: bool) -> (f32, f32) {
    let (qmin, qmax) = qrange(bits, symmetric);
    if symmetric {
        let amax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        ((amax / qmax).max(1e-8), 0.0)
    } else {
        let wmax = w.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let wmin = w.iter().copied().fold(f32::INFINITY, f32::min);
        let s1 = ((wmax - wmin) / (qmax - qmin)).max(1e-8);
        // zp maps wmin → qmin; NOT clamped to the grid — fake-quant keeps
        // full range for one-sided data (integer kernels would clamp).
        let zp = qmin - (wmin / s1).round();
        (s1, zp)
    }
}

/// Rounding-to-nearest fake-quant (the Rust oracle).
pub fn rtn(w: &[f32], s1: f32, zp: f32, qmin: f32, qmax: f32) -> Vec<f32> {
    w.iter()
        .map(|&x| {
            let n = ((x / s1).round() + zp).clamp(qmin, qmax);
            s1 * (n - zp)
        })
        .collect()
}

/// RTN integer grid codes.
pub fn rtn_codes(w: &[f32], s1: f32, zp: f32, qmin: f32, qmax: f32) -> Vec<f32> {
    w.iter()
        .map(|&x| ((x / s1).round() + zp).clamp(qmin, qmax))
        .collect()
}

/// Per-channel RTN codes: `s1`/`zp` indexed by row, `w` is (rows, cols).
pub fn rtn_codes_rows(w: &[f32], rows: usize, cols: usize, s1: &[f32], zp: &[f32],
                      qmin: f32, qmax: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(w.len());
    for r in 0..rows {
        for c in 0..cols {
            let x = w[r * cols + c];
            out.push(((x / s1[r]).round() + zp[r]).clamp(qmin, qmax));
        }
    }
    out
}

impl Tensor {
    /// Cast helper for analysis code.
    pub fn cast_f32(&self) -> Tensor {
        match self.dtype() {
            DType::F32 => self.clone(),
            DType::I32 => Tensor::from_f32(self.to_f32_vec(), self.shape()).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_zip_reduce() {
        let a = Tensor::from_f32(vec![1., -2., 3.], &[3]).unwrap();
        let b = a.map(|x| x * 2.0);
        assert_eq!(b.as_f32().unwrap(), &[2., -4., 6.]);
        let c = a.zip(&b, |x, y| x + y).unwrap();
        assert_eq!(c.sum(), 3.0 + -6.0 + 9.0);
        assert_eq!(a.abs_max(), 3.0);
        assert_eq!(a.min(), -2.0);
    }

    #[test]
    fn mse_basic() {
        let a = Tensor::from_f32(vec![0., 0.], &[2]).unwrap();
        let b = Tensor::from_f32(vec![3., 4.], &[2]).unwrap();
        assert!((a.mse(&b).unwrap() - 12.5).abs() < 1e-6);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 4.0);
        let c = Tensor::from_f32(vec![0.; 3], &[3]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn argmax_topk() {
        let t = Tensor::from_f32(vec![0.1, 0.9, 0.3, 0.7, 0.2, 0.1], &[2, 3]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
        let tk = t.topk_rows(2).unwrap();
        assert_eq!(tk[0], vec![1, 2]);
        assert_eq!(tk[1], vec![0, 1]);
    }

    #[test]
    fn argmax_rows_breaks_ties_low_and_skips_nan() {
        // the sample_token contract: ties resolve to the lowest index, and
        // NaN is never the answer (max_by used to return the *last* max)
        let t = Tensor::from_f32(
            vec![
                1.0, 5.0, 5.0, 0.0, // tie between 1 and 2 → 1
                f32::NAN, 2.0, 2.0, 1.0, // NaN prefix → first max at 1
                3.0, f32::NAN, 3.0, 3.0, // NaN in the middle → 0
                f32::NAN, f32::NAN, f32::NAN, f32::NAN, // all NaN → lowest index
            ],
            &[4, 4],
        )
        .unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 1, 0, 0]);
    }

    #[test]
    fn matmul_variants_agree() {
        // A: (2,3), B: (4,3) — NT against hand-computed values.
        let a = Tensor::from_f32(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        let b = Tensor::from_f32(
            vec![1., 0., 0., 0., 1., 0., 0., 0., 1., 1., 1., 1.],
            &[4, 3],
        )
        .unwrap();
        let nt = a.matmul_nt(&b).unwrap();
        assert_eq!(nt.shape(), &[2, 4]);
        assert_eq!(nt.as_f32().unwrap(), &[1., 2., 3., 6., 4., 5., 6., 15.]);
        // NN with B transposed manually must match NT.
        let bt = Tensor::from_f32(
            vec![1., 0., 0., 1., 0., 1., 0., 1., 0., 0., 1., 1.],
            &[3, 4],
        )
        .unwrap();
        let nn = a.matmul_nn(&bt).unwrap();
        assert_eq!(nn.as_f32().unwrap(), nt.as_f32().unwrap());
        // TN: Aᵀ·A is symmetric with known diagonal.
        let tn = a.matmul_tn(&a).unwrap();
        assert_eq!(tn.shape(), &[3, 3]);
        let v = tn.as_f32().unwrap();
        assert_eq!(v[0], 17.0); // 1² + 4²
        assert_eq!(v[4], 29.0); // 2² + 5²
        assert_eq!(v[1], v[3]);
        assert!(a.matmul_nt(&bt).is_err());
        assert!(a.matmul_nn(&b).is_err());
    }

    #[test]
    fn row_col_sums() {
        let t = Tensor::from_f32(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        assert_eq!(t.row_sum().unwrap().as_f32().unwrap(), &[6.0, 15.0]);
        assert_eq!(t.row_sum().unwrap().shape(), &[2, 1]);
        assert_eq!(t.col_sum().unwrap().as_f32().unwrap(), &[5.0, 7.0, 9.0]);
        assert_eq!(t.col_sum().unwrap().shape(), &[1, 3]);
    }

    #[test]
    fn qrange_matches_paper() {
        assert_eq!(qrange(4, true), (-8.0, 7.0));
        assert_eq!(qrange(8, false), (0.0, 255.0));
        assert_eq!(qrange(2, true), (-2.0, 1.0));
    }

    #[test]
    fn rtn_idempotent() {
        // quantizing an already-quantized tensor is the identity
        let w = vec![0.3, -0.7, 1.2, 0.05];
        let (s1, zp) = minmax_scale(&w, 4, true);
        let q1 = rtn(&w, s1, zp, -8.0, 7.0);
        let q2 = rtn(&q1, s1, zp, -8.0, 7.0);
        assert_eq!(q1, q2);
    }

    #[test]
    fn rtn_grid_membership() {
        let w = vec![0.33, -0.21, 0.9, -1.4];
        let (s1, zp) = minmax_scale(&w, 3, true);
        for q in rtn(&w, s1, zp, -4.0, 3.0) {
            let n = q / s1;
            assert!((n - n.round()).abs() < 1e-5);
            assert!(n >= -4.0 && n <= 3.0);
        }
    }

    // ---- transformer-block primitives -----------------------------------

    use crate::util::rng::Pcg32;

    /// Central finite difference of a scalar functional `f` with respect to
    /// one slot of `base`, in f32 forward / f64 accumulate.
    fn fd(base: &[f32], k: usize, eps: f32, f: impl Fn(&[f32]) -> f64) -> f64 {
        let mut hi = base.to_vec();
        let mut lo = base.to_vec();
        hi[k] += eps;
        lo[k] -= eps;
        (f(&hi) - f(&lo)) / (2.0 * eps as f64)
    }

    fn dot64(a: &Tensor, g: &[f32]) -> f64 {
        a.as_f32().unwrap().iter().zip(g).map(|(&x, &gi)| x as f64 * gi as f64).sum()
    }

    #[test]
    fn softmax_rows_normalizes_and_orders() {
        let t = Tensor::from_f32(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0], &[2, 3]).unwrap();
        let p = t.softmax_rows().unwrap();
        let v = p.as_f32().unwrap();
        assert!((v[..3].iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v[0] < v[1] && v[1] < v[2]);
        // numerically stable under huge logits
        assert!((v[5] - 1.0).abs() < 1e-6 && v[3] == 0.0);
    }

    #[test]
    fn softmax_backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(17);
        let (n, c) = (3usize, 5usize);
        let xv: Vec<f32> = (0..n * c).map(|_| rng.next_normal()).collect();
        let gv: Vec<f32> = (0..n * c).map(|_| rng.next_normal()).collect();
        let x = Tensor::from_f32(xv.clone(), &[n, c]).unwrap();
        let g = Tensor::from_f32(gv.clone(), &[n, c]).unwrap();
        let y = x.softmax_rows().unwrap();
        let dx = softmax_rows_bwd(&y, &g).unwrap();
        let dxv = dx.as_f32().unwrap();
        let f = |xs: &[f32]| {
            let t = Tensor::from_f32(xs.to_vec(), &[n, c]).unwrap();
            dot64(&t.softmax_rows().unwrap(), &gv)
        };
        for k in 0..n * c {
            let num = fd(&xv, k, 1e-3, f);
            assert!(
                (dxv[k] as f64 - num).abs() < 2e-3 * (1.0 + num.abs()),
                "softmax dx[{k}]: analytic {} vs numeric {num}",
                dxv[k]
            );
        }
    }

    #[test]
    fn gelu_backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(19);
        let xv: Vec<f32> = (0..64).map(|_| rng.next_normal() * 2.0).collect();
        let gv: Vec<f32> = (0..64).map(|_| rng.next_normal()).collect();
        let x = Tensor::from_f32(xv.clone(), &[64]).unwrap();
        let g = Tensor::from_f32(gv.clone(), &[64]).unwrap();
        let dx = gelu_bwd(&x, &g).unwrap();
        let dxv = dx.as_f32().unwrap();
        let f = |xs: &[f32]| {
            let t = Tensor::from_f32(xs.to_vec(), &[64]).unwrap();
            dot64(&t.gelu(), &gv)
        };
        for k in 0..64 {
            let num = fd(&xv, k, 1e-3, f);
            assert!(
                (dxv[k] as f64 - num).abs() < 2e-3 * (1.0 + num.abs()),
                "gelu dx[{k}]: analytic {} vs numeric {num}",
                dxv[k]
            );
        }
        // sanity: gelu(0) = 0, gelu(x) → x for large x, → 0 for very negative
        assert_eq!(Tensor::from_f32(vec![0.0], &[1]).unwrap().gelu().as_f32().unwrap()[0], 0.0);
        let big = Tensor::from_f32(vec![10.0, -10.0], &[2]).unwrap().gelu();
        assert!((big.as_f32().unwrap()[0] - 10.0).abs() < 1e-4);
        assert!(big.as_f32().unwrap()[1].abs() < 1e-4);
    }

    #[test]
    fn layernorm_forward_statistics() {
        let mut rng = Pcg32::seeded(23);
        let (n, c) = (4usize, 16usize);
        let x = Tensor::from_f32(
            (0..n * c).map(|_| 3.0 + 2.0 * rng.next_normal()).collect(),
            &[n, c],
        )
        .unwrap();
        let (y, mean, rstd) = layernorm_rows(&x, &vec![1.0; c], &vec![0.0; c], 1e-5).unwrap();
        assert_eq!(mean.len(), n);
        assert_eq!(rstd.len(), n);
        let yv = y.as_f32().unwrap();
        for i in 0..n {
            let row = &yv[i * c..(i + 1) * c];
            let mu = row.iter().sum::<f32>() / c as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
            assert!(mu.abs() < 1e-5, "normalized row mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "normalized row var {var}");
        }
    }

    #[test]
    fn layernorm_backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(29);
        let (n, c) = (3usize, 8usize);
        let xv: Vec<f32> = (0..n * c).map(|_| rng.next_normal()).collect();
        let gnv: Vec<f32> = (0..c).map(|_| 0.5 + rng.next_f32()).collect();
        let bv: Vec<f32> = (0..c).map(|_| rng.next_normal() * 0.1).collect();
        let gv: Vec<f32> = (0..n * c).map(|_| rng.next_normal()).collect();
        let x = Tensor::from_f32(xv.clone(), &[n, c]).unwrap();
        let g = Tensor::from_f32(gv.clone(), &[n, c]).unwrap();
        let (_, mean, rstd) = layernorm_rows(&x, &gnv, &bv, 1e-5).unwrap();
        let (dx, dgain, dbias) = layernorm_rows_bwd(&x, &gnv, &mean, &rstd, &g).unwrap();
        let dxv = dx.as_f32().unwrap();
        let f_x = |xs: &[f32]| {
            let t = Tensor::from_f32(xs.to_vec(), &[n, c]).unwrap();
            dot64(&layernorm_rows(&t, &gnv, &bv, 1e-5).unwrap().0, &gv)
        };
        for k in 0..n * c {
            let num = fd(&xv, k, 1e-3, f_x);
            assert!(
                (dxv[k] as f64 - num).abs() < 5e-3 * (1.0 + num.abs()),
                "layernorm dx[{k}]: analytic {} vs numeric {num}",
                dxv[k]
            );
        }
        let f_gain = |gs: &[f32]| {
            dot64(&layernorm_rows(&x, gs, &bv, 1e-5).unwrap().0, &gv)
        };
        let f_bias = |bs: &[f32]| {
            dot64(&layernorm_rows(&x, &gnv, bs, 1e-5).unwrap().0, &gv)
        };
        for k in 0..c {
            let ng = fd(&gnv, k, 1e-3, f_gain);
            let nb = fd(&bv, k, 1e-3, f_bias);
            assert!((dgain[k] as f64 - ng).abs() < 5e-3 * (1.0 + ng.abs()), "dgain[{k}]");
            assert!((dbias[k] as f64 - nb).abs() < 5e-3 * (1.0 + nb.abs()), "dbias[{k}]");
        }
    }

    #[test]
    fn asymmetric_zero_point() {
        // all-positive data: the unclamped zp preserves the full range
        let w = vec![0.1, 0.5, 0.9];
        let (s1, zp) = minmax_scale(&w, 8, false);
        assert!(zp < 0.0, "one-sided positive data needs negative zp, got {zp}");
        let q = rtn(&w, s1, zp, 0.0, 255.0);
        for (a, b) in w.iter().zip(&q) {
            assert!((a - b).abs() <= s1, "err {} > step {s1}", (a - b).abs());
        }
    }
}
