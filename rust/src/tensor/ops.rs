//! Element-wise and reduction operations over [`Tensor`], plus the Rust-side
//! reference quantizers used by tests and the grid-shift analysis.

use super::{DType, Tensor};
use crate::Result;
use anyhow::bail;

impl Tensor {
    /// Element-wise map over f32 values (i32 tensors are converted).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        let data: Vec<f32> = self.to_f32_vec().into_iter().map(f).collect();
        Tensor::from_f32(data, self.shape()).expect("same shape")
    }

    /// Element-wise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape() != other.shape() {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        let data: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| f(x, y)).collect();
        Tensor::from_f32(data, self.shape())
    }

    pub fn sum(&self) -> f32 {
        self.to_f32_vec().iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        self.sum() / self.len() as f32
    }

    pub fn min(&self) -> f32 {
        self.to_f32_vec().iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.to_f32_vec().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.to_f32_vec().iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// In-place row-broadcast bias add + optional ReLU on a 2-D tensor —
    /// the shared contraction epilogue (recon unit forwards and the packed
    /// inference engine).
    pub fn bias_relu_inplace(&mut self, bias: Option<&[f32]>, relu: bool) -> Result<()> {
        if self.ndim() != 2 {
            bail!("bias_relu_inplace on {:?}", self.shape());
        }
        let (n, r) = (self.shape()[0], self.shape()[1]);
        let yv = self.as_f32_mut()?;
        if let Some(b) = bias {
            if b.len() != r {
                bail!("bias of {} values on output width {r}", b.len());
            }
            for i in 0..n {
                for (v, bj) in yv[i * r..(i + 1) * r].iter_mut().zip(b) {
                    *v += bj;
                }
            }
        }
        if relu {
            for v in yv.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(())
    }

    /// Largest absolute element-wise difference — the parity metric between
    /// kernel implementations (fused packed GEMM vs the f32 paths).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("max_abs_diff shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        Ok(a.iter().zip(&b).fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs())))
    }

    /// Mean squared difference — the reconstruction-loss metric.
    pub fn mse(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("mse shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let a = self.to_f32_vec();
        let b = other.to_f32_vec();
        let s: f32 = a.iter().zip(&b).map(|(&x, &y)| (x - y) * (x - y)).sum();
        Ok(s / a.len().max(1) as f32)
    }

    /// Row-wise argmax over a 2-D tensor (logits → predictions).
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.ndim() != 2 {
            bail!("argmax_rows on {:?}", self.shape());
        }
        let (n, c) = (self.shape()[0], self.shape()[1]);
        let v = self.to_f32_vec();
        Ok((0..n)
            .map(|i| {
                let row = &v[i * c..(i + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// `A · Bᵀ` for `A: (m, k)`, `B: (r, k)` → `(m, r)`.  The native
    /// reconstruction hot path (`Ŷ = X̃ · Ŵᵀ`) — both operands are read
    /// row-contiguously, so the naive triple loop is cache-friendly.
    pub fn matmul_nt(&self, b: &Tensor) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[1] != b.shape()[1] {
            bail!("matmul_nt shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (m, k) = (self.shape()[0], self.shape()[1]);
        let r = b.shape()[0];
        let av = self.as_f32()?;
        let bv = b.as_f32()?;
        let mut out = vec![0.0f32; m * r];
        for i in 0..m {
            let arow = &av[i * k..(i + 1) * k];
            for j in 0..r {
                let brow = &bv[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for t in 0..k {
                    acc += arow[t] * brow[t];
                }
                out[i * r + j] = acc;
            }
        }
        Tensor::from_f32(out, &[m, r])
    }

    /// `A · B` for `A: (m, k)`, `B: (k, c)` → `(m, c)`  (activation
    /// cotangent: `∂L/∂X = G · Ŵ`).  Inner loops run saxpy-style over
    /// contiguous rows of B.
    pub fn matmul_nn(&self, b: &Tensor) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[1] != b.shape()[0] {
            bail!("matmul_nn shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (m, k) = (self.shape()[0], self.shape()[1]);
        let c = b.shape()[1];
        let av = self.as_f32()?;
        let bv = b.as_f32()?;
        let mut out = vec![0.0f32; m * c];
        for i in 0..m {
            let orow = &mut out[i * c..(i + 1) * c];
            for t in 0..k {
                let a = av[i * k + t];
                if a == 0.0 {
                    continue;
                }
                let brow = &bv[t * c..(t + 1) * c];
                for j in 0..c {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_f32(out, &[m, c])
    }

    /// `Aᵀ · B` for `A: (n, m)`, `B: (n, c)` → `(m, c)`  (weight cotangent:
    /// `∂L/∂Ŵ = Gᵀ · X`).
    pub fn matmul_tn(&self, b: &Tensor) -> Result<Tensor> {
        if self.ndim() != 2 || b.ndim() != 2 || self.shape()[0] != b.shape()[0] {
            bail!("matmul_tn shape mismatch {:?} vs {:?}", self.shape(), b.shape());
        }
        let (n, m) = (self.shape()[0], self.shape()[1]);
        let c = b.shape()[1];
        let av = self.as_f32()?;
        let bv = b.as_f32()?;
        let mut out = vec![0.0f32; m * c];
        for t in 0..n {
            let arow = &av[t * m..(t + 1) * m];
            let brow = &bv[t * c..(t + 1) * c];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out[i * c..(i + 1) * c];
                for j in 0..c {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_f32(out, &[m, c])
    }

    /// Row sums of a 2-D tensor → `(r, 1)`.
    pub fn row_sum(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            bail!("row_sum on {:?}", self.shape());
        }
        let (r, c) = (self.shape()[0], self.shape()[1]);
        let v = self.as_f32()?;
        let out: Vec<f32> = (0..r).map(|i| v[i * c..(i + 1) * c].iter().sum()).collect();
        Tensor::from_f32(out, &[r, 1])
    }

    /// Column sums of a 2-D tensor → `(1, c)`.
    pub fn col_sum(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            bail!("col_sum on {:?}", self.shape());
        }
        let (r, c) = (self.shape()[0], self.shape()[1]);
        let v = self.as_f32()?;
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for j in 0..c {
                out[j] += v[i * c + j];
            }
        }
        Tensor::from_f32(out, &[1, c])
    }

    /// Top-k indices per row (descending) — for top-5 accuracy.
    pub fn topk_rows(&self, k: usize) -> Result<Vec<Vec<usize>>> {
        if self.ndim() != 2 {
            bail!("topk_rows on {:?}", self.shape());
        }
        let (n, c) = (self.shape()[0], self.shape()[1]);
        let v = self.to_f32_vec();
        Ok((0..n)
            .map(|i| {
                let row = &v[i * c..(i + 1) * c];
                let mut idx: Vec<usize> = (0..c).collect();
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal));
                idx.truncate(k);
                idx
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Reference quantization math (mirrors python/compile/kernels/ref.py; the
// pytest/cargo cross-check pins these against the Pallas kernels).
// ---------------------------------------------------------------------------

/// Integer grid range for a bit-width (symmetric = signed two's complement).
pub fn qrange(bits: u32, symmetric: bool) -> (f32, f32) {
    if symmetric {
        (-(2f32.powi(bits as i32 - 1)), 2f32.powi(bits as i32 - 1) - 1.0)
    } else {
        (0.0, 2f32.powi(bits as i32) - 1.0)
    }
}

/// Min/max calibration of (s1, zero_point) for per-tensor quantization.
pub fn minmax_scale(w: &[f32], bits: u32, symmetric: bool) -> (f32, f32) {
    let (qmin, qmax) = qrange(bits, symmetric);
    if symmetric {
        let amax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        ((amax / qmax).max(1e-8), 0.0)
    } else {
        let wmax = w.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let wmin = w.iter().copied().fold(f32::INFINITY, f32::min);
        let s1 = ((wmax - wmin) / (qmax - qmin)).max(1e-8);
        // zp maps wmin → qmin; NOT clamped to the grid — fake-quant keeps
        // full range for one-sided data (integer kernels would clamp).
        let zp = qmin - (wmin / s1).round();
        (s1, zp)
    }
}

/// Rounding-to-nearest fake-quant (the Rust oracle).
pub fn rtn(w: &[f32], s1: f32, zp: f32, qmin: f32, qmax: f32) -> Vec<f32> {
    w.iter()
        .map(|&x| {
            let n = ((x / s1).round() + zp).clamp(qmin, qmax);
            s1 * (n - zp)
        })
        .collect()
}

/// RTN integer grid codes.
pub fn rtn_codes(w: &[f32], s1: f32, zp: f32, qmin: f32, qmax: f32) -> Vec<f32> {
    w.iter()
        .map(|&x| ((x / s1).round() + zp).clamp(qmin, qmax))
        .collect()
}

/// Per-channel RTN codes: `s1`/`zp` indexed by row, `w` is (rows, cols).
pub fn rtn_codes_rows(w: &[f32], rows: usize, cols: usize, s1: &[f32], zp: &[f32],
                      qmin: f32, qmax: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(w.len());
    for r in 0..rows {
        for c in 0..cols {
            let x = w[r * cols + c];
            out.push(((x / s1[r]).round() + zp[r]).clamp(qmin, qmax));
        }
    }
    out
}

impl Tensor {
    /// Cast helper for analysis code.
    pub fn cast_f32(&self) -> Tensor {
        match self.dtype() {
            DType::F32 => self.clone(),
            DType::I32 => Tensor::from_f32(self.to_f32_vec(), self.shape()).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_zip_reduce() {
        let a = Tensor::from_f32(vec![1., -2., 3.], &[3]).unwrap();
        let b = a.map(|x| x * 2.0);
        assert_eq!(b.as_f32().unwrap(), &[2., -4., 6.]);
        let c = a.zip(&b, |x, y| x + y).unwrap();
        assert_eq!(c.sum(), 3.0 + -6.0 + 9.0);
        assert_eq!(a.abs_max(), 3.0);
        assert_eq!(a.min(), -2.0);
    }

    #[test]
    fn mse_basic() {
        let a = Tensor::from_f32(vec![0., 0.], &[2]).unwrap();
        let b = Tensor::from_f32(vec![3., 4.], &[2]).unwrap();
        assert!((a.mse(&b).unwrap() - 12.5).abs() < 1e-6);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 4.0);
        let c = Tensor::from_f32(vec![0.; 3], &[3]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn argmax_topk() {
        let t = Tensor::from_f32(vec![0.1, 0.9, 0.3, 0.7, 0.2, 0.1], &[2, 3]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
        let tk = t.topk_rows(2).unwrap();
        assert_eq!(tk[0], vec![1, 2]);
        assert_eq!(tk[1], vec![0, 1]);
    }

    #[test]
    fn matmul_variants_agree() {
        // A: (2,3), B: (4,3) — NT against hand-computed values.
        let a = Tensor::from_f32(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        let b = Tensor::from_f32(
            vec![1., 0., 0., 0., 1., 0., 0., 0., 1., 1., 1., 1.],
            &[4, 3],
        )
        .unwrap();
        let nt = a.matmul_nt(&b).unwrap();
        assert_eq!(nt.shape(), &[2, 4]);
        assert_eq!(nt.as_f32().unwrap(), &[1., 2., 3., 6., 4., 5., 6., 15.]);
        // NN with B transposed manually must match NT.
        let bt = Tensor::from_f32(
            vec![1., 0., 0., 1., 0., 1., 0., 1., 0., 0., 1., 1.],
            &[3, 4],
        )
        .unwrap();
        let nn = a.matmul_nn(&bt).unwrap();
        assert_eq!(nn.as_f32().unwrap(), nt.as_f32().unwrap());
        // TN: Aᵀ·A is symmetric with known diagonal.
        let tn = a.matmul_tn(&a).unwrap();
        assert_eq!(tn.shape(), &[3, 3]);
        let v = tn.as_f32().unwrap();
        assert_eq!(v[0], 17.0); // 1² + 4²
        assert_eq!(v[4], 29.0); // 2² + 5²
        assert_eq!(v[1], v[3]);
        assert!(a.matmul_nt(&bt).is_err());
        assert!(a.matmul_nn(&b).is_err());
    }

    #[test]
    fn row_col_sums() {
        let t = Tensor::from_f32(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        assert_eq!(t.row_sum().unwrap().as_f32().unwrap(), &[6.0, 15.0]);
        assert_eq!(t.row_sum().unwrap().shape(), &[2, 1]);
        assert_eq!(t.col_sum().unwrap().as_f32().unwrap(), &[5.0, 7.0, 9.0]);
        assert_eq!(t.col_sum().unwrap().shape(), &[1, 3]);
    }

    #[test]
    fn qrange_matches_paper() {
        assert_eq!(qrange(4, true), (-8.0, 7.0));
        assert_eq!(qrange(8, false), (0.0, 255.0));
        assert_eq!(qrange(2, true), (-2.0, 1.0));
    }

    #[test]
    fn rtn_idempotent() {
        // quantizing an already-quantized tensor is the identity
        let w = vec![0.3, -0.7, 1.2, 0.05];
        let (s1, zp) = minmax_scale(&w, 4, true);
        let q1 = rtn(&w, s1, zp, -8.0, 7.0);
        let q2 = rtn(&q1, s1, zp, -8.0, 7.0);
        assert_eq!(q1, q2);
    }

    #[test]
    fn rtn_grid_membership() {
        let w = vec![0.33, -0.21, 0.9, -1.4];
        let (s1, zp) = minmax_scale(&w, 3, true);
        for q in rtn(&w, s1, zp, -4.0, 3.0) {
            let n = q / s1;
            assert!((n - n.round()).abs() < 1e-5);
            assert!(n >= -4.0 && n <= 3.0);
        }
    }

    #[test]
    fn asymmetric_zero_point() {
        // all-positive data: the unclamped zp preserves the full range
        let w = vec![0.1, 0.5, 0.9];
        let (s1, zp) = minmax_scale(&w, 8, false);
        assert!(zp < 0.0, "one-sided positive data needs negative zp, got {zp}");
        let q = rtn(&w, s1, zp, 0.0, 255.0);
        for (a, b) in w.iter().zip(&q) {
            assert!((a - b).abs() <= s1, "err {} > step {s1}", (a - b).abs());
        }
    }
}
