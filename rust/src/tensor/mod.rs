//! Minimal n-dimensional f32/i32 tensor library.
//!
//! This is the coordinator-side substrate for everything that is *not* the
//! numeric hot path (which runs inside AOT-compiled HLO): calibration-set
//! slicing, metric computation, grid-shift analysis, CLE/AHB verification,
//! and report assembly.  Row-major (C) contiguous storage only — views are
//! materialized, which is fine at coordinator scale.

mod ops;

pub use ops::*;

use crate::Result;
use anyhow::{anyhow, bail};

/// Element type tag, mirroring the FXT container and PJRT literals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A dense row-major tensor of f32 or i32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Data,
}

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    // ---- constructors ---------------------------------------------------

    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Result<Self> {
        if data.len() != shape.iter().product::<usize>() {
            bail!("shape {:?} wants {} elems, got {}", shape, shape.iter().product::<usize>(), data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::F32(data) })
    }

    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Result<Self> {
        if data.len() != shape.iter().product::<usize>() {
            bail!("shape {:?} wants {} elems, got {}", shape, shape.iter().product::<usize>(), data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::I32(data) })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: Data::F32(vec![0.0; shape.iter().product()]) }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: Data::F32(vec![v; shape.iter().product()]) }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { shape: vec![], data: Data::I32(vec![v]) }
    }

    // ---- accessors ------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    /// f32 view regardless of storage (i32 is converted).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.data {
            Data::F32(v) => v.clone(),
            Data::I32(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn item(&self) -> Result<f32> {
        if self.len() != 1 {
            bail!("item() on tensor of {} elements", self.len());
        }
        Ok(self.to_f32_vec()[0])
    }

    // ---- shape manipulation ----------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        let mut t = self.clone();
        t.shape = shape.to_vec();
        Ok(t)
    }

    /// Rows `lo..hi` along axis 0 (materialized slice).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Self> {
        if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
            bail!("slice_rows({lo},{hi}) on shape {:?}", self.shape);
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        let t = match &self.data {
            Data::F32(v) => Data::F32(v[lo * row..hi * row].to_vec()),
            Data::I32(v) => Data::I32(v[lo * row..hi * row].to_vec()),
        };
        Ok(Self { shape, data: t })
    }

    /// Gather rows by index along axis 0.
    pub fn gather_rows(&self, idx: &[usize]) -> Result<Self> {
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        let t = match &self.data {
            Data::F32(v) => {
                let mut out = Vec::with_capacity(idx.len() * row);
                for &i in idx {
                    if i >= self.shape[0] {
                        bail!("gather index {i} out of bounds {}", self.shape[0]);
                    }
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                Data::F32(out)
            }
            Data::I32(v) => {
                let mut out = Vec::with_capacity(idx.len() * row);
                for &i in idx {
                    if i >= self.shape[0] {
                        bail!("gather index {i} out of bounds {}", self.shape[0]);
                    }
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                Data::I32(out)
            }
        };
        Ok(Self { shape, data: t })
    }

    /// Concatenate along axis 0.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| anyhow!("concat of nothing"))?;
        let mut shape = first.shape.clone();
        let mut n0 = 0;
        for p in parts {
            if p.shape[1..] != first.shape[1..] {
                bail!("concat shape mismatch {:?} vs {:?}", p.shape, first.shape);
            }
            n0 += p.shape[0];
        }
        shape[0] = n0;
        match first.dtype() {
            DType::F32 => {
                let mut data = Vec::new();
                for p in parts {
                    data.extend_from_slice(p.as_f32()?);
                }
                Tensor::from_f32(data, &shape)
            }
            DType::I32 => {
                let mut data = Vec::new();
                for p in parts {
                    data.extend_from_slice(p.as_i32()?);
                }
                Tensor::from_i32(data, &shape)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape() {
        let t = Tensor::from_f32(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert!(Tensor::from_f32(vec![1.0], &[2]).is_err());
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_f32((0..24).map(|i| i as f32).collect(), &[2, 3, 4]).unwrap();
        let r = t.reshape(&[6, 4]).unwrap();
        assert_eq!(r.shape(), &[6, 4]);
        assert_eq!(r.as_f32().unwrap()[5], 5.0);
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn slice_and_gather() {
        let t = Tensor::from_f32((0..12).map(|i| i as f32).collect(), &[4, 3]).unwrap();
        let s = t.slice_rows(1, 3).unwrap();
        assert_eq!(s.shape(), &[2, 3]);
        assert_eq!(s.as_f32().unwrap(), &[3., 4., 5., 6., 7., 8.]);
        let g = t.gather_rows(&[3, 0]).unwrap();
        assert_eq!(g.as_f32().unwrap(), &[9., 10., 11., 0., 1., 2.]);
        assert!(t.gather_rows(&[4]).is_err());
    }

    #[test]
    fn concat() {
        let a = Tensor::from_f32(vec![1., 2.], &[1, 2]).unwrap();
        let b = Tensor::from_f32(vec![3., 4., 5., 6.], &[2, 2]).unwrap();
        let c = Tensor::concat_rows(&[a, b]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.as_f32().unwrap()[4], 5.0);
    }

    #[test]
    fn scalars() {
        assert_eq!(Tensor::scalar(3.5).item().unwrap(), 3.5);
        assert_eq!(Tensor::scalar_i32(7).to_f32_vec(), vec![7.0]);
    }
}
