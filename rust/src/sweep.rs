//! Experiment sweeps: drive a whole paper table (or figure series) from a
//! TOML config — the `flexround sweep --config configs/<exp>.toml` path.
//!
//! A sweep is a grid over (models × methods × bits × settings [× sample
//! sizes]); each cell is one PTQ run + evaluation.  The emitted table uses
//! the paper's layout: one row per (setting, method, bits), one column per
//! model, cells formatted like the paper ("top1/top5", PPL, BLEU, …).

use crate::config::Config;
use crate::coordinator::{Plan, Session};
use crate::eval;
use crate::manifest::Manifest;
use crate::report::{fmt_metric, Reporter, Table};
use crate::runtime::Backend;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;

/// Run one sweep config on the selected engine; emits one report table.
pub fn run_sweep(cfg: &Config, man: &Manifest, rt: &dyn Backend, rep: &Reporter) -> Result<()> {
    let id = cfg.str("sweep.id", "sweep");
    let title = cfg.str("sweep.title", &id);
    let models = cfg
        .list_str("sweep.models")
        .ok_or_else(|| anyhow!("sweep.models missing"))?;
    let methods = cfg
        .list_str("sweep.methods")
        .ok_or_else(|| anyhow!("sweep.methods missing"))?;
    let bits = cfg.list_usize("sweep.bits").unwrap_or_else(|| vec![4]);
    let settings = cfg.list_str("sweep.settings").unwrap_or_else(|| vec!["B".into()]);
    let mode = cfg.str("sweep.mode", "w");
    let abits = cfg.usize("sweep.abits", 8);
    let match_abits = cfg.boolean("sweep.match_abits", false);
    let metric_keys = cfg.list_str("sweep.metric_keys");
    let iters = cfg.usize("sweep.iters", 0);
    let calib_n = cfg.usize("sweep.calib_n", 0);
    let seed = cfg.usize("sweep.seed", 7) as u64;
    let samples = cfg.list_usize("sweep.samples"); // Figure 7 axis
    let verbose = cfg.boolean("sweep.verbose", false);
    let parallel_units = cfg.boolean("sweep.parallel_units", false);

    let mut columns: Vec<&str> = vec!["Method", "# Bits (W/A)"];
    if samples.is_some() {
        columns.push("Samples");
    }
    let model_cols: Vec<String> = models.clone();
    let mut all_cols = columns.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    all_cols.extend(model_cols.iter().cloned());
    let mut table = Table::new(&title, &all_cols.iter().map(String::as_str).collect::<Vec<_>>());

    // sessions once per model
    let mut sessions = BTreeMap::new();
    for m in &models {
        sessions.insert(m.clone(), Session::open(rt, man, m)?);
    }

    // per-unit reconstruction losses, keyed (model, unit-with-bits) →
    // method → final loss; fuels the scheme-comparison companion table
    let mut unit_losses: BTreeMap<(String, String), BTreeMap<String, f64>> = BTreeMap::new();

    // full-precision row
    {
        let mut cells = vec!["Full-precision".to_string(), "32/32".to_string()];
        if samples.is_some() {
            cells.push("-".into());
        }
        for m in &models {
            let sess = &sessions[m];
            let met = eval_for(sess, None)?;
            cells.push(fmt_cell(&filter_metrics(met, &metric_keys)));
        }
        table.row(cells);
    }

    let sample_axis = samples.unwrap_or_else(|| vec![0]);
    for &b in &bits {
        for setting in &settings {
            for method in &methods {
                for &n in &sample_axis {
                    let a = if match_abits { b } else { abits };
                    let mut cells = vec![
                        format!("{setting} + {}", pretty_method(method)),
                        if mode == "w" { format!("{b}/32") } else { format!("{b}/{a}") },
                    ];
                    if sample_axis.len() > 1 || n > 0 {
                        if sample_axis != [0] {
                            cells.push(format!("{n}"));
                        }
                    }
                    for m in &models {
                        let sess = &sessions[m];
                        let mut plan = Plan::new(m, method);
                        plan.mode = mode.clone();
                        plan.bits_w = b as u32;
                        plan.abits = a as u32;
                        plan.iters = iters;
                        plan.drop_p = if setting == "Q" { 0.5 } else { 0.0 };
                        plan.calib_n = if n > 0 { n } else { calib_n };
                        plan.seed = seed;
                        plan.verbose = verbose;
                        plan.parallel_units = parallel_units;
                        let r = sess.quantize(&plan)?;
                        for u in &r.units {
                            unit_losses
                                .entry((m.clone(), format!("{} W{b}", u.unit)))
                                .or_default()
                                .insert(method.clone(), u.final_loss);
                        }
                        let met = eval_for(sess, Some(&r))?;
                        if verbose {
                            eprintln!("  [{id}] {m} {setting}+{method} W{b}: {met:?}");
                        }
                        cells.push(fmt_cell(&filter_metrics(met, &metric_keys)));
                    }
                    table.row(cells);
                }
            }
        }
    }

    rep.table(&id, &table)?;
    println!("sweep {id}: {} rows → reports/{id}.md", table.rows.len());

    // companion table: one row per (model, unit), one column per rounding
    // scheme — the FlexRound-vs-AdaRound comparison at reconstruction-loss
    // granularity, from the same run (no re-quantization)
    if methods.len() > 1 && !unit_losses.is_empty() {
        let uid = format!("{id}-units");
        let mut cols = vec!["Model".to_string(), "Unit".to_string()];
        cols.extend(methods.iter().map(|m| pretty_method(m).to_string()));
        let mut ut = Table::new(
            &format!("{title} — per-unit reconstruction loss by scheme"),
            &cols.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        for ((model, unit), per_method) in &unit_losses {
            let mut cells = vec![model.clone(), unit.clone()];
            for method in &methods {
                cells.push(match per_method.get(method) {
                    Some(l) => format!("{l:.4e}"),
                    None => "-".to_string(),
                });
            }
            ut.row(cells);
        }
        rep.table(&uid, &ut)?;
        println!("sweep {uid}: {} units → reports/{uid}.md", ut.rows.len());
    }
    Ok(())
}

fn filter_metrics(m: BTreeMap<String, f64>, keys: &Option<Vec<String>>)
                  -> BTreeMap<String, f64> {
    match keys {
        None => m,
        Some(ks) => m.into_iter().filter(|(k, _)| ks.iter().any(|x| x == k)).collect(),
    }
}

fn eval_for(sess: &Session, r: Option<&crate::coordinator::QuantResult>)
            -> Result<BTreeMap<String, f64>> {
    let mut m = BTreeMap::new();
    match sess.model.kind.as_str() {
        "cnn" => m.extend(match r {
            Some(r) => eval::eval_cnn(sess, r)?,
            None => eval::eval_cnn_fp(sess)?,
        }),
        // native transformer-block LMs evaluate on any build/backend
        "block_lm" => {
            m.insert("ppl".into(), eval::eval_ppl_hidden(sess, r, "eval_x", "eval_y")?);
        }
        #[cfg(feature = "pjrt")]
        "encoder" => m.extend(eval::eval_encoder(sess, r)?),
        #[cfg(feature = "pjrt")]
        "decoder" => {
            if sess.model.name == "dec_lora" {
                m.insert("bleu_seen".into(), eval::eval_d2t_bleu(sess, r, "seen")?);
                m.insert("bleu_unseen".into(), eval::eval_d2t_bleu(sess, r, "unseen")?);
            } else {
                m.insert("ppl".into(), eval::eval_ppl(sess, r, "eval_x")?);
                if sess.model.name == "llm_mini" {
                    for task in eval::MC_TASKS {
                        m.insert(format!("mc_{task}"), eval::eval_mc(sess, r, task)?);
                    }
                }
            }
        }
        k => anyhow::bail!("cannot evaluate model kind {k:?} with this build/backend"),
    }
    Ok(m)
}

/// Cell format mirrors the paper: "top1/top5" for CNNs, "PPL", task accs.
fn fmt_cell(m: &BTreeMap<String, f64>) -> String {
    if m.contains_key("top1") {
        format!("{}/{}", fmt_metric("top1", m["top1"]), fmt_metric("top5", m["top5"]))
    } else if m.contains_key("ppl") && m.len() == 1 {
        fmt_metric("ppl", m["ppl"])
    } else {
        m.iter()
            .map(|(k, v)| format!("{k}={}", fmt_metric(k, *v)))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

fn pretty_method(m: &str) -> &str {
    match m {
        "rtn" => "RTN",
        "adaround" => "AdaRound",
        "adaquant" => "AdaQuant",
        "flexround" => "FlexRound (Ours)",
        "flexround_fixed_s1" => "FlexRound, fixed s1 (Abl. 1)",
        "flexround_no_s34" => "FlexRound, no s3/s4 (Abl. 2)",
        "adaquant_flexround" => "AdaQuant + FlexRound",
        other => other,
    }
}
