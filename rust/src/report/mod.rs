//! Report emission: paper-layout markdown tables, CSV series for figures,
//! and machine-readable JSON — everything lands under `reports/`.

use crate::ser::json::{self, Json};
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A markdown/CSV table builder with the paper's row/column layout.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.columns.join(" | "));
        let _ = writeln!(s, "|{}|", self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.columns.join(","));
        for r in &self.rows {
            let esc: Vec<String> = r
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            let _ = writeln!(s, "{}", esc.join(","));
        }
        s
    }
}

/// Report sink rooted at a directory.
pub struct Reporter {
    pub dir: PathBuf,
    pub quiet: bool,
}

impl Reporter {
    pub fn new(dir: &Path, quiet: bool) -> Result<Reporter> {
        std::fs::create_dir_all(dir).map_err(|e| anyhow!("mkdir {}: {e}", dir.display()))?;
        Ok(Reporter { dir: dir.to_path_buf(), quiet })
    }

    /// Print + persist a table as markdown and CSV.
    pub fn table(&self, id: &str, t: &Table) -> Result<()> {
        if !self.quiet {
            println!("{}", t.markdown());
        }
        std::fs::write(self.dir.join(format!("{id}.md")), t.markdown())?;
        std::fs::write(self.dir.join(format!("{id}.csv")), t.csv())?;
        Ok(())
    }

    /// Persist raw CSV series data (figure points).
    pub fn series(&self, id: &str, header: &str, rows: &[String]) -> Result<()> {
        let mut s = String::with_capacity(rows.len() * 16 + header.len() + 1);
        let _ = writeln!(s, "{header}");
        for r in rows {
            let _ = writeln!(s, "{r}");
        }
        std::fs::write(self.dir.join(format!("{id}.csv")), s)?;
        if !self.quiet {
            println!("  wrote {} ({} points)", self.dir.join(format!("{id}.csv")).display(), rows.len());
        }
        Ok(())
    }

    /// Persist a metrics map as JSON.
    pub fn metrics(&self, id: &str, metrics: &BTreeMap<String, f64>) -> Result<()> {
        let obj = Json::Obj(
            metrics
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        std::fs::write(self.dir.join(format!("{id}.json")), json::to_string(&obj, 1))?;
        Ok(())
    }
}

/// Format a metric with the paper's precision (acc in %, ppl with 2dp).
pub fn fmt_metric(key: &str, v: f64) -> String {
    if key.contains("ppl") {
        format!("{v:.2}")
    } else if key.contains("bleu") {
        format!("{v:.2}")
    } else {
        format!("{:.2}", v * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Table 2 analog", &["Method", "Bits", "Top-1/Top-5"]);
        t.row(vec!["B + FlexRound".into(), "4/32".into(), "70.28/89.44".into()]);
        let md = t.markdown();
        assert!(md.contains("| Method | Bits | Top-1/Top-5 |"));
        assert!(md.contains("B + FlexRound"));
        let csv = t.csv();
        assert!(csv.starts_with("Method,Bits,"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["v,with\"quote".into()]);
        assert!(t.csv().contains("\"v,with\"\"quote\""));
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_metric("top1", 0.7028), "70.28");
        assert_eq!(fmt_metric("ppl", 12.345), "12.35");
    }
}
