//! The reconstruction session: state + the per-unit PTQ loop.

use super::{beta_schedule, Plan};
use crate::manifest::{Manifest, ModelInfo, PackEntry, UnitInfo};
use crate::runtime::{Exec, Runtime};
use crate::tensor::{qrange, Tensor};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

/// Learned state of one unit after reconstruction.
#[derive(Clone)]
pub struct UnitState {
    pub unit: String,
    pub method: String,
    /// flat parameter values, in pack order
    pub params: Vec<Tensor>,
    pub entries: Vec<PackEntry>,
    pub first_loss: f64,
    pub final_loss: f64,
    pub bits_w: u32,
    pub abits: u32,
}

/// Outcome of a full PTQ run.
pub struct QuantResult {
    pub plan: Plan,
    pub units: Vec<UnitState>,
    pub recon_seconds: f64,
    pub recon_steps: u64,
}

/// A loaded model: weights + inits + datasets + artifact handles.
pub struct Session<'rt> {
    pub rt: &'rt Runtime,
    pub man: &'rt Manifest,
    pub model: &'rt ModelInfo,
    pub weights: BTreeMap<String, Tensor>,
    pub inits: BTreeMap<String, Tensor>,
    pub data: BTreeMap<String, Tensor>,
}

impl<'rt> Session<'rt> {
    pub fn open(rt: &'rt Runtime, man: &'rt Manifest, model: &str) -> Result<Session<'rt>> {
        let mi = man.model(model)?;
        let weights = crate::ser::fxt::read(&man.artifact_path(&mi.weights_file))?;
        let inits = crate::ser::fxt::read(&man.artifact_path(&mi.init_file))?;
        let data = crate::ser::fxt::read(&man.artifact_path(&mi.data_file))?;
        Ok(Session { rt, man, model: mi, weights, inits, data })
    }

    pub fn dataset(&self, name: &str) -> Result<&Tensor> {
        self.data
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no dataset {name:?}", self.model.name))
    }

    // ------------------------------------------------------------------
    // Input pipeline
    // ------------------------------------------------------------------

    /// Calibration inputs to the first unit: images directly, or the
    /// embedding output for token models (chunked by calib_batch).
    pub fn first_unit_inputs(&self, xs: &Tensor) -> Result<Vec<Tensor>> {
        let b = self.model.calib_batch;
        let n = xs.shape()[0];
        if n % b != 0 {
            bail!("dataset rows {n} not a multiple of batch {b}");
        }
        let mut chunks = Vec::with_capacity(n / b);
        if let Some(embed) = &self.model.embed_artifact {
            let exe = self.rt.load(embed)?;
            for i in (0..n).step_by(b) {
                let chunk = xs.slice_rows(i, i + b)?;
                let out = exe.run(self.rt, &[chunk], false)?;
                chunks.push(out.into_iter().next().unwrap());
            }
        } else {
            for i in (0..n).step_by(b) {
                chunks.push(xs.slice_rows(i, i + b)?);
            }
        }
        Ok(chunks)
    }

    /// Advance activations one unit through the *full-precision* chain.
    pub fn advance_fp(&self, unit: &UnitInfo, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.rt.load(unit.artifact("fp")?)?;
        chunks
            .iter()
            .map(|c| Ok(exe.run(self.rt, std::slice::from_ref(c), false)?.into_iter().next().unwrap()))
            .collect()
    }

    /// Advance activations one unit through the *quantized* chain with the
    /// learned parameters.
    ///
    /// Input-liveness note: `jax.jit` prunes arguments that are dead in the
    /// lowered graph, so weight-only ("w") executables do not take the
    /// activation-quant scalars — the assembly below mirrors exactly what
    /// the AOT build kept (PJRT rejects any arity mismatch loudly).
    pub fn advance_q(&self, unit: &UnitInfo, st: &UnitState, mode: &str,
                     chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.rt.load(unit.artifact(&format!("q.{}.{}", st.method, mode))?)?;
        let scal = self.q_scalars(st, mode);
        let live = live_params(&st.method, &st.entries, &st.params);
        chunks
            .iter()
            .map(|c| {
                let mut inputs = vec![c.clone()];
                inputs.extend(scal.iter().cloned());
                inputs.extend(live.iter().cloned());
                Ok(exe.run(self.rt, &inputs, false)?.into_iter().next().unwrap())
            })
            .collect()
    }

    fn q_scalars(&self, st: &UnitState, mode: &str) -> Vec<Tensor> {
        let (qmin_w, qmax_w) = qrange(st.bits_w, self.model.symmetric);
        let mut v = vec![Tensor::scalar(qmin_w), Tensor::scalar(qmax_w)];
        if mode == "wa" {
            let (qmin_a, qmax_a) = qrange(st.abits, false);
            v.push(Tensor::scalar(qmin_a));
            v.push(Tensor::scalar(qmax_a));
        }
        v
    }

    // ------------------------------------------------------------------
    // Parameter initialization from the exported init packs
    // ------------------------------------------------------------------

    /// Initial flat parameter values for (unit, method, mode, bits).
    pub fn init_params(&self, unit: &UnitInfo, method: &str, mode: &str,
                       bits_w: u32, abits: u32) -> Result<(Vec<Tensor>, Vec<PackEntry>)> {
        let entries = unit.pack(method, mode)?.to_vec();
        let mut out = Vec::with_capacity(entries.len());
        for e in &entries {
            if let Some(site) = e.name.strip_prefix("act") {
                let (site_i, key) = site
                    .split_once('.')
                    .ok_or_else(|| anyhow!("bad act entry {:?}", e.name))?;
                let range = self
                    .inits
                    .get(&format!("actrange/{}/site{}", unit.name, site_i))
                    .ok_or_else(|| anyhow!("missing actrange for {}/{}", unit.name, site_i))?;
                let lo = range.as_f32()?[0];
                let hi = range.as_f32()?[1];
                let (qmin_a, qmax_a) = qrange(abits, false);
                let step = ((hi - lo) / (qmax_a - qmin_a)).max(1e-6);
                let zp = (-lo / step).round().clamp(qmin_a, qmax_a);
                let v = if key == "step" { step } else { zp };
                out.push(Tensor::from_f32(vec![v], &[1, 1])?);
            } else {
                let key = format!("init/{}/{}/b{}/{}", unit.name, method, bits_w, e.name);
                let t = self
                    .inits
                    .get(&key)
                    .ok_or_else(|| anyhow!("missing init tensor {key:?}"))?;
                out.push(t.clone());
            }
        }
        Ok((out, entries))
    }

    // ------------------------------------------------------------------
    // The PTQ reconstruction loop
    // ------------------------------------------------------------------

    /// Run the full per-unit reconstruction pipeline for `plan`.
    pub fn quantize(&self, plan: &Plan) -> Result<QuantResult> {
        let mi = self.model;
        let iters = if plan.iters == 0 { mi.iters_default } else { plan.iters };
        let lr = if plan.lr == 0.0 { mi.lr_for(&plan.method) } else { plan.lr };
        let calib_full = self.dataset("calib_x")?;
        let calib_n = if plan.calib_n == 0 {
            calib_full.shape()[0]
        } else {
            plan.calib_n.min(calib_full.shape()[0])
        };
        // round down to a chunk multiple ≥ one batch
        let b = mi.calib_batch;
        let calib_n = (calib_n / b).max(1) * b;
        let calib = calib_full.slice_rows(0, calib_n)?;

        let mut rng = Pcg32::seeded(plan.seed);
        let mut x_fp = self.first_unit_inputs(&calib)?;
        let mut x_q = x_fp.clone();

        let mut states = Vec::new();
        let mut recon_seconds = 0.0;
        let mut recon_steps = 0u64;

        for unit in &mi.units {
            let bits_w = unit.bits_override.unwrap_or(plan.bits_w);
            let abits = if unit.bits_override == Some(8) { 8 } else { plan.abits };
            let y_fp = self.advance_fp(unit, &x_fp)?; // targets = fp outputs

            let (mut params, entries) =
                self.init_params(unit, &plan.method, &plan.mode, bits_w, abits)?;
            let mut st = UnitState {
                unit: unit.name.clone(),
                method: plan.method.clone(),
                // params/entries placeholders replaced after recon
                params: params.clone(),
                entries: entries.clone(),
                first_loss: f64::NAN,
                final_loss: f64::NAN,
                bits_w,
                abits,
            };

            if plan.method != "rtn" && iters > 0 {
                let t0 = Instant::now();
                let exe = self.rt.load(
                    unit.artifact(&format!("recon.{}.{}", plan.method, plan.mode))?)?;
                let (qmin_w, qmax_w) = qrange(bits_w, mi.symmetric);
                let (qmin_a, qmax_a) = qrange(abits, false);
                let wa = plan.mode == "wa";
                let has_beta = plan.method == "adaround";
                // Adam state starts at zero
                let mut m: Vec<Tensor> =
                    params.iter().map(|p| Tensor::zeros(p.shape())).collect();
                let mut v = m.clone();
                let x_all = Tensor::concat_rows(&x_q)?;
                let y_all = Tensor::concat_rows(&y_fp)?;
                let n = x_all.shape()[0];

                for t in 1..=iters {
                    let idx = rng.sample_indices(n, b);
                    let xb = x_all.gather_rows(&idx)?;
                    let yb = y_all.gather_rows(&idx)?;
                    let beta = beta_schedule(t, iters);
                    let seed = (rng.next_u32() & 0x7FFF_FFFF) as i32;
                    // same liveness rule as advance_q: jit pruned the scalars
                    // that are dead in this (method, mode) — qmin_a/qmax_a/
                    // drop_p/seed in "w" mode, beta for non-AdaRound methods.
                    let mut inputs = vec![
                        xb,
                        yb,
                        Tensor::scalar(qmin_w),
                        Tensor::scalar(qmax_w),
                    ];
                    if wa {
                        inputs.push(Tensor::scalar(qmin_a));
                        inputs.push(Tensor::scalar(qmax_a));
                        inputs.push(Tensor::scalar(plan.drop_p as f32));
                    }
                    if has_beta {
                        inputs.push(Tensor::scalar(beta as f32));
                    }
                    inputs.push(Tensor::scalar(lr as f32));
                    inputs.push(Tensor::scalar(t as f32));
                    if wa {
                        inputs.push(Tensor::scalar_i32(seed));
                    }
                    inputs.extend(params.iter().cloned());
                    inputs.extend(m.iter().cloned());
                    inputs.extend(v.iter().cloned());
                    let out = exe.run(self.rt, &inputs, true)?;
                    let np = params.len();
                    if out.len() != 1 + 3 * np {
                        bail!(
                            "recon {}: expected {} outputs, got {}",
                            unit.name, 1 + 3 * np, out.len()
                        );
                    }
                    let loss = out[0].item()? as f64;
                    if t == 1 {
                        st.first_loss = loss;
                    }
                    st.final_loss = loss;
                    let mut it = out.into_iter();
                    let _ = it.next();
                    params = it.by_ref().take(np).collect();
                    m = it.by_ref().take(np).collect();
                    v = it.by_ref().take(np).collect();
                    recon_steps += 1;
                    if plan.verbose && (t == 1 || t % 100 == 0 || t == iters) {
                        eprintln!(
                            "    [{}/{}] iter {t}/{iters} loss {loss:.6}",
                            self.model.name, unit.name
                        );
                    }
                }
                st.params = params.clone();
                recon_seconds += t0.elapsed().as_secs_f64();
            }

            // advance both chains
            x_q = self.advance_q(unit, &st, &plan.mode, &x_q)?;
            x_fp = y_fp;
            states.push(st);
        }

        Ok(QuantResult {
            plan: plan.clone(),
            units: states,
            recon_seconds,
            recon_steps,
        })
    }

    // ------------------------------------------------------------------
    // Quantized / fp forward over an arbitrary dataset (for eval)
    // ------------------------------------------------------------------

    /// Run `xs` through the fully quantized chain; returns final outputs
    /// per chunk (logits for CNNs, hidden states for transformers).
    pub fn forward_q(&self, result: &QuantResult, xs: &Tensor) -> Result<Vec<Tensor>> {
        let mut chunks = self.first_unit_inputs(xs)?;
        for (unit, st) in self.model.units.iter().zip(&result.units) {
            chunks = self.advance_q(unit, st, &result.plan.mode, &chunks)?;
        }
        Ok(chunks)
    }

    /// Full-precision forward (baseline metrics).
    pub fn forward_fp(&self, xs: &Tensor) -> Result<Vec<Tensor>> {
        let mut chunks = self.first_unit_inputs(xs)?;
        for unit in &self.model.units {
            chunks = self.advance_fp(unit, &chunks)?;
        }
        Ok(chunks)
    }

    /// Load a head executable by key ("lm", "logits", task names, "span").
    pub fn head(&self, key: &str) -> Result<Rc<Exec>> {
        let f = self
            .model
            .head_artifacts
            .get(key)
            .ok_or_else(|| anyhow!("model {} has no head {key:?}", self.model.name))?;
        self.rt.load(f)
    }

    /// Export fake-quantized weights + integer codes for each layer of a
    /// unit (the Figure 3–6 data): returns [(Ŵ, codes)] in layer order.
    pub fn export_qw(&self, unit: &UnitInfo, st: &UnitState) -> Result<Vec<(Tensor, Tensor)>> {
        let exe = self.rt.load(unit.artifact(&format!("qw.{}", st.method))?)?;
        let (qmin_w, qmax_w) = qrange(st.bits_w, self.model.symmetric);
        // qw artifacts were lowered against the "w" pack (no act entries);
        // derive its length from the state's own pack so wa-only models
        // (whose manifest records no "w" pack) still export correctly —
        // the weight entries are a strict prefix of the wa pack.
        let n_w = st.entries.iter().filter(|e| !e.name.starts_with("act")).count();
        let mut inputs = vec![Tensor::scalar(qmin_w), Tensor::scalar(qmax_w)];
        inputs.extend(live_params(
            &st.method, &st.entries[..n_w], &st.params[..n_w]).into_iter());
        let out = exe.run(self.rt, &inputs, true)?;
        if out.len() != 2 * unit.layers.len() {
            bail!("qw {}: expected {} outputs, got {}", unit.name, 2 * unit.layers.len(), out.len());
        }
        let mut res = Vec::new();
        let mut it = out.into_iter();
        while let (Some(w), Some(c)) = (it.next(), it.next()) {
            res.push((w, c));
        }
        Ok(res)
    }
}

// UnitState carries its method for advance_q
impl UnitState {
    pub fn rtn_like(&self) -> bool {
        self.method == "rtn"
    }
}

/// Parameters that are *live* in a forward-only (q/qw) executable.
///
/// The ablation `flexround_no_s34` replaces s3/s4 with constant ones in the
/// forward, so `jax.jit` pruned those slots out of the compiled signature —
/// mirror that here (recon executables still take them: they round-trip
/// through the Adam state outputs).
fn live_params(method: &str, entries: &[PackEntry], params: &[Tensor]) -> Vec<Tensor> {
    entries
        .iter()
        .zip(params)
        .filter(|(e, _)| {
            !(method == "flexround_no_s34"
                && (e.name.ends_with(".s3") || e.name.ends_with(".s4")))
        })
        .map(|(_, p)| p.clone())
        .collect()
}
