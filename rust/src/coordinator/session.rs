//! The reconstruction session: state + the per-unit PTQ loop.
//!
//! A [`Session`] owns the host-side model state (weights / init packs /
//! calibration data, all FXT) and drives whichever
//! [`Backend`](crate::runtime::Backend) it was opened with — the PJRT
//! artifact engine or the native pure-Rust engine (DESIGN.md §Backends).

use super::{Plan, UnitState};
use crate::infer::{Engine, PackedLayer, PackedMatrix, PackedModel, PackedUnit};
use crate::manifest::{Manifest, ModelInfo, PackEntry, UnitInfo};
use crate::runtime::{Backend, QView, ReconTask, UnitCtx};
use crate::tensor::{qrange, Tensor};
use crate::util::rng::Pcg32;
use crate::Result;
use anyhow::{anyhow, bail};
use std::collections::BTreeMap;

/// Outcome of a full PTQ run.
pub struct QuantResult {
    pub plan: Plan,
    pub units: Vec<UnitState>,
    pub recon_seconds: f64,
    pub recon_steps: u64,
}

/// A loaded model: weights + inits + datasets + the engine handle.
pub struct Session<'rt> {
    pub backend: &'rt dyn Backend,
    pub man: &'rt Manifest,
    pub model: &'rt ModelInfo,
    pub weights: BTreeMap<String, Tensor>,
    pub inits: BTreeMap<String, Tensor>,
    pub data: BTreeMap<String, Tensor>,
}

impl<'rt> Session<'rt> {
    pub fn open(backend: &'rt dyn Backend, man: &'rt Manifest, model: &str) -> Result<Session<'rt>> {
        let mi = man.model(model)?;
        let weights = crate::ser::fxt::read(&man.artifact_path(&mi.weights_file))?;
        let inits = crate::ser::fxt::read(&man.artifact_path(&mi.init_file))?;
        let data = crate::ser::fxt::read(&man.artifact_path(&mi.data_file))?;
        Ok(Session { backend, man, model: mi, weights, inits, data })
    }

    pub fn dataset(&self, name: &str) -> Result<&Tensor> {
        self.data
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no dataset {name:?}", self.model.name))
    }

    /// The PJRT runtime behind the engine, when there is one (heads, embeds
    /// and raw artifact execution have no native equivalent).
    #[cfg(feature = "pjrt")]
    pub fn runtime(&self) -> Result<&crate::runtime::Runtime> {
        self.backend.as_pjrt().ok_or_else(|| {
            anyhow!(
                "this operation executes HLO artifacts and needs the PJRT backend \
                 (current backend: {}); rerun with --backend pjrt",
                self.backend.name()
            )
        })
    }

    /// Engine view of one unit: manifest entry + host weight/bias tensors +
    /// unit-level extras (layernorm parameters under `p/{unit}/…`).
    pub fn unit_ctx<'s>(&'s self, unit: &'s UnitInfo) -> UnitCtx<'s> {
        let weights = unit
            .layers
            .iter()
            .map(|l| self.weights.get(&format!("w/{}/{}", unit.name, l.name)))
            .collect();
        let biases = unit
            .layers
            .iter()
            .map(|l| self.weights.get(&format!("b/{}/{}", unit.name, l.name)))
            .collect();
        let pfx = format!("p/{}/", unit.name);
        let extras = self
            .weights
            .iter()
            .filter_map(|(k, t)| k.strip_prefix(&pfx).map(|s| (s.to_string(), t)))
            .collect();
        UnitCtx { model: self.model, unit, weights, biases, extras }
    }

    fn qview<'s>(st: &'s UnitState, mode: &'s str) -> QView<'s> {
        QView {
            method: &st.method,
            mode,
            bits_w: st.bits_w,
            abits: st.abits,
            params: &st.params,
            entries: &st.entries,
        }
    }

    // ------------------------------------------------------------------
    // Input pipeline
    // ------------------------------------------------------------------

    /// Calibration inputs to the first unit: images directly, or the
    /// embedding output for token models (chunked by calib_batch).
    pub fn first_unit_inputs(&self, xs: &Tensor) -> Result<Vec<Tensor>> {
        let b = self.model.calib_batch;
        let n = xs.shape()[0];
        if n % b != 0 {
            bail!("dataset rows {n} not a multiple of batch {b}");
        }
        if let Some(embed) = &self.model.embed_artifact {
            #[cfg(feature = "pjrt")]
            if let Some(rt) = self.backend.as_pjrt() {
                let exe = rt.load(embed)?;
                let mut chunks = Vec::with_capacity(n / b);
                for i in (0..n).step_by(b) {
                    let chunk = xs.slice_rows(i, i + b)?;
                    let out = exe.run(rt, &[chunk], false)?;
                    chunks.push(out.into_iter().next().unwrap());
                }
                return Ok(chunks);
            }
            bail!(
                "model {} embeds tokens via artifact {embed:?}; this needs the PJRT backend",
                self.model.name
            );
        }
        let mut chunks = Vec::with_capacity(n / b);
        for i in (0..n).step_by(b) {
            chunks.push(xs.slice_rows(i, i + b)?);
        }
        Ok(chunks)
    }

    /// Advance activations one unit through the *full-precision* chain.
    pub fn advance_fp(&self, unit: &UnitInfo, chunks: &[Tensor]) -> Result<Vec<Tensor>> {
        self.backend.unit_forward_fp(&self.unit_ctx(unit), chunks)
    }

    /// Advance activations one unit through the *quantized* chain with the
    /// learned parameters.
    pub fn advance_q(
        &self,
        unit: &UnitInfo,
        st: &UnitState,
        mode: &str,
        chunks: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.backend
            .unit_forward_q(&self.unit_ctx(unit), &Self::qview(st, mode), chunks)
    }

    // ------------------------------------------------------------------
    // Parameter initialization from the exported init packs
    // ------------------------------------------------------------------

    /// Initial flat parameter values for (unit, method, mode, bits).
    pub fn init_params(&self, unit: &UnitInfo, method: &str, mode: &str,
                       bits_w: u32, abits: u32) -> Result<(Vec<Tensor>, Vec<PackEntry>)> {
        let entries = unit.pack(method, mode)?.to_vec();
        let mut out = Vec::with_capacity(entries.len());
        for e in &entries {
            if let Some(site) = e.name.strip_prefix("act") {
                let (site_i, key) = site
                    .split_once('.')
                    .ok_or_else(|| anyhow!("bad act entry {:?}", e.name))?;
                let range = self
                    .inits
                    .get(&format!("actrange/{}/site{}", unit.name, site_i))
                    .ok_or_else(|| anyhow!("missing actrange for {}/{}", unit.name, site_i))?;
                let lo = range.as_f32()?[0];
                let hi = range.as_f32()?[1];
                let (qmin_a, qmax_a) = qrange(abits, false);
                let step = ((hi - lo) / (qmax_a - qmin_a)).max(1e-6);
                let zp = (-lo / step).round().clamp(qmin_a, qmax_a);
                let v = if key == "step" { step } else { zp };
                out.push(Tensor::from_f32(vec![v], &[1, 1])?);
            } else {
                let key = format!("init/{}/{}/b{}/{}", unit.name, method, bits_w, e.name);
                match self.inits.get(&key) {
                    Some(t) => out.push(t.clone()),
                    // exports written before the scheme zoo have no adaround
                    // init pack — derive one from the grids they do have
                    None if method == "adaround" => {
                        out.push(self.adaround_fallback_init(unit, e, bits_w)?)
                    }
                    None => bail!("missing init tensor {key:?}"),
                }
            }
        }
        Ok((out, entries))
    }

    /// AdaRound init values when the export has no `init/…/adaround/…` keys:
    /// `s1`/`zp` reuse the FlexRound (or RTN) grid for the same bit-width —
    /// AdaRound freezes them anyway — and `V` is derived from the host-side
    /// weights at the RTN-fraction init
    /// ([`crate::recon::rounding::adaround::init_v`]).
    fn adaround_fallback_init(
        &self,
        unit: &UnitInfo,
        e: &PackEntry,
        bits_w: u32,
    ) -> Result<Tensor> {
        let (layer, key) = e
            .name
            .split_once('.')
            .ok_or_else(|| anyhow!("bad pack entry name {:?}", e.name))?;
        let lookup = |k: &str| -> Option<&Tensor> {
            ["flexround", "rtn"].iter().find_map(|m| {
                self.inits
                    .get(&format!("init/{}/{m}/b{bits_w}/{layer}.{k}", unit.name))
            })
        };
        match key {
            "s1" | "zp" => lookup(key).cloned().ok_or_else(|| {
                anyhow!(
                    "missing init tensor init/{}/adaround/b{bits_w}/{} and no \
                     flexround/rtn grid to fall back on",
                    unit.name,
                    e.name
                )
            }),
            "v" => {
                let w = self
                    .weights
                    .get(&format!("w/{}/{layer}", unit.name))
                    .ok_or_else(|| {
                        anyhow!(
                            "adaround init for {}/{layer}.v needs the host weights \
                             w/{}/{layer}",
                            unit.name,
                            unit.name
                        )
                    })?;
                let s1 = lookup("s1").ok_or_else(|| {
                    anyhow!(
                        "adaround init for {}/{layer}.v needs a flexround/rtn s1 grid",
                        unit.name
                    )
                })?;
                crate::recon::rounding::adaround::init_v(w, s1)
            }
            other => bail!(
                "no adaround fallback init for pack entry {:?} (key {other:?})",
                e.name
            ),
        }
    }

    // ------------------------------------------------------------------
    // The PTQ reconstruction loop
    // ------------------------------------------------------------------

    fn recon_task<'s>(
        &'s self,
        plan: &Plan,
        unit: &'s UnitInfo,
        st: &UnitState,
        iters: usize,
        lr: f64,
        batch: usize,
        x: Vec<Tensor>,
        y: Vec<Tensor>,
        rng: Pcg32,
    ) -> ReconTask<'s> {
        ReconTask {
            cx: self.unit_ctx(unit),
            method: plan.method.clone(),
            mode: plan.mode.clone(),
            bits_w: st.bits_w,
            abits: st.abits,
            iters,
            lr,
            drop_p: plan.drop_p,
            batch,
            verbose: plan.verbose,
            entries: st.entries.clone(),
            params: st.params.clone(),
            x,
            y,
            rng,
        }
    }

    /// Run the full per-unit reconstruction pipeline for `plan`.
    ///
    /// Two schedules:
    ///
    /// * sequential (default) — the paper's §3.1 protocol: each unit sees
    ///   the *quantized-path* activations X̃ of its predecessors, so units
    ///   must reconstruct in topological order;
    /// * `plan.parallel_units` — every unit reconstructs against
    ///   full-precision inputs (AdaQuant-style layer-parallel PTQ), which
    ///   makes units independent; the engine fans them out over the worker
    ///   pool via [`Backend::reconstruct_many`].
    pub fn quantize(&self, plan: &Plan) -> Result<QuantResult> {
        let mi = self.model;
        let iters = if plan.iters == 0 { mi.iters_default } else { plan.iters };
        let lr = if plan.lr == 0.0 { mi.lr_for(&plan.method) } else { plan.lr };
        let calib_full = self.dataset("calib_x")?;
        let calib_n = if plan.calib_n == 0 {
            calib_full.shape()[0]
        } else {
            plan.calib_n.min(calib_full.shape()[0])
        };
        // round down to a chunk multiple ≥ one batch
        let b = mi.calib_batch;
        let calib_n = (calib_n / b).max(1) * b;
        let calib = calib_full.slice_rows(0, calib_n)?;

        let mut rng = Pcg32::seeded(plan.seed);
        let mut x_fp = self.first_unit_inputs(&calib)?;
        let mut x_q = x_fp.clone();

        let mut states = Vec::new();
        let mut recon_seconds = 0.0;
        let mut recon_steps = 0u64;
        let learns = plan.method != "rtn" && iters > 0;

        let new_state = |unit: &UnitInfo| -> Result<UnitState> {
            let bits_w = unit.bits_override.unwrap_or(plan.bits_w);
            let abits = if unit.bits_override == Some(8) { 8 } else { plan.abits };
            let (params, entries) = self.init_params(unit, &plan.method, &plan.mode, bits_w, abits)?;
            Ok(UnitState {
                unit: unit.name.clone(),
                method: plan.method.clone(),
                params,
                entries,
                first_loss: f64::NAN,
                final_loss: f64::NAN,
                bits_w,
                abits,
            })
        };

        if plan.parallel_units {
            let mut tasks = Vec::new();
            let mut task_unit = Vec::new();
            for (ui, unit) in mi.units.iter().enumerate() {
                let y_fp = self.advance_fp(unit, &x_fp)?;
                let st = new_state(unit)?;
                if learns {
                    tasks.push(self.recon_task(
                        plan, unit, &st, iters, lr, b,
                        x_fp.clone(), y_fp.clone(), rng.fork(ui as u64),
                    ));
                    task_unit.push(ui);
                }
                states.push(st);
                x_fp = y_fp;
            }
            let outcomes = self.backend.reconstruct_many(&tasks)?;
            drop(tasks);
            for (o, &ui) in outcomes.into_iter().zip(&task_unit) {
                let st = &mut states[ui];
                st.params = o.params;
                st.first_loss = o.first_loss;
                st.final_loss = o.final_loss;
                recon_steps += o.steps;
                recon_seconds += o.seconds;
            }
        } else {
            for (ui, unit) in mi.units.iter().enumerate() {
                let y_fp = self.advance_fp(unit, &x_fp)?; // targets = fp outputs
                let mut st = new_state(unit)?;
                if learns {
                    let task = self.recon_task(
                        plan, unit, &st, iters, lr, b,
                        x_q.clone(), y_fp.clone(), rng.fork(ui as u64),
                    );
                    let o = self.backend.reconstruct(&task)?;
                    st.params = o.params;
                    st.first_loss = o.first_loss;
                    st.final_loss = o.final_loss;
                    recon_steps += o.steps;
                    recon_seconds += o.seconds;
                }
                // advance both chains
                x_q = self.advance_q(unit, &st, &plan.mode, &x_q)?;
                x_fp = y_fp;
                states.push(st);
            }
        }

        Ok(QuantResult {
            plan: plan.clone(),
            units: states,
            recon_seconds,
            recon_steps,
        })
    }

    // ------------------------------------------------------------------
    // Quantized / fp forward over an arbitrary dataset (for eval)
    // ------------------------------------------------------------------

    /// Run `xs` through the fully quantized chain; returns final outputs
    /// per chunk (logits for CNNs, hidden states for transformers).
    ///
    /// Fast path: weight-only results over contraction units lower to a
    /// bit-packed [`Engine`] (one fused dequant-GEMM per layer instead of
    /// materializing every Ŵ); anything the packed engine cannot express
    /// (wa mode, conv units, odd bit-widths) is detected by a cheap
    /// pre-check — no export work — and falls back to the generic per-unit
    /// [`Session::advance_q`] chain.  Callers forwarding many datasets
    /// against one result can hoist [`Session::packed_engine`] out of the
    /// loop to pay the export/pack once.
    pub fn forward_q(&self, result: &QuantResult, xs: &Tensor) -> Result<Vec<Tensor>> {
        if self.check_packable(result).is_ok() {
            if let Ok(engine) = self.packed_engine(result) {
                let chunks = self.first_unit_inputs(xs)?;
                return chunks.iter().map(|c| engine.forward(c)).collect();
            }
        }
        let mut chunks = self.first_unit_inputs(xs)?;
        for (unit, st) in self.model.units.iter().zip(&result.units) {
            chunks = self.advance_q(unit, st, &result.plan.mode, &chunks)?;
        }
        Ok(chunks)
    }

    /// Cheap packed-engine eligibility check — the single source of truth
    /// for what [`Session::packed_model`] can express (mode, unit kinds,
    /// bit-widths).  Costs nothing beyond a scan of the unit list.
    fn check_packable(&self, result: &QuantResult) -> Result<()> {
        if result.plan.mode != "w" {
            bail!(
                "packed export is weight-only; mode {:?} quantizes activations too",
                result.plan.mode
            );
        }
        for (unit, st) in self.model.units.iter().zip(&result.units) {
            // the packed engine executes exactly the natively-executable
            // kinds — one predicate, shared with the native backend
            if !crate::runtime::native::native_unit_kind(&unit.kind) {
                bail!(
                    "packed engine supports the native unit kinds {:?}; unit {:?} is {:?}",
                    crate::runtime::native::NATIVE_KINDS,
                    unit.name,
                    unit.kind
                );
            }
            if unit.kind == "transformer_block" && self.model.seq.is_none() {
                bail!(
                    "packed export of transformer_block unit {:?} needs the model's \
                     \"seq\" (rows per sequence)",
                    unit.name
                );
            }
            if !crate::infer::packed::SUPPORTED_BITS.contains(&st.bits_w) {
                bail!(
                    "packed store supports bits in {:?}; unit {:?} is {}-bit",
                    crate::infer::packed::SUPPORTED_BITS,
                    unit.name,
                    st.bits_w
                );
            }
        }
        Ok(())
    }

    /// Lower a weight-only quantization result to a bit-packed model: per
    /// layer, the exported integer codes packed at `bits_w` plus the per-row
    /// `(s1, zp)` grid and the FP bias.  This is everything inference needs —
    /// `PackedModel::save` writes it as a self-contained `.fxt` artifact
    /// that reloads with no FP weights at all (`flexround pack` / `infer`).
    pub fn packed_model(&self, result: &QuantResult) -> Result<PackedModel> {
        // validate the whole model before exporting anything, so ineligible
        // models fail fast with no wasted fake-quant work
        self.check_packable(result)?;
        let mut units = Vec::with_capacity(self.model.units.len());
        for (unit, st) in self.model.units.iter().zip(&result.units) {
            let (qmin, _) = qrange(st.bits_w, self.model.symmetric);
            let slots = crate::recon::map_pack(unit, &st.method, &st.entries).map_err(|e| {
                anyhow!(
                    "packed export supports the native rounding schemes \
                     (rtn, flexround*, adaround); unit {:?}: {e:#}",
                    unit.name
                )
            })?;
            let codes = self
                .backend
                .export_codes(&self.unit_ctx(unit), &Self::qview(st, "w"))?;
            let n = unit.layers.len();
            if codes.len() != n {
                bail!(
                    "unit {:?}: export returned {} code tensors for {n} layers",
                    unit.name,
                    codes.len()
                );
            }
            let mut layers = Vec::with_capacity(n);
            for (li, layer) in unit.layers.iter().enumerate() {
                let mat = PackedMatrix::from_tensors(
                    &codes[li],
                    &st.params[slots[li].s1],
                    &st.params[slots[li].zp],
                    st.bits_w,
                    qmin as i32,
                )?;
                let bias = self
                    .weights
                    .get(&format!("b/{}/{}", unit.name, layer.name))
                    .map(|t| t.as_f32().map(|v| v.to_vec()))
                    .transpose()?;
                layers.push(PackedLayer {
                    name: layer.name.clone(),
                    mat,
                    bias,
                    relu_after: unit.kind == "mlp_relu" && li + 1 < n,
                    act: None,
                });
            }
            let pu = if unit.kind == "transformer_block" {
                // block_def_for re-validates the canonical layer list and
                // pulls the layernorm extras + head/seq geometry
                let cx = self.unit_ctx(unit);
                let def = crate::block::block_def_for(&cx)?;
                PackedUnit {
                    name: unit.name.clone(),
                    kind: "transformer_block".to_string(),
                    heads: def.heads,
                    seq: def.seq,
                    ln1: Some((def.ln1_g.as_f32()?.to_vec(), def.ln1_b.as_f32()?.to_vec())),
                    ln2: Some((def.ln2_g.as_f32()?.to_vec(), def.ln2_b.as_f32()?.to_vec())),
                    layers,
                }
            } else {
                PackedUnit::stack(&unit.name, layers)
            };
            units.push(pu);
        }
        Ok(PackedModel { units })
    }

    /// [`Session::packed_model`] wrapped in a ready-to-run [`Engine`].
    pub fn packed_engine(&self, result: &QuantResult) -> Result<Engine> {
        Ok(Engine::new(self.packed_model(result)?, crate::util::pool::default_workers()))
    }

    /// [`Session::packed_model`] plus a **static activation grid** per
    /// stack-unit layer — the W4A8 artifact (DESIGN.md §Rounding-Schemes).
    /// Grids are calibrated by replaying the reconstruction batches through
    /// the weight-quantized model with activations still f32 (the grid must
    /// cover exactly what serving feeds each GEMM), recording every layer's
    /// input min/max, and fitting an `abits` asymmetric
    /// [`crate::recon::rounding::ActQuant`] to it.  Transformer-block
    /// layers stay weight-only: layernorm / attention / GELU keep the
    /// inter-projection activations f32 anyway, so a static grid there buys
    /// no integer-domain GEMM without a much larger rework.
    pub fn packed_model_with_acts(&self, result: &QuantResult, abits: u32) -> Result<PackedModel> {
        if !(1..=16).contains(&abits) {
            bail!("activation bit-width {abits} out of range (1..=16)");
        }
        let pm = self.packed_model(result)?;
        let _span = crate::obs::span("pack/act_calibrate");
        let chunks = self.first_unit_inputs(self.dataset("calib_x")?)?;
        let mut ranges: Vec<Vec<(f32, f32)>> = pm
            .units
            .iter()
            .map(|u| vec![(f32::INFINITY, f32::NEG_INFINITY); u.layers.len()])
            .collect();
        let engine = Engine::new(pm, crate::util::pool::default_workers());
        for chunk in &chunks {
            let mut h = chunk.clone();
            for (ui, unit) in engine.model().units.iter().enumerate() {
                if unit.kind == "transformer_block" {
                    h = engine.unit_forward(unit, &h)?;
                    continue;
                }
                // stack unit: record each layer's observed input range, then
                // advance through that layer (weight-quantized, f32 acts)
                for (li, layer) in unit.layers.iter().enumerate() {
                    let (lo, hi) = &mut ranges[ui][li];
                    for &v in h.as_f32()? {
                        *lo = lo.min(v);
                        *hi = hi.max(v);
                    }
                    let mut y =
                        crate::infer::kernels::gemm_fused(&h, &layer.mat, engine.workers)?;
                    y.bias_relu_inplace(layer.bias.as_deref(), layer.relu_after)?;
                    h = y;
                }
            }
        }
        let mut pm = engine.into_model();
        for (unit, ur) in pm.units.iter_mut().zip(&ranges) {
            if unit.kind == "transformer_block" {
                continue;
            }
            for (layer, &(lo, hi)) in unit.layers.iter_mut().zip(ur) {
                if lo <= hi {
                    layer.act =
                        Some(crate::recon::rounding::ActQuant::calibrate(lo, hi, abits));
                }
            }
        }
        Ok(pm)
    }

    /// [`Session::packed_model`] plus a trailing `head` stack unit packed
    /// from the native `head/lm` weights ([`Session::packed_head_unit`]):
    /// a **generation-complete** artifact.  `flexround generate --packed`
    /// projects hidden states through this head and ties token embeddings
    /// to its rows, so the one `.fxt` file is all decode needs — still no
    /// FP weights inside.
    pub fn packed_lm_model(&self, result: &QuantResult) -> Result<PackedModel> {
        let mut pm = self.packed_model(result)?;
        pm.units.push(self.packed_head_unit()?);
        Ok(pm)
    }

    /// The native `head/lm` weights packed as a `head` stack unit (8-bit
    /// asymmetric per-row RTN) — the piece that makes an already-packed
    /// block model generation-complete without re-packing its blocks.
    pub fn packed_head_unit(&self) -> Result<PackedUnit> {
        let head = self.weights.get("head/lm").ok_or_else(|| {
            anyhow!(
                "model {} has no native lm head (weights-FXT key \"head/lm\") to pack",
                self.model.name
            )
        })?;
        if head.ndim() != 2 {
            bail!("head/lm must be a (vocab, d) matrix, got {:?}", head.shape());
        }
        let (rows, cols) = (head.shape()[0], head.shape()[1]);
        let hv = head.as_f32()?;
        let bits = 8u32;
        let (qmin, qmax) = qrange(bits, false);
        let mut s1 = Vec::with_capacity(rows);
        let mut zp = Vec::with_capacity(rows);
        for r in 0..rows {
            let (s, z) =
                crate::tensor::minmax_scale(&hv[r * cols..(r + 1) * cols], bits, false);
            s1.push(s);
            zp.push(z);
        }
        let codes: Vec<i32> =
            crate::tensor::rtn_codes_rows(hv, rows, cols, &s1, &zp, qmin, qmax)
                .iter()
                .map(|&c| c as i32)
                .collect();
        let mat = PackedMatrix::pack(&codes, rows, cols, bits, qmin as i32, s1, zp)?;
        Ok(PackedUnit::stack(
            "head",
            vec![PackedLayer { name: "lm".into(), mat, bias: None, relu_after: false, act: None }],
        ))
    }

    /// Full-precision forward (baseline metrics).
    pub fn forward_fp(&self, xs: &Tensor) -> Result<Vec<Tensor>> {
        let mut chunks = self.first_unit_inputs(xs)?;
        for unit in &self.model.units {
            chunks = self.advance_fp(unit, &chunks)?;
        }
        Ok(chunks)
    }

    /// Load a head executable by key ("lm", "logits", task names, "span").
    /// PJRT only — heads exist solely as AOT artifacts.
    #[cfg(feature = "pjrt")]
    pub fn head(&self, key: &str) -> Result<std::rc::Rc<crate::runtime::Exec>> {
        let f = self
            .model
            .head_artifacts
            .get(key)
            .ok_or_else(|| anyhow!("model {} has no head {key:?}", self.model.name))?;
        self.runtime()?.load(f)
    }

    /// Export fake-quantized weights + integer codes for each layer of a
    /// unit (the Figure 3–6 data): returns [(Ŵ, codes)] in layer order.
    pub fn export_qw(&self, unit: &UnitInfo, st: &UnitState) -> Result<Vec<(Tensor, Tensor)>> {
        self.backend
            .export_qw(&self.unit_ctx(unit), &Self::qview(st, "w"))
    }
}
