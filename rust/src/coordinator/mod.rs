//! The PTQ coordinator — the paper's experimental engine as a Rust system.
//!
//! Given a [`Plan`] (model × method × bits × mode × setting), a [`Session`]:
//!
//! 1. loads the model's weights / init packs / datasets (FXT),
//! 2. propagates the calibration set through the *full-precision* unit chain
//!    (targets `Y = unit_fp(X)`),
//! 3. for each unit in topological order, asks the selected
//!    [`Backend`](crate::runtime::Backend) to reconstruct it — `iters` Adam
//!    steps on random calibration minibatches, learning the method's
//!    parameters (FlexRound's s1/S2/s3/s4, AdaRound's V, …) and, in "wa"
//!    mode, the LSQ activation steps with QDrop mixing (`drop_p` = 0
//!    reproduces the BRECQ setting, 0.5 QDrop).  The PJRT engine executes
//!    the AOT recon graphs; the native engine runs [`crate::recon`],
//! 4. advances the *quantized-path* calibration activations X̃ through the
//!    learned unit (the paper's §3.1 X vs X̃ distinction) — or, with
//!    [`Plan::parallel_units`], reconstructs every unit against FP inputs
//!    concurrently,
//! 5. evaluates the fully quantized model (accuracy / perplexity / BLEU /
//!    zero-shot multiple choice) via [`crate::eval`].
//!
//! β annealing for AdaRound's rounding regularizer and the iteration seeds
//! for QDrop masks are generated here and passed as executable inputs.

pub mod session;

pub use session::*;

use crate::manifest::PackEntry;
use crate::tensor::Tensor;

/// What to quantize and how — one row of one paper table.
#[derive(Clone, Debug)]
pub struct Plan {
    pub model: String,
    pub method: String,
    /// "w" (weight-only) or "wa" (weights + activations)
    pub mode: String,
    pub bits_w: u32,
    pub abits: u32,
    pub iters: usize,
    pub lr: f64,
    /// QDrop probability: 0.0 → BRECQ setting ("B + X"), 0.5 → QDrop ("Q + X")
    pub drop_p: f64,
    /// Number of calibration samples to use (≤ exported calib_n)
    pub calib_n: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Reconstruct units against full-precision inputs so they become
    /// independent and fan out across the worker pool (`--parallel-units`).
    /// The default `false` keeps the paper's sequential X̃ protocol.
    pub parallel_units: bool,
}

impl Plan {
    pub fn new(model: &str, method: &str) -> Plan {
        Plan {
            model: model.to_string(),
            method: method.to_string(),
            mode: "w".to_string(),
            bits_w: 4,
            abits: 8,
            iters: 0, // 0 → manifest default
            lr: 0.0,  // 0 → manifest default for the method
            drop_p: 0.0,
            calib_n: 0, // 0 → all exported
            seed: 7,
            verbose: false,
            parallel_units: false,
        }
    }

    pub fn setting_label(&self) -> &'static str {
        if self.mode == "w" {
            "B"
        } else if self.drop_p > 0.0 {
            "Q"
        } else {
            "B"
        }
    }
}

/// Learned state of one unit after reconstruction.
#[derive(Clone)]
pub struct UnitState {
    pub unit: String,
    pub method: String,
    /// flat parameter values, in pack order
    pub params: Vec<Tensor>,
    pub entries: Vec<PackEntry>,
    pub first_loss: f64,
    pub final_loss: f64,
    pub bits_w: u32,
    pub abits: u32,
}

// UnitState carries its method for advance_q
impl UnitState {
    pub fn rtn_like(&self) -> bool {
        self.method == "rtn"
    }
}

/// AdaRound β annealing (matches `python/compile/graphs.py::_beta`).  The
/// canonical copy lives with the rounding schemes —
/// [`crate::recon::rounding::beta_schedule`] — because the native loop feeds
/// it into [`crate::recon::Rounding::backward`] per step; this alias keeps
/// the coordinator-facing name.
pub fn beta_schedule(t: usize, iters: usize) -> f64 {
    crate::recon::rounding::beta_schedule(t, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_anneals_hi_to_lo() {
        let n = 100;
        assert_eq!(beta_schedule(1, n), 20.0);
        assert_eq!(beta_schedule(19, n), 20.0);
        let mid = beta_schedule(60, n);
        assert!(mid < 20.0 && mid > 2.0);
        let end = beta_schedule(100, n);
        assert!(end < 2.5, "end beta {end}");
        // monotone non-increasing after warmup
        let mut prev = f64::INFINITY;
        for t in 20..=100 {
            let b = beta_schedule(t, n);
            assert!(b <= prev + 1e-9);
            prev = b;
        }
    }

    #[test]
    fn plan_setting_labels() {
        let mut p = Plan::new("m", "flexround");
        assert_eq!(p.setting_label(), "B");
        p.mode = "wa".into();
        assert_eq!(p.setting_label(), "B");
        p.drop_p = 0.5;
        assert_eq!(p.setting_label(), "Q");
    }
}
