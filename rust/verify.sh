#!/bin/sh
# CI / pre-merge gate for the Rust tree.  Run from rust/:
#
#   ./verify.sh          # build + test + doc (tier-1 superset)
#
# Steps:
#   1. release build, default features (native + pjrt-stub scaffolding)
#   1a. FlexRound-through-trait golden parity gate: the rounding-scheme
#       trait refactor must keep FlexRound bit-identical to the Python
#       reference (tests/native_recon.rs + tests/infer.rs golden fixtures)
#   1b. kernel-parity smoke, run THREE times: rust/tests/kernels.rs is the
#       differential harness (scalar tiles vs the SIMD arm under a ULP
#       budget, integer-domain fused GEMM — i32 and i16-madd routes —
#       bit-exact vs the rowwise oracle).  Pass 1 forces
#       FLEXROUND_FORCE_SCALAR=1 so the scalar tiles are the *active* arm;
#       pass 2 runs the AVX2 arm with FLEXROUND_FORCE_NO_MADD=1 (the
#       f32/i32 SIMD routes, madd auto-selection killed); pass 3
#       auto-detects everything, i16-madd included.  A failure names which
#       route diverged (fast, fails early — a kernel regression should not
#       wait for the full suite)
#   1c. scheduler differential smoke, same two-arm pattern:
#       rust/tests/sched.rs pins batched multi-session decode (paged KV
#       pool, evict/spill/restore) bit-identical to per-session generate —
#       on the forced-scalar arm and the auto-detected arm, so an ISA-
#       specific kernel change cannot silently split the two decode paths
#   1d. observability kill-switch gate: the kernel and scheduler
#       differential smokes re-run with FLEXROUND_OBS=off (spans and
#       hot-path counters disabled) — instrumentation must never touch
#       numerics, so parity has to hold bit-identically in both modes —
#       and the obs microbench (benches/obs.rs) fails the gate if a
#       disabled span costs more than nanoseconds (writes BENCH_obs.json)
#   1e. kernel bench build gate: benches/kernels.rs (the BENCH_kernels.json
#       producer, including the unpack and i16-madd sections) must compile
#       in release before the full suite runs
#   2. full test suite (artifact tests self-skip when artifacts/ is absent)
#   3. native-only build (--no-default-features): the backend must build
#      with zero xla surface
#   4. all secondary targets compile, debug AND release (benches, examples —
#      release because that is how the bench trajectories actually run)
#   5. rustdoc with -D warnings: every doc reference must resolve
#   6. clippy — BLOCKING for all of src/ (any clippy diagnostic anchored
#      under rust/src/ fails the gate; promoted from the per-directory
#      block/infer gate in PR 4 — this includes the new src/linalg/ kernel
#      core); advisory with -D warnings for the remaining targets
#      (benches/tests/examples)
#   7. rustfmt check — advisory until the pre-existing tree is formatted
#      (new code should be clean; the gate hardens once `cargo fmt` has
#      been run repo-wide)
set -eu
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== FlexRound-through-trait golden parity gate =="
# The Rounding-trait refactor (DESIGN.md §Rounding-Schemes) must leave the
# FlexRound math bit-identical: the Python-pinned golden fixtures and the
# packed-GEMM parity fixture fail first if the trait plumbing drifted.
if ! cargo test -q --release --test native_recon golden; then
    echo "golden parity FAILED: FlexRound through the Rounding trait diverged from the Python reference"
    exit 1
fi
if ! cargo test -q --release --test infer golden; then
    echo "golden parity FAILED: packed export through the Rounding trait diverged from the fixture"
    exit 1
fi

echo "== kernel-parity smoke, pass 1/3: forced-scalar arm =="
if ! FLEXROUND_FORCE_SCALAR=1 cargo test -q --release --test kernels; then
    echo "kernel parity FAILED on the forced-SCALAR route (src/linalg/micro.rs tiles + scalar word-walk decode)"
    exit 1
fi
echo "== kernel-parity smoke, pass 2/3: AVX2 arm, i16-madd auto-route disabled =="
if ! FLEXROUND_FORCE_NO_MADD=1 cargo test -q --release --test kernels; then
    echo "kernel parity FAILED on the AVX2-f32/i32 route (src/linalg/simd.rs, FLEXROUND_FORCE_NO_MADD=1 — madd auto-selection off)"
    exit 1
fi
echo "== kernel-parity smoke, pass 3/3: auto arm, i16-madd enabled =="
if ! cargo test -q --release --test kernels; then
    echo "kernel parity FAILED on the auto/i16-madd route (src/linalg/simd.rs dot_i16_madd + in-register unpack)"
    exit 1
fi

echo "== scheduler differential smoke, pass 1/2: forced-scalar arm =="
if ! FLEXROUND_FORCE_SCALAR=1 cargo test -q --release --test sched; then
    echo "scheduler differential FAILED on the forced-SCALAR path (batched decode vs generate)"
    exit 1
fi
echo "== scheduler differential smoke, pass 2/2: auto-detected arm =="
if ! cargo test -q --release --test sched; then
    echo "scheduler differential FAILED on the auto/SIMD path (batched decode vs generate)"
    exit 1
fi

echo "== observability kill-switch gate: FLEXROUND_OBS=off parity smokes =="
if ! FLEXROUND_OBS=off cargo test -q --release --test kernels; then
    echo "kernel parity FAILED with observability disabled (FLEXROUND_OBS=off)"
    exit 1
fi
if ! FLEXROUND_OBS=off cargo test -q --release --test sched; then
    echo "scheduler differential FAILED with observability disabled (FLEXROUND_OBS=off)"
    exit 1
fi
echo "== observability disabled-overhead microbench (benches/obs.rs) =="
if ! cargo bench --bench obs; then
    echo "obs overhead gate FAILED: a disabled span must cost nanoseconds"
    exit 1
fi

echo "== kernel bench builds (benches/kernels.rs — BENCH_kernels.json producer) =="
if ! cargo build --release --bench kernels; then
    echo "bench build FAILED: benches/kernels.rs must compile (it produces BENCH_kernels.json)"
    exit 1
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo build --no-default-features (native-only) =="
cargo build --no-default-features --lib --bins

echo "== cargo build --all-targets (benches + examples) =="
cargo build --all-targets

echo "== cargo build --release --benches --examples =="
cargo build --release --benches --examples

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (BLOCKING across all of src/) =="
    clippy_out=$(cargo clippy --all-targets --message-format short 2>&1) || true
    if printf '%s\n' "$clippy_out" \
        | grep -E 'src/[^ :]*:[0-9]+:[0-9]+: (warning|error)' \
        | grep -v 'generated [0-9]* warning' >/dev/null; then
        printf '%s\n' "$clippy_out" | grep -E 'src/[^ :]*:[0-9]+:[0-9]+:' || true
        echo "clippy: diagnostics anywhere under rust/src/ are blocking"
        exit 1
    fi
    echo "== cargo clippy --all-targets (-D warnings; advisory for benches/tests/examples) =="
    cargo clippy --all-targets -- -D warnings \
        || echo "clippy: lint drift outside src/ (advisory; hardens once benches/tests are clean)"
else
    echo "== cargo clippy unavailable; skipped =="
fi

echo "== cargo doc --no-deps (-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (advisory) =="
    cargo fmt --check || echo "fmt: formatting drift (advisory; not failing the gate yet)"
else
    echo "== cargo fmt unavailable; skipped =="
fi

echo "verify.sh: OK"
