//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build image has no crates.io access, so the substrate crates are
//! vendored in-tree (see the workspace `Cargo.toml`).  This shim provides
//! exactly the surface the `flexround` crate uses: [`Error`], [`Result`],
//! and the [`anyhow!`] / [`bail!`] / [`ensure!`] macros.  Any type
//! implementing `std::error::Error` converts into [`Error`] via `?`.

use std::fmt;

/// A string-backed error value (the shim keeps no backtrace or cause chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, mirroring `anyhow::Error::context` semantics.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket conversion below coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Format-string error constructor.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert-or-error.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/at/all")?;
        Ok(())
    }

    #[test]
    fn conversions_and_macros() {
        let e = anyhow!("bad {} ({})", "thing", 3);
        assert_eq!(e.to_string(), "bad thing (3)");
        assert_eq!(format!("{e:#}"), "bad thing (3)");
        assert!(io_fail().is_err());
        let c = anyhow!("inner").context("outer");
        assert_eq!(c.to_string(), "outer: inner");
    }

    fn bails(x: i32) -> Result<i32> {
        ensure!(x >= 0, "negative {x}");
        if x == 0 {
            bail!("zero");
        }
        Ok(x)
    }

    #[test]
    fn bail_ensure() {
        assert!(bails(-1).is_err());
        assert!(bails(0).is_err());
        assert_eq!(bails(2).unwrap(), 2);
    }
}
