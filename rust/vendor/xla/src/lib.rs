//! Compile-only stub of the `xla` crate (PJRT C API bindings).
//!
//! The build image vendors no C++ XLA toolchain, so this crate mirrors the
//! exact API surface `flexround::runtime::pjrt` uses and fails **at
//! runtime** — `PjRtClient::cpu()` returns an error, which the coordinator
//! surfaces as "use `--backend native` or point Cargo at a real xla
//! checkout".  Type-checking the whole PJRT path everywhere (CI included)
//! while keeping the default build self-contained is the point; swap this
//! for the real bindings with a `[patch]`/path override when PJRT execution
//! is wanted (see README §PJRT backend).

use std::borrow::Borrow;
use std::path::Path;

/// Stub error: a plain message (the real crate's `Error` is also opaque and
/// only ever formatted with `{:?}` by the caller).
#[derive(Debug, Clone)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable (the vendored `xla` crate is a compile-only stub; \
         use --backend native, or override the `xla` dependency with real bindings)"
    )))
}

/// Marker for element types literals can carry.
pub trait ArrayElement: Copy {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U8,
    Pred,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    Pred,
}

/// Host literal (stub: carries no data — it can never reach a device).
pub struct Literal;

impl Literal {
    pub fn scalar<T: ArrayElement>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1<T: ArrayElement>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable("Literal::convert")
    }
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::scalar(1.0f32);
        assert!(lit.array_shape().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{:?}", PjRtClient::cpu().err().unwrap());
        assert!(msg.contains("stub"));
    }
}
