//! Continuous-batching scheduler acceptance gate (DESIGN.md
//! §Continuous-Batching) — the tentpole contract is **bit-identity**:
//!
//! * batched multi-session decode emits exactly the token streams that
//!   per-session [`generate::generate`] emits, at several concurrency
//!   levels, at 4 and 8 bits, across greedy and temperature/top-k
//!   sampling;
//! * pool pressure that forces evict → FXT-spill → restore cycles
//!   mid-generation does not perturb a single token;
//! * the page layout (page size, segment count) is invisible to the
//!   streams — paged attention reads are the contiguous walk;
//! * the admission bound (`max_active`) queues and drains without
//!   reordering or losing sessions.
//!
//! verify.sh runs this differential on both ISA arms
//! (`FLEXROUND_FORCE_SCALAR=1` and auto-dispatch).

use flexround::infer::generate::{self, GenOpts};
use flexround::infer::Engine;
use flexround::sched::{SchedConfig, Scheduler};
use flexround::tensor::Tensor;

fn lm_engine(bits: u32) -> Engine {
    Engine::new(generate::synthetic_lm(2, 16, 4, 32, 8, 24, bits, 13).unwrap(), 2)
}

/// A varied batch of sessions: prompt lengths 2–9, max_new 4–12, greedy and
/// temperature/top-k sampling, distinct seeds — so concurrency-dependent
/// bugs cannot hide behind uniform shapes.
fn session_mix(model: &flexround::infer::PackedModel, n: usize) -> Vec<(Tensor, GenOpts)> {
    let temps = [0.0f32, 0.8, 1.0, 0.7, 0.9];
    let top_ks = [0usize, 5, 8, 3, 4];
    (0..n)
        .map(|i| {
            let plen = 1 + (3 * i + 1) % 9;
            let (_, prompt) = generate::random_prompt(model, plen, 90 + i as u64).unwrap();
            let opts = GenOpts {
                max_new: 4 + (5 * i) % 9,
                temp: temps[i % temps.len()],
                top_k: top_ks[i % top_ks.len()],
                seed: 1000 + 37 * i as u64,
            };
            (prompt, opts)
        })
        .collect()
}

/// Submit every session, run the scheduler dry, and return the token
/// streams in submit order (handles are assigned in submit order).
fn run_batched(
    engine: Engine,
    cfg: SchedConfig,
    mix: &[(Tensor, GenOpts)],
) -> (Scheduler, Vec<Vec<usize>>) {
    let mut sched = Scheduler::new(engine, cfg).unwrap();
    for (prompt, opts) in mix {
        sched.submit(prompt.as_f32().unwrap().to_vec(), *opts).unwrap();
    }
    let mut fin = sched.run_all().unwrap();
    assert_eq!(fin.len(), mix.len(), "every submitted session must finish");
    fin.sort_by_key(|f| f.handle);
    let streams = fin.into_iter().map(|f| f.tokens).collect();
    (sched, streams)
}

#[test]
fn batched_decode_is_bit_identical_to_solo_generate() {
    for bits in [4u32, 8] {
        for n in [2usize, 4, 5] {
            let engine = lm_engine(bits);
            let mix = session_mix(engine.model(), n);
            let (sched, streams) = run_batched(engine, SchedConfig::default(), &mix);
            for (i, ((prompt, opts), got)) in mix.iter().zip(&streams).enumerate() {
                let want = generate::generate(sched.engine(), prompt, opts).unwrap().tokens;
                assert_eq!(
                    got, &want,
                    "{bits}-bit, {n} concurrent sessions: session {i} diverged from its \
                     solo decode"
                );
            }
            assert_eq!(sched.pages_in_use(), 0, "retired sessions must free their pages");
            assert!(!sched.has_work());
        }
    }
}

#[test]
fn eviction_spill_restore_midstream_is_bit_identical() {
    // 4 pages × 4 tokens = 16 slots; each session needs 6 + 8 = 14, so two
    // concurrent sessions cannot coexist at depth — one must be evicted
    // mid-generation, spill to FXT files, and restore later.
    for bits in [4u32, 8] {
        let dir = std::env::temp_dir()
            .join(format!("flexround_sched_spill_{bits}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine = lm_engine(bits);
        let mix: Vec<(Tensor, GenOpts)> = (0..2)
            .map(|i| {
                let (_, prompt) =
                    generate::random_prompt(engine.model(), 6, 400 + i as u64).unwrap();
                let opts = GenOpts {
                    max_new: 8,
                    temp: if i == 0 { 0.0 } else { 0.9 },
                    top_k: if i == 0 { 0 } else { 6 },
                    seed: 500 + 11 * i as u64,
                };
                (prompt, opts)
            })
            .collect();
        let cfg = SchedConfig {
            pool_pages: 4,
            page_tokens: 4,
            max_active: 4,
            prefill_chunk: 32,
            spill_dir: Some(dir.clone()),
        };
        let (sched, streams) = run_batched(engine, cfg, &mix);
        assert!(
            sched.evictions() >= 1,
            "{bits}-bit: pool pressure must force at least one eviction"
        );
        for (i, ((prompt, opts), got)) in mix.iter().zip(&streams).enumerate() {
            let want = generate::generate(sched.engine(), prompt, opts).unwrap().tokens;
            assert_eq!(
                got, &want,
                "{bits}-bit: session {i} diverged across its evict/spill/restore cycle"
            );
        }
        assert_eq!(sched.pages_in_use(), 0);
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("actcache_")
            })
            .count();
        assert_eq!(leftovers, 0, "finished sessions must leave no spill files behind");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn page_layout_is_invisible_to_the_token_streams() {
    // 3-token pages (every session straddles many segments) vs 64-token
    // pages (every session fits one segment): identical streams, because
    // the segmented attention walk is the contiguous walk.
    let engine = lm_engine(4);
    let mix = session_mix(engine.model(), 3);
    let fine = SchedConfig { pool_pages: 64, page_tokens: 3, ..SchedConfig::default() };
    let coarse = SchedConfig { pool_pages: 4, page_tokens: 64, ..SchedConfig::default() };
    let (_, fine_streams) = run_batched(engine, fine, &mix);
    let (_, coarse_streams) = run_batched(lm_engine(4), coarse, &mix);
    assert_eq!(
        fine_streams, coarse_streams,
        "page size must not leak into the sampled tokens"
    );
}

#[test]
fn admission_bound_queues_and_drains_every_session() {
    let engine = lm_engine(8);
    let mix = session_mix(engine.model(), 6);
    let cfg = SchedConfig { max_active: 2, ..SchedConfig::default() };
    let (sched, streams) = run_batched(engine, cfg, &mix);
    let (peak_sessions, peak_pages) = sched.occupancy_peaks();
    assert!(peak_sessions <= 2, "admission control must cap concurrency at max_active");
    assert!(peak_pages >= 1);
    assert_eq!(sched.active_sessions(), 0);
    assert_eq!(sched.queued_sessions(), 0);
    for (i, ((prompt, opts), got)) in mix.iter().zip(&streams).enumerate() {
        let want = generate::generate(sched.engine(), prompt, opts).unwrap().tokens;
        assert_eq!(got, &want, "queued session {i} diverged from its solo decode");
    }
}
