//! Integration tests for the observability endpoint: `/metrics` exposition
//! well-formedness, `/healthz` during an active multi-session scheduler
//! run, and clean listener shutdown (no lingering thread / socket).

use flexround::infer::generate::{self, GenOpts};
use flexround::infer::{BatchPolicy, Engine, Server};
use flexround::obs::MetricsServer;
use flexround::ser::json::{self, Json};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to metrics endpoint");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .expect("status line");
    let body = match buf.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => String::new(),
    };
    (status, body)
}

/// Every non-comment line must be `name[{labels}] value` with a numeric
/// value; every `# TYPE` line must name a known metric kind.
fn assert_exposition_well_formed(body: &str) {
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("typed metric name");
            let kind = it.next().expect("metric kind");
            assert!(!name.is_empty());
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown metric kind {kind:?} in {line:?}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "only # TYPE comments are emitted, got {line:?}");
        let (name, val) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value in {line:?}"));
        assert!(!name.is_empty(), "empty metric name in {line:?}");
        val.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric value in {line:?}"));
    }
}

#[test]
fn metrics_and_healthz_serve_during_active_scheduler_run() {
    let model = generate::synthetic_lm(2, 16, 4, 32, 8, 24, 4, 5).unwrap();
    let server = Server::start(
        Engine::new(model, 1),
        BatchPolicy { max_batch: 4, deadline: Duration::from_micros(200) },
    )
    .unwrap();
    let ms = MetricsServer::start(
        "127.0.0.1:0",
        Json::object(vec![("name", Json::from_str_val("synthetic_lm"))]),
    )
    .unwrap();
    let addr = ms.addr();
    assert_ne!(addr.port(), 0, "port 0 must resolve to a real ephemeral port");

    // a mixed workload: three long-decode sessions racing a row client
    let gen_threads: Vec<_> = (0..3)
        .map(|i| {
            let client = server.client();
            let prompt = {
                // prompts come off the server's own model shape
                let m = generate::synthetic_lm(2, 16, 4, 32, 8, 24, 4, 5).unwrap();
                let (_, p) = generate::random_prompt(&m, 3, 11 + i).unwrap();
                p.as_f32().unwrap().to_vec()
            };
            let opts = GenOpts { max_new: 300, temp: 0.8, top_k: 4, seed: 13 + i };
            std::thread::spawn(move || client.generate(prompt, opts).unwrap().len())
        })
        .collect();
    let row_client = server.client();
    for _ in 0..4 {
        assert_eq!(row_client.call(vec![0.5; 4 * 16]).unwrap().len(), 4 * 24);
    }

    // probe while the sessions are (almost certainly) still decoding —
    // the endpoint must answer concurrently with the batcher + scheduler
    let (status, health) = http_get(addr, "/healthz");
    assert_eq!(status, 200);
    let doc = json::parse(health.trim()).expect("healthz is valid JSON");
    assert_eq!(doc.get("status").unwrap().str().unwrap(), "ok");
    assert!(doc.get("uptime_secs").unwrap().num().unwrap() >= 0.0);
    assert_eq!(doc.get("model").unwrap().get("name").unwrap().str().unwrap(), "synthetic_lm");
    let sched = doc.get("scheduler").expect("healthz carries scheduler liveness");
    assert!(sched.get("steps").unwrap().num().unwrap() >= 0.0);
    assert!(sched.get("pages_in_use").is_ok() && sched.get("evictions").is_ok());

    let (status, metrics_live) = http_get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_exposition_well_formed(&metrics_live);

    for t in gen_threads {
        assert_eq!(t.join().unwrap(), 300);
    }

    // after the workload: the serve/sched families must all be present
    let (_, metrics) = http_get(addr, "/metrics");
    assert_exposition_well_formed(&metrics);
    for family in [
        "flexround_serve_queue_depth",
        "flexround_serve_batch_rows",
        "flexround_serve_row_wait_ms",
        "flexround_serve_row_service_ms",
        "flexround_serve_gen_wait_ms",
        "flexround_serve_gen_service_ms",
        "flexround_serve_requests_total",
        "flexround_serve_gen_sessions_total",
        "flexround_sched_steps_total",
        "flexround_sched_active_sessions",
        "flexround_sched_pages_in_use",
    ] {
        assert!(metrics.contains(family), "/metrics is missing {family}");
    }
    // histogram families render the full exposition shape
    assert!(metrics.contains("flexround_serve_row_wait_ms_bucket{le=\"+Inf\"}"));
    assert!(metrics.contains("flexround_serve_row_wait_ms_count"));
    assert!(metrics.contains("flexround_serve_row_wait_ms_p99"));

    let (status, _) = http_get(addr, "/nope");
    assert_eq!(status, 404);

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.gen_sessions, 3);
    ms.shutdown().expect("endpoint joins cleanly");
    // no lingering listener: the port must refuse connections now
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
        "listener socket survived shutdown"
    );
}

#[test]
fn endpoint_shuts_down_cleanly_with_no_traffic() {
    let ms = MetricsServer::start("127.0.0.1:0", Json::Null).unwrap();
    let addr = ms.addr();
    ms.shutdown().expect("idle endpoint joins cleanly");
    assert!(TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err());
}

#[test]
fn endpoint_drop_joins_the_listener_thread() {
    let addr = {
        let ms = MetricsServer::start("127.0.0.1:0", Json::Null).unwrap();
        let (status, _) = http_get(ms.addr(), "/metrics");
        assert_eq!(status, 200);
        ms.addr()
        // ms drops here: Drop must stop + join, not leak the thread
    };
    assert!(TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err());
}
