//! Transformer-block acceptance gate — artifact-free and PJRT-free:
//!
//! * a synthetic `transformer_block` manifest runs end-to-end natively —
//!   calibration → block-by-block FlexRound reconstruction (both
//!   `--recon-input fp` and `--recon-input quant`) → pack → `Engine`
//!   forward — with the packed engine matching the generic f32 quantized
//!   chain within 1e-4;
//! * the disk-spillable activation cache: a calibration set larger than the
//!   memory budget spills chunks to disk, and the pipeline's results are
//!   bit-identical to the all-in-memory run (caching is value-transparent);
//! * native perplexity through the weights-FXT lm head (`eval_ppl_hidden`)
//!   reports finite quantized-vs-FP deltas on the synthetic manifest;
//! * `Session::quantize` routes `transformer_block` units through the
//!   native backend (op-level finite-difference gradchecks live in
//!   `tensor::ops` and `block::tests`).

use flexround::block::{
    chain_mse, run_pipeline, synthetic_block_model, PipelineOpts, ReconInput, SyntheticBlockSpec,
};
use flexround::coordinator::Plan;
use flexround::eval;
use flexround::infer::{Engine, PackedModel};
use flexround::runtime::Native;

fn spec() -> SyntheticBlockSpec {
    SyntheticBlockSpec {
        blocks: 2,
        d: 16,
        heads: 2,
        mlp: 32,
        seq: 4,
        calib_seqs: 8,
        eval_seqs: 4,
        chunk_seqs: 2,
        vocab: 24,
        bits: 4,
        seed: 7,
    }
}

fn opts(recon_input: ReconInput, iters: usize) -> PipelineOpts {
    let mut o = PipelineOpts::new("flexround", 4);
    o.iters = iters;
    o.lr = 3e-3;
    o.recon_input = recon_input;
    o
}

#[test]
fn pipeline_improves_over_rtn_in_both_input_modes() {
    let fx = synthetic_block_model(&spec()).unwrap();
    let backend = Native::with_workers(2);
    let sess = fx.session(&backend);
    let calib = sess.dataset("calib_x").unwrap().clone();

    // RTN-at-init baseline: zero learning iterations
    let base = run_pipeline(&sess, &opts(ReconInput::Quant, 0)).unwrap();
    assert_eq!(base.result.recon_steps, 0);
    let mse_rtn = chain_mse(&sess, &base.result, &calib).unwrap();
    assert!(mse_rtn.is_finite() && mse_rtn > 0.0);

    for mode in [ReconInput::Fp, ReconInput::Quant] {
        let out = run_pipeline(&sess, &opts(mode, 60)).unwrap();
        assert_eq!(out.result.recon_steps, 120, "60 iters × 2 blocks");
        assert_eq!(out.result.units.len(), 2);
        for u in &out.result.units {
            assert!(
                u.first_loss.is_finite() && u.final_loss.is_finite(),
                "block {} losses must be finite under {mode:?}",
                u.unit
            );
        }
        let mse = chain_mse(&sess, &out.result, &calib).unwrap();
        assert!(
            mse < mse_rtn,
            "{mode:?}-input pipeline should beat the RTN init: {mse_rtn:.6} → {mse:.6}"
        );
    }
}

#[test]
fn activation_cache_spills_and_results_are_identical() {
    let fx = synthetic_block_model(&spec()).unwrap();
    let backend = Native::new();
    let sess = fx.session(&backend);

    let in_memory = run_pipeline(&sess, &opts(ReconInput::Quant, 25)).unwrap();
    assert_eq!(in_memory.spilled_chunks, 0);

    // one chunk is chunk_seqs·seq·d·4 = 2·4·16·4 = 512 bytes; a 600-byte
    // budget forces every chain past its budget on the second chunk
    let dir = std::env::temp_dir()
        .join(format!("flexround_block_pipeline_spill_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cached = opts(ReconInput::Quant, 25);
    cached.cache_dir = Some(dir.clone());
    cached.cache_budget_bytes = 600;
    let spilled = run_pipeline(&sess, &cached).unwrap();
    assert!(
        spilled.spilled_chunks > 0,
        "calibration set larger than the budget must spill to disk"
    );

    // caching is value-transparent: learned parameters and losses are
    // bit-identical to the all-in-memory run
    for (a, b) in in_memory.result.units.iter().zip(&spilled.result.units) {
        assert_eq!(a.final_loss, b.final_loss, "block {} loss drifted under spill", a.unit);
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }
    // drop of the run's caches removed the spill files
    let leftovers = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().starts_with("actcache_")
        })
        .count();
    assert_eq!(leftovers, 0, "spill files must be cleaned up");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipeline_pack_engine_roundtrip_matches_generic_chain() {
    let fx = synthetic_block_model(&spec()).unwrap();
    let backend = Native::with_workers(2);
    let sess = fx.session(&backend);
    let out = run_pipeline(&sess, &opts(ReconInput::Quant, 40)).unwrap();

    // pack → save → reload: the artifact carries no FP weights
    let pm = sess.packed_model(&out.result).unwrap();
    assert!(pm.has_blocks());
    assert_eq!(pm.seq(), 4);
    assert!(pm.packed_bytes() < pm.fp32_bytes(), "4-bit pack must shrink the block");
    let path = std::env::temp_dir()
        .join(format!("flexround_block_pack_{}.fxt", std::process::id()));
    pm.save(&path).unwrap();
    let loaded = PackedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(pm, loaded);

    // generic f32 quantized chain vs the packed engine, chunk by chunk
    let engine = Engine::new(loaded, 2);
    let calib = sess.dataset("calib_x").unwrap();
    let chunks = sess.first_unit_inputs(calib).unwrap();
    let mut generic = chunks.clone();
    for (unit, st) in sess.model.units.iter().zip(&out.result.units) {
        generic = sess.advance_q(unit, st, "w", &generic).unwrap();
    }
    for (chunk, want) in chunks.iter().zip(&generic) {
        let got = engine.forward(chunk).unwrap();
        assert_eq!(got.shape(), want.shape());
        let d = got.max_abs_diff(want).unwrap();
        let tol = 1e-4 * (1.0 + want.abs_max());
        assert!(d <= tol, "packed block engine vs f32 chain: max|Δ| {d} > {tol}");
    }

    // flattened-sequence serving entry: same rows, reshaped
    let seq_d = engine.in_width().unwrap();
    assert_eq!(seq_d, 4 * 16);
    let flat = chunks[0]
        .reshape(&[chunks[0].shape()[0] / 4, seq_d])
        .unwrap();
    let served = engine.forward(&flat).unwrap();
    let direct = engine.forward(&chunks[0]).unwrap();
    assert_eq!(
        served.as_f32().unwrap(),
        direct.as_f32().unwrap(),
        "serving layout must match the token layout"
    );

    // Session::forward_q takes the packed fast path for block models too
    let via_session = sess.forward_q(&out.result, calib).unwrap();
    for (a, b) in via_session.iter().zip(&generic) {
        let d = a.max_abs_diff(b).unwrap();
        assert!(d <= 1e-4 * (1.0 + b.abs_max()), "forward_q fast path drift {d}");
    }
}

#[test]
fn native_perplexity_reports_quantized_vs_fp_delta() {
    let fx = synthetic_block_model(&spec()).unwrap();
    let backend = Native::new();
    let sess = fx.session(&backend);
    let ppl_fp = eval::eval_ppl_hidden(&sess, None, "eval_x", "eval_y").unwrap();
    assert!(ppl_fp.is_finite() && ppl_fp >= 1.0, "fp perplexity {ppl_fp}");

    let out = run_pipeline(&sess, &opts(ReconInput::Quant, 40)).unwrap();
    let ppl_q = eval::eval_ppl_hidden(&sess, Some(&out.result), "eval_x", "eval_y").unwrap();
    assert!(ppl_q.is_finite() && ppl_q >= 1.0, "quantized perplexity {ppl_q}");
    // teacher labels are the FP argmax, so FP is the floor up to clipping
    assert!(
        ppl_fp < ppl_q * 2.0,
        "fp ppl {ppl_fp} should not be far above quantized ppl {ppl_q}"
    );
}

#[test]
fn session_quantize_routes_blocks_through_native_backend() {
    let fx = synthetic_block_model(&spec()).unwrap();
    let backend = Native::with_workers(2);
    let sess = fx.session(&backend);
    let mut plan = Plan::new("block_lm", "flexround");
    plan.iters = 20;
    plan.lr = 3e-3;
    let r = sess.quantize(&plan).unwrap();
    assert_eq!(r.recon_steps, 40, "20 iters × 2 blocks");
    for u in &r.units {
        assert!(u.first_loss.is_finite() && u.final_loss.is_finite(), "block {}", u.unit);
    }
    // quantized and fp chains both run end to end with the right shapes
    let calib = sess.dataset("calib_x").unwrap();
    let q = sess.forward_q(&r, calib).unwrap();
    let fp = sess.forward_fp(calib).unwrap();
    assert_eq!(q.len(), fp.len());
    assert_eq!(q[0].shape(), &[8, 16]); // chunk_seqs·seq × d
    // rtn also runs (no learning)
    let rtn = sess.quantize(&Plan::new("block_lm", "rtn")).unwrap();
    assert_eq!(rtn.recon_steps, 0);
    let _ = sess.forward_q(&rtn, calib).unwrap();
}

#[test]
fn mid_pipeline_error_leaves_the_cache_dir_empty() {
    // Satellite regression (PR 4): a pipeline that *fails* between blocks —
    // here block 1's weights are missing from the FXT export — must not
    // leak spill files; every ActivationCache cleans up via purge()/Drop on
    // the error path.
    let mut fx = synthetic_block_model(&spec()).unwrap();
    assert!(fx.weights.remove("w/blk1/wq").is_some(), "fixture layout changed");
    let backend = Native::new();
    let sess = fx.session(&backend);

    let dir = std::env::temp_dir()
        .join(format!("flexround_block_pipeline_errleak_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut o = opts(ReconInput::Quant, 5);
    o.cache_dir = Some(dir.clone());
    o.cache_budget_bytes = 1; // force every chunk of every chain to spill
    let err = run_pipeline(&sess, &o);
    assert!(err.is_err(), "a block with missing weights must fail the pipeline");
    let leftovers = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().starts_with("actcache_")
        })
        .count();
    assert_eq!(leftovers, 0, "an erroring pipeline must not leak spill files");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipeline_rejects_quant_input_mismatch_gracefully() {
    // sanity on the ReconInput parser used by the CLI
    assert!(matches!(ReconInput::parse("fp"), Ok(ReconInput::Fp)));
    assert!(matches!(ReconInput::parse("quant"), Ok(ReconInput::Quant)));
    assert!(ReconInput::parse("bogus").is_err());
    // and on the spec validator
    let mut bad = spec();
    bad.heads = 3; // 16 % 3 != 0
    assert!(synthetic_block_model(&bad).is_err());
}
