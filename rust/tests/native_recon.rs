//! Native-backend integration tests — run everywhere, no artifacts and no
//! PJRT needed (the acceptance gate for the artifact-free scenario):
//!
//! * golden-fixture parity against the Python reference kernel math
//!   (`python/tests/gen_flexround_golden.py` mirrors `ref.py`; tolerance
//!   1e-5 on Ŵ),
//! * a full `Session::quantize` run over a synthetic manifest on the
//!   [`Native`] backend: MSE reduction vs the RTN init, determinism,
//!   grid-valid exports, and sequential-vs-parallel-unit agreement.

use flexround::coordinator::{Plan, Session};
use flexround::manifest::{LayerInfo, Manifest, ModelInfo, PackEntry, UnitInfo};
use flexround::recon;
use flexround::runtime::Native;
use flexround::ser::json::{self, Json};
use flexround::tensor::{minmax_scale, Tensor};
use flexround::util::rng::Pcg32;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Golden parity vs the Python reference kernel
// ---------------------------------------------------------------------------

fn f32s(v: &Json) -> Vec<f32> {
    v.arr()
        .expect("array")
        .iter()
        .map(|x| x.num().expect("number") as f32)
        .collect()
}

#[test]
fn golden_parity_with_python_reference() {
    let text = std::fs::read_to_string("tests/fixtures/flexround_golden.json")
        .expect("golden fixture (regenerate with python3 python/tests/gen_flexround_golden.py)");
    let doc = json::parse(&text).expect("fixture json");
    let cases = doc.get("cases").unwrap().arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let name = case.get("name").unwrap().str().unwrap();
        let r = case.get("rows").unwrap().usize().unwrap();
        let c = case.get("cols").unwrap().usize().unwrap();
        let b = case.get("batch").unwrap().usize().unwrap();
        let qmin = case.get("qmin").unwrap().num().unwrap() as f32;
        let qmax = case.get("qmax").unwrap().num().unwrap() as f32;
        let w = Tensor::from_f32(f32s(case.get("w").unwrap()), &[r, c]).unwrap();
        let s1 = Tensor::from_f32(f32s(case.get("s1").unwrap()), &[r, 1]).unwrap();
        let s2 = Tensor::from_f32(f32s(case.get("s2").unwrap()), &[r, c]).unwrap();
        let s3 = Tensor::from_f32(f32s(case.get("s3").unwrap()), &[r, 1]).unwrap();
        let s4 = Tensor::from_f32(f32s(case.get("s4").unwrap()), &[1, c]).unwrap();
        let zp = Tensor::from_f32(f32s(case.get("zp").unwrap()), &[r, 1]).unwrap();

        let what = recon::fq_forward(&w, &s1, Some(&s2), Some(&s3), Some(&s4), &zp, qmin, qmax)
            .unwrap();
        let codes = recon::fq_codes(&w, &s1, Some(&s2), Some(&s3), Some(&s4), &zp, qmin, qmax)
            .unwrap();
        let want_what = f32s(case.get("what").unwrap());
        let want_codes = f32s(case.get("codes").unwrap());
        for (i, (got, want)) in what.as_f32().unwrap().iter().zip(&want_what).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "{name}: Ŵ[{i}] = {got} vs reference {want}"
            );
        }
        // codes export as i32 (the bit-packable form)
        for (i, (got, want)) in codes.to_f32_vec().iter().zip(&want_codes).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "{name}: code[{i}] = {got} vs reference {want}"
            );
        }

        // fused path: Ŷ = X · Ŵᵀ
        let x = Tensor::from_f32(f32s(case.get("x").unwrap()), &[b, c]).unwrap();
        let y = x.matmul_nt(&what).unwrap();
        let want_y = f32s(case.get("y").unwrap());
        for (i, (got, want)) in y.as_f32().unwrap().iter().zip(&want_y).enumerate() {
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "{name}: Ŷ[{i}] = {got} vs reference {want}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Synthetic manifest + session over the Native backend
// ---------------------------------------------------------------------------

const BITS: u32 = 4;

fn entry(name: &str, shape: &[usize], learnable: bool) -> PackEntry {
    PackEntry { name: name.to_string(), shape: shape.to_vec(), learnable }
}

fn linear_unit(name: &str, layer: &str, rows: usize, cols: usize) -> UnitInfo {
    let mut packs = BTreeMap::new();
    packs.insert(
        "flexround.w".to_string(),
        vec![
            entry(&format!("{layer}.s1"), &[rows, 1], true),
            entry(&format!("{layer}.s2"), &[rows, cols], true),
            entry(&format!("{layer}.s3"), &[rows, 1], true),
            entry(&format!("{layer}.s4"), &[1, cols], true),
            entry(&format!("{layer}.zp"), &[rows, 1], false),
        ],
    );
    packs.insert(
        "rtn.w".to_string(),
        vec![
            entry(&format!("{layer}.s1"), &[rows, 1], false),
            entry(&format!("{layer}.zp"), &[rows, 1], false),
        ],
    );
    UnitInfo {
        name: name.to_string(),
        kind: "linear".to_string(),
        bits_override: None,
        in_shape: vec![cols],
        out_shape: vec![rows],
        act_sites: 0,
        heads: 1,
        layers: vec![LayerInfo {
            name: layer.to_string(),
            kind: "linear".to_string(),
            rows,
            cols,
            conv_shape: None,
            stride: 1,
        }],
        artifacts: BTreeMap::new(),
        packs,
    }
}

struct Fixture {
    man: Manifest,
    weights: BTreeMap<String, Tensor>,
    inits: BTreeMap<String, Tensor>,
    data: BTreeMap<String, Tensor>,
}

/// Two chained linear units (12 → 8 → 6) with FXT-style maps, FlexRound +
/// RTN packs, and per-row min/max inits — everything `Session` needs, built
/// in memory (no files, no artifacts).
fn synthetic_fixture() -> Fixture {
    let mut rng = Pcg32::seeded(1234);
    let dims = [(8usize, 12usize), (6usize, 8usize)];
    let mut weights = BTreeMap::new();
    let mut inits = BTreeMap::new();
    let mut units = Vec::new();
    for (ui, &(rows, cols)) in dims.iter().enumerate() {
        let uname = format!("u{ui}");
        let wv: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal() * 0.5).collect();
        let w = Tensor::from_f32(wv.clone(), &[rows, cols]).unwrap();
        weights.insert(format!("w/{uname}/fc"), w);
        let s1: Vec<f32> = (0..rows)
            .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], BITS, true).0)
            .collect();
        for method in ["flexround", "rtn"] {
            let pfx = format!("init/{uname}/{method}/b{BITS}");
            inits.insert(
                format!("{pfx}/fc.s1"),
                Tensor::from_f32(s1.clone(), &[rows, 1]).unwrap(),
            );
            inits.insert(format!("{pfx}/fc.zp"), Tensor::zeros(&[rows, 1]));
        }
        let pfx = format!("init/{uname}/flexround/b{BITS}");
        inits.insert(format!("{pfx}/fc.s2"), Tensor::full(&[rows, cols], 1.0));
        inits.insert(format!("{pfx}/fc.s3"), Tensor::full(&[rows, 1], 1.0));
        inits.insert(format!("{pfx}/fc.s4"), Tensor::full(&[1, cols], 1.0));
        units.push(linear_unit(&uname, "fc", rows, cols));
    }

    let calib_n = 64;
    let calib = Tensor::from_f32(
        (0..calib_n * dims[0].1).map(|_| rng.next_normal()).collect(),
        &[calib_n, dims[0].1],
    )
    .unwrap();
    let mut data = BTreeMap::new();
    let mut datasets = BTreeMap::new();
    datasets.insert("calib_x".to_string(), vec![calib_n, dims[0].1]);
    data.insert("calib_x".to_string(), calib);

    let mut lr_default = BTreeMap::new();
    lr_default.insert("flexround".to_string(), 4e-3);
    let model = ModelInfo {
        name: "m".to_string(),
        kind: "cnn".to_string(),
        task: "synthetic".to_string(),
        fp_metric: BTreeMap::new(),
        symmetric: true,
        per_channel: true,
        bits_w: vec![BITS],
        abits: vec![8],
        methods_w: vec!["rtn".to_string(), "flexround".to_string()],
        methods_wa: vec![],
        calib_n,
        calib_batch: 16,
        seq: None,
        units,
        embed_artifact: None,
        head_artifacts: BTreeMap::new(),
        weights_file: "unused.fxt".to_string(),
        init_file: "unused.fxt".to_string(),
        data_file: "unused.fxt".to_string(),
        datasets,
        iters_default: 0, // plan.iters == 0 → no learning (RTN-at-init runs)
        lr_default,
        drop_p_default: 0.0,
    };
    let mut models = BTreeMap::new();
    models.insert("m".to_string(), model);
    let man = Manifest {
        dir: std::env::temp_dir(),
        calib_batch: 16,
        models,
    };
    Fixture { man, weights, inits, data }
}

fn open<'a>(fx: &'a Fixture, backend: &'a Native) -> Session<'a> {
    Session {
        backend,
        man: &fx.man,
        model: fx.man.model("m").unwrap(),
        weights: fx.weights.clone(),
        inits: fx.inits.clone(),
        data: fx.data.clone(),
    }
}

fn full_batch_mse(sess: &Session, r: &flexround::coordinator::QuantResult) -> f64 {
    let calib = sess.dataset("calib_x").unwrap();
    let q = sess.forward_q(r, calib).unwrap();
    let fp = sess.forward_fp(calib).unwrap();
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for (a, b) in q.iter().zip(&fp) {
        acc += a.mse(b).unwrap() as f64 * a.len() as f64;
        n += a.len();
    }
    acc / n as f64
}

#[test]
fn native_session_reduces_mse_vs_rtn_init() {
    let fx = synthetic_fixture();
    let backend = Native::with_workers(2);
    let sess = open(&fx, &backend);

    // RTN-at-init baseline: zero learning iterations (iters_default = 0).
    let base_plan = Plan::new("m", "flexround");
    let base = sess.quantize(&base_plan).unwrap();
    let mse_rtn = full_batch_mse(&sess, &base);

    let mut plan = Plan::new("m", "flexround");
    plan.iters = 150;
    let r = sess.quantize(&plan).unwrap();
    for u in &r.units {
        assert!(u.first_loss.is_finite() && u.final_loss.is_finite(), "unit {}", u.unit);
    }
    assert_eq!(r.recon_steps, 300, "150 iters × 2 units");
    let mse_learned = full_batch_mse(&sess, &r);
    assert!(
        mse_learned < mse_rtn,
        "native reconstruction should beat the RTN init: {mse_rtn:.6} → {mse_learned:.6}"
    );
}

#[test]
fn native_session_is_deterministic() {
    let fx = synthetic_fixture();
    let backend = Native::with_workers(2);
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 20;
    let a = sess.quantize(&plan).unwrap();
    let b = sess.quantize(&plan).unwrap();
    for (ua, ub) in a.units.iter().zip(&b.units) {
        assert_eq!(ua.final_loss, ub.final_loss, "unit {} not deterministic", ua.unit);
        for (pa, pb) in ua.params.iter().zip(&ub.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }
}

#[test]
fn native_export_codes_lie_on_grid() {
    let fx = synthetic_fixture();
    let backend = Native::new();
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 30;
    let r = sess.quantize(&plan).unwrap();
    for (unit, st) in sess.model.units.iter().zip(&r.units) {
        for (what, codes) in sess.export_qw(unit, st).unwrap() {
            assert_eq!(what.len(), codes.len());
            for x in codes.to_f32_vec() {
                assert!((-8.0..=7.0).contains(&x), "code {x} outside 4-bit grid");
                assert!((x - x.round()).abs() < 1e-4, "code {x} not integral");
            }
        }
    }
}

#[test]
fn parallel_units_agree_with_sequential_on_first_unit() {
    // The first unit sees identical inputs (X̃ = X) under both schedules and
    // the same forked rng stream, so its learned parameters must match
    // bit-for-bit; later units differ (FP vs quantized inputs) by design.
    let fx = synthetic_fixture();
    let backend = Native::with_workers(4);
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 25;
    let seq = sess.quantize(&plan).unwrap();
    plan.parallel_units = true;
    let par = sess.quantize(&plan).unwrap();
    assert_eq!(seq.units.len(), par.units.len());
    assert_eq!(seq.units[0].final_loss, par.units[0].final_loss);
    for (a, b) in seq.units[0].params.iter().zip(&par.units[0].params) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
    for u in &par.units {
        assert!(u.final_loss.is_finite());
    }
}

#[test]
fn rtn_runs_without_learning() {
    let fx = synthetic_fixture();
    let backend = Native::new();
    let sess = open(&fx, &backend);
    let plan = Plan::new("m", "rtn");
    let r = sess.quantize(&plan).unwrap();
    assert_eq!(r.recon_steps, 0);
    for u in &r.units {
        assert!(u.rtn_like());
        assert!(u.first_loss.is_nan(), "rtn has no reconstruction loss");
    }
    // the quantized forward still runs end to end
    let out = sess.forward_q(&r, sess.dataset("calib_x").unwrap()).unwrap();
    assert_eq!(out.len(), 4); // 64 rows / batch 16
    assert_eq!(out[0].shape(), &[16, 6]);
}
