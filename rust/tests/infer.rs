//! Quantized-inference integration tests — the packed-weight acceptance
//! gate, artifact-free and PJRT-free:
//!
//! * golden-fixture parity: the fused packed GEMM must match the f32
//!   `X · Ŵᵀ` path within 1e-4 on `tests/fixtures/flexround_golden.json`
//!   (same fixture the reconstruction math is pinned against);
//! * the full deployment round trip: `Session::quantize` → packed `.fxt`
//!   artifact on disk → reload with **no FP weights available** → batched
//!   `Engine::forward` matches the generic f32 quantized chain within 1e-4.

use flexround::coordinator::{Plan, Session};
use flexround::infer::{Engine, PackedMatrix, PackedModel};
use flexround::manifest::{LayerInfo, Manifest, ModelInfo, PackEntry, UnitInfo};
use flexround::recon;
use flexround::runtime::Native;
use flexround::ser::json::{self, Json};
use flexround::tensor::{minmax_scale, Tensor};
use flexround::util::rng::Pcg32;
use std::collections::BTreeMap;

fn f32s(v: &Json) -> Vec<f32> {
    v.arr()
        .expect("array")
        .iter()
        .map(|x| x.num().expect("number") as f32)
        .collect()
}

/// Bits for a `[qmin, qmax]` grid that spans a power of two.
fn grid_bits(qmin: f32, qmax: f32) -> u32 {
    let span = (qmax - qmin + 1.0) as u32;
    assert!(span.is_power_of_two(), "fixture grid span {span} not a power of two");
    span.trailing_zeros()
}

#[test]
fn golden_fixture_fused_gemm_parity() {
    let text = std::fs::read_to_string("tests/fixtures/flexround_golden.json")
        .expect("golden fixture (regenerate with python3 python/tests/gen_flexround_golden.py)");
    let doc = json::parse(&text).expect("fixture json");
    let cases = doc.get("cases").unwrap().arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let name = case.get("name").unwrap().str().unwrap();
        let r = case.get("rows").unwrap().usize().unwrap();
        let c = case.get("cols").unwrap().usize().unwrap();
        let b = case.get("batch").unwrap().usize().unwrap();
        let qmin = case.get("qmin").unwrap().num().unwrap() as f32;
        let qmax = case.get("qmax").unwrap().num().unwrap() as f32;
        let bits = grid_bits(qmin, qmax);
        let w = Tensor::from_f32(f32s(case.get("w").unwrap()), &[r, c]).unwrap();
        let s1 = Tensor::from_f32(f32s(case.get("s1").unwrap()), &[r, 1]).unwrap();
        let s2 = Tensor::from_f32(f32s(case.get("s2").unwrap()), &[r, c]).unwrap();
        let s3 = Tensor::from_f32(f32s(case.get("s3").unwrap()), &[r, 1]).unwrap();
        let s4 = Tensor::from_f32(f32s(case.get("s4").unwrap()), &[1, c]).unwrap();
        let zp = Tensor::from_f32(f32s(case.get("zp").unwrap()), &[r, 1]).unwrap();

        let what = recon::fq_forward(&w, &s1, Some(&s2), Some(&s3), Some(&s4), &zp, qmin, qmax)
            .unwrap();
        let codes = recon::fq_codes(&w, &s1, Some(&s2), Some(&s3), Some(&s4), &zp, qmin, qmax)
            .unwrap();
        let packed =
            PackedMatrix::from_tensors(&codes, &s1, &zp, bits, qmin as i32).unwrap();

        // the packed store reproduces Ŵ itself…
        let d = packed.dequantize().unwrap().max_abs_diff(&what).unwrap();
        assert!(d <= 1e-5, "{name}: dequantized packed weights drift {d} from Ŵ");

        // …and the fused kernel reproduces the f32 GEMM within 1e-4
        let x = Tensor::from_f32(f32s(case.get("x").unwrap()), &[b, c]).unwrap();
        let want = x.matmul_nt(&what).unwrap();
        for workers in [1usize, 4] {
            let got = flexround::infer::kernels::gemm_fused(&x, &packed, workers).unwrap();
            assert_eq!(got.shape(), want.shape());
            let d = got.max_abs_diff(&want).unwrap();
            let tol = 1e-4 * (1.0 + want.abs_max());
            assert!(
                d <= tol,
                "{name}: fused packed GEMM (workers={workers}) max|Δ| {d} > {tol}"
            );
        }
        let got = flexround::infer::kernels::gemm_ref(&x, &packed).unwrap();
        let d = got.max_abs_diff(&want).unwrap();
        assert!(d <= 1e-4 * (1.0 + want.abs_max()), "{name}: reference kernel drift {d}");
    }
}

// ---------------------------------------------------------------------------
// End-to-end: quantize → pack → save → reload (no FP weights) → serve math
// ---------------------------------------------------------------------------

const BITS: u32 = 4;

fn entry(name: &str, shape: &[usize], learnable: bool) -> PackEntry {
    PackEntry { name: name.to_string(), shape: shape.to_vec(), learnable }
}

fn linear_unit(name: &str, layer: &str, rows: usize, cols: usize) -> UnitInfo {
    let mut packs = BTreeMap::new();
    packs.insert(
        "flexround.w".to_string(),
        vec![
            entry(&format!("{layer}.s1"), &[rows, 1], true),
            entry(&format!("{layer}.s2"), &[rows, cols], true),
            entry(&format!("{layer}.s3"), &[rows, 1], true),
            entry(&format!("{layer}.s4"), &[1, cols], true),
            entry(&format!("{layer}.zp"), &[rows, 1], false),
        ],
    );
    UnitInfo {
        name: name.to_string(),
        kind: "linear".to_string(),
        bits_override: None,
        in_shape: vec![cols],
        out_shape: vec![rows],
        act_sites: 0,
        heads: 1,
        layers: vec![LayerInfo {
            name: layer.to_string(),
            kind: "linear".to_string(),
            rows,
            cols,
            conv_shape: None,
            stride: 1,
        }],
        artifacts: BTreeMap::new(),
        packs,
    }
}

struct Fixture {
    man: Manifest,
    weights: BTreeMap<String, Tensor>,
    inits: BTreeMap<String, Tensor>,
    data: BTreeMap<String, Tensor>,
}

/// Two chained linear units (12 → 8 → 6), biases included, built in memory —
/// the same shape of fixture `tests/native_recon.rs` uses.
fn synthetic_fixture() -> Fixture {
    let mut rng = Pcg32::seeded(4321);
    let dims = [(8usize, 12usize), (6usize, 8usize)];
    let mut weights = BTreeMap::new();
    let mut inits = BTreeMap::new();
    let mut units = Vec::new();
    for (ui, &(rows, cols)) in dims.iter().enumerate() {
        let uname = format!("u{ui}");
        let wv: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal() * 0.5).collect();
        let w = Tensor::from_f32(wv.clone(), &[rows, cols]).unwrap();
        weights.insert(format!("w/{uname}/fc"), w);
        let bias: Vec<f32> = (0..rows).map(|_| rng.next_normal() * 0.1).collect();
        weights.insert(format!("b/{uname}/fc"), Tensor::from_f32(bias, &[rows]).unwrap());
        let s1: Vec<f32> = (0..rows)
            .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], BITS, true).0)
            .collect();
        let pfx = format!("init/{uname}/flexround/b{BITS}");
        inits.insert(format!("{pfx}/fc.s1"), Tensor::from_f32(s1, &[rows, 1]).unwrap());
        inits.insert(format!("{pfx}/fc.zp"), Tensor::zeros(&[rows, 1]));
        inits.insert(format!("{pfx}/fc.s2"), Tensor::full(&[rows, cols], 1.0));
        inits.insert(format!("{pfx}/fc.s3"), Tensor::full(&[rows, 1], 1.0));
        inits.insert(format!("{pfx}/fc.s4"), Tensor::full(&[1, cols], 1.0));
        units.push(linear_unit(&uname, "fc", rows, cols));
    }

    let calib_n = 64;
    let calib = Tensor::from_f32(
        (0..calib_n * dims[0].1).map(|_| rng.next_normal()).collect(),
        &[calib_n, dims[0].1],
    )
    .unwrap();
    let mut data = BTreeMap::new();
    let mut datasets = BTreeMap::new();
    datasets.insert("calib_x".to_string(), vec![calib_n, dims[0].1]);
    data.insert("calib_x".to_string(), calib);

    let mut lr_default = BTreeMap::new();
    lr_default.insert("flexround".to_string(), 4e-3);
    let model = ModelInfo {
        name: "m".to_string(),
        kind: "cnn".to_string(),
        task: "synthetic".to_string(),
        fp_metric: BTreeMap::new(),
        symmetric: true,
        per_channel: true,
        bits_w: vec![BITS],
        abits: vec![8],
        methods_w: vec!["flexround".to_string()],
        methods_wa: vec![],
        calib_n,
        calib_batch: 16,
        seq: None,
        units,
        embed_artifact: None,
        head_artifacts: BTreeMap::new(),
        weights_file: "unused.fxt".to_string(),
        init_file: "unused.fxt".to_string(),
        data_file: "unused.fxt".to_string(),
        datasets,
        iters_default: 0,
        lr_default,
        drop_p_default: 0.0,
    };
    let mut models = BTreeMap::new();
    models.insert("m".to_string(), model);
    let man = Manifest { dir: std::env::temp_dir(), calib_batch: 16, models };
    Fixture { man, weights, inits, data }
}

fn open<'a>(fx: &'a Fixture, backend: &'a Native) -> Session<'a> {
    Session {
        backend,
        man: &fx.man,
        model: fx.man.model("m").unwrap(),
        weights: fx.weights.clone(),
        inits: fx.inits.clone(),
        data: fx.data.clone(),
    }
}

/// The generic (non-packed) quantized chain, chunk by chunk.
fn generic_forward_q(
    sess: &Session,
    r: &flexround::coordinator::QuantResult,
    xs: &Tensor,
) -> Vec<Tensor> {
    let mut chunks = sess.first_unit_inputs(xs).unwrap();
    for (unit, st) in sess.model.units.iter().zip(&r.units) {
        chunks = sess.advance_q(unit, st, &r.plan.mode, &chunks).unwrap();
    }
    chunks
}

#[test]
fn packed_roundtrip_serves_without_fp_weights() {
    let fx = synthetic_fixture();
    let backend = Native::with_workers(2);
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 40;
    let result = sess.quantize(&plan).unwrap();

    // save the packed artifact, then reload it from disk — the loaded model
    // never touches `sess.weights` again
    let pm = sess.packed_model(&result).unwrap();
    assert!(pm.packed_bytes() < pm.fp32_bytes(), "4-bit pack must shrink the weights");
    let path = std::env::temp_dir()
        .join(format!("flexround_infer_roundtrip_{}.fxt", std::process::id()));
    pm.save(&path).unwrap();
    let loaded = PackedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(pm, loaded);

    let engine = Engine::new(loaded, 2);
    let calib = sess.dataset("calib_x").unwrap();
    let want = generic_forward_q(&sess, &result, calib);
    let chunks = sess.first_unit_inputs(calib).unwrap();
    assert_eq!(want.len(), chunks.len());
    for (chunk, want) in chunks.iter().zip(&want) {
        let got = engine.forward(chunk).unwrap();
        assert_eq!(got.shape(), want.shape());
        let d = got.max_abs_diff(want).unwrap();
        let tol = 1e-4 * (1.0 + want.abs_max());
        assert!(d <= tol, "packed engine vs f32 quantized chain: max|Δ| {d} > {tol}");
    }

    // `Session::forward_q` takes the same fast path and must agree too
    let via_session = sess.forward_q(&result, calib).unwrap();
    for (a, b) in via_session.iter().zip(&want) {
        let d = a.max_abs_diff(b).unwrap();
        assert!(d <= 1e-4 * (1.0 + b.abs_max()), "forward_q fast path drift {d}");
    }
}

#[test]
fn packed_export_rejects_wa_mode() {
    let fx = synthetic_fixture();
    let backend = Native::new();
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 0;
    let mut result = sess.quantize(&plan).unwrap();
    result.plan.mode = "wa".to_string();
    assert!(sess.packed_model(&result).is_err());
}
