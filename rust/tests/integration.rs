//! Integration tests over the real AOT artifacts (runtime + coordinator +
//! eval).  Each test self-skips when `make artifacts` has not produced the
//! model it needs (or when the vendored `xla` stub cannot create a PJRT
//! client), so `cargo test` is green at any build stage; CI/full runs with
//! real bindings exercise everything.
#![cfg(feature = "pjrt")]

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::runtime::Pjrt;
use flexround::tensor::Tensor;
use flexround::{eval, quant};
use std::path::Path;

fn load(model: &str) -> Option<(Manifest, Pjrt)> {
    let art = Path::new("artifacts");
    let man = Manifest::load(art).ok()?;
    if !man.models.contains_key(model) {
        eprintln!("skip: model {model} not in manifest yet");
        return None;
    }
    // all artifacts present?
    let mi = &man.models[model];
    for u in &mi.units {
        for f in u.artifacts.values() {
            if !man.artifact_path(f).exists() {
                eprintln!("skip: artifact {f} missing");
                return None;
            }
        }
    }
    let rt = Pjrt::new(art).ok()?;
    Some((man, rt))
}

#[test]
fn fp_chain_is_deterministic() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let calib = sess.dataset("calib_x").unwrap();
    let x = calib.slice_rows(0, sess.model.calib_batch).unwrap();
    let a = sess.forward_fp(&x).unwrap();
    let b = sess.forward_fp(&x).unwrap();
    assert_eq!(a.len(), b.len());
    for (p, q) in a.iter().zip(&b) {
        assert_eq!(p.as_f32().unwrap(), q.as_f32().unwrap());
    }
    // CNN chain ends at logits
    assert_eq!(a[0].shape()[1], 10);
}

#[test]
fn rtn_8bit_close_to_fp() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut plan = Plan::new("tinymobilenet", "rtn");
    plan.bits_w = 8;
    plan.calib_n = 64;
    let r = sess.quantize(&plan).unwrap();
    let q = eval::eval_cnn(&sess, &r).unwrap();
    let fp = eval::eval_cnn_fp(&sess).unwrap();
    assert!(
        (fp["top1"] - q["top1"]).abs() < 0.03,
        "8-bit RTN should be near-lossless: fp {} vs q {}",
        fp["top1"],
        q["top1"]
    );
}

#[test]
fn flexround_reduces_reconstruction_loss() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut plan = Plan::new("tinymobilenet", "flexround");
    plan.bits_w = 3;
    plan.iters = 60;
    plan.calib_n = 128;
    let r = sess.quantize(&plan).unwrap();
    let mut improved = 0;
    for u in &r.units {
        assert!(u.final_loss.is_finite(), "unit {} loss not finite", u.unit);
        if u.final_loss < u.first_loss {
            improved += 1;
        }
    }
    assert!(
        improved * 2 >= r.units.len(),
        "reconstruction should reduce loss on most units ({improved}/{})",
        r.units.len()
    );
}

#[test]
fn flexround_beats_rtn_at_low_bits() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut rtn_plan = Plan::new("tinymobilenet", "rtn");
    rtn_plan.bits_w = 3;
    rtn_plan.calib_n = 64;
    let rtn_m = eval::eval_cnn(&sess, &sess.quantize(&rtn_plan).unwrap()).unwrap();
    let mut fx = Plan::new("tinymobilenet", "flexround");
    fx.bits_w = 3;
    fx.iters = 150;
    fx.calib_n = 256;
    let fx_m = eval::eval_cnn(&sess, &sess.quantize(&fx).unwrap()).unwrap();
    assert!(
        fx_m["top1"] >= rtn_m["top1"] - 1e-9,
        "FlexRound {} should beat RTN {} at 3-bit",
        fx_m["top1"],
        rtn_m["top1"]
    );
}

#[test]
fn quantize_is_seed_deterministic() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut plan = Plan::new("tinymobilenet", "flexround");
    plan.bits_w = 4;
    plan.iters = 10;
    plan.calib_n = 64;
    let a = sess.quantize(&plan).unwrap();
    let b = sess.quantize(&plan).unwrap();
    for (ua, ub) in a.units.iter().zip(&b.units) {
        assert_eq!(ua.final_loss, ub.final_loss, "unit {} not deterministic", ua.unit);
        for (pa, pb) in ua.params.iter().zip(&ub.params) {
            assert_eq!(pa.as_f32().unwrap(), pb.as_f32().unwrap());
        }
    }
}

#[test]
fn qw_export_codes_lie_on_grid() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut plan = Plan::new("tinymobilenet", "flexround");
    plan.bits_w = 4;
    plan.iters = 20;
    plan.calib_n = 64;
    let r = sess.quantize(&plan).unwrap();
    let unit = &sess.model.units[1];
    let st = &r.units[1];
    for (what, codes) in sess.export_qw(unit, st).unwrap() {
        let c = codes.to_f32_vec();
        for &x in &c {
            assert!((-8.0..=7.0).contains(&x), "code {x} outside 4-bit grid");
            assert!((x - x.round()).abs() < 1e-4, "code {x} not integral");
        }
        assert_eq!(what.len(), codes.len());
    }
    // grid-shift analysis runs and reports sane fractions
    for gs in quant::grid_shifts(&sess, unit, st).unwrap() {
        assert!(gs.aggressive_frac <= gs.shifted_frac);
        assert!(gs.shifted_frac <= 1.0);
    }
}

#[test]
fn wa_mode_runs_with_qdrop_and_brecq_settings() {
    let Some((man, rt)) = load("tinyresnet_a") else { return };
    let sess = Session::open(&rt, &man, "tinyresnet_a").unwrap();
    for drop_p in [0.0, 0.5] {
        let mut plan = Plan::new("tinyresnet_a", "flexround");
        plan.mode = "wa".into();
        plan.bits_w = 4;
        plan.abits = 4;
        plan.drop_p = drop_p;
        plan.iters = 15;
        plan.calib_n = 64;
        let r = sess.quantize(&plan).unwrap();
        let m = eval::eval_cnn(&sess, &r).unwrap();
        assert!(m["top1"] > 0.05, "W4A4 drop_p={drop_p} collapsed: {}", m["top1"]);
    }
}

#[test]
fn decoder_ppl_pipeline() {
    let Some((man, rt)) = load("dec_small_lma") else { return };
    let sess = Session::open(&rt, &man, "dec_small_lma").unwrap();
    let fp = eval::eval_ppl(&sess, None, "eval_x").unwrap();
    assert!(fp > 1.0 && fp < 100.0, "fp ppl {fp}");
    let mut plan = Plan::new("dec_small_lma", "flexround");
    plan.mode = "wa".into();
    plan.bits_w = 8;
    plan.drop_p = 0.5;
    plan.iters = 40;
    let r = sess.quantize(&plan).unwrap();
    let q = eval::eval_ppl(&sess, Some(&r), "eval_x").unwrap();
    assert!(q < fp * 1.5, "8-bit PTQ ppl {q} should stay near fp {fp}");
}

#[test]
fn encoder_eval_pipeline() {
    let Some((man, rt)) = load("enc_small") else { return };
    let sess = Session::open(&rt, &man, "enc_small").unwrap();
    let fp = eval::eval_encoder(&sess, None).unwrap();
    // enc_small is deliberately tiny (d=48, 2 layers, multi-task): individual
    // tasks land between ~0.53 (entail) and ~0.62 (para).  The pipeline check
    // is above-chance on every task and clearly-learned on the best one —
    // method *orderings* (the paper's claim) are asserted by the sweeps.
    let mut best = 0.0f64;
    for task in eval::NLU_TASKS {
        assert!(fp[task] > 0.5, "fp {task} acc {} at/below chance", fp[task]);
        best = best.max(fp[task]);
    }
    assert!(best > 0.58, "no NLU task clearly learned (best {best})");
    assert!(fp.contains_key("span_em"));
}

#[test]
fn llm_mc_scoring_shapes() {
    let Some((man, rt)) = load("llm_mini") else { return };
    let sess = Session::open(&rt, &man, "llm_mini").unwrap();
    let acc = eval::eval_mc(&sess, None, "copy").unwrap();
    assert!(acc > 0.3, "fp copy-task accuracy {acc} should beat 25% chance");
}

#[test]
fn per_channel_init_shapes() {
    let Some((man, rt)) = load("llm_mini") else { return };
    let sess = Session::open(&rt, &man, "llm_mini").unwrap();
    let unit = &sess.model.units[0];
    let (params, entries) = sess.init_params(unit, "flexround", "w", 8, 8).unwrap();
    let s1 = entries.iter().position(|e| e.name == "wq.s1").unwrap();
    assert_eq!(params[s1].shape(), &[128, 1]);
    // per-channel zero-points differ across rows for asymmetric weights
    let zp = entries.iter().position(|e| e.name == "wq.zp").unwrap();
    let zpv = params[zp].as_f32().unwrap();
    assert!(zpv.iter().any(|&z| z != zpv[0]), "per-channel zp should vary");
}

#[test]
fn calib_n_rounds_to_batch_multiple() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    let mut plan = Plan::new("tinymobilenet", "rtn");
    plan.bits_w = 8;
    plan.calib_n = 33; // not a multiple of 32 → rounds down to 32
    let r = sess.quantize(&plan).unwrap();
    assert_eq!(r.units.len(), sess.model.units.len());
}

#[test]
fn missing_artifact_is_clean_error() {
    let art = Path::new("artifacts");
    let Ok(_man) = Manifest::load(art) else { return };
    let Ok(rt) = Pjrt::new(art) else { return }; // stub xla: no client
    let err = rt.load("definitely_missing.hlo.txt");
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("definitely_missing"));
}

#[test]
fn dataset_tensors_match_manifest_shapes() {
    let Some((man, rt)) = load("tinymobilenet") else { return };
    let sess = Session::open(&rt, &man, "tinymobilenet").unwrap();
    for (name, shape) in &sess.model.datasets {
        let t: &Tensor = sess.dataset(name).unwrap();
        assert_eq!(t.shape(), &shape[..], "dataset {name}");
    }
}
