//! Cross-module substrate tests + randomized property tests that need no
//! artifacts (run everywhere, including before `make artifacts`).

use flexround::config::Config;
use flexround::ser::json::{self, Json};
use flexround::tensor::{minmax_scale, qrange, rtn, rtn_codes, Tensor};
use flexround::util::prop::{gen_weights, Prop};
use flexround::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// Quantizer invariants (mirror the hypothesis suite on the Python side)
// ---------------------------------------------------------------------------

#[test]
fn prop_rtn_error_bounded_by_half_step() {
    Prop::new("rtn error ≤ s1/2 inside range").cases(300).check(|rng| {
        let n = 1 + rng.below(200) as usize;
        let w = gen_weights(rng, n);
        let bits = 2 + rng.below(7);
        let (qmin, qmax) = qrange(bits, true);
        let (s1, zp) = minmax_scale(&w, bits, true);
        let q = rtn(&w, s1, zp, qmin, qmax);
        for (x, y) in w.iter().zip(&q) {
            // symmetric minmax clips at most the single extreme negative value
            let n_ideal = x / s1;
            if n_ideal >= qmin && n_ideal <= qmax {
                if (x - y).abs() > s1 / 2.0 + 1e-5 {
                    return Err(format!("|{x} - {y}| > {}/2", s1));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rtn_asymmetric_covers_range() {
    Prop::new("asym rtn error ≤ s1").cases(300).check(|rng| {
        let n = 2 + rng.below(100) as usize;
        let w = gen_weights(rng, n);
        let bits = 4 + rng.below(5);
        let (qmin, qmax) = qrange(bits, false);
        let (s1, zp) = minmax_scale(&w, bits, false);
        let q = rtn(&w, s1, zp, qmin, qmax);
        for (x, y) in w.iter().zip(&q) {
            if (x - y).abs() > s1 + 1e-4 {
                return Err(format!("asym err |{x}-{y}| > step {s1}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codes_monotone_in_weights() {
    Prop::new("rtn codes monotone").cases(200).check(|rng| {
        let n = 2 + rng.below(50) as usize;
        let mut w = gen_weights(rng, n);
        w.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (s1, zp) = minmax_scale(&w, 4, true);
        let codes = rtn_codes(&w, s1, zp, -8.0, 7.0);
        for i in 1..codes.len() {
            if codes[i] < codes[i - 1] {
                return Err("codes not monotone".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_docs() {
    Prop::new("json roundtrip").cases(200).check(|rng| {
        let doc = random_json(rng, 0);
        let text = json::to_string(&doc, if rng.below(2) == 0 { 0 } else { 2 });
        let back = json::parse(&text).map_err(|e| e.to_string())?;
        if !json_eq(&doc, &back) {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
    match if depth > 3 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.next_f32() * 2000.0 - 1000.0) as f64),
        3 => Json::Str(random_string(rng)),
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth + 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}_{}", random_string(rng)), random_json(rng, depth + 1)))
                .collect(),
        ),
    }
}

fn random_string(rng: &mut Pcg32) -> String {
    let chars = ["a", "β", "\"", "\\", "\n", "x", "0", "é", "~", "\t"];
    (0..rng.below(8)).map(|_| chars[rng.below(chars.len() as u32) as usize]).collect()
}

fn json_eq(a: &Json, b: &Json) -> bool {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => (x - y).abs() <= 1e-9 * x.abs().max(1.0),
        (Json::Arr(x), Json::Arr(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| json_eq(p, q))
        }
        (Json::Obj(x), Json::Obj(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|((k1, v1), (k2, v2))| k1 == k2 && json_eq(v1, v2))
        }
        _ => a == b,
    }
}

#[test]
fn prop_fxt_roundtrip_random_tensors() {
    use std::collections::BTreeMap;
    Prop::new("fxt roundtrip").cases(60).check(|rng| {
        let mut m = BTreeMap::new();
        for i in 0..1 + rng.below(6) {
            let ndim = rng.below(4) as usize;
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(5) as usize).collect();
            let n: usize = shape.iter().product::<usize>().max(1);
            let t = if rng.below(2) == 0 {
                Tensor::from_f32((0..n).map(|_| rng.next_normal()).collect(), &shape).unwrap()
            } else {
                Tensor::from_i32((0..n).map(|_| rng.next_u32() as i32).collect(), &shape).unwrap()
            };
            m.insert(format!("t{i}/{}", random_string(rng)), t);
        }
        let path = std::env::temp_dir().join(format!("fxt_prop_{}.fxt", rng.next_u32()));
        flexround::ser::fxt::write(&path, &m).map_err(|e| e.to_string())?;
        let back = flexround::ser::fxt::read(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if back != m {
            return Err("fxt mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_config_overrides_take_precedence() {
    Prop::new("config layering").cases(100).check(|rng| {
        let base = rng.below(1000);
        let over = rng.below(1000);
        let mut c = Config::new();
        c.load_str(&format!("[s]\nk = {base}\n")).map_err(|e| e.to_string())?;
        c.set_override(&format!("s.k={over}")).map_err(|e| e.to_string())?;
        if c.usize("s.k", 0) != over as usize {
            return Err("override lost".into());
        }
        Ok(())
    });
}

#[test]
fn bleu_identity_dominates() {
    use flexround::eval::bleu::bleu4;
    Prop::new("bleu(x,x) ≥ bleu(y,x)").cases(150).check(|rng| {
        let n = 5 + rng.below(10) as usize;
        let x: Vec<i32> = (0..n).map(|_| rng.below(20) as i32).collect();
        let mut y = x.clone();
        let k = rng.below(n as u32) as usize;
        y[k] = (y[k] + 1 + rng.below(5) as i32) % 20;
        if bleu4(&x, &x) + 1e-9 < bleu4(&y, &x) {
            return Err("identity not maximal".into());
        }
        Ok(())
    });
}

#[test]
fn pool_matches_serial_reference() {
    use flexround::util::pool::par_map;
    let items: Vec<Vec<f32>> = (0..40)
        .map(|i| {
            let mut r = Pcg32::seeded(i);
            gen_weights(&mut r, 64)
        })
        .collect();
    let par = par_map(4, &items, |_, w| {
        let (s1, zp) = minmax_scale(w, 4, true);
        rtn(w, s1, zp, -8.0, 7.0)
    });
    for (i, w) in items.iter().enumerate() {
        let (s1, zp) = minmax_scale(w, 4, true);
        assert_eq!(par[i], rtn(w, s1, zp, -8.0, 7.0));
    }
}

#[test]
fn tensor_slice_gather_consistency() {
    Prop::new("gather(i..j) == slice(i,j)").cases(100).check(|rng| {
        let rows = 2 + rng.below(20) as usize;
        let cols = 1 + rng.below(8) as usize;
        let t = Tensor::from_f32(gen_weights(rng, rows * cols), &[rows, cols]).unwrap();
        let lo = rng.below(rows as u32) as usize;
        let hi = lo + rng.below((rows - lo + 1) as u32) as usize;
        let idx: Vec<usize> = (lo..hi).collect();
        let a = t.slice_rows(lo, hi).map_err(|e| e.to_string())?;
        let b = t.gather_rows(&idx).map_err(|e| e.to_string())?;
        if a != b {
            return Err("slice != gather".into());
        }
        Ok(())
    });
}
