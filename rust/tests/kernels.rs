//! Kernel-parity suite: the blocked `linalg` core against the retained
//! naive oracles, bit-for-bit, across every orientation the repo uses —
//! plus the fused dequant path at all packed bit-widths and the batch-1
//! gemv dispatch (DESIGN.md §Compute-Kernels).
//!
//! These pins are exact (`==`, not tolerance): every kernel keeps one
//! accumulator per output element with the contraction index ascending, so
//! blocked ≡ naive, serial ≡ parallel, and gemv ≡ batched-row hold by
//! construction.  `verify.sh` runs this file as its fast kernel smoke gate.

use flexround::infer::kernels::{gemm_fused, gemm_fused_rowwise, gemm_ref};
use flexround::infer::PackedMatrix;
use flexround::linalg::{self, Dispatch, PAR_FLOPS_MIN};
use flexround::tensor::{qrange, Tensor};
use flexround::util::prop::Prop;
use flexround::util::rng::Pcg32;

fn randt(rng: &mut Pcg32, rows: usize, cols: usize) -> Tensor {
    Tensor::from_f32((0..rows * cols).map(|_| rng.next_normal()).collect(), &[rows, cols])
        .expect("random tensor")
}

fn random_packed(rng: &mut Pcg32, rows: usize, cols: usize, bits: u32) -> PackedMatrix {
    let (qmin, qmax) = qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
    let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
    let zp: Vec<f32> = (0..rows).map(|_| rng.below(3) as f32 - 1.0).collect();
    PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).expect("pack")
}

#[test]
fn blocked_gemms_match_naive_oracles_bitwise() {
    // random dims 1..=40 deliberately straddle the 4×8 tile in every way:
    // full tiles, ragged row edges, ragged column edges, sub-tile problems
    Prop::new("linalg::gemm_* ≡ naive oracles").cases(120).check(|rng| {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(40) as usize;
        let r = 1 + rng.below(40) as usize;
        let a = randt(rng, m, k);
        let bt = randt(rng, r, k);
        let nt = a.matmul_nt_with(&bt, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let nt_ref = linalg::gemm_nt_ref(
            a.as_f32().map_err(|e| e.to_string())?,
            bt.as_f32().map_err(|e| e.to_string())?,
            m,
            k,
            r,
        );
        if nt.as_f32().map_err(|e| e.to_string())? != nt_ref.as_slice() {
            return Err(format!("NT {m}×{k}·({r}×{k})ᵀ drifted from the naive oracle"));
        }
        let bn = randt(rng, k, r);
        let nn = a.matmul_nn_with(&bn, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let nn_ref = linalg::gemm_nn_ref(
            a.as_f32().map_err(|e| e.to_string())?,
            bn.as_f32().map_err(|e| e.to_string())?,
            m,
            k,
            r,
        );
        if nn.as_f32().map_err(|e| e.to_string())? != nn_ref.as_slice() {
            return Err(format!("NN {m}×{k}·{k}×{r} drifted from the naive oracle"));
        }
        let at = randt(rng, k, m);
        let tn = at.matmul_tn_with(&bn, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let tn_ref = linalg::gemm_tn_ref(
            at.as_f32().map_err(|e| e.to_string())?,
            bn.as_f32().map_err(|e| e.to_string())?,
            k,
            m,
            r,
        );
        if tn.as_f32().map_err(|e| e.to_string())? != tn_ref.as_slice() {
            return Err(format!("TN ({k}×{m})ᵀ·{k}×{r} drifted from the naive oracle"));
        }
        Ok(())
    });
}

#[test]
fn serial_and_parallel_dispatch_are_bit_identical() {
    Prop::new("linalg serial ≡ parallel").cases(24).check(|rng| {
        // dims chosen to clear the flops threshold so the pool actually
        // fans out, with ragged edges to cross panel boundaries mid-tile
        let m = 42 + rng.below(23) as usize;
        let k = 42 + rng.below(23) as usize;
        let r = 42 + rng.below(23) as usize;
        assert!(m * k * r >= PAR_FLOPS_MIN, "{m}·{k}·{r} must clear the dispatch threshold");
        let a = randt(rng, m, k);
        let bt = randt(rng, r, k);
        let s = a.matmul_nt_with(&bt, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let p = a.matmul_nt_with(&bt, &Dispatch::new(4)).map_err(|e| e.to_string())?;
        if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
            return Err(format!("NT serial vs parallel drift at {m}×{k}×{r}"));
        }
        let bn = randt(rng, k, r);
        let s = a.matmul_nn_with(&bn, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let p = a.matmul_nn_with(&bn, &Dispatch::new(3)).map_err(|e| e.to_string())?;
        if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
            return Err(format!("NN serial vs parallel drift at {m}×{k}×{r}"));
        }
        let at = randt(rng, k, m);
        let s = at.matmul_tn_with(&bn, &Dispatch::serial()).map_err(|e| e.to_string())?;
        let p = at.matmul_tn_with(&bn, &Dispatch::new(5)).map_err(|e| e.to_string())?;
        if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
            return Err(format!("TN serial vs parallel drift at {m}×{k}×{r}"));
        }
        Ok(())
    });
}

#[test]
fn k_zero_contractions_are_well_defined_zeros() {
    // a (3, 0)·(5, 0)ᵀ contraction is empty: the answer is all zeros, not
    // an error or garbage — tile edges must tolerate empty k slices
    let a = Tensor::from_f32(vec![], &[3, 0]).unwrap();
    let b = Tensor::from_f32(vec![], &[5, 0]).unwrap();
    let nt = a.matmul_nt(&b).unwrap();
    assert_eq!(nt.shape(), &[3, 5]);
    assert_eq!(nt.as_f32().unwrap(), &[0.0; 15]);
    // NN with an empty inner axis, TN with zero shared rows
    let bn = Tensor::from_f32(vec![], &[0, 4]).unwrap();
    let nn = a.matmul_nn(&bn).unwrap();
    assert_eq!(nn.shape(), &[3, 4]);
    assert_eq!(nn.as_f32().unwrap(), &[0.0; 12]);
    let at = Tensor::from_f32(vec![], &[0, 2]).unwrap();
    let tn = at.matmul_tn(&bn).unwrap();
    assert_eq!(tn.shape(), &[2, 4]);
    assert_eq!(tn.as_f32().unwrap(), &[0.0; 8]);
    // zero-row B: a (3, k)·(0, k)ᵀ product is a (3, 0) tensor
    let a2 = Tensor::from_f32(vec![1.0; 6], &[3, 2]).unwrap();
    let b0 = Tensor::from_f32(vec![], &[0, 2]).unwrap();
    assert_eq!(a2.matmul_nt(&b0).unwrap().shape(), &[3, 0]);
}

#[test]
fn batch1_rows_take_the_gemv_path_with_identical_bits() {
    Prop::new("gemv dispatch ≡ batched rows").cases(40).check(|rng| {
        let k = 1 + rng.below(50) as usize;
        let r = 1 + rng.below(30) as usize;
        let n = 2 + rng.below(5) as usize;
        let x = randt(rng, n, k);
        let b = randt(rng, r, k);
        let full = x.matmul_nt_with(&b, &Dispatch::serial()).map_err(|e| e.to_string())?;
        for i in 0..n {
            let row = x.slice_rows(i, i + 1).map_err(|e| e.to_string())?;
            // m == 1 dispatches to linalg::gemv_nt inside gemm_nt
            let one = row.matmul_nt(&b).map_err(|e| e.to_string())?;
            let fv = full.as_f32().map_err(|e| e.to_string())?;
            if one.as_f32().map_err(|e| e.to_string())? != &fv[i * r..(i + 1) * r] {
                return Err(format!("gemv row {i} ≠ batched row ({n}×{k}·{r}ᵀ)"));
            }
        }
        Ok(())
    });
}

#[test]
fn fused_panel_kernel_matches_oracles_at_all_packed_widths() {
    Prop::new("fused panel ≡ rowwise ≡ scalar ref, 2/3/4/8-bit").cases(40).check(|rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let rows = 1 + rng.below(24) as usize;
        let cols = 1 + rng.below(40) as usize;
        let n = 1 + rng.below(6) as usize;
        let m = random_packed(rng, rows, cols, bits);
        let x = randt(rng, n, cols);
        let rowwise = gemm_fused_rowwise(&x, &m).map_err(|e| e.to_string())?;
        let reference = gemm_ref(&x, &m).map_err(|e| e.to_string())?;
        for workers in [1usize, 4] {
            let fused = gemm_fused(&x, &m, workers).map_err(|e| e.to_string())?;
            // bit-exact against the retained rowwise kernel
            if fused.as_f32().map_err(|e| e.to_string())?
                != rowwise.as_f32().map_err(|e| e.to_string())?
            {
                return Err(format!(
                    "panel(workers={workers}) ≠ rowwise at {bits}-bit {rows}×{cols} batch {n}"
                ));
            }
            // tolerance against the independent scalar reference (different
            // algebraic form, so only ≤1e-4-close, as PR 2 pinned)
            let d = fused.max_abs_diff(&reference).map_err(|e| e.to_string())?;
            let tol = 1e-4 * (1.0 + reference.abs_max());
            if d > tol {
                return Err(format!(
                    "panel vs scalar ref: max|Δ| {d} > {tol} at {bits}-bit {rows}×{cols}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn fused_batch1_decode_path_is_bit_identical() {
    // the gemv fast path inside gemm_fused is what decode_step runs; its
    // bits must equal both the batched kernel's row and the rowwise oracle
    let mut rng = Pcg32::seeded(97);
    for bits in [2u32, 3, 4, 8] {
        let m = random_packed(&mut rng, 48, 31, bits);
        let batch = randt(&mut rng, 4, 31);
        let full = gemm_fused(&batch, &m, 1).unwrap();
        for i in 0..4 {
            let row = batch.slice_rows(i, i + 1).unwrap();
            let one = gemm_fused(&row, &m, 1).unwrap();
            let oracle = gemm_fused_rowwise(&row, &m).unwrap();
            assert_eq!(one.as_f32().unwrap(), oracle.as_f32().unwrap(), "{bits}-bit vs oracle");
            assert_eq!(
                one.as_f32().unwrap(),
                &full.as_f32().unwrap()[i * 48..(i + 1) * 48],
                "{bits}-bit batch-1 row {i} vs batched"
            );
        }
    }
}

#[test]
fn fused_serial_parallel_bit_identity_holds() {
    // kernels.rs pinned this for the old kernel; re-pin on the panel kernel
    let mut rng = Pcg32::seeded(13);
    for bits in [4u32, 8] {
        let m = random_packed(&mut rng, 128, 96, bits);
        let x = randt(&mut rng, 16, 96);
        let serial = gemm_fused(&x, &m, 1).unwrap();
        let par = gemm_fused(&x, &m, 4).unwrap();
        assert_eq!(serial.as_f32().unwrap(), par.as_f32().unwrap(), "{bits}-bit");
    }
}
